"""Fault-tolerant training driver: train, get preempted, auto-resume, and
optionally compress gradients as they would cross pods.

    PYTHONPATH=src python examples/train_with_faults.py
"""
import dataclasses
import pathlib
import tempfile

from repro.configs import get_config
from repro.models import build_model
from repro.train.loop import LoopConfig, Trainer


def main():
    cfg = dataclasses.replace(get_config("starcoder2-3b").reduced(),
                              num_layers=2, remat=False)
    model = build_model(cfg)
    ckpt_dir = pathlib.Path(tempfile.mkdtemp()) / "ckpts"
    lcfg = LoopConfig(total_steps=60, ckpt_every=15, batch_size=4,
                      seq_len=64, peak_lr=1e-3, grad_compress=True)

    print("run 1: training with 1-bit error-feedback grad compression...")
    t1 = Trainer(model, ckpt_dir, lcfg)
    res1 = t1.run(interrupt_at=25)       # simulated preemption
    print(f"  preempted at step {res1['completed']}, "
          f"loss {res1['losses'][0]:.3f} -> {res1['losses'][-1]:.3f}")

    print("run 2: fresh process auto-resumes from the newest checkpoint...")
    t2 = Trainer(model, ckpt_dir, lcfg)
    res2 = t2.run()
    print(f"  resumed and finished at step {res2['completed']}, "
          f"final loss {res2['losses'][-1]:.3f}")
    assert res2["completed"] == lcfg.total_steps
    print("done: restart was transparent (deterministic data + atomic "
          "checkpoints).")


if __name__ == "__main__":
    main()
