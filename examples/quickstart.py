"""Quickstart: compress a fine-tune into a 1-bit per-axis delta, save it,
hot-swap it onto the resident base, and verify quality.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import pathlib
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import calibration as C
from repro.core import loader as L
from repro.core import store as S
from repro.data.pipeline import SyntheticLM
from repro.models import build_model
from repro.train.step import init_train_state, make_train_step


def main():
    # 1. a small base model + a real fine-tune on a shifted distribution
    cfg = dataclasses.replace(get_config("qwen3-8b").reduced(),
                              num_layers=2, compute_dtype="float32",
                              remat=False)
    model = build_model(cfg)
    step = jax.jit(make_train_step(model, peak_lr=5e-3, warmup=5))
    state = init_train_state(model, jax.random.PRNGKey(0))
    src = SyntheticLM(cfg.vocab_size, seed=0)
    for i in range(30):
        state, m = step(state, src.lm_batch(i, 4, 32))
    base = state.params
    ft_src = SyntheticLM(cfg.vocab_size, seed=7)
    for i in range(15):
        state, m = step(state, ft_src.lm_batch(i, 4, 32))
    ft = state.params
    print(f"trained base + fine-tune (loss {float(m['loss']):.3f})")

    # 2. compress: sign mask + per-axis scales, calibrated (paper Alg. 1-7)
    calib = [ft_src.lm_batch(1000 + i, 4, 32) for i in range(3)]
    dm, report = C.calibrate_transformer(model, base, ft, calib,
                                         epochs=2, e2e_epochs=2,
                                         lr=1e-3, e2e_lr=1e-3)
    print("axis selections:", {k: v for k, v in report["axis"].items()})

    # 3. save the artifact; report sizes
    out = pathlib.Path(tempfile.mkdtemp()) / "variant_a"
    manifest = S.save_artifact(dm, out, base_fp=S.base_fingerprint(base))
    fp16 = C.fp16_checkpoint_nbytes(ft)
    print(f"artifact {manifest['artifact_bytes']/1e6:.2f} MB vs "
          f"fp16 checkpoint {fp16/1e6:.2f} MB "
          f"({fp16/manifest['artifact_bytes']:.2f}x smaller)")

    # 4. hot-swap onto the resident base (fused Pallas unpack path)
    dm2 = S.load_artifact(out, expect_base_fp=S.base_fingerprint(base))
    student, stats = L.apply_artifact(base, dm2)
    print(f"swap: {stats['seconds']*1e3:.1f} ms, "
          f"{stats['transferred_bytes']/1e6:.2f} MB moved")

    # 5. quality: student vs teacher on held-out data
    fwd = jax.jit(lambda p, b: model.forward(p, b)[0])
    batch = ft_src.lm_batch(9999, 4, 32)
    err = float(jnp.mean((fwd(ft, batch) - fwd(student, batch)) ** 2))
    base_err = float(jnp.mean((fwd(ft, batch) - fwd(base, batch)) ** 2))
    print(f"teacher-student logit MSE {err:.5f} "
          f"(base-teacher: {base_err:.5f}, "
          f"{base_err/max(err,1e-12):.1f}x closer)")


if __name__ == "__main__":
    main()
