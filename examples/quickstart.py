"""Quickstart: compress a fine-tune into a 1-bit per-axis delta, publish
it as version 1 of a variant, serve it, ship a second fine-tune as an
incremental update patch, and roll back — the full lifecycle in one file.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import pathlib
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import calibration as C
from repro.data.pipeline import SyntheticLM
from repro.models import build_model
from repro.serving import Deployment
from repro.train.step import init_train_state, make_train_step


def main():
    # 1. a small base model + a real fine-tune on a shifted distribution
    cfg = dataclasses.replace(get_config("qwen3-8b").reduced(),
                              num_layers=2, compute_dtype="float32",
                              remat=False)
    model = build_model(cfg)
    step = jax.jit(make_train_step(model, peak_lr=5e-3, warmup=5))
    state = init_train_state(model, jax.random.PRNGKey(0))
    src = SyntheticLM(cfg.vocab_size, seed=0)
    for i in range(30):
        state, m = step(state, src.lm_batch(i, 4, 32))
    base = state.params
    ft_src = SyntheticLM(cfg.vocab_size, seed=7)
    for i in range(15):
        state, m = step(state, ft_src.lm_batch(i, 4, 32))
    ft = state.params
    print(f"trained base + fine-tune (loss {float(m['loss']):.3f})")

    # 2. compress: sign mask + per-axis scales, calibrated (paper Alg. 1-7)
    calib = [ft_src.lm_batch(1000 + i, 4, 32) for i in range(3)]
    dm, report = C.calibrate_transformer(model, base, ft, calib,
                                         epochs=2, e2e_epochs=2,
                                         lr=1e-3, e2e_lr=1e-3)
    print("axis selections:", {k: v for k, v in report["axis"].items()})

    # 3. publish as version 1 of a variant and serve it — the Deployment
    # facade owns the store (manifest v3 + lineage), the registry, and the
    # serving engine; callers only see publish/update/rollback/submit
    out = pathlib.Path(tempfile.mkdtemp())
    dep = Deployment(model, base, root_dir=out / "variants",
                     batch_size=4, prompt_len=16, max_len=64)
    v1 = dep.publish("task_a", dm)
    full_bytes = dep.store.artifact_bytes("task_a", v1)
    fp16 = C.fp16_checkpoint_nbytes(ft)
    print(f"published 'task_a' v{v1}: {full_bytes/1e6:.2f} MB vs "
          f"fp16 checkpoint {fp16/1e6:.2f} MB "
          f"({fp16/full_bytes:.2f}x smaller)")

    rid = dep.submit(jnp.arange(1, 9), variant="task_a", max_new_tokens=8)
    dep.drain()
    print(f"served: {dep.status(rid)}")

    # 4. frequent updates: the fine-tune trains a little more and ships an
    # attention-only refresh — the localized regime where an incremental
    # patch (XOR'd sign planes + zero-run-suppressed fp16 diffs) beats a
    # full republish; hot-swap in, rollback is a pointer move
    for i in range(15, 19):
        state, _ = step(state, ft_src.lm_batch(i, 4, 32))
    old_flat = C.flatten_params(ft)
    new_flat = C.flatten_params(state.params)
    refreshed = C.unflatten_like(base, {
        p: new_flat[p] if p.split(".")[-1] in ("wq", "wk", "wv", "wo")
        else v for p, v in old_flat.items()})
    v2 = dep.update("task_a", C.compress(base, refreshed))
    patch_bytes = dep.store.artifact_bytes("task_a", v2)
    print(f"update -> v{v2}: patch {patch_bytes/1e6:.2f} MB "
          f"({patch_bytes/full_bytes:.2f}x of a full publish)")
    dep.rollback("task_a")
    print(f"rolled back to v{dep.current('task_a')}")

    # 5. quality: student (served weights) vs teacher on held-out data
    from repro.core import loader as L
    student, _ = L.apply_artifact(base, dep.store.load("task_a", v1))
    fwd = jax.jit(lambda p, b: model.forward(p, b)[0])
    batch = ft_src.lm_batch(9999, 4, 32)
    err = float(jnp.mean((fwd(ft, batch) - fwd(student, batch)) ** 2))
    base_err = float(jnp.mean((fwd(ft, batch) - fwd(base, batch)) ** 2))
    print(f"teacher-student logit MSE {err:.5f} "
          f"(base-teacher: {base_err:.5f}, "
          f"{base_err/max(err,1e-12):.1f}x closer)")


if __name__ == "__main__":
    main()
