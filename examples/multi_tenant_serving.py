"""End-to-end driver: serve a small model with batched requests across
multiple hot-swapped fine-tuned variants (the paper's deployment story).

    PYTHONPATH=src python examples/multi_tenant_serving.py
"""
import dataclasses
import pathlib
import tempfile

import jax
import numpy as np

from repro.configs import get_config
from repro.core import calibration as C
from repro.core import store as S
from repro.data.pipeline import SyntheticLM
from repro.models import build_model
from repro.serving import ServingEngine, VariantRegistry
from repro.train.step import init_train_state, make_train_step


def main():
    cfg = dataclasses.replace(get_config("deepseek-7b").reduced(),
                              num_layers=2, remat=False)
    model = build_model(cfg)

    # base + three quick fine-tunes (different data seeds = different tasks)
    step = jax.jit(make_train_step(model, peak_lr=5e-3, warmup=5))
    state = init_train_state(model, jax.random.PRNGKey(0))
    src = SyntheticLM(cfg.vocab_size, seed=0)
    for i in range(25):
        state, _ = step(state, src.lm_batch(i, 4, 32))
    base = state.params

    tmp = pathlib.Path(tempfile.mkdtemp())
    fp = S.base_fingerprint(base)
    variants = {}
    for name, seed in [("code", 11), ("chat", 22), ("math", 33)]:
        st = dataclasses.replace(state, params=base)
        ft_src = SyntheticLM(cfg.vocab_size, seed=seed)
        for i in range(10):
            st, _ = step(st, ft_src.lm_batch(i, 4, 32))
        dm = C.compress(base, st.params)
        S.save_artifact(dm, tmp / name, base_fp=fp)
        variants[name] = tmp / name
        print(f"variant {name!r}: artifact "
              f"{sum(f.stat().st_size for f in (tmp/name).iterdir())/1e6:.2f} MB")

    # serving: one resident base, three tenants kept resident as PACKED
    # overlays (mode="fused" — on-the-fly delta GEMMs, ~1/16 the HBM of a
    # dense copy per tenant, so all three fit where one dense copy would)
    reg = VariantRegistry(base, max_resident=8, mode="fused")
    for name, path in variants.items():
        reg.register(name, path)
    eng = ServingEngine(model, reg, batch_size=4, prompt_len=16, max_len=64)

    rng = np.random.default_rng(0)
    rids = []
    for i in range(16):
        prompt = rng.integers(1, cfg.vocab_size, size=8)
        variant = ["code", "chat", "math", "__base__"][i % 4]
        rids.append((eng.submit(prompt, variant=variant, max_new_tokens=8),
                     variant))
    eng.run_until_drained()

    done = sum(1 for rid, _ in rids if eng.result(rid).status == "done")
    print(f"\nserved {done}/{len(rids)} requests")
    print(f"engine: {eng.metrics}")
    print(f"registry: swaps={reg.stats['swaps']} hits={reg.stats['hits']} "
          f"swap_time={reg.stats['swap_seconds']*1e3:.1f} ms "
          f"transferred={reg.stats['transferred_bytes']/1e6:.2f} MB "
          f"resident={reg.stats['resident_bytes']/1e6:.2f} MB "
          f"(dense copy would be "
          f"{3 * sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(base))/1e6:.2f} MB)")
    sample = eng.result(rids[0][0])
    print(f"sample output ({rids[0][1]}): {sample.out_tokens}")


if __name__ == "__main__":
    main()
