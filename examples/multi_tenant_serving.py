"""End-to-end driver: serve a small model with batched requests across
multiple fine-tuned variants through the versioned lifecycle control
plane — publish, serve, incremental update + hot-swap, rollback
(the paper's frequent-model-updates deployment story).

    PYTHONPATH=src python examples/multi_tenant_serving.py
"""
import dataclasses
import pathlib
import tempfile

import jax
import numpy as np

from repro.configs import get_config
from repro.core import calibration as C
from repro.data.pipeline import SyntheticLM
from repro.models import build_model
from repro.serving import Deployment
from repro.train.step import init_train_state, make_train_step


def main():
    cfg = dataclasses.replace(get_config("deepseek-7b").reduced(),
                              num_layers=2, remat=False)
    model = build_model(cfg)

    # base + three quick fine-tunes (different data seeds = different tasks)
    step = jax.jit(make_train_step(model, peak_lr=5e-3, warmup=5))
    state = init_train_state(model, jax.random.PRNGKey(0))
    src = SyntheticLM(cfg.vocab_size, seed=0)
    for i in range(25):
        state, _ = step(state, src.lm_batch(i, 4, 32))
    base = state.params

    # one deployment = store + registry + engine behind publish/update/
    # rollback/submit/drain/status; tenants stay resident as PACKED
    # overlays (mode="fused" — on-the-fly delta GEMMs, ~1/16 the HBM of a
    # dense copy per tenant, so all three fit where one dense copy would)
    tmp = pathlib.Path(tempfile.mkdtemp())
    dep = Deployment(model, base, root_dir=tmp / "variants", mode="fused",
                     scheduler="continuous", batch_size=4, prompt_len=16,
                     max_len=64, bank_size=6)

    states = {}
    for name, seed in [("code", 11), ("chat", 22), ("math", 33)]:
        st = dataclasses.replace(state, params=base)
        ft_src = SyntheticLM(cfg.vocab_size, seed=seed)
        for i in range(10):
            st, _ = step(st, ft_src.lm_batch(i, 4, 32))
        states[name] = st
        v = dep.publish(name, C.compress(base, st.params))
        print(f"published {name!r} v{v}: "
              f"{dep.store.artifact_bytes(name, v)/1e6:.2f} MB")

    rng = np.random.default_rng(0)
    rids = []
    for i in range(16):
        prompt = rng.integers(1, cfg.vocab_size, size=8)
        variant = ["code", "chat", "math", "__base__"][i % 4]
        rids.append((dep.submit(prompt, variant=variant, max_new_tokens=8),
                     variant))
    dep.drain()

    # frequent updates: 'code' gets an attention-only refresh (continued
    # training, shipped for just the attention projections — the localized
    # regime where an incremental patch beats a full republish: untouched
    # modules cost nothing on the wire); hot-swap it, then roll back with
    # a constant-time pointer move
    st = states["code"]
    ft_src = SyntheticLM(cfg.vocab_size, seed=11)
    for i in range(10, 14):
        st, _ = step(st, ft_src.lm_batch(i, 4, 32))
    old_flat = C.flatten_params(states["code"].params)
    new_flat = C.flatten_params(st.params)
    refreshed = C.unflatten_like(base, {
        p: new_flat[p] if p.split(".")[-1] in ("wq", "wk", "wv", "wo")
        else v for p, v in old_flat.items()})
    v2 = dep.update("code", C.compress(base, refreshed))
    full, patch = (dep.store.artifact_bytes("code", v) for v in (1, v2))
    print(f"update 'code' -> v{v2}: patch {patch/1e6:.2f} MB "
          f"({patch/full:.2f}x of a full publish)")
    rid_v2 = dep.submit(rng.integers(1, cfg.vocab_size, size=8),
                        variant="code", max_new_tokens=8)
    dep.drain()
    print(f"post-update request: {dep.status(rid_v2)}")
    v_back = dep.rollback("code")
    print(f"rollback 'code' -> v{v_back}")

    done = sum(1 for rid, _ in rids if dep.result(rid).status == "done")
    stats = dep.stats
    print(f"\nserved {done}/{len(rids)} requests")
    print(f"engine: {dep.metrics}")
    print(f"registry: swaps={stats['swaps']} hits={stats['hits']} "
          f"swap_time={stats['swap_seconds']*1e3:.1f} ms "
          f"transferred={stats['transferred_bytes']/1e6:.2f} MB "
          f"resident={stats['resident_bytes']/1e6:.2f} MB "
          f"(dense copy would be "
          f"{3 * sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(base))/1e6:.2f} MB)")
    sample = dep.result(rids[0][0])
    print(f"sample output ({rids[0][1]}): {sample.out_tokens}")


if __name__ == "__main__":
    main()
