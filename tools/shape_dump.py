"""Dev tool: dump shapes of matching collective ops in a cell's HLO."""
import sys

from byte_attr import lower_cell  # noqa: E402  (same dir)

import re


def main():
    arch, shape, pattern = sys.argv[1], sys.argv[2], sys.argv[3]
    txt = lower_cell(arch, shape)
    seen = {}
    for line in txt.splitlines():
        ls = line.strip()
        if re.search(pattern, ls):
            m = re.search(r"= (\(?\S+?\)?) (all-reduce|all-gather|"
                          r"reduce-scatter|all-to-all)", ls)
            if m:
                key = (m.group(2), m.group(1)[:90])
                seen[key] = seen.get(key, 0) + 1
    for (op, s), c in sorted(seen.items(), key=lambda kv: -kv[1])[:12]:
        print(f"n={c:4d}  {op:14s} {s}")


if __name__ == "__main__":
    main()
