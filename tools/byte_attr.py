"""Dev tool: per-op-name byte attribution for a dry-run cell's HLO.

Usage: PYTHONPATH=src python tools/byte_attr.py <arch> <shape> [multi]
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import re
import sys
from collections import defaultdict

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config
from repro.distributed import hlo_cost as HC
from repro.distributed.sharding import rules_for, shard_ctx, tree_shardings
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.models.param import split
from repro.optim.adamw import AdamWState
from repro.train.step import (TrainState, make_decode_step,
                              make_prefill_step, make_train_step)


def lower_cell(arch, shape_name, multi_pod=False, opt_flags=()):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    long_ctx = shape_name == "long_500k"
    rules = rules_for(shape.kind, long_context=long_ctx)
    params_p = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    ps, ax = split(params_p)
    psh = tree_shardings(ps, ax, rules, mesh)
    bs = model.input_specs(shape.seq_len, shape.global_batch, kind=shape.kind)
    bsh = tree_shardings(bs, model.batch_pspecs(shape.kind), rules, mesh)
    repl = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    if shape.kind == "train":
        f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
        opt = AdamWState(mu=jax.tree.map(f32, ps), nu=jax.tree.map(f32, ps),
                         count=jax.ShapeDtypeStruct((), jnp.int32))
        st = TrainState(step=jax.ShapeDtypeStruct((), jnp.int32), params=ps,
                        opt=opt)
        ssh = TrainState(step=repl, params=psh,
                         opt=AdamWState(mu=psh, nu=psh, count=repl))
        fn, args, shards = make_train_step(model, param_axes=ax), (st, bs), (ssh, bsh)
        with mesh, shard_ctx(mesh, rules):
            _, m_struct = jax.eval_shape(fn, *args)
        out_sh = (ssh, jax.tree.map(lambda _: repl, m_struct))
        with mesh, shard_ctx(mesh, rules):
            return jax.jit(fn, in_shardings=shards,
                           out_shardings=out_sh).lower(*args).compile(
                               ).as_text()
    else:
        serve = jax.tree.map(lambda s: jax.ShapeDtypeStruct(
            s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype), ps)
        if shape.kind == "prefill":
            fn = make_prefill_step(model, max_len=shape.seq_len)
            args, shards = (serve, bs), (psh, bsh)
        else:
            cache = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            csh = tree_shardings(
                cache, model.cache_pspecs(
                    long_ctx, kv_seq_shard="kv_seq_shard" in opt_flags),
                rules, mesh)
            fn = make_decode_step(model)
            args = (serve, bs["tokens"], cache)
            shards = (psh, bsh["tokens"], csh)
    with mesh, shard_ctx(mesh, rules):
        return jax.jit(fn, in_shardings=shards).lower(*args).compile().as_text()


def attribute(txt, top=25):
    mh = HC.HloCostModel(txt)
    agg = defaultdict(float)

    def src(i):
        m = re.search(r'op_name="([^"]*)"', i.attrs)
        nm = m.group(1) if m else "?"
        nm = re.sub(r"\d+", "#", nm)
        return i.opcode + " :: " + nm[-90:]

    def walk(comp, mult):
        for i in mh.comps.get(comp, []):
            opc = i.opcode
            if opc == "while":
                trips = mh._trip_count(i)
                b = HC._BODY_RE.search(i.attrs)
                if b:
                    walk(b.group(1), mult * trips)
            elif opc in ("fusion", "call", "async-start"):
                m = HC._CALLS_RE.search(i.attrs)
                if m:
                    walk(m.group(1), mult)
            elif opc == "gather":
                agg[src(i)] += mult * 2 * i.result_bytes
            elif opc == "dynamic-update-slice":
                s = (mh.shapes[comp].get(i.operands[1])
                     if len(i.operands) > 1 else None)
                agg[src(i)] += mult * 2 * (s[0] if s else 0)
            elif opc in HC._MATERIALIZE or opc == "dot":
                agg[src(i)] += mult * (i.result_bytes
                                       + mh._operand_bytes(comp, i))

    walk(mh.entry, 1.0)
    for k, v in sorted(agg.items(), key=lambda kv: -kv[1])[:top]:
        print(f"{v/1e9:9.1f} GB  {k}")


def attribute_collectives(txt, top=25):
    mh = HC.HloCostModel(txt)
    agg = defaultdict(float)
    cnt = defaultdict(float)

    def src(i):
        m = re.search(r'op_name="([^"]*)"', i.attrs)
        nm = m.group(1) if m else "?"
        nm = re.sub(r"\d+", "#", nm)
        return i.opcode + " :: " + nm[-100:]

    def walk(comp, mult):
        for i in mh.comps.get(comp, []):
            opc = i.opcode
            if opc == "while":
                trips = mh._trip_count(i)
                b = HC._BODY_RE.search(i.attrs)
                if b:
                    walk(b.group(1), mult * trips)
            elif opc in ("fusion", "call", "async-start"):
                m = HC._CALLS_RE.search(i.attrs)
                if m:
                    walk(m.group(1), mult)
            else:
                base = opc[:-6] if opc.endswith("-start") else opc
                if base in HC._COLLECTIVES:
                    agg[src(i)] += mult * i.result_bytes
                    cnt[src(i)] += mult

    walk(mh.entry, 1.0)
    for k, v in sorted(agg.items(), key=lambda kv: -kv[1])[:top]:
        print(f"{v/1e9:9.2f} GB  n={cnt[k]:6.0f}  {k}")


if __name__ == "__main__":
    arch = sys.argv[1] if len(sys.argv) > 1 else "whisper-base"
    shape = sys.argv[2] if len(sys.argv) > 2 else "train_4k"
    mode = sys.argv[3] if len(sys.argv) > 3 else "bytes"
    opts = tuple(sys.argv[4:])
    txt = lower_cell(arch, shape, multi_pod=(mode == "multi"),
                     opt_flags=opts)
    if mode == "coll":
        attribute_collectives(txt)
    else:
        attribute(txt)
