"""Calibration pipeline tests on a tiny base/fine-tune pair.

Validates the paper's pipeline end to end: compression, per-layer
activation matching, axis selection, e2e logit calibration — and the core
quality ordering (calibrated vector ≤ MSE of scalar BitDelta vs teacher).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import calibration as C
from repro.models import build_model
from repro.models import transformer as T
from repro.models.param import split
from repro.train.step import make_train_step, init_train_state


@pytest.fixture(scope="module")
def tiny_pair():
    """Base = random init trained 30 steps; fine-tune = 15 more steps on a
    shifted task — a real (small) fine-tuning delta."""
    cfg = dataclasses.replace(get_config("deepseek-7b").reduced(),
                              num_layers=2, compute_dtype="float32",
                              remat=False)
    model = build_model(cfg)
    from repro.data.pipeline import SyntheticLM
    src = SyntheticLM(cfg.vocab_size, seed=0)
    step = jax.jit(make_train_step(model, peak_lr=5e-3, warmup=5))
    state = init_train_state(model, jax.random.PRNGKey(0))
    for i in range(30):
        state, _ = step(state, src.lm_batch(i, 4, 32))
    base_params = state.params
    src2 = SyntheticLM(cfg.vocab_size, seed=99)
    for i in range(15):
        state, _ = step(state, src2.lm_batch(i, 4, 32))
    ft_params = state.params
    batches = [src.lm_batch(1000 + i, 4, 32) for i in range(4)]
    return model, base_params, ft_params, batches


def test_compress_targets_and_extras(tiny_pair):
    model, base, ft, _ = tiny_pair
    dm = C.compress(base, ft)
    names = {k.split(".")[-1] for k in dm.deltas}
    assert {"wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"} <= names
    # embeddings / norms are extras, not deltas
    assert not any("embed" in k for k in dm.deltas)
    assert any("embed" in k for k in dm.extras)
    # artifact much smaller than an fp16 checkpoint of the same params
    ratio = C.fp16_checkpoint_nbytes(ft) / C.artifact_nbytes(dm)
    assert ratio > 1.5, ratio


def test_apply_delta_roundtrip_close(tiny_pair):
    """With init scales the student must be closer to FT than base is."""
    model, base, ft, batches = tiny_pair
    dm = C.compress(base, ft)
    student = C.apply_delta(base, dm)
    logits_ft, _ = model.forward(ft, batches[0])
    logits_st, _ = model.forward(student, batches[0])
    logits_bs, _ = model.forward(base, batches[0])
    err_st = float(jnp.mean((logits_ft - logits_st) ** 2))
    err_bs = float(jnp.mean((logits_ft - logits_bs) ** 2))
    assert err_st < err_bs, (err_st, err_bs)


def test_full_calibration_improves_and_selects_axes(tiny_pair):
    model, base, ft, batches = tiny_pair
    cfg = model.cfg
    fwd = jax.jit(lambda p, b: T.forward(p, b, cfg)[0])

    def teacher_mse(dm):
        student = C.apply_delta(base, dm)
        errs = [float(jnp.mean((fwd(ft, b) - fwd(student, b)) ** 2))
                for b in batches]
        return sum(errs) / len(errs)

    dm0 = C.compress(base, ft)
    err_init = teacher_mse(dm0)

    dm_cal, report = C.calibrate_transformer(
        model, base, ft, batches, epochs=2, e2e_epochs=2, lr=1e-3,
        e2e_lr=1e-3)
    err_cal = teacher_mse(dm_cal)
    assert err_cal < err_init, (err_cal, err_init)
    # axis selection recorded per projection per layer
    assert "attn.wq" in report["axis"]
    assert len(report["axis"]["attn.wq"]) == 2  # layers
    assert all(a in ("row", "col") for a in report["axis"]["attn.wq"])
    # e2e losses decreased overall
    assert report["e2e_losses"][-1] < report["e2e_losses"][0] * 1.5


def test_vector_beats_scalar_bitdelta(tiny_pair):
    """Paper's main quality claim at the logit level."""
    model, base, ft, batches = tiny_pair
    cfg = model.cfg
    fwd = jax.jit(lambda p, b: T.forward(p, b, cfg)[0])

    def teacher_mse(dm):
        student = C.apply_delta(base, dm)
        errs = [float(jnp.mean((fwd(ft, b) - fwd(student, b)) ** 2))
                for b in batches]
        return sum(errs) / len(errs)

    dm_vec, _ = C.calibrate_transformer(model, base, ft, batches,
                                        epochs=2, e2e_epochs=2,
                                        lr=1e-3, e2e_lr=1e-3)
    dm_sca, _ = C.calibrate_transformer(model, base, ft, batches,
                                        scalar=True, e2e_epochs=2,
                                        lr=1e-3, e2e_lr=1e-3)
    assert teacher_mse(dm_vec) <= teacher_mse(dm_sca) * 1.05, \
        (teacher_mse(dm_vec), teacher_mse(dm_sca))


def test_scalar_mode_artifact_smaller_but_close(tiny_pair):
    model, base, ft, _ = tiny_pair
    dm_vec = C.compress(base, ft)
    dm_sca = C.compress(base, ft, scalar=True)
    assert C.artifact_nbytes(dm_sca) <= C.artifact_nbytes(dm_vec)
    # vector adds only a tiny overhead (paper Table 2: ~same sizes)
    assert C.artifact_nbytes(dm_vec) < C.artifact_nbytes(dm_sca) * 1.1
