"""Versioned variant lifecycle: VariantStore lineage + update patches,
registry hot-swap/rollback semantics, and the Deployment control plane
(DESIGN.md §10).

Parity contract: a version materialised through ANY lineage (full publish,
chain of XOR/zero-run patches, rollback + re-forward) must be bit-identical
in the wire domain to a fresh full publish of the same weights — so greedy
tokens match exactly no matter how a version reached the serving node.
"""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import calibration as C
from repro.core import store as S
from repro.models import build_model
from repro.models.param import split
from repro.serving import Deployment
from repro.serving.variants import OverlayBank, VariantRegistry

PROMPT = np.arange(1, 7)


@pytest.fixture(scope="module")
def setup():
    """Model + base + three fine-tunes: ft2/ft3 are INCREMENTAL
    continuations of ft1 (a fraction of rows move), the regime update
    patches are built for."""
    cfg = dataclasses.replace(get_config("deepseek-7b").reduced(),
                              num_layers=2, compute_dtype="float32",
                              remat=False)
    model = build_model(cfg)
    base, _ = split(model.init(jax.random.PRNGKey(0)))
    pert, _ = split(model.init(jax.random.PRNGKey(1)))
    ft1 = jax.tree.map(lambda b, p: b + 0.05 * p, base, pert)

    def inc(ft):
        def upd(l1, lb):
            if l1.ndim < 2:
                return l1
            n = max(1, l1.shape[-2] // 8)
            return l1.at[..., :n, :].add(
                0.3 * (l1[..., :n, :] - lb[..., :n, :]))
        return jax.tree.map(upd, ft, base)

    ft2 = inc(ft1)
    ft3 = inc(ft2)
    return (model, base, C.compress(base, ft1), C.compress(base, ft2),
            C.compress(base, ft3))


def _dep(model, base, root=None, **kw):
    kw.setdefault("batch_size", 2)
    kw.setdefault("prompt_len", 8)
    kw.setdefault("max_len", 32)
    kw.setdefault("bank_size", 4)
    return Deployment(model, base, root_dir=root, **kw)


def _serve(dep, variant, n=4):
    rid = dep.submit(PROMPT, variant=variant, max_new_tokens=n)
    dep.drain()
    assert dep.result(rid).status == "done"
    return dep.result(rid).out_tokens


def _wire_equal(dm_a, dm_b):
    assert set(dm_a.deltas) == set(dm_b.deltas)
    assert set(dm_a.extras) == set(dm_b.extras)
    for k, ea in dm_a.deltas.items():
        eb = dm_b.deltas[k]
        np.testing.assert_array_equal(
            np.asarray(ea.packed), np.asarray(eb.packed), err_msg=k)
        np.testing.assert_array_equal(
            np.asarray(ea.v_row).astype(np.float16),
            np.asarray(eb.v_row).astype(np.float16), err_msg=k)
        np.testing.assert_array_equal(
            np.asarray(ea.v_col).astype(np.float16),
            np.asarray(eb.v_col).astype(np.float16), err_msg=k)
        np.testing.assert_array_equal(
            np.asarray(ea.use_row), np.asarray(eb.use_row), err_msg=k)
    for k, va in dm_a.extras.items():
        np.testing.assert_array_equal(
            np.asarray(va).astype(np.float16),
            np.asarray(dm_b.extras[k]).astype(np.float16), err_msg=k)


# ---------------------------------------------------------------------------
# VariantStore: lineage, patches, rollback, integrity
# ---------------------------------------------------------------------------

def test_store_publish_lineage_and_manifest_v3(setup, tmp_path):
    _, base, dm1, dm2, _ = setup
    st = S.VariantStore(tmp_path, base_fp=S.base_fingerprint(base))
    assert st.publish("prod", dm1) == 1
    assert st.publish("prod", dm2) == 2
    assert st.versions("prod") == [1, 2] and st.latest("prod") == 2
    m = S.read_manifest(tmp_path / "prod" / "v0002")
    assert m["version"] == S.STORE_VERSION and m["kind"] == "full"
    assert m["lineage"] == {"variant": "prod", "version": 2,
                            "parent_version": None}
    assert st.artifact_bytes("prod", 2) == m["artifact_bytes"] > 0


def test_store_update_patch_exact_and_small(setup, tmp_path):
    _, base, dm1, dm2, _ = setup
    st = S.VariantStore(tmp_path)
    st.publish("prod", dm1)
    v2 = st.publish_update("prod", dm2)
    assert st.version_info("prod", v2)["kind"] == "patch"
    # bit-exact vs a fresh full publish of the same weights
    st2 = S.VariantStore(tmp_path / "ref")
    st2.publish("ref", dm2)
    _wire_equal(st.load("prod", v2), st2.load("ref", 1))
    # and the incremental regime actually ships fewer bytes
    assert st.artifact_bytes("prod", v2) < \
        0.5 * st.artifact_bytes("prod", 1)


def test_store_patch_chain_and_cold_materialise(setup, tmp_path):
    _, base, dm1, dm2, dm3 = setup
    st = S.VariantStore(tmp_path)
    st.publish("prod", dm1)
    st.publish_update("prod", dm2)
    v3 = st.publish_update("prod", dm3)
    assert st.lineage("prod", v3) == [1, 2, 3]
    ref = S.VariantStore(tmp_path / "ref")
    ref.publish("ref", dm3)
    _wire_equal(st.load("prod", v3), ref.load("ref", 1))
    # cold: a fresh store over the same directory (empty cache) walks the
    # full->patch->patch chain from disk
    cold = S.VariantStore(tmp_path)
    _wire_equal(cold.load("prod"), ref.load("ref", 1))


def test_store_rollback_pointer_and_monotonic_ids(setup, tmp_path):
    _, base, dm1, dm2, _ = setup
    st = S.VariantStore(tmp_path)
    st.publish("prod", dm1)
    st.publish_update("prod", dm2)
    assert st.rollback("prod") == 1 and st.latest("prod") == 1
    # ids never reuse: the next publish is 3, not 2
    assert st.publish("prod", dm2) == 3
    with pytest.raises(KeyError):
        st.rollback("prod", 99)


def test_store_structure_change_requires_full_publish(setup, tmp_path):
    _, base, dm1, _, _ = setup
    st = S.VariantStore(tmp_path)
    st.publish("prod", dm1)
    smaller = type(dm1)(deltas=dict(list(dm1.deltas.items())[:-1]),
                        extras=dm1.extras)
    with pytest.raises(ValueError):
        st.publish_update("prod", smaller)


def test_patch_dir_rejected_by_plain_load_artifact(setup, tmp_path):
    _, base, dm1, dm2, _ = setup
    st = S.VariantStore(tmp_path)
    st.publish("prod", dm1)
    v2 = st.publish_update("prod", dm2)
    with pytest.raises(ValueError):
        S.load_artifact(tmp_path / "prod" / f"v{v2:04d}")


def test_wrong_parent_patch_detected(setup, tmp_path):
    """A patch applied over the wrong parent artifact fails the recorded
    result sha — corruption and lineage mix-ups cannot serve silently."""
    _, base, dm1, dm2, dm3 = setup
    st = S.VariantStore(tmp_path)
    st.publish("prod", dm1)
    st.publish_update("prod", dm2)
    # overwrite v1's payload with different weights, keeping the lineage
    S.save_artifact(dm3, st._vdir("prod", 1))
    cold = S.VariantStore(tmp_path)           # no cache
    with pytest.raises(IOError):
        cold.load("prod", 2)


def test_torn_manifest_rejected(setup, tmp_path):
    """Satellite: a partially written manifest (crash that bypassed the
    atomic tmp+os.replace finalize) must be rejected, not half-parsed."""
    _, base, dm1, _, _ = setup
    S.save_artifact(dm1, tmp_path / "v1")
    mpath = tmp_path / "v1" / "manifest.json"
    full_text = mpath.read_text()
    mpath.write_text(full_text[:len(full_text) // 2])   # torn JSON
    with pytest.raises(IOError):
        S.load_artifact(tmp_path / "v1")
    mpath.write_text("{}")                              # valid JSON, torn
    with pytest.raises(IOError):
        S.load_artifact(tmp_path / "v1")
    mpath.write_text(full_text)                         # restored
    S.load_artifact(tmp_path / "v1")


def test_v1_manifest_compat(setup, tmp_path):
    """Pre-lineage manifests (no files/artifact_bytes/kind/lineage) still
    load through the compat path."""
    _, base, dm1, _, _ = setup
    S.save_artifact(dm1, tmp_path / "v1")
    mpath = tmp_path / "v1" / "manifest.json"
    m = json.loads(mpath.read_text())
    for k in ("files", "artifact_bytes", "kind", "lineage"):
        m.pop(k)
    m["version"] = 1
    mpath.write_text(json.dumps(m))
    _wire_equal(S.load_artifact(tmp_path / "v1"), dm1)


# ---------------------------------------------------------------------------
# registry + engine: hot-swap, rollback, pinned in-flight versions
# ---------------------------------------------------------------------------

def test_hot_swap_inflight_finishes_on_old_version(setup):
    """The atomic-swap contract: a request decoding v1 when the pointer
    moves to v2 finishes with EXACTLY the tokens it would have produced
    had no update happened; requests admitted after the move serve v2."""
    model, base, dm1, dm2, _ = setup

    dep = _dep(model, base)
    dep.publish("prod", dm1)
    rid_old = dep.submit(PROMPT, variant="prod", max_new_tokens=5)
    # stage mid-flight: admit + prefill without draining
    dep.engine._prefill_admitted(dep.engine._admit_free_slots())
    assert dep.status(rid_old)["status"] == "running"
    assert dep.registry.bank.pinned("prod@v1")

    dep.update("prod", dm2)
    rid_new = dep.submit(PROMPT, variant="prod", max_new_tokens=5)
    dep.drain()
    old_tokens = dep.result(rid_old).out_tokens
    new_tokens = dep.result(rid_new).out_tokens
    assert dep.status(rid_old)["version"] == 1
    assert dep.status(rid_new)["version"] == 2

    ref1 = _dep(model, base)
    ref1.publish("prod", dm1)
    assert old_tokens == _serve(ref1, "prod", 5)
    ref2 = _dep(model, base)
    ref2.publish("prod", dm2)
    assert new_tokens == _serve(ref2, "prod", 5)
    assert not dep.registry.bank.pinned("prod@v1")


def test_submit_against_version_swapped_mid_queue(setup):
    """Satellite: a QUEUED request resolves the serving pointer at
    admission — a version published while it waited is what it serves."""
    model, base, dm1, dm2, _ = setup
    dep = _dep(model, base)
    dep.publish("prod", dm1)
    _serve(dep, "prod")                       # warm + resident at v1
    rid = dep.submit(PROMPT, variant="prod", max_new_tokens=4)
    assert dep.status(rid) == {"status": "queued", "rid": rid,
                               "variant": "prod", "version": None,
                               "tokens_generated": 0, "error": None,
                               "first_token_at": None,
                               "ttft_seconds": None}
    dep.update("prod", dm2)                   # swap while rid is queued
    dep.drain()
    assert dep.status(rid)["version"] == 2
    ref = _dep(model, base)
    ref.publish("prod", dm2)
    assert dep.result(rid).out_tokens == _serve(ref, "prod")


def test_status_across_full_lifecycle(setup):
    """Satellite: engine.status/Deployment.status across queued -> active
    -> done -> after rollback of the variant the request ran on."""
    model, base, dm1, dm2, _ = setup
    dep = _dep(model, base)
    dep.publish("prod", dm1)
    dep.update("prod", dm2)
    rid = dep.submit(PROMPT, variant="prod", max_new_tokens=3)
    assert dep.engine.status(rid) == "queued"
    dep.engine._prefill_admitted(dep.engine._admit_free_slots())
    assert dep.engine.status(rid) == "running"
    dep.drain()
    assert dep.engine.status(rid) == "done"
    assert dep.status(rid)["version"] == 2
    # rolling back the variant the request ran on does not rewrite history
    dep.rollback("prod")
    assert dep.engine.status(rid) == "done"
    assert dep.status(rid)["version"] == 2
    assert dep.engine.status(404404) == "unknown"
    assert dep.status(404404) == {"status": "unknown", "rid": 404404}


def test_explicit_version_addressing_and_rollback_hit(setup):
    """``name@vN`` pins a version regardless of the pointer; rollback is a
    pointer move that re-admits the still-resident old version as a bank
    HIT (no artifact reload)."""
    model, base, dm1, dm2, _ = setup
    dep = _dep(model, base)
    dep.publish("prod", dm1)
    t1 = _serve(dep, "prod")
    dep.update("prod", dm2)
    t2 = _serve(dep, "prod")
    # explicit old version while the pointer is at v2
    assert _serve(dep, "prod@v1") == t1
    swaps_before = dep.stats["swaps"]
    hits_before = dep.stats["hits"]
    assert dep.rollback("prod") == 1
    assert _serve(dep, "prod") == t1
    assert dep.stats["swaps"] == swaps_before      # no reload
    assert dep.stats["hits"] > hits_before          # bank hit
    # forward again to the latest version id
    dep.rollback("prod", 2)
    assert _serve(dep, "prod") == t2


def test_group_scheduler_versioned_lifecycle(setup):
    """The grouped (dense-capable) scheduler serves the same versioned
    surface: update swaps what a group resolves, rollback restores it."""
    model, base, dm1, dm2, _ = setup
    dep = _dep(model, base, scheduler="group", mode="dense",
               max_resident=2)
    dep.publish("prod", dm1)
    t1 = _serve(dep, "prod")
    dep.update("prod", dm2)
    t2 = _serve(dep, "prod")
    dep.rollback("prod")
    assert _serve(dep, "prod") == t1
    ref = _dep(model, base, scheduler="group", mode="dense")
    ref.publish("prod", dm2)
    assert t2 == _serve(ref, "prod")


def test_deployment_store_backed_lifecycle(setup, tmp_path):
    """Store-backed deployment: update ships a patch, and a FRESH
    deployment over the same directory HYDRATES from versions.json — a
    restarted node serves previously published variants at their
    persisted pointer, identical tokens, no re-publish needed."""
    model, base, dm1, dm2, _ = setup
    dep = _dep(model, base, root=tmp_path / "store")
    dep.publish("prod", dm1)
    v2 = dep.update("prod", dm2)
    assert dep.store.version_info("prod", v2)["kind"] == "patch"
    t2 = _serve(dep, "prod")
    cold = _dep(model, base, root=tmp_path / "store")
    assert cold.variants() == ["__base__", "prod"]
    assert cold.current("prod") == v2
    assert _serve(cold, "prod") == t2        # cold chain materialise
    ref = _dep(model, base)
    ref.publish("prod", dm1)
    t1 = _serve(ref, "prod")
    # every persisted version hydrates: explicit addressing of the OLD
    # version works on the restarted node without a rollback first
    assert _serve(cold, "prod@v1") == t1
    assert cold.rollback("prod") == 1        # lineage survives restart too
    assert _serve(cold, "prod") == t1


def test_deployment_lazy_hydration_defers_store_reads(setup, tmp_path):
    """Restart hydration is LAZY by default (DESIGN.md §14): constructing
    a Deployment over an existing store does ZERO per-name index/artifact
    reads — a name's lineage registers on FIRST reference, and names that
    are never requested are never read.  ``eager=True`` restores the old
    hydrate-everything-up-front behaviour."""
    model, base, dm1, dm2, _ = setup
    seed = _dep(model, base, root=tmp_path / "store")
    seed.publish("a", dm1)
    seed.publish("b", dm2)
    t_a = _serve(seed, "a")

    def spy_store():
        calls = {}
        st = S.VariantStore(tmp_path / "store")
        orig_idx, orig_load = st._read_index, st.load

        def idx(name):
            calls[f"index:{name}"] = calls.get(f"index:{name}", 0) + 1
            return orig_idx(name)

        def load(name, version=None, *, pacer=None):
            calls[f"load:{name}"] = calls.get(f"load:{name}", 0) + 1
            return orig_load(name, version, pacer=pacer)

        st._read_index, st.load = idx, load
        return st, calls

    st, calls = spy_store()
    dep = Deployment(model, base, store=st, batch_size=2, prompt_len=8,
                     max_len=32, bank_size=4)
    assert calls == {}                       # construction reads nothing
    assert dep.variants() == ["__base__", "a", "b"]   # names() only
    assert calls == {}
    assert _serve(dep, "a") == t_a           # first reference hydrates
    assert calls.get("load:a", 0) >= 1
    assert "load:b" not in calls and "index:b" not in calls
    assert dep.current("a") == 1             # hydration is idempotent...
    assert calls.get("load:a") == 1          # ...and artifact loads don't repeat
    _serve(dep, "b")                         # b reads only when referenced
    assert calls.get("load:b", 0) >= 1

    st2, calls2 = spy_store()
    Deployment(model, base, store=st2, batch_size=2, prompt_len=8,
               max_len=32, bank_size=4, eager=True)
    assert calls2.get("index:a", 0) >= 1     # eager walks every lineage
    assert calls2.get("index:b", 0) >= 1


def test_store_rejects_path_traversal_names(setup, tmp_path):
    _, base, dm1, _, _ = setup
    st = S.VariantStore(tmp_path / "store")
    for bad in ("..", ".", "a/b", "a@b", "", "a\\b"):
        with pytest.raises(ValueError):
            st.publish(bad, dm1)
    assert not (tmp_path / "versions.json").exists()
    st.publish("ok-name_1.2", dm1)           # safe charset accepted


def test_deployment_rejects_dense_continuous(setup):
    model, base, dm1, _, _ = setup
    with pytest.raises(ValueError):
        _dep(model, base, mode="dense")      # default scheduler continuous
    dep = _dep(model, base)                  # fused + continuous
    with pytest.raises(ValueError):
        dep.publish("prod", dm1, mode="dense")


def test_store_cache_bounded(setup, tmp_path):
    """The materialisation cache is LRU-bounded: a long chain of frequent
    updates must not pin every historical version's arrays in memory."""
    _, base, dm1, dm2, _ = setup
    st = S.VariantStore(tmp_path, cache_versions=2)
    st.publish("prod", dm1)
    st.publish_update("prod", dm2)
    for _ in range(4):
        st.publish_update("prod", st.load("prod"))
    assert len(st._cache) <= 2
    # evicted versions still materialise correctly from disk
    ref = S.VariantStore(tmp_path / "ref")
    ref.publish("ref", dm2)
    _wire_equal(st.load("prod", 2), ref.load("ref", 1))
    assert len(st._cache) <= 2


# ---------------------------------------------------------------------------
# overlay bank accounting (satellite: admit -> evict -> admit reuse)
# ---------------------------------------------------------------------------

def test_bank_nbytes_stable_across_admit_evict_admit(setup):
    """Regression: the bank allocates once at full size — nbytes() must
    return to its value after the first admit when a slot is evicted and
    reused by a DIFFERENT variant, and registry resident_bytes must not
    drift across the cycle."""
    model, base, dm1, dm2, _ = setup
    bank = OverlayBank(base, 3)
    assert bank.nbytes() == 0
    bank.admit("a", dm1)
    allocated = bank.nbytes()
    assert allocated > 0
    bank.evict("a")
    assert bank.nbytes() == allocated
    bank.admit("b", dm2)
    assert bank.nbytes() == allocated
    assert bank.stats["evictions"] == 1

    reg = VariantRegistry(base, mode="fused", bank_size=3)
    reg.register("a", dm1)
    reg.register("b", dm2)
    reg.bank_resolve("a")
    charged = reg.stats["resident_bytes"]
    assert charged == reg.bank.nbytes()
    reg.evict("a")
    reg.bank_resolve("b")
    reg.bank_resolve("a")        # slot churn: b evicted? no — free slot
    assert reg.stats["resident_bytes"] == charged == reg.bank.nbytes()


def test_full_lifecycle_parity_under_async_admission(setup, tmp_path):
    """The whole PR-3 lifecycle — publish, incremental update + hot-swap,
    rollback — replayed with the ASYNC admission pipeline must emit
    bit-identical greedy tokens to the synchronous control plane, with
    every admission landing through the between-step commit hook."""
    model, base, dm1, dm2, _ = setup

    def lifecycle(async_adm, root):
        dep = _dep(model, base, root=root, async_admission=async_adm)
        dep.publish("prod", dm1)
        t1 = _serve(dep, "prod", 5)
        dep.update("prod", dm2)
        t2 = _serve(dep, "prod", 5)
        if async_adm:
            dep.admission.wait()          # no live tickets across rollback
        dep.rollback("prod")
        t3 = _serve(dep, "prod", 5)
        dep.close()
        return t1, t2, t3

    sync_toks = lifecycle(False, tmp_path / "sync")
    async_toks = lifecycle(True, tmp_path / "async")
    assert async_toks == sync_toks
    assert sync_toks[2] == sync_toks[0]   # rollback re-serves v1 exactly


def test_registry_set_version_drops_stale_dense_resident(setup):
    """Hot-swapping a dense-resident variant frees the old version's full
    materialised copy (stats stay balanced); the bank path instead keeps
    the old slot for constant-time rollback."""
    model, base, dm1, dm2, _ = setup
    reg = VariantRegistry(base, mode="dense", max_resident=2)
    reg.set_version("prod", 1, dm1)
    reg.resolve("prod")
    before = reg.stats["resident_bytes"]
    assert before > 0
    reg.set_version("prod", 2, dm2)
    assert reg.stats["resident_bytes"] == 0    # v1 copy dropped
    reg.resolve("prod")
    assert reg.stats["resident_bytes"] == before
