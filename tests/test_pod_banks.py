"""Pod-local overlay banks + affinity routing (DESIGN.md §17).

Three tiers, matching the CI job layout:

* pure rule/spec resolution with fake meshes (no devices) — always runs;
* bank residency semantics (per-pod slot tables, per_device_nbytes,
  evict-while-pinned / evict-while-staging) on a 3-axis
  (pod, data, model) mesh — needs 4 devices (sharded-smoke CI job);
* end-to-end engine parity + affinity routing on a (2, 2, 2) mesh —
  needs 8 devices (pod-smoke CI job); skips elsewhere.

Contract under test: pod-local banking is a LAYOUT + ROUTING decision.
Greedy tokens must match the global-bank engine bit-for-bit whether a
request was an affinity hit or a cold-pod miss; slot indices returned by
the bank are GLOBAL (pod p owns [p*size, (p+1)*size), its base slot is
p*size); admission writes exactly one pod's shard.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config
from repro.core import calibration as C
from repro.distributed import sharding as S
from repro.models import build_model
from repro.models.param import split
from repro.serving import Deployment, VariantRegistry
from repro.serving.variants import OverlayBank


def _fake_mesh(shape, names):
    class M:
        axis_names = names
        devices = np.empty(shape, object)
    return M()


def _mesh_pod(pod=2, data=1, model=2) -> Mesh:
    n = pod * data * model
    if len(jax.devices()) < n:
        pytest.skip(f"needs {n} devices (pod/sharded-smoke CI jobs)")
    return Mesh(np.asarray(jax.devices()[:n]).reshape(pod, data, model),
                ("pod", "data", "model"))


def _pair(arch: str = "deepseek-7b", layers: int = 2):
    cfg = dataclasses.replace(get_config(arch).reduced(), num_layers=layers,
                              compute_dtype="float32", remat=False)
    model = build_model(cfg)
    base, axes = split(model.init(jax.random.PRNGKey(0)))
    pert, _ = split(model.init(jax.random.PRNGKey(1)))
    ft1 = jax.tree.map(lambda b, f: b + 0.05 * f, base, pert)
    ft2 = jax.tree.map(lambda b, f: b - 0.05 * f, base, pert)
    return model, base, axes, C.compress(base, ft1), C.compress(base, ft2)


# ---------------------------------------------------------------------------
# rule resolution (no devices)
# ---------------------------------------------------------------------------

def test_bank_rule_pod_sharded():
    mesh = _fake_mesh((2, 2, 2), ("pod", "data", "model"))
    rules = S.rules_for("decode", pod_banks=True)
    assert S.resolve_spec((10,), ("bank",), rules, mesh) == P("pod")
    # default rules keep the bank replicated even on a pod mesh
    base = S.rules_for("decode")
    assert S.resolve_spec((10,), ("bank",), base, mesh) == P(None)


def test_bank_rule_degrades_without_pod_axis():
    """pod_banks rules on a 2-axis mesh fall through to replicated (the
    divisibility fallback skips absent axes) — tier-1 CPU safety."""
    mesh = _fake_mesh((2, 2), ("data", "model"))
    rules = S.rules_for("decode", pod_banks=True)
    assert S.resolve_spec((10,), ("bank",), rules, mesh) == P(None)


def test_bank_rule_indivisible_slots_replicates():
    mesh = _fake_mesh((2, 2, 2), ("pod", "data", "model"))
    rules = S.rules_for("decode", pod_banks=True)
    # 2 pods cannot split 7 slots evenly -> replicated, not an error
    assert S.resolve_spec((7,), ("bank",), rules, mesh) == P(None)


def test_act_batch_pod_major_on_pod_mesh():
    """Lanes block-partition pod-major: act_batch resolves to
    ("pod", "data") when the pod axis exists — the layout the engine's
    _lane_pod mapping assumes."""
    mesh = _fake_mesh((2, 2, 2), ("pod", "data", "model"))
    rules = S.rules_for("decode")
    assert S.resolve_spec((8,), ("act_batch",), rules, mesh) == \
        P(("pod", "data"))


# ---------------------------------------------------------------------------
# per-pod bank residency semantics (slot math needs no mesh at all)
# ---------------------------------------------------------------------------

def test_global_slot_convention_host_only():
    model, base, axes, dm1, dm2 = _pair()
    bank = OverlayBank(base, 4, pods=1)
    s1, p1 = bank.admit("a@v1", dm1)
    assert s1 == 1 and p1 > 0
    assert bank.base_slot() == 0
    assert bank.slot_of("a@v1") == 1
    # LRU hit: same slot, no payload
    assert bank.admit("a@v1", None) == (1, 0)


def test_registry_pod_banks_requires_pod_mesh():
    model, base, axes, dm1, _ = _pair()
    with pytest.raises(ValueError, match="pod"):
        VariantRegistry(base, pod_banks=True)        # no mesh at all


def test_pod_bank_per_pod_slots_and_eviction():
    """Per-pod slot tables on a (2, 1, 2) mesh: global slot ids, per-pod
    base slots, independent LRU/eviction, evict-while-pinned and
    evict-while-staging refusals."""
    model, base, axes, dm1, dm2 = _pair()
    mesh = _mesh_pod(2, 1, 2)
    shardings = S.tree_shardings(base, axes, S.rules_for("decode"), mesh)
    base_dev = jax.device_put(base, shardings)
    bank = OverlayBank(base_dev, 3, mesh=mesh, param_axes=axes, pods=2)
    assert bank.total_slots == 6
    assert bank.base_slot(0) == 0 and bank.base_slot(1) == 3

    s_a0, pay = bank.admit("a@v1", dm1, pod=0)
    assert s_a0 == 1 and pay > 0
    s_a1, pay1 = bank.admit("a@v1", dm1, pod=1)     # same vkey, other pod
    assert s_a1 == 4 and pay1 > 0                   # global ids differ
    assert bank.pods_holding("a@v1") == [0, 1]
    assert bank.slot_of("a@v1", pod=1) == 4
    assert sorted(bank.resident()) == ["a@v1"]
    assert bank.pod_resident() == {0: ["a@v1"], 1: ["a@v1"]}

    # admission traffic: pod-sharded bank crosses no pod boundary
    assert bank.stats["admit_bytes_in_pod"] == pay + pay1
    assert bank.stats["admit_bytes_cross_pod"] == 0

    # pin in pod 0 only: evicting pod 0 raises, pod 1 evicts fine
    bank.pin("a@v1", pod=0)
    with pytest.raises(RuntimeError, match="pinned"):
        bank.evict("a@v1", pod=0)
    with pytest.raises(RuntimeError, match="pinned"):
        bank.evict("a@v1")                 # pod=None hits the pinned pod
    bank.evict("a@v1", pod=1)
    assert bank.pods_holding("a@v1") == [0]
    bank.unpin("a@v1", pod=0)

    # staging marks are per (pod, vkey)
    bank.mark_staging("b@v1", pod=1)
    assert bank.staging("b@v1") and bank.staging("b@v1", pod=1)
    assert not bank.staging("b@v1", pod=0)
    with pytest.raises(RuntimeError, match="staging"):
        bank.evict("b@v1", pod=1)
    bank.unmark_staging("b@v1", pod=1)

    # per-pod LRU pressure: fill pod 0's two variant slots, third admit
    # evicts pod 0's LRU but never touches pod 1's table
    bank.admit("b@v1", dm2, pod=0)
    bank.admit("a@v1", dm1, pod=1)
    ev0 = bank.stats["evictions"]
    s_c, _ = bank.admit("c@v1", dm2, pod=0)
    assert s_c in (1, 2)                   # reused a pod-0 slot
    assert bank.stats["evictions"] == ev0 + 1
    assert bank.pods_holding("a@v1") in ([1], [0, 1])
    assert "c@v1" in bank._slots           # back-compat merged view


def test_per_device_and_per_pod_nbytes():
    """A pod-sharded bank puts each pod's slot range only on its own
    devices: per-device bytes are uniform, and the per-pod rollup keyed
    by the mesh's pod coordinate covers all devices."""
    model, base, axes, dm1, _ = _pair()
    mesh = _mesh_pod(2, 1, 2)
    shardings = S.tree_shardings(base, axes, S.rules_for("decode"), mesh)
    base_dev = jax.device_put(base, shardings)
    bank = OverlayBank(base_dev, 2, mesh=mesh, param_axes=axes, pods=2)
    bank.admit("a@v1", dm1, pod=0)
    per_dev = bank.per_device_nbytes()
    assert len(per_dev) == 4               # every mesh device holds bank
    per_pod = bank.per_pod_nbytes()
    assert sorted(per_pod) == [0, 1]
    assert sum(per_pod.values()) == sum(per_dev.values())

    # global bank on the same mesh: same totals pattern, one merged pod
    # range replicated everywhere -> per-device bytes match across pods
    bank_g = OverlayBank(base_dev, 4, mesh=mesh, param_axes=axes)
    bank_g.admit("a@v1", dm1)
    g_dev = bank_g.per_device_nbytes()
    assert len(set(g_dev.values())) <= 2   # weight tiles may differ by axis
    # replication accounting: global-bank admit charges cross-pod bytes
    assert bank_g.stats["admit_bytes_cross_pod"] == \
        bank_g.stats["admit_bytes_in_pod"]


# ---------------------------------------------------------------------------
# TTFT reservoir (single device)
# ---------------------------------------------------------------------------

def test_ttft_percentiles_in_status():
    model, base, axes, dm1, _ = _pair()
    dep = Deployment(model, base, batch_size=2, prompt_len=16, max_len=64,
                     bank_size=4)
    dep.publish("a", dm1)
    for i in range(4):
        dep.submit(np.arange(1, 9), variant=["__base__", "a"][i % 2],
                   max_new_tokens=2)
    dep.drain()
    tt = dep.status()["ttft"]
    assert tt["count"] == 4
    assert 0 < tt["p50_seconds"] <= tt["p99_seconds"] <= tt["max_seconds"]
    dep.close()


# ---------------------------------------------------------------------------
# end-to-end engine parity + routing (8 devices: pod-smoke CI job)
# ---------------------------------------------------------------------------

TRAFFIC = ["v0", "v0", "v1", "v0", "v1", "v0", "v1", "v0"]


def _run_dep(model, base, axes, dms, mesh, **kw):
    dep = Deployment(model, base, batch_size=4, prompt_len=16, max_len=64,
                     bank_size=4, mesh=mesh,
                     param_axes=axes if mesh is not None else None, **kw)
    for name, dm in dms.items():
        dep.publish(name, dm)
    rids = [dep.submit(np.arange(1, 9), variant=v, max_new_tokens=4)
            for v in TRAFFIC]
    dep.drain()
    toks = [dep.result(r).out_tokens for r in rids]
    assert all(dep.result(r).status == "done" for r in rids)
    return toks, dep


def test_pod_banks_engine_parity():
    """Pod-local banks + affinity routing emit exactly the global bank's
    greedy tokens (hits AND misses), with per-pod status reporting."""
    model, base, axes, dm1, dm2 = _pair()
    mesh = _mesh_pod(2, 2, 2)
    dms = {"v0": dm1, "v1": dm2}
    toks_g, dep_g = _run_dep(model, base, axes, dms, mesh)
    toks_p, dep_p = _run_dep(model, base, axes, dms, mesh, pod_banks=True)
    assert toks_p == toks_g
    st = dep_p.status()
    assert st["affinity"]["pods"] == 2
    assert st["affinity"]["hits"] > 0      # skew makes v0 re-route warm
    assert st["affinity"]["misses"] > 0    # first touches are cold
    assert sorted(st["hbm"]["bank_per_pod"]) == [0, 1]
    res = st["hbm"]["bank_resident_per_pod"]
    assert set(res) == {0, 1}
    # zero cross-pod admission traffic under the pod-sharded layout
    assert dep_p.registry.bank.stats["admit_bytes_cross_pod"] == 0
    assert dep_g.registry.bank.stats["admit_bytes_cross_pod"] > 0
    dep_g.close()
    dep_p.close()


def test_pod_banks_gspmd_parity():
    """The global-index GSPMD lowering serves the pod-sharded bank with
    the same tokens as the shard_map translation path."""
    model, base, axes, dm1, dm2 = _pair()
    mesh = _mesh_pod(2, 2, 2)
    dms = {"v0": dm1, "v1": dm2}
    toks_sm, dep_sm = _run_dep(model, base, axes, dms, mesh,
                               pod_banks=True)
    toks_g, dep_g = _run_dep(model, base, axes, dms, mesh, pod_banks=True,
                             kernel_dispatch="gspmd")
    assert toks_sm == toks_g
    dep_sm.close()
    dep_g.close()


def test_pod_banks_async_admission():
    """Per-pod admission tickets: the async pipeline commits each pod's
    ingest independently and requests drain to done with parity intact."""
    model, base, axes, dm1, dm2 = _pair()
    mesh = _mesh_pod(2, 2, 2)
    dms = {"v0": dm1, "v1": dm2}
    toks_sync, dep_s = _run_dep(model, base, axes, dms, mesh,
                                pod_banks=True)
    toks_async, dep_a = _run_dep(model, base, axes, dms, mesh,
                                 pod_banks=True, async_admission=True,
                                 admission_pacing_s=0.0)
    assert toks_async == toks_sync
    assert dep_a.metrics["async_admits"] > 0
    dep_s.close()
    dep_a.close()


def test_pod_banks_rejects_speculative():
    model, base, axes, _, _ = _pair()
    with pytest.raises(ValueError, match="speculative"):
        Deployment(model, base, pod_banks=True, speculative=True)
