"""End-to-end system test: the paper's full lifecycle on a tiny model.

train base → fine-tune → calibrated per-axis compression → artifact on
disk → hot-swap onto resident base → multi-tenant serving — asserting the
paper's qualitative claims at each stage.
"""
import dataclasses
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import calibration as C
from repro.core import loader as L
from repro.core import store as S
from repro.data.pipeline import SyntheticLM
from repro.models import build_model
from repro.serving import ServingEngine, VariantRegistry
from repro.train.step import init_train_state, make_train_step


@pytest.mark.slow
def test_full_lifecycle(tmp_path):
    cfg = dataclasses.replace(get_config("qwen3-8b").reduced(),
                              num_layers=2, compute_dtype="float32",
                              remat=False)
    model = build_model(cfg)

    # 1. pretrain + fine-tune
    step = jax.jit(make_train_step(model, peak_lr=5e-3, warmup=5))
    state = init_train_state(model, jax.random.PRNGKey(0))
    src = SyntheticLM(cfg.vocab_size, seed=0)
    for i in range(30):
        state, m = step(state, src.lm_batch(i, 4, 32))
    base = state.params
    ft_src = SyntheticLM(cfg.vocab_size, seed=9)
    for i in range(15):
        state, m = step(state, ft_src.lm_batch(i, 4, 32))
    ft = state.params

    # 2. calibrated compression (paper Alg. 1-7)
    calib = [ft_src.lm_batch(1000 + i, 4, 32) for i in range(3)]
    dm, report = C.calibrate_transformer(model, base, ft, calib,
                                         epochs=2, e2e_epochs=2,
                                         lr=1e-3, e2e_lr=1e-3)
    assert report["axis"]  # axis selection happened

    # 3. artifact round trip + integrity
    fp = S.base_fingerprint(base)
    manifest = S.save_artifact(dm, tmp_path / "v", base_fp=fp)
    assert manifest["artifact_bytes"] < C.fp16_checkpoint_nbytes(ft)
    dm2 = S.load_artifact(tmp_path / "v", expect_base_fp=fp)

    # 4. hot swap: student ≈ teacher on held-out data
    student, stats = L.apply_artifact(base, dm2)
    fwd = jax.jit(lambda p, b: model.forward(p, b)[0])
    batch = ft_src.lm_batch(5000, 4, 32)
    mse_student = float(jnp.mean((fwd(ft, batch) - fwd(student, batch)) ** 2))
    mse_base = float(jnp.mean((fwd(ft, batch) - fwd(base, batch)) ** 2))
    assert mse_student < 0.5 * mse_base, (mse_student, mse_base)

    # 5. serving with the swapped variant — dense residency
    reg = VariantRegistry(base)
    reg.register("v", dm2)
    eng = ServingEngine(model, reg, batch_size=2, prompt_len=8, max_len=32)
    rid = eng.submit(np.arange(1, 7), variant="v", max_new_tokens=4)
    eng.run_until_drained()
    assert eng.result(rid).status == "done"
    assert len(eng.result(rid).out_tokens) == 4

    # 6. the same artifact served on the fly (packed overlay, no dense
    # reconstruction) generates the same greedy tokens at a fraction of
    # the resident bytes
    reg_f = VariantRegistry(base, mode="fused")
    reg_f.register("v", dm2)
    eng_f = ServingEngine(model, reg_f, batch_size=2, prompt_len=8,
                          max_len=32)
    rid_f = eng_f.submit(np.arange(1, 7), variant="v", max_new_tokens=4)
    eng_f.run_until_drained()
    assert eng_f.result(rid_f).status == "done"
    assert len(eng_f.result(rid_f).out_tokens) == 4
    # first greedy token must agree; later tokens can diverge once any
    # logit pair lands within fp16 rounding (extras are fp16 in fused
    # residency) — numeric parity is asserted in test_fused_serving
    assert eng_f.result(rid_f).out_tokens[0] == eng.result(rid).out_tokens[0]
    assert reg_f.resident_nbytes("v") < reg.resident_nbytes("v") / 4
