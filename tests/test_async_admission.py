"""Async admission pipeline (DESIGN.md §13): staged off-thread ingest,
between-step commit, `admitting` status surfacing, and the lifecycle
guards (evict/rollback while staging) under concurrency.

Correctness bar: a variant admitted ASYNCHRONOUSLY — ingest and H2D
staging overlapping in-flight decode of other lanes — must yield greedy
tokens BIT-IDENTICAL to the synchronous inline-admission path, and the
PR-3 lifecycle invariants (version pinning, rollback, failed-artifact
retry budgets) must hold with the second execution timeline running.
"""
import dataclasses
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import calibration as C
from repro.core import store as S
from repro.models import build_model
from repro.models.param import split
from repro.serving import Deployment

PROMPT = np.arange(1, 7)


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_config("deepseek-7b").reduced(),
                              num_layers=2, compute_dtype="float32",
                              remat=False)
    model = build_model(cfg)
    base, _ = split(model.init(jax.random.PRNGKey(0)))
    pert, _ = split(model.init(jax.random.PRNGKey(1)))
    ft1 = jax.tree.map(lambda b, p: b + 0.05 * p, base, pert)
    ft2 = jax.tree.map(lambda b, p: b + 0.08 * p, base, pert)
    return model, base, C.compress(base, ft1), C.compress(base, ft2)


def _dep(model, base, root=None, **kw):
    kw.setdefault("batch_size", 2)
    kw.setdefault("prompt_len", 8)
    kw.setdefault("max_len", 96)
    kw.setdefault("bank_size", 4)
    return Deployment(model, base, root_dir=root, **kw)


def _serve(dep, variant, n=4):
    rid = dep.submit(PROMPT, variant=variant, max_new_tokens=n)
    dep.drain()
    assert dep.result(rid).status == "done"
    return dep.result(rid).out_tokens


# ---------------------------------------------------------------------------
# parity: async-admitted variants produce bit-identical greedy tokens
# ---------------------------------------------------------------------------

def test_async_admission_token_parity(setup, tmp_path):
    """Store-backed publish/update served through the async pipeline must
    emit exactly the sync path's tokens, and actually commit off the
    inline path (async_admits > 0)."""
    model, base, dm1, dm2 = setup
    tokens = {}
    for mode in ("sync", "async"):
        dep = _dep(model, base, root=tmp_path / mode,
                   async_admission=(mode == "async"))
        dep.publish("prod", dm1)
        t1 = _serve(dep, "prod", 5)
        dep.update("prod", dm2)
        t2 = _serve(dep, "prod", 5)
        tokens[mode] = (t1, t2)
        if mode == "async":
            assert dep.metrics["async_admits"] >= 2
            assert dep.admission.stats["failures"] == 0
        dep.close()
    assert tokens["async"] == tokens["sync"]


def test_async_admission_overlaps_inflight_decode(setup):
    """The point of the pipeline: while OTHER lanes decode, a new variant
    ingests+stages in the background — decode steps run with admission in
    flight (no stop-the-world), and the variant's tokens still match a
    clean-room serve."""
    model, base, dm1, _ = setup
    dep = _dep(model, base, async_admission=True)

    def slow_artifact():
        time.sleep(0.15)          # pretend the store read/patch chain
        return dm1                # takes a while (it runs OFF-thread)
    dep.registry.set_version("slow", 1, slow_artifact)

    dep.engine.record_step_times = True
    r_base = [dep.submit(PROMPT, variant="__base__", max_new_tokens=64)
              for _ in range(2)]
    rid = dep.submit(PROMPT, variant="slow", max_new_tokens=5)
    dep.drain()
    assert all(dep.result(r).status == "done" for r in r_base)
    assert dep.result(rid).status == "done"
    # decode made progress during ingest: some steps ran with a live
    # admission (the base lanes never waited for the 150 ms artifact)
    assert any(busy for _, _, busy in dep.engine.step_times)
    assert dep.metrics["async_admits"] == 1
    dep.close()

    ref = _dep(model, base)
    ref.publish("slow", dm1)
    assert dep.result(rid).out_tokens == _serve(ref, "slow", 5)


# ---------------------------------------------------------------------------
# control-plane semantics: non-blocking verbs, wait= escape hatch, status
# ---------------------------------------------------------------------------

def test_publish_nonblocking_with_wait_escape_hatch(setup, tmp_path):
    model, base, dm1, dm2 = setup
    dep = _dep(model, base, root=tmp_path / "s", async_admission=True)
    v1 = dep.publish("prod", dm1)
    # non-blocking: the version is NOT bank-resident at return (commit
    # happens between decode steps or in wait) but ingest was enqueued
    assert dep.registry.bank is None or \
        f"prod@v{v1}" not in dep.registry.bank._slots
    dep.admission.wait("prod")
    assert f"prod@v{v1}" in dep.registry.bank._slots
    # wait=True restores the blocking contract in one call
    v2 = dep.update("prod", dm2, wait=True)
    assert f"prod@v{v2}" in dep.registry.bank._slots
    dep.close()


def test_admitting_status_surfaced(setup):
    """A request queued behind ingest reports ``admitting`` — distinct
    from ``queued`` (no admission pending) and from ``unknown``."""
    model, base, dm1, _ = setup
    dep = _dep(model, base, async_admission=True)
    dep.publish("prod", dm1)
    rid = dep.submit(PROMPT, variant="prod", max_new_tokens=3)
    # one admission pass, no drain: the variant is still staging (commits
    # only happen in the drain hook), so the request must be skipped and
    # surfaced as admitting, and the pipeline as in flight
    dep.engine._admit_free_slots()
    assert dep.engine.status(rid) == "admitting"
    assert dep.status(rid)["status"] == "admitting"
    assert dep.admitting() == ["prod@v1"]
    dep.drain()
    assert dep.engine.status(rid) == "done"
    assert dep.admitting() == []
    dep.close()


# ---------------------------------------------------------------------------
# lifecycle guards under concurrency
# ---------------------------------------------------------------------------

def test_evict_while_staging_raises(setup):
    model, base, dm1, _ = setup
    dep = _dep(model, base, async_admission=True)

    def slow_artifact():
        time.sleep(0.2)
        return dm1
    dep.registry.set_version("prod", 1, slow_artifact)
    dep.admission.prefetch("prod")
    with pytest.raises(RuntimeError, match="staging"):
        dep.registry.evict("prod")
    dep.admission.wait("prod")            # admission lands ...
    dep.registry.evict("prod")            # ... then eviction is clean
    assert "prod@v1" not in dep.registry.bank._slots
    dep.close()


def test_rollback_while_staging_raises(setup):
    model, base, dm1, dm2 = setup
    dep = _dep(model, base, async_admission=True)
    dep.publish("prod", dm1, wait=True)
    t1 = _serve(dep, "prod", 4)

    def slow_v2():
        time.sleep(0.2)
        return dm2
    dep.registry.set_version("prod", 2, slow_v2)
    dep.admission.prefetch("prod")
    with pytest.raises(RuntimeError, match="mid-admission"):
        dep.rollback("prod")
    dep.admission.wait("prod")
    assert dep.rollback("prod") == 1      # clean once the admission lands
    assert _serve(dep, "prod", 4) == t1   # rollback re-serves v1 exactly
    dep.close()


def test_ingest_failure_respects_retry_budget(setup, tmp_path):
    """A corrupt artifact failing on the INGEST THREAD must fail the
    request through the same max_retries budget as the sync path — and
    the node keeps serving other variants."""
    model, base, dm1, _ = setup
    st = S.VariantStore(tmp_path / "s", base_fp=S.base_fingerprint(base))
    st.publish("bad", dm1)
    # truncate the payload AFTER publish: the chunked reader must raise
    blob = tmp_path / "s" / "bad" / "v0001" / "deltas.npz"
    blob.write_bytes(blob.read_bytes()[: blob.stat().st_size // 2])
    dep = _dep(model, base, root=tmp_path / "s", async_admission=True,
               max_retries=1)
    dep.publish("good", C.compress(base, jax.tree.map(
        lambda b: b, base)))               # identity delta, valid artifact
    rid_bad = dep.submit(PROMPT, variant="bad", max_new_tokens=3)
    rid_good = dep.submit(PROMPT, variant="good", max_new_tokens=3)
    dep.drain()
    assert dep.result(rid_bad).status == "failed"
    assert "truncated" in dep.result(rid_bad).error
    assert dep.result(rid_good).status == "done"
    assert dep.admission.stats["failures"] >= 1
    # a failed ticket never leaves a stale staging mark behind
    assert not dep.registry.bank.staging("bad@v1")
    dep.close()


def test_version_pinning_survives_async_hot_swap(setup):
    """PR-3 invariant under the second timeline: a lane decoding v1 when
    an ASYNC update lands finishes on v1's pinned slot; post-swap
    admissions serve v2."""
    model, base, dm1, dm2 = setup
    dep = _dep(model, base, async_admission=True)
    dep.publish("prod", dm1, wait=True)
    rid_old = dep.submit(PROMPT, variant="prod", max_new_tokens=5)
    dep.engine._prefill_admitted(dep.engine._admit_free_slots())
    assert dep.registry.bank.pinned("prod@v1")
    dep.update("prod", dm2)                # non-blocking hot-swap
    rid_new = dep.submit(PROMPT, variant="prod", max_new_tokens=5)
    dep.drain()
    assert dep.status(rid_old)["version"] == 1
    assert dep.status(rid_new)["version"] == 2
    ref1 = _dep(model, base)
    ref1.publish("prod", dm1)
    assert dep.result(rid_old).out_tokens == _serve(ref1, "prod", 5)
    ref2 = _dep(model, base)
    ref2.publish("prod", dm2)
    assert dep.result(rid_new).out_tokens == _serve(ref2, "prod", 5)
    dep.close()
