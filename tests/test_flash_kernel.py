"""Pallas flash-attention forward kernel vs the dense oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as K
from repro.models import attention as A


def _qkv(key, b, s, t, hq, hkv, hd, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, hq, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (b, t, hkv, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (b, t, hkv, hd)).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("s,t", [(16, 16), (8, 32)])
def test_flash_kernel_matches_reference(hq, hkv, s, t):
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, s, t, hq, hkv, 16)
    got = K.flash_attention_fwd(q, k, v, causal=False)
    want = A.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2)])
def test_flash_kernel_causal(hq, hkv):
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 32, 32, hq, hkv, 8)
    got = K.flash_attention_fwd(q, k, v, causal=True)
    want = A.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_kernel_bf16():
    q, k, v = _qkv(jax.random.PRNGKey(2), 1, 16, 16, 4, 4, 16,
                   dtype=jnp.bfloat16)
    got = K.flash_attention_fwd(q, k, v, causal=True)
    want = A.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_flash_kernel_offsets_match_jnp_flash():
    """Cross-check against the jnp flash path with absolute offsets."""
    q, k, v = _qkv(jax.random.PRNGKey(3), 1, 8, 24, 2, 2, 8)
    got = K.flash_attention_fwd(q, k, v, causal=True, q_offset=16)
    want = A.flash_attention(q, k, v, causal=True, q_offset=16, chunk=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
