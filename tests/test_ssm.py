"""Chunkwise-parallel forms must match the step-recurrent oracles exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm


def _mlstm_recurrent(q, k, v, ig, fg):
    b, s, h, hd = q.shape
    state = ssm.mlstm_init_state(b, h, hd)
    outs = []
    for t in range(s):
        state, ht = ssm.mlstm_step(state, q[:, t], k[:, t], v[:, t],
                                   ig[:, t], fg[:, t])
        outs.append(ht)
    return jnp.stack(outs, axis=1), state


@pytest.mark.parametrize("s,chunk", [(8, 4), (16, 4), (12, 5), (16, 16), (7, 3)])
def test_mlstm_chunkwise_matches_recurrent(s, chunk):
    key = jax.random.PRNGKey(0)
    b, h, hd = 2, 3, 8
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    ig = jax.random.normal(ks[3], (b, s, h)) * 2.0
    fg = jax.random.normal(ks[4], (b, s, h)) * 2.0 + 1.0
    y_ref, st_ref = _mlstm_recurrent(q, k, v, ig, fg)
    y_chk, st_chk = ssm.mlstm_chunkwise(q, k, v, ig, fg, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_chk["m"]), np.asarray(st_ref["m"]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_chk["C"]), np.asarray(st_ref["C"]),
                               rtol=2e-4, atol=2e-4)


def test_mlstm_chunkwise_state_continuation():
    """Processing [first half] then [second half with carried state] must
    equal one full pass."""
    key = jax.random.PRNGKey(1)
    b, s, h, hd = 1, 16, 2, 4
    ks = jax.random.split(key, 5)
    q, k, v = (jax.random.normal(ks[i], (b, s, h, hd)) for i in range(3))
    ig = jax.random.normal(ks[3], (b, s, h))
    fg = jax.random.normal(ks[4], (b, s, h)) + 1.0
    y_full, _ = ssm.mlstm_chunkwise(q, k, v, ig, fg, chunk=4)
    y1, st = ssm.mlstm_chunkwise(q[:, :8], k[:, :8], v[:, :8], ig[:, :8], fg[:, :8], chunk=4)
    y2, _ = ssm.mlstm_chunkwise(q[:, 8:], k[:, 8:], v[:, 8:], ig[:, 8:], fg[:, 8:],
                                state=st, chunk=4)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=2e-4, atol=2e-4)


def _mamba_recurrent(x, bm, cm, dt, a_log, d_skip):
    b, s, h, p = x.shape
    n = bm.shape[-1]
    state = ssm.mamba_init_state(b, h, p, n)
    outs = []
    for t in range(s):
        state, yt = ssm.mamba_step(state, x[:, t], bm[:, t], cm[:, t],
                                   dt[:, t], a_log, d_skip)
        outs.append(yt)
    return jnp.stack(outs, axis=1), state


@pytest.mark.parametrize("s,chunk", [(8, 4), (16, 8), (12, 5), (16, 16)])
def test_mamba_chunkwise_matches_recurrent(s, chunk):
    key = jax.random.PRNGKey(2)
    b, h, p, n = 2, 3, 4, 6
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (b, s, h, p))
    bm = jax.random.normal(ks[1], (b, s, n))
    cm = jax.random.normal(ks[2], (b, s, n))
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, s, h)))
    a_log = jax.random.normal(ks[4], (h,)) * 0.5
    d_skip = jax.random.normal(ks[5], (h,))
    y_ref, st_ref = _mamba_recurrent(x, bm, cm, dt, a_log, d_skip)
    y_chk, st_chk = ssm.mamba_chunkwise(x, bm, cm, dt, a_log, d_skip, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_chk), np.asarray(st_ref),
                               rtol=2e-4, atol=2e-4)


def test_mamba_state_continuation():
    key = jax.random.PRNGKey(3)
    b, s, h, p, n = 1, 12, 2, 3, 4
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (b, s, h, p))
    bm = jax.random.normal(ks[1], (b, s, n))
    cm = jax.random.normal(ks[2], (b, s, n))
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, s, h)))
    a_log = jax.random.normal(ks[4], (h,)) * 0.5
    d_skip = jnp.zeros((h,))
    y_full, _ = ssm.mamba_chunkwise(x, bm, cm, dt, a_log, d_skip, chunk=4)
    y1, st = ssm.mamba_chunkwise(x[:, :4], bm[:, :4], cm[:, :4], dt[:, :4],
                                 a_log, d_skip, chunk=4)
    y2, _ = ssm.mamba_chunkwise(x[:, 4:], bm[:, 4:], cm[:, 4:], dt[:, 4:],
                                a_log, d_skip, state=st, chunk=4)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=2e-4, atol=2e-4)


def test_slstm_scan_shapes_and_determinism():
    key = jax.random.PRNGKey(4)
    b, s, h, hd = 2, 10, 2, 4
    ks = jax.random.split(key, 8)
    pre = [jax.random.normal(ks[i], (b, s, h, hd)) for i in range(4)]
    rs = [jax.random.normal(ks[4 + i], (h, hd, hd)) * 0.1 for i in range(4)]
    y, st = ssm.slstm_scan(*pre, *rs)
    assert y.shape == (b, s, h, hd)
    assert jnp.isfinite(y).all()
    y2, _ = ssm.slstm_scan(*pre, *rs)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y2))


def test_slstm_step_matches_scan_prefix():
    key = jax.random.PRNGKey(5)
    b, s, h, hd = 1, 5, 2, 3
    ks = jax.random.split(key, 8)
    pre = [jax.random.normal(ks[i], (b, s, h, hd)) for i in range(4)]
    rs = [jax.random.normal(ks[4 + i], (h, hd, hd)) * 0.1 for i in range(4)]
    y_scan, _ = ssm.slstm_scan(*pre, *rs)
    state = ssm.slstm_init_state(b, h, hd)
    for t in range(s):
        state, ht = ssm.slstm_step(state, *(x[:, t] for x in pre), *rs)
        np.testing.assert_allclose(np.asarray(ht), np.asarray(y_scan[:, t]),
                                   rtol=1e-5, atol=1e-5)
