"""Mesh-sharded multi-variant serving (DESIGN.md §11).

Pure-resolution tests use the fake-mesh idiom from test_sharding.py; the
execution tests need >= 4 host devices (run under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` — the CI
sharded-smoke job) and skip on the tier-1 single-device run.

Parity contract: sharding is a LAYOUT decision — banked mixed-variant
decode on a (data, model) mesh must produce the same greedy tokens as the
single-device path, with every overlay/bank leaf resident on its derived
placement and bank admission running as one jitted scatter on the sharded
leaves.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.core import calibration as C
from repro.core import loader as L
from repro.distributed import sharding as S
from repro.models import build_model
from repro.models import delta_overlay as DO
from repro.models.param import split
from repro.serving import Deployment, ServingEngine, VariantRegistry
from repro.serving.variants import OverlayBank


def _mesh22() -> Mesh:
    if len(jax.devices()) < 4:
        pytest.skip("needs 4 devices (sharded-smoke CI job)")
    return Mesh(np.asarray(jax.devices()[:4]).reshape(2, 2),
                ("data", "model"))


def _fake_mesh(shape, names):
    class M:
        axis_names = names
        devices = np.empty(shape, object)
    return M()


def _pair(arch: str = "deepseek-7b", layers: int = 2):
    """Base + two perturbation fine-tunes (fp32 compute for tight parity,
    same recipe as test_continuous_batching)."""
    cfg = dataclasses.replace(get_config(arch).reduced(), num_layers=layers,
                              compute_dtype="float32", remat=False)
    model = build_model(cfg)
    base, axes = split(model.init(jax.random.PRNGKey(0)))
    pert, _ = split(model.init(jax.random.PRNGKey(1)))
    ft1 = jax.tree.map(lambda b, f: b + 0.05 * f, base, pert)
    ft2 = jax.tree.map(lambda b, f: b - 0.05 * f, base, pert)
    return model, base, axes, C.compress(base, ft1), C.compress(base, ft2)


# ---------------------------------------------------------------------------
# pure pspec derivation (no devices)
# ---------------------------------------------------------------------------

def test_entry_axes_derivation():
    mesh = _fake_mesh((16, 16), ("data", "model"))
    rules = S.rules_for("decode")
    ax = DO.entry_axes(("ffn", "embed"))
    assert ax.packed == ("ffn", None)          # packed byte dim replicated
    assert ax.v_row == ("ffn",)
    assert ax.v_col == ("embed",)
    # resolved under serve rules: ffn -> model, embed replicated over data
    spec = S.resolve_spec((4096, 128), ax.packed, rules, mesh)
    assert spec == P("model", None)
    assert S.resolve_spec((4096,), ax.v_row, rules, mesh) == P("model")
    assert S.resolve_spec((1024,), ax.v_col, rules, mesh) == P(None)


def test_entry_axes_banked_stacked():
    """Leaves under a scan stack put the bank axis at position 1 (after
    the layer dim), and "bank" always resolves replicated."""
    ax = DO.entry_axes(("layers", "ffn", "embed"), path="layers.mlp.w_gate",
                       bank=True)
    assert ax.packed == ("layers", "bank", "ffn", None)
    assert ax.v_row == ("layers", "bank", "ffn")
    assert DO.extra_axes(("vocab", "embed"), path="embed", bank=True) == \
        ("bank", "vocab", "embed")
    mesh = _fake_mesh((16, 16), ("data", "model"))
    rules = S.rules_for("decode")
    spec = S.resolve_spec((4, 8, 4096, 128), ax.packed, rules, mesh)
    assert spec == P(None, None, "model", None)


def test_overlay_pspecs_tree_mirrors_overlay():
    model, base, axes, dm1, _ = _pair(layers=2)
    tree = DO.overlay_pspecs(axes, sorted(dm1.deltas), sorted(dm1.extras),
                             bank=True)
    # every delta path resolves to an OverlayEntry of axis tuples, every
    # extras path to a plain tuple with the bank axis inserted
    flat_axes = DO.flatten_axes(axes)
    for path in dm1.deltas:
        node = tree
        for part in path.split("."):
            node = node[part]
        assert isinstance(node, DO.OverlayEntry)
        assert "bank" in node.packed
    for path in dm1.extras:
        node = tree
        for part in path.split("."):
            node = node[part]
        assert isinstance(node, tuple)
        assert len(node) == len(flat_axes[path]) + 1


# ---------------------------------------------------------------------------
# loader placement (regression: v_row/v_col/extras must land sharded)
# ---------------------------------------------------------------------------

def test_device_put_overlay_places_every_leaf():
    """Regression: device_put_overlay used to place only the packed mask
    with param_shardings — v_row/v_col went to the default device.  Every
    overlay leaf and every extras leaf must land on a NamedSharding of the
    serving mesh, and the spec-surgery derivation in the loader must agree
    with the logical derivation in delta_overlay."""
    mesh = _mesh22()
    model, base, axes, dm1, _ = _pair(layers=2)
    rules = S.rules_for("decode")
    param_sh = S.tree_shardings(base, axes, rules, mesh)
    params_view, overlay, _ = L.device_put_overlay(
        base, dm1, param_shardings=param_sh)

    flat_want = DO.overlay_shardings(
        axes, C.flatten_params(base), sorted(dm1.deltas), (), rules, mesh)
    for path in dm1.deltas:
        node = overlay
        for part in path.split("."):
            node = node[part]
        want = flat_want[path]
        for leaf, want_sh in [(node.packed, want.packed),
                              (node.v_row, want.v_row),
                              (node.v_col, want.v_col)]:
            assert isinstance(leaf.sharding, NamedSharding), path
            assert leaf.sharding.mesh == mesh, path
            assert leaf.sharding.spec == want_sh.spec, (
                path, leaf.sharding.spec, want_sh.spec)
    # extras swap into the params view on the weight's own sharding
    flat_view = C.flatten_params(params_view)
    flat_sh = C.flatten_params(param_sh)
    for path in dm1.extras:
        assert flat_view[path].sharding == flat_sh[path], path


def test_apply_update_preserves_sharding():
    """A zero (identity) update patch applied to sharded parent leaves
    must leave the result on the SAME sharding (patches apply in place —
    no replicated round-trip)."""
    mesh = _mesh22()
    model, base, axes, dm1, _ = _pair(layers=2)
    rules = S.rules_for("decode")
    param_sh = S.tree_shardings(base, axes, rules, mesh)
    flat_sh = C.flatten_params(param_sh)
    path = next(iter(dm1.deltas))
    e = dm1.deltas[path]
    mask_sh = L._mask_sharding(flat_sh[path], e.packed.ndim)
    deltas = dict(dm1.deltas)
    deltas[path] = dataclasses.replace(
        e, packed=jax.device_put(e.packed, mask_sh))
    dm_sharded = C.DeltaModel(deltas=deltas, extras=dm1.extras)
    patch = {path: {
        "packed": np.zeros(e.packed.size, np.uint8),
        "v_row": np.zeros(e.v_row.size, np.uint16),
        "v_col": np.zeros(e.v_col.size, np.uint16),
        "use_row": np.zeros(e.use_row.size, bool).reshape(e.use_row.shape),
    }}
    dm2 = L.apply_update(dm_sharded, patch, {})
    got = dm2.deltas[path].packed
    assert got.sharding.spec == mask_sh.spec
    np.testing.assert_array_equal(np.asarray(got), np.asarray(e.packed))


# ---------------------------------------------------------------------------
# sharded overlay bank
# ---------------------------------------------------------------------------

def test_bank_admit_evict_readmit_sharded():
    """Bank lifecycle on a 2x2 mesh: leaves allocated on their derived
    shardings, admission = one jitted scatter on the sharded leaves, slot
    reuse after eviction, per-device byte accounting covers every shard."""
    mesh = _mesh22()
    model, base, axes, dm1, dm2 = _pair(layers=2)
    bank = OverlayBank(base, 3, mesh=mesh, param_axes=axes)
    s1, payload = bank.admit("a", dm1)
    assert s1 == 1 and payload > 0
    for path, want in bank.shardings.items():
        leaf = bank._flat[path]
        leaves = ([leaf] if not isinstance(leaf, DO.OverlayEntry)
                  else [leaf.packed, leaf.v_row, leaf.v_col])
        wants = ([want] if not isinstance(want, DO.OverlayEntry)
                 else [want.packed, want.v_row, want.v_col])
        for lf, w in zip(leaves, wants):
            assert isinstance(lf.sharding, NamedSharding), path
            assert lf.sharding.spec == w.spec, path
    s2, _ = bank.admit("b", dm2)
    assert s2 == 2
    # per-device accounting: every mesh device holds bank bytes, and the
    # total equals nbytes (replicated leaves counted once per device)
    per_dev = bank.per_device_nbytes()
    assert set(per_dev) == {str(d) for d in mesh.devices.flatten()}
    assert all(v > 0 for v in per_dev.values())
    bank.evict("a")
    s3, _ = bank.admit("c", dm1)
    assert s3 == 1                       # slot reuse
    assert bank.resident() == ["b", "c"]


def test_sharded_banked_decode_logits_parity():
    """Mixed-variant banked prefill + decode on the mesh vs single-device:
    logits agree to fp32-reduction tolerance, greedy tokens exactly."""
    mesh = _mesh22()
    model, base, axes, dm1, dm2 = _pair(layers=2)
    batch = {"tokens": jnp.asarray(np.random.default_rng(7).integers(
        1, model.cfg.vocab_size, size=(4, 8)), jnp.int32)}

    def run(mesh_or_none):
        if mesh_or_none is None:
            bank = OverlayBank(base, 4)
            params = base
        else:
            rules = S.rules_for("decode")
            param_sh = S.tree_shardings(base, axes, rules, mesh_or_none)
            params = jax.device_put(base, param_sh)
            bank = OverlayBank(params, 4, mesh=mesh_or_none,
                               param_axes=axes)
        s1, _ = bank.admit("v1", dm1)
        s2, _ = bank.admit("v2", dm2)
        vidx = jnp.asarray([0, s1, s2, s1], jnp.int32)
        pf = jax.jit(lambda p, bk, vi, b: model.prefill(
            p, b, 32, overlay=bk, variant_idx=vi))
        dc = jax.jit(lambda p, bk, vi, t, c: model.decode_step(
            p, t, c, overlay=bk, variant_idx=vi))
        lg, cache = pf(params, bank.tree, vidx, batch)
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
        dl, _ = dc(params, bank.tree, vidx, tok, cache)
        return np.asarray(lg), np.asarray(dl)

    want_pre, want_dec = run(None)
    got_pre, got_dec = run(mesh)
    scale = float(np.max(np.abs(want_pre)))
    tol = 1e-4 * max(scale, 1.0)
    assert float(np.max(np.abs(got_pre - want_pre))) < tol
    assert float(np.max(np.abs(got_dec - want_dec))) < tol
    np.testing.assert_array_equal(got_pre.argmax(-1), want_pre.argmax(-1))
    np.testing.assert_array_equal(got_dec.argmax(-1), want_dec.argmax(-1))


# ---------------------------------------------------------------------------
# engine / deployment end to end
# ---------------------------------------------------------------------------

def test_engine_sharded_greedy_token_parity():
    """Acceptance: the continuous-batching engine on a (2, 2) mesh emits
    bit-identical greedy tokens to the single-device engine for a mixed
    base + 2-variant workload (incl. slot reuse: more requests than
    lanes)."""
    mesh = _mesh22()
    model, base, axes, dm1, dm2 = _pair(layers=2)

    def run(mesh_or_none):
        dep = Deployment(model, base, batch_size=2, prompt_len=8,
                         max_len=32, bank_size=4, mesh=mesh_or_none,
                         param_axes=axes if mesh_or_none else None)
        dep.publish("v1", dm1)
        dep.publish("v2", dm2)
        rids = [dep.submit(np.arange(1, 7), variant=v, max_new_tokens=m)
                for v, m in [("v1", 3), ("__base__", 5), ("v2", 2),
                             ("v1", 4), ("v2", 3)]]
        dep.drain()
        assert dep.active() == 0 and dep.pending() == 0
        return [dep.result(r).out_tokens for r in rids]

    assert run(mesh) == run(None)


def test_engine_sharded_group_mode_parity():
    """The group scheduler (dense + fused residency) also runs sharded:
    same tokens as single-device for both residency modes."""
    mesh = _mesh22()
    model, base, axes, dm1, _ = _pair(layers=2)

    def run(mode, mesh_or_none):
        kw = {}
        if mesh_or_none is not None:
            rules = S.rules_for("decode")
            param_sh = S.tree_shardings(base, axes, rules, mesh_or_none)
            kw = dict(param_shardings=param_sh, mesh=mesh_or_none,
                      param_axes=axes)
            params = jax.device_put(base, param_sh)
        else:
            params = base
        reg = VariantRegistry(params, mode=mode, max_resident=4, **kw)
        reg.register("v1", dm1)
        eng = ServingEngine(model, reg, batch_size=2, prompt_len=8,
                            max_len=32, scheduler="group",
                            mesh=mesh_or_none)
        rids = [eng.submit(np.arange(1, 7), variant=v, max_new_tokens=3)
                for v in ["v1", "__base__", "v1"]]
        eng.run_until_drained()
        return [eng.result(r).out_tokens for r in rids]

    for mode in ("fused", "dense"):
        assert run(mode, mesh) == run(mode, None), mode


def test_registry_bank_hotswap_sharded():
    """Versioned hot-swap over the sharded bank: update moves the pointer,
    rollback re-admits as a bank hit, tokens match the unsharded path."""
    mesh = _mesh22()
    model, base, axes, dm1, dm2 = _pair(layers=2)

    def run(mesh_or_none):
        dep = Deployment(model, base, batch_size=2, prompt_len=8,
                         max_len=32, bank_size=4, mesh=mesh_or_none,
                         param_axes=axes if mesh_or_none else None)
        dep.publish("v", dm1)
        out = []
        r1 = dep.submit(np.arange(1, 7), variant="v", max_new_tokens=3)
        dep.drain()
        out.append(dep.result(r1).out_tokens)
        dep.update("v", dm2)
        r2 = dep.submit(np.arange(1, 7), variant="v", max_new_tokens=3)
        dep.drain()
        out.append(dep.result(r2).out_tokens)
        dep.rollback("v")
        hits_before = dep.stats["hits"]
        r3 = dep.submit(np.arange(1, 7), variant="v", max_new_tokens=3)
        dep.drain()
        out.append(dep.result(r3).out_tokens)
        return out, dep.stats["hits"] - hits_before

    want, _ = run(None)
    got, hits = run(mesh)
    assert got == want
    assert got[0] == got[2]              # rollback serves v1 again (tokens
                                         # of v1/v2 may coincide on a toy
                                         # model — only v1==v1 is contract)
    assert hits >= 1                     # rollback re-admitted as bank hit
