"""Checkpoint/restart, preemption, corruption, elastic, grad compression."""
import dataclasses
import json
import pathlib
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.distributed import compression as GC
from repro.models import build_model
from repro.train.loop import LoopConfig, Trainer
from repro.train.step import init_train_state


def _tiny_model():
    cfg = dataclasses.replace(get_config("starcoder2-3b").reduced(),
                              num_layers=2, remat=False,
                              compute_dtype="float32")
    return build_model(cfg)


def test_checkpoint_roundtrip(tmp_path):
    model = _tiny_model()
    state = init_train_state(model, jax.random.PRNGKey(0))
    mgr = CheckpointManager(tmp_path, keep=2)
    mgr.save(10, state)
    step, restored = mgr.restore_latest(state)
    assert step == 10
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_retention_keeps_last_n(tmp_path):
    model = _tiny_model()
    state = init_train_state(model, jax.random.PRNGKey(0))
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (10, 20, 30, 40):
        mgr.save(s, state)
    assert mgr.list_steps() == [30, 40]


def test_corrupt_checkpoint_skipped(tmp_path):
    model = _tiny_model()
    state = init_train_state(model, jax.random.PRNGKey(0))
    mgr = CheckpointManager(tmp_path, keep=5)
    mgr.save(10, state)
    mgr.save(20, state)
    # corrupt the newest
    arrs = dict(np.load(tmp_path / "step_00000020" / "arrays.npz"))
    k = next(iter(arrs))
    arrs[k] = arrs[k] + 1.0
    np.savez(tmp_path / "step_00000020" / "arrays.npz", **arrs)
    step, _ = mgr.restore_latest(state)
    assert step == 10  # fell back past the corrupt one


def test_preemption_resume_bit_exact(tmp_path):
    """Run 30 steps straight vs (preempt at 13 → resume): identical final
    loss trajectory, because data is a pure function of the step."""
    model = _tiny_model()
    lcfg = LoopConfig(total_steps=30, ckpt_every=10, batch_size=2,
                      seq_len=32, peak_lr=1e-3)
    t_straight = Trainer(model, tmp_path / "a", lcfg)
    res_a = t_straight.run()

    t1 = Trainer(model, tmp_path / "b", lcfg)
    res_b1 = t1.run(interrupt_at=13)
    assert res_b1["interrupted"] and res_b1["completed"] == 13
    t2 = Trainer(model, tmp_path / "b", lcfg)
    res_b2 = t2.run()
    assert res_b2["completed"] == 30
    # trajectories match after the resume point
    np.testing.assert_allclose(res_a["losses"][-5:], res_b2["losses"][-5:],
                               rtol=1e-4, atol=1e-5)


def test_grad_compression_preserves_convergence(tmp_path):
    model = _tiny_model()
    base = LoopConfig(total_steps=25, ckpt_every=100, batch_size=2,
                      seq_len=32, peak_lr=1e-3)
    res_fp = Trainer(model, tmp_path / "fp", base).run()
    res_c = Trainer(model, tmp_path / "c",
                    dataclasses.replace(base, grad_compress=True)).run()
    # both converge: final loss well below initial, compressed within 25%
    assert res_fp["losses"][-1] < res_fp["losses"][0]
    assert res_c["losses"][-1] < res_c["losses"][0]
    assert res_c["losses"][-1] < res_fp["losses"][-1] * 1.25


def test_wire_bytes_accounting():
    g = jnp.zeros((256, 512))
    comp, full = GC.wire_bytes(g)
    assert full == 4 * 256 * 512
    assert comp == 256 * 512 // 8 + 2 * 256
    assert full / comp > 15


def test_quantize_dequantize_ef_reduces_error():
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (64, 128))
    transform, init = GC.make_ef_transform()
    ef = init({"w": g})
    # repeated identical grads: with EF the *accumulated* applied update
    # approaches the true accumulated gradient
    applied = jnp.zeros_like(g)
    grads = {"w": g}
    for _ in range(8):
        out, ef = transform(grads, ef)
        applied = applied + out["w"]
    rel = float(jnp.linalg.norm(applied - 8 * g) / jnp.linalg.norm(8 * g))
    one_shot, _ = transform(grads, init({"w": g}))
    rel_one = float(jnp.linalg.norm(one_shot["w"] - g) / jnp.linalg.norm(g))
    assert rel < rel_one  # error feedback beats memoryless quantisation


def test_elastic_remesh_smaller_data_axis():
    from repro.distributed.sharding import rules_for
    from repro.train.loop import remesh
    model = _tiny_model()
    state = init_train_state(model, jax.random.PRNGKey(0))
    mesh, state_sh = remesh(model, state, None, new_data=1, new_model=1,
                            rules=rules_for("train"))
    # shardings resolve for every leaf
    assert len(jax.tree.leaves(state_sh,
                               is_leaf=lambda x: hasattr(x, "spec"))) == \
        len(jax.tree.leaves(state))
