"""Layer-level unit tests: rmsnorm custom VJP vs autodiff reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import rmsnorm


def _ref_rmsnorm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


@pytest.mark.parametrize("shape", [(4, 8), (2, 3, 16), (5, 64)])
def test_rmsnorm_forward_matches_reference(shape):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, shape)
    scale = 1.0 + 0.1 * jax.random.normal(jax.random.PRNGKey(1), shape[-1:])
    np.testing.assert_allclose(np.asarray(rmsnorm(x, scale)),
                               np.asarray(_ref_rmsnorm(x, scale)),
                               rtol=1e-5, atol=1e-5)


def test_rmsnorm_gradients_match_reference():
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (3, 4, 32))
    scale = 1.0 + 0.1 * jax.random.normal(jax.random.PRNGKey(3), (32,))

    def loss_new(x, s):
        return jnp.sum(jnp.sin(rmsnorm(x, s).astype(jnp.float32)))

    def loss_ref(x, s):
        return jnp.sum(jnp.sin(_ref_rmsnorm(x, s).astype(jnp.float32)))

    gx_n, gs_n = jax.grad(loss_new, argnums=(0, 1))(x, scale)
    gx_r, gs_r = jax.grad(loss_ref, argnums=(0, 1))(x, scale)
    np.testing.assert_allclose(np.asarray(gx_n), np.asarray(gx_r),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(gs_n), np.asarray(gs_r),
                               rtol=2e-4, atol=2e-4)


def test_rmsnorm_multidim_scale_gradients():
    """Per-head (H, hd) scales (xlstm out_norm) must round-trip the VJP."""
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 6, 4, 8))
    scale = 1.0 + 0.1 * jax.random.normal(jax.random.PRNGKey(6), (4, 8))

    def loss_new(x, s):
        return jnp.sum(jnp.sin(rmsnorm(x, s).astype(jnp.float32)))

    def loss_ref(x, s):
        return jnp.sum(jnp.sin(_ref_rmsnorm(x, s).astype(jnp.float32)))

    gx_n, gs_n = jax.grad(loss_new, argnums=(0, 1))(x, scale)
    gx_r, gs_r = jax.grad(loss_ref, argnums=(0, 1))(x, scale)
    assert gs_n.shape == scale.shape
    np.testing.assert_allclose(np.asarray(gx_n), np.asarray(gx_r),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(gs_n), np.asarray(gs_r),
                               rtol=2e-4, atol=2e-4)


def test_rmsnorm_bf16_cotangent_stays_bf16():
    """The design property: bf16 in → bf16 dx (no fp32 promotion)."""
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 8, 16)).astype(jnp.bfloat16)
    scale = jnp.ones((16,), jnp.float32)
    dx = jax.grad(lambda x: jnp.sum(
        rmsnorm(x, scale).astype(jnp.float32)))(x)
    assert dx.dtype == jnp.bfloat16
