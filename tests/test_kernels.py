"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles.

Sweeps shapes / dtypes / axis modes per the assignment ("For each Pallas
kernel, sweep shapes/dtypes and assert_allclose against the ref.py oracle").
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import delta as D
from repro.kernels import ops as K
from repro.kernels import ref as R


def _case(key, d_out, d_in, mode, dtype):
    k1, k2 = jax.random.split(key)
    wb = (jax.random.normal(k1, (d_out, d_in), jnp.float32) * 0.1).astype(dtype)
    delta = 0.01 * jax.random.normal(k2, (d_out, d_in), jnp.float32)
    packed = D.pack_signs(D.sign_mask(delta))
    v = D.init_scale(delta, mode).astype(jnp.float32)
    return packed, v, wb


SHAPES = [(8, 16), (16, 128), (128, 256), (256, 512), (100, 40), (24, 72)]
MODES = ["row", "col", "scalar"]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_unpack_apply_sweep(shape, mode, dtype):
    d_out, d_in = shape
    packed, v, wb = _case(jax.random.PRNGKey(hash(shape) % 2**31), d_out, d_in, mode, dtype)
    got = K.unpack_apply(packed, v, wb, mode=mode, out_dtype=jnp.float32)
    want = R.unpack_apply_ref(packed, v, wb, mode, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(4, 8, 16), (8, 16, 128), (16, 128, 256), (32, 100, 40)])
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_bitlinear_sweep(shape, mode, dtype):
    m, n, k_dim = shape
    packed, v, wb = _case(jax.random.PRNGKey(hash(shape) % 2**31), n, k_dim, mode, dtype)
    x = (jax.random.normal(jax.random.PRNGKey(9), (m, k_dim)) * 0.5).astype(dtype)
    got = K.bitlinear(x, packed, v, wb, mode=mode)
    want = R.bitlinear_ref(x, packed, v, wb, mode)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_bitlinear_leading_batch_dims():
    packed, v, wb = _case(jax.random.PRNGKey(0), 32, 64, "row", jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 64))
    got = K.bitlinear(x, packed, v, wb, mode="row")
    assert got.shape == (2, 3, 32)
    want = R.bitlinear_ref(x.reshape(-1, 64), packed, v, wb, "row").reshape(2, 3, 32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(4, 8, 16), (8, 16, 128), (16, 128, 256)])
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_bitlinear_axes_sweep(shape, mode, dtype):
    """Dual-axis kernel vs single-mode oracle: zeroing the unselected
    vector must reduce the v_row+v_col sum to the selected scale."""
    m, n, k_dim = shape
    packed, v, wb = _case(jax.random.PRNGKey(hash(shape) % 2**31), n, k_dim,
                          mode, dtype)
    if mode == "row":
        vr, vc = v, jnp.zeros((k_dim,), jnp.float32)
    elif mode == "col":
        vr, vc = jnp.zeros((n,), jnp.float32), v
    else:   # scalar broadcasts into v_row (overlay convention)
        vr = jnp.broadcast_to(v, (n,))
        vc = jnp.zeros((k_dim,), jnp.float32)
    x = (jax.random.normal(jax.random.PRNGKey(9), (m, k_dim)) * 0.5).astype(dtype)
    got = K.bitlinear_axes(x, packed, vr, vc, wb)
    want = R.bitlinear_ref(x, packed, v, wb, mode)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_bitlinear_axes_mixed_vectors():
    """Both vectors non-zero: v_eff[n,k] = v_row[n] + v_col[k]."""
    n, k_dim, m = 24, 72, 8
    packed, _, wb = _case(jax.random.PRNGKey(3), n, k_dim, "row", jnp.float32)
    vr = jnp.abs(jax.random.normal(jax.random.PRNGKey(4), (n,)))
    vc = jnp.abs(jax.random.normal(jax.random.PRNGKey(5), (k_dim,)))
    x = jax.random.normal(jax.random.PRNGKey(6), (m, k_dim))
    got = K.bitlinear_axes(x, packed, vr, vc, wb)
    want = R.bitlinear_axes_ref(x, packed, vr, vc, wb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    d_out=st.integers(1, 8).map(lambda i: i * 16),
    d_in=st.integers(1, 8).map(lambda i: i * 16),
    mode=st.sampled_from(MODES),
    seed=st.integers(0, 2**31 - 1),
)
def test_unpack_apply_property(d_out, d_in, mode, seed):
    packed, v, wb = _case(jax.random.PRNGKey(seed), d_out, d_in, mode, jnp.float32)
    got = K.unpack_apply(packed, v, wb, mode=mode, out_dtype=jnp.float32)
    want = R.unpack_apply_ref(packed, v, wb, mode, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_block_picker_alignment():
    assert K._pick_block(4096, 512, multiple=8) == 512
    assert K._pick_block(100, 512, multiple=1) == 100
    assert K._pick_block(40, 512, multiple=8) == 40
    assert K._pick_block(24, 16, multiple=8) == 8
