"""HLO static analyzer: trip-count-aware flops/bytes/collectives."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import hlo_cost as HC


def _hlo(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_scanned_matmul_flops_multiplied_by_trips():
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    x = jax.ShapeDtypeStruct((64, 256), jnp.float32)

    def once(w, x):
        return jnp.tanh(x @ w)

    def scanned(w, x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    f1 = HC.analyze(_hlo(once, w, x))["flops"]
    f7 = HC.analyze(_hlo(scanned, w, x))["flops"]
    expected = 2 * 64 * 256 * 256
    assert abs(f1 - expected) / expected < 0.01, f1
    assert abs(f7 - 7 * expected) / (7 * expected) < 0.01, f7


def test_nested_scan_multiplies():
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 128), jnp.float32)

    def nested(w, x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    f = HC.analyze(_hlo(nested, w, x))["flops"]
    expected = 15 * 2 * 8 * 128 * 128
    assert abs(f - expected) / expected < 0.01, f


def test_unrolled_matches_scanned_model():
    """Same computation scanned vs unrolled must cost the same."""
    ws = jax.ShapeDtypeStruct((4, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)

    def scanned(ws, x):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    def unrolled(ws, x):
        for i in range(4):
            x = jnp.tanh(x @ ws[i])
        return x

    fs = HC.analyze(_hlo(scanned, ws, x))["flops"]
    fu = HC.analyze(_hlo(unrolled, ws, x))["flops"]
    assert abs(fs - fu) / fu < 0.01, (fs, fu)


def test_collectives_counted_with_trips():
    import os
    # need >1 device for real collectives; spawn subprocess with forced devices
    import subprocess, sys, textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.distributed import hlo_cost as HC
        mesh = Mesh(np.asarray(jax.devices()[:4]).reshape(4), ("model",))
        w_s = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        x_s = jax.ShapeDtypeStruct((8, 128), jnp.float32)
        ws_sh = NamedSharding(mesh, P("model", None))  # row-sharded weight
        x_sh = NamedSharding(mesh, P())
        def f(w, x):
            def body(c, _):
                # contraction over the sharded dim -> per-iteration all-reduce
                y = jnp.tanh(c @ w)
                y = jax.lax.with_sharding_constraint(
                    y, NamedSharding(mesh, P()))
                return y, None
            y, _ = jax.lax.scan(body, x, None, length=6)
            return y
        txt = jax.jit(f, in_shardings=(ws_sh, x_sh)).lower(w_s, x_s).compile().as_text()
        res = HC.analyze(txt)
        agc = sum(res["collectives"]["counts"].values())
        assert agc >= 6, (res["collectives"]["counts"],
                          [l for l in txt.splitlines() if "all-" in l][:5])
        print("OK", agc)
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.getcwd().replace("/tests", ""))
    assert r.returncode == 0 and "OK" in r.stdout, r.stderr[-2000:]
