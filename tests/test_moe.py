"""MoE routing unit tests: capacity semantics, dropless exactness, aux."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import moe as M
from repro.models.param import split as psplit


def _cfg(**kw):
    base = get_config("deepseek-moe-16b").reduced()
    return dataclasses.replace(base, **kw)


def _params(cfg, key=0):
    p = M.moe_init(jax.random.PRNGKey(key), cfg)
    return jax.tree.map(lambda q: q.value, p,
                        is_leaf=lambda q: hasattr(q, "axes"))


def _dense_reference(p, x, cfg):
    """Dropless oracle: every token through its top-k experts, computed
    densely over all experts then masked."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = (xf @ p["router"].T).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    top_val, top_idx = jax.lax.top_k(probs, cfg.top_k)
    top_val = top_val / top_val.sum(-1, keepdims=True)
    h = jax.nn.silu(jnp.einsum("td,efd->tef", xf, p["w_gate"])) * \
        jnp.einsum("td,efd->tef", xf, p["w_up"])
    y_all = jnp.einsum("tef,edf->ted", h, p["w_down"])  # (T,E,D)
    w = jnp.zeros((xf.shape[0], cfg.num_experts))
    w = jax.vmap(lambda wr, idx, val: wr.at[idx].set(val))(w, top_idx, top_val)
    y = jnp.einsum("te,ted->td", w, y_all)
    if "shared" in p:
        from repro.models.layers import mlp_apply
        y = y + mlp_apply(p["shared"], xf)
    return y.reshape(b, s, d)


def test_dropless_capacity_matches_dense_reference():
    cfg = _cfg(capacity_factor=float(8))  # cap == group size: no drops
    p = _params(cfg)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, aux = M.moe_apply(p, x, cfg)
    y_ref = _dense_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=5e-4, atol=5e-4)


def test_capacity_drops_tokens_when_tight():
    cfg = _cfg(capacity_factor=0.25)
    p = _params(cfg)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model))
    y_tight, _ = M.moe_apply(p, x, cfg)
    y_loose, _ = M.moe_apply(p, x, dataclasses.replace(
        cfg, capacity_factor=8.0))
    # outputs must differ (some tokens dropped) but stay finite
    assert not np.allclose(np.asarray(y_tight), np.asarray(y_loose))
    assert np.isfinite(np.asarray(y_tight)).all()


def test_aux_loss_uniform_router_near_one():
    """Perfectly balanced router -> aux ≈ 1 (Switch normalisation)."""
    cfg = _cfg()
    p = _params(cfg)
    p = dict(p, router=jnp.zeros_like(p["router"]))  # uniform probs
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 32, cfg.d_model))
    _, aux = M.moe_apply(p, x, cfg)
    assert 0.8 < float(aux) < 1.3, float(aux)


def test_group_tokens_shapes():
    x = jnp.zeros((4, 128, 8))
    xg, orig = M._group_tokens(x, target_group=64)
    assert xg.shape[0] * xg.shape[1] == 4 * 128
    assert orig == (4, 128, 8)


def test_moe_gradients_flow_to_all_parts():
    cfg = _cfg(capacity_factor=8.0)
    p = _params(cfg)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(4), (1, 16, cfg.d_model))

    def loss(p):
        y, aux = M.moe_apply(p, x, cfg)
        return jnp.sum(y.astype(jnp.float32) ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    for name in ("router", "w_gate", "w_up", "w_down"):
        assert float(jnp.sum(jnp.abs(g[name]))) > 0, name
