"""Optional-dependency shim: run unit tests even without ``hypothesis``.

The property-based tests decorate with @given/@settings and build
strategies via ``st``; when hypothesis is not installed (the CPU smoke
container does not ship it) those tests skip cleanly instead of killing
collection for the whole module.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:                    # pragma: no cover - env dependent
    import pytest

    HAVE_HYPOTHESIS = False

    class _Chain:
        """Stand-in strategy: every attribute/call returns itself, so
        module-level strategy expressions still evaluate."""
        def __getattr__(self, _name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _Chain()

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kwargs):
        return lambda fn: fn
