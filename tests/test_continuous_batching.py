"""Mixed-variant continuous batching: banked kernel, overlay bank,
slot scheduler (DESIGN.md §9).

Parity contract: a heterogeneous decode batch (base + fused variants, one
``variant_idx`` per row) must match per-variant fused serving row for row —
the banked kernel computes each row's Ŵ from the same packed mask + axis
vectors, and banked extras store the same fp16-rounded values the
per-variant params view carries.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import calibration as C
from repro.core import delta as D
from repro.core import loader as L
from repro.kernels import ops as K
from repro.kernels import ref as R
from repro.models import build_model
from repro.models.param import split
from repro.serving import ServingEngine, VariantRegistry
from repro.serving.variants import OverlayBank


# ---------------------------------------------------------------------------
# banked kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,k,v", [(4, 16, 32, 2), (8, 32, 64, 5),
                                     (6, 24, 40, 3)])
def test_banked_kernel_matches_ref(m, n, k, v):
    rng = np.random.default_rng(m + n + k)
    packed = jnp.asarray(rng.integers(0, 256, (v, n, k // 8)), jnp.uint8)
    v_row = jnp.asarray(rng.normal(size=(v, n)), jnp.float16).at[0].set(0)
    v_col = jnp.asarray(rng.normal(size=(v, k)), jnp.float16).at[0].set(0)
    wb = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    vidx = jnp.asarray(rng.integers(0, v, (m,)), jnp.int32)
    got = K.bitlinear_axes_banked(x, vidx, packed, v_row, v_col, wb)
    want = R.bitlinear_axes_banked_ref(x, vidx, packed, v_row, v_col, wb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_banked_kernel_rows_match_single_variant_kernel():
    """Each row of a mixed batch equals the per-variant fused kernel run on
    the same rows; slot-0 rows equal the plain base GEMM."""
    rng = np.random.default_rng(0)
    v, n, k, m = 4, 32, 64, 8
    packed = jnp.asarray(rng.integers(0, 256, (v, n, k // 8)), jnp.uint8)
    v_row = jnp.asarray(rng.normal(size=(v, n)), jnp.float16).at[0].set(0)
    v_col = jnp.asarray(rng.normal(size=(v, k)), jnp.float16).at[0].set(0)
    wb = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    vidx = jnp.asarray([0, 1, 2, 3, 0, 1, 2, 3], jnp.int32)
    y = K.bitlinear_axes_banked(x, vidx, packed, v_row, v_col, wb)
    base = x @ wb.T
    np.testing.assert_allclose(np.asarray(y[vidx == 0]),
                               np.asarray(base[vidx == 0]),
                               rtol=1e-5, atol=1e-5)
    for vi in range(1, v):
        ys = K.bitlinear_axes(x, packed[vi], v_row[vi], v_col[vi], wb)
        rows = np.asarray(vidx == vi)
        np.testing.assert_allclose(np.asarray(y)[rows],
                                   np.asarray(ys)[rows],
                                   rtol=1e-5, atol=1e-5)


def test_banked_kernel_leading_dims_broadcast():
    """(B, S, K) input with (B,) variant_idx: every row of a sequence uses
    its batch lane's variant."""
    rng = np.random.default_rng(1)
    v, n, k = 3, 16, 32
    packed = jnp.asarray(rng.integers(0, 256, (v, n, k // 8)), jnp.uint8)
    v_row = jnp.asarray(rng.normal(size=(v, n)), jnp.float16).at[0].set(0)
    v_col = jnp.asarray(rng.normal(size=(v, k)), jnp.float16).at[0].set(0)
    wb = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 4, k)), jnp.float32)
    vidx = jnp.asarray([1, 2], jnp.int32)
    got = K.bitlinear_axes_banked(x, vidx, packed, v_row, v_col, wb)
    flat = K.bitlinear_axes_banked(
        x.reshape(8, k), jnp.repeat(vidx, 4), packed, v_row, v_col, wb)
    np.testing.assert_allclose(np.asarray(got).reshape(8, n),
                               np.asarray(flat), rtol=1e-6, atol=1e-6)


def _banked_operands(rng, v, n, k):
    packed = jnp.asarray(rng.integers(0, 256, (v, n, k // 8)), jnp.uint8)
    v_row = jnp.asarray(rng.normal(size=(v, n)), jnp.float16).at[0].set(0)
    v_col = jnp.asarray(rng.normal(size=(v, k)), jnp.float16).at[0].set(0)
    wb = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    return packed, v_row, v_col, wb


@pytest.mark.parametrize("t", [2, 4])
@pytest.mark.parametrize("dispatch", ["shard_map", "gspmd"])
def test_banked_kernel_multi_token_decode_shapes(t, dispatch):
    """(B, T, K) banked decode — the speculative verify_step shape
    (DESIGN.md §15): every row must be BIT-IDENTICAL to the T = 1
    per-token call the continuous scheduler makes (anything looser breaks
    the speculative scheduler's exactness guarantee), and allclose vs the
    dense oracle.  Both kernel lowerings: the shard_map per-shard path
    (1x1 mesh) and the global/GSPMD path."""
    from jax.sharding import Mesh
    from repro.distributed import sharding as S
    from repro.kernels import dispatch as KD
    from repro.kernels import ref as R

    rng = np.random.default_rng(10 + t)
    v, n, k, b = 3, 32, 64, 4
    packed, v_row, v_col, wb = _banked_operands(rng, v, n, k)
    x = jnp.asarray(rng.normal(size=(b, t, k)), jnp.float32)
    vidx = jnp.asarray(rng.integers(0, v, (b,)), jnp.int32)

    if dispatch == "shard_map":
        mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                    ("data", "model"))
        ctx = S.shard_ctx(mesh, S.rules_for("decode"))
    else:
        ctx = KD.no_dispatch()
    with ctx:
        got = K.bitlinear_axes_banked(x, vidx, packed, v_row, v_col, wb)
        per_tok = jnp.stack(
            [K.bitlinear_axes_banked(x[:, j], vidx, packed, v_row, v_col,
                                     wb) for j in range(t)], axis=1)
    assert got.shape == (b, t, n)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(per_tok))
    want = R.bitlinear_axes_banked_ref(
        x.reshape(b * t, k), jnp.repeat(vidx, t), packed, v_row, v_col, wb)
    np.testing.assert_allclose(np.asarray(got).reshape(b * t, n),
                               np.asarray(want), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# model-level mixed-variant parity
# ---------------------------------------------------------------------------

def _pair3(arch: str, layers: int = 2):
    """Base + two perturbation fine-tunes (fp32 compute for tight parity)."""
    cfg = get_config(arch).reduced()
    if layers:
        cfg = dataclasses.replace(cfg, num_layers=layers)
    cfg = dataclasses.replace(cfg, compute_dtype="float32", remat=False)
    model = build_model(cfg)
    base, _ = split(model.init(jax.random.PRNGKey(0)))
    pert, _ = split(model.init(jax.random.PRNGKey(1)))
    ft1 = jax.tree.map(lambda b, f: b + 0.05 * f, base, pert)
    ft2 = jax.tree.map(lambda b, f: b - 0.05 * f, base, pert)
    return model, base, C.compress(base, ft1), C.compress(base, ft2)


def _batch(model, bs=3, s=8, seed=7):
    cfg = model.cfg
    batch = {"tokens": jnp.asarray(np.random.default_rng(seed).integers(
        1, cfg.vocab_size, size=(bs, s)), jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = jnp.zeros((bs, cfg.encoder_frames, cfg.d_model),
                                    jnp.float32)
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.zeros(
            (bs, cfg.num_image_tokens, cfg.d_model), jnp.float32)
    return batch


def _per_variant_rows(model, base, dms, batch, max_len=32):
    """Reference: serve each row's variant separately on the PR-1 fused
    path; returns (prefill logits, one-step decode logits) stacked."""
    pre_rows, dec_rows = [], []
    for row, dm in enumerate(dms):
        if dm is None:
            params, ov = base, None
            pl, cc = jax.jit(lambda p, b: model.prefill(p, b, max_len)
                             )(params, batch)
            tok = jnp.argmax(pl, -1).astype(jnp.int32)
            dl, _ = jax.jit(model.decode_step)(params, tok, cc)
        else:
            params, ov, _ = L.device_put_overlay(base, dm)
            pl, cc = jax.jit(lambda p, o, b: model.prefill(
                p, b, max_len, overlay=o))(params, ov, batch)
            tok = jnp.argmax(pl, -1).astype(jnp.int32)
            dl, _ = jax.jit(lambda p, o, t, c: model.decode_step(
                p, t, c, overlay=o))(params, ov, tok, cc)
        pre_rows.append(pl[row])
        dec_rows.append(dl[row])
    return jnp.stack(pre_rows), jnp.stack(dec_rows)


@pytest.mark.parametrize("arch,layers", [("qwen3-8b", 2),
                                         ("deepseek-7b", 2)])
def test_mixed_decode_batch_parity_vs_per_variant(arch, layers):
    """Heterogeneous (base + 2 fused variants) prefill + decode batch vs
    per-variant fused serving: logits agree per row to fp32 rounding and
    greedy tokens agree exactly."""
    model, base, dm1, dm2 = _pair3(arch, layers)
    bank = OverlayBank(base, 4)
    s1, _ = bank.admit("v1", dm1)
    s2, _ = bank.admit("v2", dm2)
    batch = _batch(model)
    vidx = jnp.asarray([0, s1, s2], jnp.int32)

    lg, cache = jax.jit(lambda p, bk, vi, b: model.prefill(
        p, b, 32, overlay=bk, variant_idx=vi))(base, bank.tree, vidx, batch)
    tok = jnp.argmax(lg, -1).astype(jnp.int32)
    dl, _ = jax.jit(lambda p, bk, vi, t, c: model.decode_step(
        p, t, c, overlay=bk, variant_idx=vi))(base, bank.tree, vidx, tok,
                                              cache)

    want_pre, want_dec = _per_variant_rows(model, base, [None, dm1, dm2],
                                           batch)
    scale = float(jnp.max(jnp.abs(want_pre)))
    tol = 1e-4 * max(scale, 1.0)
    assert float(jnp.max(jnp.abs(lg - want_pre))) < tol
    assert float(jnp.max(jnp.abs(dl - want_dec))) < tol
    # greedy tokens: exact
    np.testing.assert_array_equal(np.asarray(jnp.argmax(lg, -1)),
                                  np.asarray(jnp.argmax(want_pre, -1)))
    np.testing.assert_array_equal(np.asarray(jnp.argmax(dl, -1)),
                                  np.asarray(jnp.argmax(want_dec, -1)))


@pytest.mark.parametrize("arch", ["whisper-base", "xlstm-350m", "zamba2-7b"])
def test_mixed_forward_parity_families(arch):
    """The other families serve heterogeneous rows through the same banked
    overlay (incl. banked extras: convs, recurrent weights, SSD params)."""
    model, base, dm1, dm2 = _pair3(arch, layers=0)
    bank = OverlayBank(base, 4)
    s1, _ = bank.admit("v1", dm1)
    s2, _ = bank.admit("v2", dm2)
    batch = _batch(model)
    vidx = jnp.asarray([0, s1, s2], jnp.int32)
    lg = jax.jit(lambda p, bk, vi, b: model.forward(
        p, b, overlay=bk, variant_idx=vi)[0])(base, bank.tree, vidx, batch)
    for row, dm in enumerate([None, dm1, dm2]):
        if dm is None:
            want = jax.jit(lambda p, b: model.forward(p, b)[0])(base, batch)
        else:
            params, ov, _ = L.device_put_overlay(base, dm)
            want = jax.jit(lambda p, o, b: model.forward(
                p, b, overlay=o)[0])(params, ov, batch)
        scale = float(jnp.max(jnp.abs(want)))
        tol = 1e-4 * max(scale, 1.0)
        assert float(jnp.max(jnp.abs(lg[row] - want[row]))) < tol, (arch,
                                                                    row)


def test_moe_mixed_batch_jittable_and_uniform_rows_match():
    """MoE falls back to masked per-variant expert application: a mixed
    batch stays jittable; a uniform batch (all rows one variant) matches
    the single-variant fused path exactly (same capacity competition)."""
    model, base, dm1, dm2 = _pair3("deepseek-moe-16b", 2)
    bank = OverlayBank(base, 4)
    s1, _ = bank.admit("v1", dm1)
    s2, _ = bank.admit("v2", dm2)
    batch = _batch(model)
    fwd = jax.jit(lambda p, bk, vi, b: model.forward(
        p, b, overlay=bk, variant_idx=vi)[0])
    # uniform rows -> identical routing/capacity as per-variant serving
    lg_uni = fwd(base, bank.tree, jnp.full((3,), s1, jnp.int32), batch)
    params, ov, _ = L.device_put_overlay(base, dm1)
    want = jax.jit(lambda p, o, b: model.forward(p, b, overlay=o)[0])(
        params, ov, batch)
    scale = float(jnp.max(jnp.abs(want)))
    assert float(jnp.max(jnp.abs(lg_uni - want))) < 1e-4 * max(scale, 1.0)
    # mixed rows: jittable, finite
    lg_mix = fwd(base, bank.tree, jnp.asarray([0, s1, s2], jnp.int32), batch)
    assert bool(jnp.isfinite(lg_mix).all())


# ---------------------------------------------------------------------------
# overlay bank lifecycle
# ---------------------------------------------------------------------------

def test_bank_admit_pin_evict_slot_reuse():
    model, base, dm1, dm2 = _pair3("deepseek-7b")
    bank = OverlayBank(base, 3)          # base + 2 variant slots
    s1, payload = bank.admit("a", dm1)
    assert s1 == 1 and payload > 0
    s2, _ = bank.admit("b", dm2)
    assert s2 == 2
    assert bank.nbytes() > 0
    # re-admit is a hit (no payload)
    assert bank.admit("a", dm1) == (1, 0)
    # full + everything pinned -> admission refuses
    bank.pin("a"); bank.pin("b")
    with pytest.raises(RuntimeError):
        bank.admit("c", dm1)
    # pinned eviction refuses; unpinned LRU slot is reused
    with pytest.raises(RuntimeError):
        bank.evict("b")
    bank.unpin("b")
    s3, _ = bank.admit("c", dm1)         # evicts "b" (LRU among unpinned)
    assert s3 == 2 and bank.resident() == ["a", "c"]
    assert bank.stats["evictions"] == 1


def test_registry_evict_banked_variant_mid_flight():
    """A banked variant referenced by an in-flight request is pinned:
    registry.evict raises until the request retires."""
    model, base, dm1, dm2 = _pair3("deepseek-7b")
    reg = VariantRegistry(base, mode="fused", bank_size=4)
    reg.register("v1", dm1)
    reg.register("v2", dm2)
    eng = ServingEngine(model, reg, batch_size=2, prompt_len=8, max_len=32,
                        scheduler="continuous")
    rid = eng.submit(np.arange(1, 7), variant="v1", max_new_tokens=4)
    # stage a mid-flight state: admit + prefill without draining
    eng._prefill_admitted(eng._admit_free_slots())
    assert eng.status(rid) == "running"
    with pytest.raises(RuntimeError):
        reg.evict("v1")
    eng.run_until_drained()                      # retires -> unpinned
    assert eng.result(rid).status == "done"
    reg.evict("v1")                              # now fine
    assert "v1" not in reg.bank.resident()


# ---------------------------------------------------------------------------
# slot scheduler
# ---------------------------------------------------------------------------

def test_scheduler_admit_retire_slot_reuse_and_budgets():
    """More requests than lanes, heterogeneous budgets: slots retire the
    moment their budget is exhausted and free lanes admit from the queue;
    every request gets exactly its budget of tokens."""
    model, base, dm1, dm2 = _pair3("deepseek-7b")
    reg = VariantRegistry(base, mode="fused", bank_size=4)
    reg.register("v1", dm1)
    reg.register("v2", dm2)
    eng = ServingEngine(model, reg, batch_size=2, prompt_len=8, max_len=32,
                        scheduler="continuous")
    budgets = [2, 5, 3, 2]
    variants = ["v1", "__base__", "v2", "v1"]
    rids = [eng.submit(np.arange(1, 7), variant=v, max_new_tokens=m)
            for v, m in zip(variants, budgets)]
    eng.run_until_drained()
    for rid, m in zip(rids, budgets):
        r = eng.result(rid)
        assert r.status == "done"
        assert len(r.out_tokens) == m
    assert eng.metrics["admitted"] == 4
    assert eng.metrics["retired"] == 4
    assert eng.metrics["prefills"] >= 2          # slot reuse => extra waves
    assert eng.pending() == 0 and eng.active() == 0


def test_scheduler_slot_reuse_preserves_isolation():
    """A request admitted into a REUSED lane must decode exactly what it
    would decode in a fresh engine (cache-row merge isolates lanes)."""
    model, base, dm1, dm2 = _pair3("deepseek-7b")

    def make_engine():
        reg = VariantRegistry(base, mode="fused", bank_size=4)
        reg.register("v1", dm1)
        reg.register("v2", dm2)
        return ServingEngine(model, reg, batch_size=2, prompt_len=8,
                             max_len=32, scheduler="continuous")

    eng = make_engine()
    eng.submit(np.arange(1, 7), variant="v1", max_new_tokens=2)
    eng.submit(np.arange(2, 8), variant="__base__", max_new_tokens=6)
    late = eng.submit(np.arange(3, 9), variant="v2", max_new_tokens=3)
    eng.run_until_drained()

    solo = make_engine()
    ref = solo.submit(np.arange(3, 9), variant="v2", max_new_tokens=3)
    solo.run_until_drained()
    assert eng.result(late).out_tokens == solo.result(ref).out_tokens


def test_scheduler_matches_grouped_serving_tokens():
    """End to end: mixed continuous batches generate exactly the tokens
    the grouped-by-variant engine generates per request."""
    model, base, dm1, dm2 = _pair3("deepseek-7b")

    def run(scheduler):
        reg = VariantRegistry(base, mode="fused", max_resident=4,
                              bank_size=4)
        reg.register("v1", dm1)
        reg.register("v2", dm2)
        eng = ServingEngine(model, reg, batch_size=2, prompt_len=8,
                            max_len=32, scheduler=scheduler)
        rids = [eng.submit(np.arange(1, 7), variant=v, max_new_tokens=3)
                for v in ["v1", "__base__", "v2", "v1", "v2"]]
        eng.run_until_drained()
        return [eng.result(r).out_tokens for r in rids]

    assert run("continuous") == run("group")


def test_engine_status_accessor_never_raises():
    model, base, dm1, _ = _pair3("deepseek-7b")
    reg = VariantRegistry(base, mode="fused", bank_size=4)
    reg.register("v1", dm1)
    eng = ServingEngine(model, reg, batch_size=2, prompt_len=8, max_len=32,
                        scheduler="continuous")
    rid = eng.submit(np.arange(1, 7), variant="v1", max_new_tokens=2)
    assert eng.status(rid) == "queued"
    assert eng.status(10_000) == "unknown"       # no KeyError
    eng.run_until_drained()
    assert eng.status(rid) == "done"
    # group-mode engines expose the same accessor
    eng2 = ServingEngine(model, reg, batch_size=2, prompt_len=8, max_len=32)
    assert eng2.status(123) == "unknown"
