"""Unit + property tests for the core delta math (repro.core.delta)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import delta as D
from repro.core.bitdelta import DeltaLinear, best_static_axis, reconstruction_error

jax.config.update("jax_enable_x64", False)


def _rand_pair(key, d_out, d_in, scale=0.02):
    k1, k2 = jax.random.split(key)
    wb = jax.random.normal(k1, (d_out, d_in), jnp.float32)
    delta = scale * jax.random.normal(k2, (d_out, d_in), jnp.float32)
    return wb, wb + delta


# ---------------------------------------------------------------------------
# pack / unpack
# ---------------------------------------------------------------------------

def test_pack_unpack_roundtrip():
    key = jax.random.PRNGKey(0)
    signs = jnp.where(jax.random.bernoulli(key, 0.5, (16, 64)), 1, -1).astype(jnp.int8)
    packed = D.pack_signs(signs)
    assert packed.shape == (16, 8) and packed.dtype == jnp.uint8
    out = D.unpack_signs(packed, 64)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(signs, np.float32))


@settings(max_examples=25, deadline=None)
@given(
    d_out=st.integers(1, 12),
    d_in_bytes=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_pack_unpack_property(d_out, d_in_bytes, seed):
    d_in = d_in_bytes * 8
    rng = np.random.default_rng(seed)
    signs = rng.choice(np.array([-1, 1], np.int8), size=(d_out, d_in))
    packed = D.pack_signs(jnp.asarray(signs))
    out = np.asarray(D.unpack_signs(packed, d_in))
    np.testing.assert_array_equal(out, signs.astype(np.float32))


def test_pack_rejects_unpackable():
    with pytest.raises(ValueError):
        D.pack_signs(jnp.ones((4, 7)))


def test_pad_to_packable():
    w = jnp.ones((3, 13))
    padded, orig = D.pad_to_packable(w)
    assert padded.shape == (3, 16) and orig == 13


def test_sign_mask_zeros_map_positive():
    s = D.sign_mask(jnp.array([[-1.0, 0.0, 2.0]]))
    np.testing.assert_array_equal(np.asarray(s), [[-1, 1, 1]])


# ---------------------------------------------------------------------------
# reconstruction identity & error structure
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["row", "col", "scalar"])
def test_reconstruct_shapes(mode):
    wb, wf = _rand_pair(jax.random.PRNGKey(1), 32, 64)
    lin = DeltaLinear.from_pair(wb, wf, mode)
    w_hat = lin.reconstruct()
    assert w_hat.shape == wb.shape
    assert jnp.isfinite(w_hat).all()


def test_exact_recovery_when_delta_is_rank_structure():
    """If ΔW = v_row ⊗ sign pattern exactly, row-mode recovers W_f exactly."""
    key = jax.random.PRNGKey(2)
    k1, k2, k3 = jax.random.split(key, 3)
    wb = jax.random.normal(k1, (16, 24))
    v = jnp.abs(jax.random.normal(k2, (16,))) + 0.1
    signs = jnp.where(jax.random.bernoulli(k3, 0.5, (16, 24)), 1.0, -1.0)
    wf = wb + v[:, None] * signs
    lin = DeltaLinear.from_pair(wb, wf, "row")
    np.testing.assert_allclose(np.asarray(lin.reconstruct()), np.asarray(wf),
                               rtol=1e-3, atol=1e-3)


def test_per_axis_beats_scalar_on_anisotropic_delta():
    """Core paper claim at the weight level: when |ΔW| varies across rows,
    a per-row scale reconstructs better than one scalar."""
    key = jax.random.PRNGKey(3)
    k1, k2, k3 = jax.random.split(key, 3)
    wb = jax.random.normal(k1, (64, 96))
    row_mag = jnp.exp(jax.random.normal(k2, (64,)))  # anisotropic magnitudes
    delta = row_mag[:, None] * jax.random.normal(k3, (64, 96)) * 0.05
    wf = wb + delta
    err_row = float(reconstruction_error(DeltaLinear.from_pair(wb, wf, "row"), wf))
    err_scalar = float(reconstruction_error(DeltaLinear.from_pair(wb, wf, "scalar"), wf))
    assert err_row < err_scalar


def test_best_static_axis_prefers_structured_axis():
    key = jax.random.PRNGKey(4)
    k1, k2, k3 = jax.random.split(key, 3)
    wb = jax.random.normal(k1, (32, 48))
    col_mag = jnp.exp(jax.random.normal(k2, (48,)))
    wf = wb + col_mag[None, :] * jax.random.normal(k3, (32, 48)) * 0.05
    assert best_static_axis(wb, wf) == "col"


# ---------------------------------------------------------------------------
# delta_matmul (on-the-fly) == dense reconstruct matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["row", "col", "scalar"])
def test_delta_matmul_matches_dense(mode):
    key = jax.random.PRNGKey(5)
    wb, wf = _rand_pair(key, 24, 40)
    lin = DeltaLinear.from_pair(wb, wf, mode)
    x = jax.random.normal(jax.random.PRNGKey(6), (7, 40))
    y_ref = lin(x, apply_mode="ref")
    y_dense = lin(x, apply_mode="dense")
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_dense),
                               rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# storage accounting (paper Table 2 structure)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode,extra", [("row", 2 * 128), ("col", 2 * 256), ("scalar", 2)])
def test_artifact_bytes(mode, extra):
    assert D.artifact_bytes(128, 256, mode) == 128 * 256 // 8 + extra


def test_compression_ratio_close_to_16x_for_large_mats():
    # 1-bit mask vs fp16: ratio -> 16x as dims grow (vector is negligible)
    r = D.compression_ratio(4096, 4096, "row")
    assert 15.5 < r < 16.0
