"""Compile-once serving (DESIGN.md §14): persistent compile cache safety,
AOT warmup, and the dispatch-memo LRU bound.

The cache's contract is asymmetric on purpose: a warm entry may only ever
be (a) the right executable or (b) a MISS.  Corruption, truncation,
environment drift and topology changes must all degrade to a clean
compile — never an exception on the serving path, never a wrong program.
Warm-path value is gated the same way the CI benchmark gates it: zero
compiles after warmup, bit-exact greedy tokens versus the cold path.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import calibration as C
from repro.core import compile_cache as CC
from repro.kernels import dispatch as _dp
from repro.models import build_model
from repro.models.param import split
from repro.serving import Deployment

PROMPT = np.arange(1, 7)


@pytest.fixture(autouse=True)
def _no_xla_cache_leak():
    """CompileCache points jax's own persistent cache at its directory
    (the fallback layer); tmp dirs die with the test, so unhook the
    global config afterwards."""
    yield
    try:
        jax.config.update("jax_compilation_cache_dir", None)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 1.0)
    except Exception:
        pass


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_config("deepseek-7b").reduced(),
                              num_layers=1, compute_dtype="float32",
                              remat=False)
    model = build_model(cfg)
    base, _ = split(model.init(jax.random.PRNGKey(0)))
    pert, _ = split(model.init(jax.random.PRNGKey(1)))
    ft = jax.tree.map(lambda b, p: b + 0.05 * p, base, pert)
    return model, base, C.compress(base, ft)


# ---------------------------------------------------------------------------
# CompileCache: round-trip + every stale/corrupt shape reads as a miss
# ---------------------------------------------------------------------------

def _compiled_double():
    return jax.jit(lambda x: x * 2).lower(jnp.ones((4,), jnp.float32)) \
        .compile()


def test_roundtrip_and_counters(tmp_path):
    cc = CC.CompileCache(tmp_path, xla_fallback=False)
    parts = ("unit", "double", CC.aval_fp(jnp.ones((4,), jnp.float32)))
    assert cc.get(parts) is None
    assert cc.stats["misses"] == 1
    assert cc.put(parts, _compiled_double())
    assert cc.stats["puts"] == 1
    exe = cc.get(parts)
    assert exe is not None and cc.stats["hits"] == 1
    np.testing.assert_array_equal(
        np.asarray(exe(jnp.ones((4,), jnp.float32))), np.full((4,), 2.0))


def test_corrupt_and_truncated_entries_miss(tmp_path):
    cc = CC.CompileCache(tmp_path, xla_fallback=False)
    parts = ("unit", "corrupt")
    cc.put(parts, _compiled_double())
    entry = cc._entry(cc.key(*parts))

    entry.write_bytes(b"not a pickle at all")
    assert cc.get(parts) is None
    assert cc.stats["corrupt"] == 1

    cc.put(parts, _compiled_double())
    entry.write_bytes(entry.read_bytes()[: entry.stat().st_size // 2])
    assert cc.get(parts) is None          # truncated mid-payload
    assert cc.stats["corrupt"] == 2

    entry.write_bytes(b"")                # zero-length file
    assert cc.get(parts) is None
    assert cc.stats["corrupt"] == 3


def test_env_fingerprint_mismatch_misses(tmp_path):
    import pickle
    cc = CC.CompileCache(tmp_path, xla_fallback=False)
    parts = ("unit", "envdrift")
    cc.put(parts, _compiled_double())
    entry = cc._entry(cc.key(*parts))
    # simulate a cache dir hand-copied from another env: same key file,
    # different recorded environment
    e = pickle.loads(entry.read_bytes())
    e["env"] = ("jax-999", "jaxlib-999", "tpu", "TPU v9", 8192, "deadbeef")
    entry.write_bytes(pickle.dumps(e))
    assert cc.get(parts) is None
    assert cc.stats["env_mismatch"] == 1


def test_mesh_and_code_fingerprints_separate_keys(tmp_path):
    cc = CC.CompileCache(tmp_path, xla_fallback=False)
    dev = np.array(jax.devices()[:1])
    mesh_a = jax.sharding.Mesh(dev.reshape(1), ("data",))
    mesh_b = jax.sharding.Mesh(dev.reshape(1, 1), ("data", "model"))
    assert CC.mesh_fp(mesh_a) != CC.mesh_fp(mesh_b) != CC.mesh_fp(None)
    base = ("engine-step", "decode")
    keys = {cc.key(*base, CC.mesh_fp(m)) for m in (mesh_a, mesh_b, None)}
    assert len(keys) == 3
    # an entry stored under one topology can never be read under another
    cc.put(base + (CC.mesh_fp(mesh_a),), _compiled_double())
    assert cc.get(base + (CC.mesh_fp(mesh_b),)) is None


def test_cached_callable_static_kwargs_and_persistence(tmp_path):
    cc = CC.CompileCache(tmp_path, xla_fallback=False)
    fn = jax.jit(lambda x, n: x * n, static_argnames=("n",))
    x = jnp.ones((3,), jnp.float32)

    a = CC.CachedCallable(fn, ("unit", "mul"), cache=cc)
    np.testing.assert_array_equal(np.asarray(a(x, n=3)), np.full((3,), 3.0))
    assert cc.stats["compiles"] == 1
    np.testing.assert_array_equal(np.asarray(a(x, n=3)), np.full((3,), 3.0))
    assert cc.stats["compiles"] == 1      # in-process executable reuse

    # a fresh instance (fresh process stand-in) deserializes, not compiles
    b = CC.CachedCallable(fn, ("unit", "mul"), cache=cc)
    np.testing.assert_array_equal(np.asarray(b(x, n=3)), np.full((3,), 3.0))
    assert cc.stats["compiles"] == 1 and cc.stats["hits"] >= 1
    # different static value -> different key -> fresh compile
    np.testing.assert_array_equal(np.asarray(b(x, n=4)), np.full((3,), 4.0))
    assert cc.stats["compiles"] == 2


# ---------------------------------------------------------------------------
# dispatch memo: bounded LRU
# ---------------------------------------------------------------------------

def test_dispatch_memo_lru_cap():
    saved_cap = _dp.memo_info()["cap"]
    saved = dict(_dp._compiled)
    _dp._compiled.clear()
    for k in ("hits", "misses", "evictions"):
        _dp.memo_stats[k] = 0
    try:
        _dp.set_memo_cap(2)
        f1 = _dp._cached_jit(("t", 1), lambda: (lambda x: x + 1))
        _dp._cached_jit(("t", 2), lambda: (lambda x: x + 2))
        assert _dp._cached_jit(("t", 1), None) is f1   # hit, no rebuild
        _dp._cached_jit(("t", 3), lambda: (lambda x: x + 3))
        info = _dp.memo_info()
        assert info["entries"] == 2 and info["evictions"] == 1
        # ("t", 2) was LRU and evicted; ("t", 1) survived the cap
        assert ("t", 2) not in _dp._compiled
        assert ("t", 1) in _dp._compiled
        assert info["hits"] == 1 and info["misses"] == 3
        # re-requesting the evicted key is a clean rebuild, not an error
        f2b = _dp._cached_jit(("t", 2), lambda: (lambda x: x + 2))
        assert np.asarray(f2b(jnp.zeros(()))) == 2
        with pytest.raises(ValueError):
            _dp.set_memo_cap(0)
    finally:
        _dp.set_memo_cap(saved_cap)
        _dp._compiled.clear()
        _dp._compiled.update(saved)


# ---------------------------------------------------------------------------
# engine integration: warm restart = zero compiles + bit-exact tokens
# ---------------------------------------------------------------------------

def _dep(model, base, cache_dir, **kw):
    kw.setdefault("batch_size", 2)
    kw.setdefault("prompt_len", 8)
    kw.setdefault("max_len", 32)
    kw.setdefault("bank_size", 4)
    return Deployment(model, base, compile_cache_dir=cache_dir, **kw)


def _serve(dep, variant, n=6):
    rid = dep.submit(PROMPT, variant=variant, max_new_tokens=n)
    dep.drain()
    assert dep.result(rid).status == "done"
    return dep.result(rid).out_tokens


def test_warm_restart_zero_compiles_bit_exact(setup, tmp_path):
    model, base, dm = setup

    cold = _dep(model, base, tmp_path)
    cold.publish("ft", dm)
    toks_cold = _serve(cold, "ft")
    st_cold = cold.status()
    assert st_cold["steps"]["compiles"] > 0
    assert st_cold["compile_cache"]["puts"] > 0

    # "restart": a fresh Deployment over the same cache dir resolves
    # every step executable by deserializing
    warm = _dep(model, base, tmp_path)
    warm.publish("ft", dm)
    toks_warm = _serve(warm, "ft")
    st_warm = warm.status()
    assert toks_warm == toks_cold
    assert st_warm["steps"]["compiles"] == 0
    assert st_warm["steps"]["cache_hits"] == st_cold["steps"]["compiles"]
    assert st_warm["compile_cache"]["compiles"] == 0
    assert st_warm["compile_cache"]["hits"] > 0


def test_warmup_covers_serving_and_status_counters(setup, tmp_path):
    model, base, dm = setup

    dep = _dep(model, base, tmp_path, warmup=True)
    st = dep.status()
    assert st["warmed"] is True
    compiles_after_warmup = st["steps"]["compiles"]
    assert compiles_after_warmup > 0
    assert st["metrics"]["warmup_seconds"] > 0
    assert set(st["dispatch_memo"]) >= {"hits", "misses", "evictions",
                                        "entries", "cap"}

    # traffic on base AND a published fused variant adds ZERO compiles:
    # warmup's abstract twins are structurally identical to runtime trees
    dep.publish("ft", dm)
    _serve(dep, "__base__")
    _serve(dep, "ft")
    assert dep.status()["steps"]["compiles"] == compiles_after_warmup

    # warm restart with warmup: every pair resolves without compiling
    dep2 = _dep(model, base, tmp_path)
    outcomes = dep2.warmup()
    assert outcomes and all(v in ("hit", "warm") for v in outcomes.values())
    assert dep2.status()["steps"]["compiles"] == 0
