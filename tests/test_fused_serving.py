"""On-the-fly (fused-overlay) variant execution: parity + residency.

Covers the §4 on-the-fly path end to end: forward/prefill/decode with a
packed delta overlay must match the dense-reconstruction path within fp16
tolerance (the overlay stores fp16 vectors/extras), and the registry's
``fused`` residency mode must keep variants resident at a small fraction
of a dense copy, evict correctly, and mix with dense residents.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import calibration as C
from repro.core import loader as L
from repro.models import build_model
from repro.models.delta_overlay import (overlay_from_deltas, overlay_nbytes,
                                        oget)
from repro.models.param import split
from repro.serving import ServingEngine, VariantRegistry
from repro.serving.engine import Request


def _pair(arch: str, layers: int = 2):
    """Untrained base + small perturbation fine-tune (enough for parity).
    ``layers=0`` keeps the reduced default (families with layer-pattern
    constraints: xlstm super-blocks, zamba attn_every)."""
    cfg = get_config(arch).reduced()
    if layers:
        cfg = dataclasses.replace(cfg, num_layers=layers)
    cfg = dataclasses.replace(cfg, compute_dtype="float32", remat=False)
    model = build_model(cfg)
    base, _ = split(model.init(jax.random.PRNGKey(0)))
    pert, _ = split(model.init(jax.random.PRNGKey(1)))
    ft = jax.tree.map(lambda b, f: b + 0.01 * f, base, pert)
    return model, base, ft


def _batch(model, rng_seed=7, bs=2, s=16):
    cfg = model.cfg
    batch = {"tokens": jnp.asarray(np.random.default_rng(rng_seed).integers(
        1, cfg.vocab_size, size=(bs, s)), jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = jnp.zeros((bs, cfg.encoder_frames, cfg.d_model),
                                    jnp.float32)
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.zeros(
            (bs, cfg.num_image_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch,layers", [
    ("qwen3-8b", 2), ("deepseek-moe-16b", 2),      # transformer + MoE
    ("whisper-base", 0), ("xlstm-350m", 0), ("zamba2-7b", 0),
])
def test_forward_parity_fused_vs_materialized(arch, layers):
    """forward(base, overlay) ≈ forward(materialised params), fp16 tol —
    all four family modules (MoE exercises pre_layers, routed expert
    stacks and shared experts through the fused grouped GEMMs; whisper
    the enc/dec/cross-attn caches; xlstm/zamba the state-carrying
    super-block scans)."""
    model, base, ft = _pair(arch, layers)
    dm = C.compress(base, ft)
    dense = C.apply_delta(base, dm)
    fused_params, overlay, _ = L.device_put_overlay(base, dm)

    batch = _batch(model)
    ld = jax.jit(lambda p, b: model.forward(p, b)[0])(dense, batch)
    lf = jax.jit(lambda p, ov, b: model.forward(p, b, overlay=ov)[0])(
        fused_params, overlay, batch)
    scale = float(jnp.max(jnp.abs(ld)))
    tol = 2e-2 * max(scale, 1.0)
    assert float(jnp.max(jnp.abs(ld - lf))) < tol

    # prefill + a decode step agree too (the serving path)
    pd, cd = jax.jit(lambda p, b: model.prefill(p, b, 32))(dense, batch)
    pf, cf = jax.jit(lambda p, ov, b: model.prefill(p, b, 32, overlay=ov))(
        fused_params, overlay, batch)
    assert float(jnp.max(jnp.abs(pd - pf))) < tol
    tok = jnp.argmax(pd, -1).astype(jnp.int32)
    dd, _ = jax.jit(model.decode_step)(dense, tok, cd)
    df, _ = jax.jit(lambda p, t, c, ov: model.decode_step(
        p, t, c, overlay=ov))(fused_params, tok, cf, overlay)
    assert float(jnp.max(jnp.abs(dd - df))) < tol


def test_overlay_canonical_form():
    """Zero-the-unselected-axis canonicalisation: v_row + v_col broadcast
    sum reproduces exactly the selected per-axis scale."""
    model, base, ft = _pair("qwen3-8b")
    dm = C.compress(base, ft)
    overlay = overlay_from_deltas(dm.deltas)
    entry = oget(oget(oget(overlay, "layers"), "attn"), "wq")
    src = dm.deltas["layers.attn.wq"]
    v_eff = (entry.v_row.astype(jnp.float32)[..., :, None]
             + entry.v_col.astype(jnp.float32)[..., None, :])
    sel = src.use_row[..., None, None]
    want = jnp.where(sel, src.v_row[..., :, None], src.v_col[..., None, :])
    assert jnp.allclose(v_eff, want, atol=1e-3)   # fp16 vector rounding
    assert overlay_nbytes(overlay) > 0


def test_fused_resident_bytes_fraction():
    """A fused resident costs a small fraction of a dense copy; with
    enough layers (linear stacks dominating extras) it is ≤ 1/8."""
    model, base, ft = _pair("qwen3-8b", layers=6)
    dm = C.compress(base, ft)
    dense, _ = L.apply_artifact(base, dm)
    dense_bytes = sum(l.size * l.dtype.itemsize
                      for l in jax.tree.leaves(dense))
    params, overlay, _ = L.device_put_overlay(base, dm)
    fused_bytes = L.fused_resident_bytes(base, params, overlay)
    assert fused_bytes <= dense_bytes / 8
    # the view aliases every untouched base weight (no hidden copies)
    base_ids = {id(l) for l in jax.tree.leaves(base)}
    from repro.core.calibration import flatten_params
    for path, leaf in flatten_params(params).items():
        if path in dm.deltas:
            assert id(leaf) in base_ids


def test_registry_fused_eviction_and_accounting():
    model, base, ft = _pair("qwen3-8b")
    dm = C.compress(base, ft)
    reg = VariantRegistry(base, max_resident=1, mode="fused")
    reg.register("a", dm)
    reg.register("b", dm)
    _, ov_a = reg.resolve("a")
    assert ov_a is not None
    bytes_a = reg.stats["resident_bytes"]
    assert bytes_a == reg.resident_nbytes("a") > 0
    reg.resolve("b")                     # evicts "a" (LRU, capacity 1)
    assert reg.resident() == ["b"]
    assert reg.stats["evictions"] == 1
    assert reg.stats["resident_bytes"] == reg.resident_nbytes("b")
    reg.evict("b")
    assert reg.resident() == [] and reg.stats["resident_bytes"] == 0
    # params_for is a dense-only accessor — and its error path must not
    # load the artifact, admit a resident, or count a swap
    swaps = reg.stats["swaps"]
    with pytest.raises(ValueError):
        reg.params_for("a")
    assert reg.stats["swaps"] == swaps and reg.resident() == []
    # max_resident=0 = cache-nothing: still serves, just never retains
    reg0 = VariantRegistry(base, max_resident=0, mode="fused")
    reg0.register("a", dm)
    _, ov = reg0.resolve("a")
    assert ov is not None and reg0.resident() == []
    assert reg0.stats["resident_bytes"] == 0 and reg0.stats["evictions"] == 1


def test_engine_mixed_dense_fused_residency():
    """One registry serving base + a dense resident + a fused resident:
    the same artifact must generate identical greedy tokens either way."""
    model, base, ft = _pair("deepseek-7b")
    dm = C.compress(base, ft)
    reg = VariantRegistry(base, max_resident=4, mode="fused")
    reg.register("vf", dm)
    reg.register("vd", dm, mode="dense")
    eng = ServingEngine(model, reg, batch_size=2, prompt_len=8, max_len=32)
    rids = {v: eng.submit(np.arange(1, 7), variant=v, max_new_tokens=4)
            for v in ("__base__", "vf", "vd")}
    eng.run_until_drained()
    out = {v: eng.result(r) for v, r in rids.items()}
    assert all(r.status == "done" for r in out.values())
    assert out["vf"].out_tokens == out["vd"].out_tokens
    assert len(out["vf"].out_tokens) == 4
    # metrics count exactly the emitted tokens (retired slots excluded)
    assert eng.metrics["tokens_generated"] == 3 * 4
    # fused resident is much lighter than the dense one
    assert reg.resident_nbytes("vf") < reg.resident_nbytes("vd") / 4


def test_take_group_preserves_queue_order():
    """_take_group stops scanning at batch_size and puts skipped requests
    back in their original positions."""
    model, base, _ = _pair("deepseek-7b")
    reg = VariantRegistry(base)
    eng = ServingEngine(model, reg, batch_size=2, prompt_len=8, max_len=32)
    order = ["a", "b", "a", "c", "a"]
    for v in order:
        eng._queue.append(Request(rid=len(eng._queue), tokens=np.arange(3),
                                  variant=v))
    group = eng._take_group()
    # batch_size=2: takes the first two "a"s, scans past b only
    assert [r.variant for r in group] == ["a", "a"]
    assert [r.variant for r in eng._queue] == ["b", "c", "a"]
    assert [r.rid for r in eng._queue] == [1, 3, 4]
