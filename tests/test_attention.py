"""Flash attention (fwd + custom VJP) vs dense reference, incl. gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A


def _qkv(key, b, s, t, hq, hkv, hd):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, hq, hd))
    k = jax.random.normal(ks[1], (b, t, hkv, hd))
    v = jax.random.normal(ks[2], (b, t, hkv, hd))
    return q, k, v


@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("window", [0, 5])
@pytest.mark.parametrize("chunk", [4, 16])
def test_flash_matches_reference(hq, hkv, window, chunk):
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, 16, 16, hq, hkv, 8)
    got = A.flash_attention(q, k, v, causal=True, window=window, chunk=chunk)
    want = A.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_flash_non_causal():
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 8, 24, 4, 4, 8)
    got = A.flash_attention(q, k, v, causal=False, chunk=8)
    want = A.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("hq,hkv,window", [(4, 4, 0), (4, 2, 0), (4, 4, 6)])
def test_flash_gradients_match_reference(hq, hkv, window):
    """Custom VJP must equal autodiff through the dense reference."""
    q, k, v = _qkv(jax.random.PRNGKey(2), 2, 12, 12, hq, hkv, 8)

    def loss_flash(q, k, v):
        o = A.flash_attention(q, k, v, causal=True, window=window, chunk=4)
        return jnp.sum(jnp.sin(o.astype(jnp.float32)))

    def loss_ref(q, k, v):
        o = A.attention_ref(q, k, v, causal=True, window=window)
        return jnp.sum(jnp.sin(o.astype(jnp.float32)))

    g_flash = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for gf, gr, name in zip(g_flash, g_ref, "qkv"):
        np.testing.assert_allclose(np.asarray(gf), np.asarray(gr),
                                   rtol=2e-4, atol=2e-4, err_msg=name)


def test_flash_traced_window_gradient():
    """window passed as traced array (gemma3 scan) must not break the VJP."""
    q, k, v = _qkv(jax.random.PRNGKey(3), 1, 8, 8, 2, 2, 4)

    def loss(q, window):
        o = A.flash_attention(q, k, v, causal=True, window=window, chunk=4)
        return jnp.sum(o.astype(jnp.float32) ** 2)

    g = jax.grad(loss)(q, jnp.int32(4))
    assert jnp.isfinite(g).all()


def test_decode_attention_matches_full():
    """Decode vs teacher-forced last position."""
    b, t, hq, hkv, hd = 2, 10, 4, 2, 8
    q, k, v = _qkv(jax.random.PRNGKey(4), b, t, t, hq, hkv, hd)
    full = A.attention_ref(q, k, v, causal=True)
    slot_pos = jnp.arange(t, dtype=jnp.int32)
    dec = A.decode_attention(q[:, -1:], k, v, slot_pos, jnp.int32(t - 1))
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, -1]),
                               rtol=1e-5, atol=1e-5)


def test_ring_cache_window_semantics():
    """Ring cache + windowed decode == dense sliding-window attention."""
    b, hq, hkv, hd, w = 1, 2, 2, 4, 4
    total = 9
    key = jax.random.PRNGKey(5)
    q_all, k_all, v_all = _qkv(key, b, total, total, hq, hkv, hd)
    cache = A.make_kv_cache(b, w, hkv, hd, dtype=jnp.float32)
    outs = []
    for pos in range(total):
        cache = A.cache_insert(cache, k_all[:, pos:pos+1], v_all[:, pos:pos+1],
                               jnp.int32(pos), ring=True)
        o = A.decode_attention(q_all[:, pos:pos+1], cache["k"], cache["v"],
                               cache["slot_pos"], jnp.int32(pos), window=w)
        outs.append(o)
    got = jnp.concatenate(outs, axis=1)
    want = A.attention_ref(q_all, k_all, v_all, causal=True, window=w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
