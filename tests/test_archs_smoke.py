"""Per-architecture smoke tests: reduced config, one forward + one decode
step on CPU, asserting output shapes and no NaNs (assignment requirement).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import build_model
from repro.models.param import split


def _smoke_batch(model, rng, batch=2, seq=16):
    cfg = model.cfg
    ks = jax.random.split(rng, 3)
    batch_d = {}
    if cfg.family == "vlm":
        n_img = cfg.num_image_tokens
        batch_d["image_embeds"] = 0.1 * jax.random.normal(
            ks[1], (batch, n_img, cfg.d_model))
        batch_d["tokens"] = jax.random.randint(
            ks[0], (batch, seq - n_img), 0, cfg.vocab_size)
    elif cfg.family == "audio":
        batch_d["frames"] = 0.1 * jax.random.normal(
            ks[1], (batch, cfg.encoder_frames, cfg.d_model))
        batch_d["tokens"] = jax.random.randint(ks[0], (batch, seq), 0,
                                               cfg.vocab_size)
    else:
        batch_d["tokens"] = jax.random.randint(ks[0], (batch, seq), 0,
                                               cfg.vocab_size)
    return batch_d


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nans(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params, specs = split(model.init(rng))
    batch = _smoke_batch(model, jax.random.PRNGKey(1))
    logits, aux = model.forward(params, batch)
    b = batch["tokens"].shape[0]
    exp_s = 16  # vlm: img tokens + text tokens = seq
    assert logits.shape == (b, exp_s, cfg.vocab_size), logits.shape
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), \
        f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_decreases_loss(arch):
    """One SGD step on the toy config must reduce next-token loss."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    batch = _smoke_batch(model, jax.random.PRNGKey(1))
    tokens = batch["tokens"]
    labels = jnp.roll(tokens, -1, axis=1)

    def loss_fn(p):
        logits, aux = model.forward(p, batch)
        # align: only text positions (last tokens.shape[1] positions)
        lt = logits[:, -tokens.shape[1]:, :].astype(jnp.float32)
        ll = jax.nn.log_softmax(lt, axis=-1)
        nll = -jnp.take_along_axis(ll, labels[..., None], axis=-1).mean()
        return nll + 0.01 * aux["moe_aux"]

    l0, g = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(l0))
    gnorm = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert gnorm > 0, f"{arch}: zero gradients"
    params2 = jax.tree.map(lambda p, gg: p - 0.5 * gg, params, g)
    l1 = loss_fn(params2)
    assert float(l1) < float(l0), f"{arch}: loss did not decrease {l0}->{l1}"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_matches_forward(arch):
    """Greedy logits from (prefill + decode_step) must match the teacher-
    forced forward at the same position — validates every cache path.

    Run in fp32: this checks cache-path *logic*; bf16 recurrence rounding
    (SSM state carries) is covered by the no-NaN smoke test instead."""
    import dataclasses
    cfg = dataclasses.replace(get_config(arch).reduced(),
                              compute_dtype="float32")
    model = build_model(cfg)
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    batch = _smoke_batch(model, jax.random.PRNGKey(1))
    tokens = batch["tokens"]
    b, s = tokens.shape

    # teacher-forced logits over full sequence
    full_logits, _ = model.forward(params, batch)

    # prefill on the first s-1 tokens, then decode token s-1
    pre_batch = dict(batch, tokens=tokens[:, :-1])
    max_len = 32
    last_logits, cache = model.prefill(params, pre_batch, max_len,
                                       cache_dtype=jnp.float32)
    np.testing.assert_allclose(
        np.asarray(last_logits, np.float32),
        np.asarray(full_logits[:, -2, :], np.float32), rtol=2e-4, atol=2e-4)

    step_logits, cache = model.decode_step(params, tokens[:, -1], cache)
    np.testing.assert_allclose(
        np.asarray(step_logits, np.float32),
        np.asarray(full_logits[:, -1, :], np.float32), rtol=2e-4, atol=5e-4)


def test_param_counts_positive():
    from repro.configs.base import param_counts
    for arch in ARCHS:
        pc = param_counts(get_config(arch))
        assert pc["total"] > 0 and 0 < pc["active"] <= pc["total"], (arch, pc)
