"""Quantized int8 base under 1-bit deltas (DESIGN.md §16).

Four layers of coverage:

* quantize/dequantize round-trip bounds + pytree/flattener contracts
  (QuantWeight is ONE leaf to the params flatteners, duck-types the
  array it replaces);
* kernel parity sweeps: plain / fused (dual-axis) / banked GEMMs and the
  unpack_apply reconstruction, each on a QuantWeight base vs the ref
  oracle's dense-dequant twin, plus the ``no_dispatch`` fallback;
* 4-device row-/col-sharded kernel parity (sharded-smoke CI job; skips
  on tier-1's single device);
* serving integration: model-forward parity across all five families,
  bank admit/evict with an int8 base, and the publish → update →
  rollback lifecycle at ``base_dtype="int8"``.

Parity contract: executing against the QuantWeight (in-tile dequant)
must match executing against the densely dequantized base — the int8
representation is the ONLY approximation, the kernels add none.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.configs import get_config
from repro.core import calibration as C
from repro.core import quantize as Q
from repro.distributed import sharding as S
from repro.kernels import dispatch as D
from repro.kernels import ops as K
from repro.kernels import ref as R
from repro.models import build_model
from repro.models.param import split
from repro.serving import Deployment, ServingEngine, VariantRegistry

RULES = S.rules_for("decode")


def _mesh22() -> Mesh:
    if len(jax.devices()) < 4:
        pytest.skip("needs 4 devices (sharded-smoke CI job)")
    return Mesh(np.asarray(jax.devices()[:4]).reshape(2, 2),
                ("data", "model"))


def _rand_w(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32) * 0.1)


def _rand_entry(rng, n, k, nb=None):
    shp = (n, k // 8) if nb is None else (nb, n, k // 8)
    packed = jnp.asarray(rng.integers(0, 256, size=shp, dtype=np.uint8))
    vr = 0.01 * jnp.abs(jnp.asarray(rng.normal(
        size=(n,) if nb is None else (nb, n)).astype(np.float32)))
    vc = jnp.zeros((k,) if nb is None else (nb, k), jnp.float32)
    return packed, vr, vc


# ---------------------------------------------------------------------------
# quantize/dequantize round trip + pytree contracts
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(16, 24), (4, 16, 24), (2, 3, 8, 16)])
def test_roundtrip_bounds(shape):
    """|deq - w| <= ~0.5 quantization steps per channel (a little slack
    for the fp16 scale rounding)."""
    w = _rand_w(np.random.default_rng(0), *shape)
    qw = Q.quantize_weight(w)
    assert qw.q.dtype == jnp.int8 and qw.q.shape == w.shape
    assert qw.scale.dtype == jnp.float16 and qw.scale.shape == w.shape[:-1]
    deq = Q.dequantize(qw)
    bound = 0.6 * np.asarray(qw.scale, np.float32)[..., None] + 1e-6
    assert (np.abs(np.asarray(deq) - np.asarray(w)) <= bound).all()


def test_quantweight_is_one_flat_leaf():
    """The params flatteners must treat a QuantWeight as ONE leaf (the
    weight it replaces), while jax.tree still sees its two arrays."""
    w = _rand_w(np.random.default_rng(1), 16, 24)
    tree = {"layers": {"0": {"wq": Q.quantize_weight(w), "norm":
                             jnp.ones((16,))}}}
    flat = C.flatten_params(tree)
    assert set(flat) == {"layers.0.wq", "layers.0.norm"}
    qw = flat["layers.0.wq"]
    assert Q.is_quant(qw)
    assert qw.shape == (16, 24) and qw.ndim == 2    # duck-typed
    assert C.is_target("layers.0.wq", qw)
    assert len(jax.tree.leaves(tree)) == 3          # q, scale, norm
    rebuilt = C.unflatten_like(tree, flat)
    assert Q.is_quant(rebuilt["layers"]["0"]["wq"])


def test_quantize_base_targets_only():
    """quantize_base quantizes exactly the shadowed targets and books the
    byte ratio; non-targets (norms, embeddings) stay untouched."""
    cfg = dataclasses.replace(get_config("deepseek-7b").reduced(),
                              num_layers=2, compute_dtype="float32",
                              remat=False)
    model = build_model(cfg)
    base, _ = split(model.init(jax.random.PRNGKey(0)))
    qparams, qsh, stats = Q.quantize_base(base)
    assert qsh is None
    flat = C.flatten_params(base)
    qflat = C.flatten_params(qparams)
    targets = {p for p, l in flat.items() if C.is_target(p, l)}
    assert stats["targets"] == len(targets) > 0
    for p in qflat:
        assert Q.is_quant(qflat[p]) == (p in targets), p
    # int8 payload + fp16 scales of an fp32 base: just over 0.25x
    assert stats["ratio"] < 0.3


def test_linear_plain_factoring():
    """No-overlay path: (x @ q.T) * scale == x @ deq.T exactly (up to
    float reassociation) — no dense dequant materialised."""
    from repro.models.layers import linear
    rng = np.random.default_rng(2)
    w = _rand_w(rng, 32, 24)
    qw = Q.quantize_weight(w)
    x = _rand_w(rng, 4, 24)
    got = linear(x, qw)
    want = x @ Q.dequantize(qw).T
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# kernel parity: plain / fused / banked / unpack vs the dequant oracle
# ---------------------------------------------------------------------------

SHAPES = [(8, 16, 32), (4, 32, 24), (8, 100, 40)]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("mode", ["row", "col", "scalar"])
def test_bitlinear_quant_parity(shape, mode):
    m, n, k = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    qw = Q.quantize_weight(_rand_w(rng, n, k))
    packed, vr, vc = _rand_entry(rng, n, k)
    v = {"row": vr, "col": 0.01 * jnp.ones((k,)),
         "scalar": jnp.float32(0.01)}[mode]
    x = _rand_w(rng, m, k)
    got = K.bitlinear(x, packed, v, qw, mode=mode)
    want = R.bitlinear_ref(x, packed, v, qw.q, mode, w_scale=qw.scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", SHAPES)
def test_bitlinear_axes_quant_parity(shape):
    m, n, k = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    qw = Q.quantize_weight(_rand_w(rng, n, k))
    packed, vr, vc = _rand_entry(rng, n, k)
    x = _rand_w(rng, m, k)
    got = K.bitlinear_axes(x, packed, vr, vc, qw)
    want = R.bitlinear_axes_ref(x, packed, vr, vc, qw.q, w_scale=qw.scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # in-tile dequant == executing against the densely dequantized base
    dense = K.bitlinear_axes(x, packed, vr, vc, Q.dequantize(qw))
    np.testing.assert_allclose(np.asarray(got), np.asarray(dense),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", SHAPES)
def test_bitlinear_axes_banked_quant_parity(shape):
    m, n, k = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    qw = Q.quantize_weight(_rand_w(rng, n, k))
    packed, vr, vc = _rand_entry(rng, n, k, nb=3)
    # slot 0 = base: zero vectors and a zero sign plane
    packed = packed.at[0].set(0)
    vr = vr.at[0].set(0)
    vidx = jnp.asarray(rng.integers(0, 3, size=(m,)), jnp.int32)
    x = _rand_w(rng, m, k)
    got = K.bitlinear_axes_banked(x, vidx, packed, vr, vc, qw)
    want = R.bitlinear_axes_banked_ref(x, vidx, packed, vr, vc, qw.q,
                                       w_scale=qw.scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("mode", ["row", "col"])
def test_unpack_apply_quant_parity(mode):
    rng = np.random.default_rng(5)
    n, k = 32, 24
    qw = Q.quantize_weight(_rand_w(rng, n, k))
    packed, vr, _ = _rand_entry(rng, n, k)
    v = vr if mode == "row" else 0.01 * jnp.ones((k,))
    got = K.unpack_apply(packed, v, qw, mode=mode)
    assert got.dtype == jnp.float16        # dense Ŵ lands in scale dtype
    want = R.unpack_apply_ref(packed, v, qw.q, mode, dtype=jnp.float16,
                              w_scale=qw.scale)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_no_dispatch_fallback_quant():
    """Outside a mesh (and under no_dispatch) the QuantWeight path must be
    byte-identical to the plain global-jit call."""
    rng = np.random.default_rng(6)
    qw = Q.quantize_weight(_rand_w(rng, 32, 24))
    packed, vr, vc = _rand_entry(rng, 32, 24)
    x = _rand_w(rng, 4, 24)
    base = K.bitlinear_axes(x, packed, vr, vc, qw)
    with D.no_dispatch():
        nd = K.bitlinear_axes(x, packed, vr, vc, qw,
                              waxes=("ffn", "embed"))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(nd))


# ---------------------------------------------------------------------------
# 4-device row-/col-sharded parity (sharded-smoke job)
# ---------------------------------------------------------------------------

def test_sharded_kernel_parity_quant():
    mesh = _mesh22()
    rng = np.random.default_rng(7)
    x = _rand_w(rng, 8, 24)

    # row-sharded: out-channel (and its scale) split over `model`
    qw = Q.quantize_weight(_rand_w(rng, 32, 24))
    packed, vr, vc = _rand_entry(rng, 32, 24)
    want = K.bitlinear_axes(x, packed, vr, vc, qw)
    with S.shard_ctx(mesh, RULES):
        got = K.bitlinear_axes(x, packed, vr, vc, qw,
                               waxes=("ffn", "embed"))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)

    # col-sharded contraction: scales replicated, partials psum'd
    x2 = _rand_w(rng, 8, 32)
    qw2 = Q.quantize_weight(_rand_w(rng, 24, 32))
    packed2, vr2, vc2 = _rand_entry(rng, 24, 32)
    want2 = K.bitlinear_axes(x2, packed2, vr2, vc2, qw2)
    with S.shard_ctx(mesh, RULES):
        got2 = K.bitlinear_axes(x2, packed2, vr2, vc2, qw2,
                                waxes=("embed", "ffn"))
    np.testing.assert_allclose(np.asarray(got2), np.asarray(want2),
                               rtol=2e-5, atol=2e-5)

    # banked + unpack on the quantized base
    packed_b, vrb, vcb = _rand_entry(rng, 32, 24, nb=3)
    vidx = jnp.asarray(rng.integers(0, 3, size=(8,)), jnp.int32)
    wantb = K.bitlinear_axes_banked(x, vidx, packed_b, vrb, vcb, qw)
    with S.shard_ctx(mesh, RULES):
        gotb = K.bitlinear_axes_banked(x, vidx, packed_b, vrb, vcb, qw,
                                       waxes=("ffn", "embed"))
    np.testing.assert_allclose(np.asarray(gotb), np.asarray(wantb),
                               rtol=2e-5, atol=2e-5)

    wantu = K.unpack_apply(packed, vr, qw, mode="row")
    with S.shard_ctx(mesh, RULES):
        gotu = K.unpack_apply(packed, vr, qw, mode="row",
                              waxes=("ffn", "embed"))
    np.testing.assert_allclose(np.asarray(gotu, np.float32),
                               np.asarray(wantu, np.float32),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# model-level parity across the five families
# ---------------------------------------------------------------------------

def _family_pair(arch: str):
    cfg = get_config(arch).reduced()
    if arch in ("deepseek-7b", "deepseek-moe-16b"):
        cfg = dataclasses.replace(cfg, num_layers=2)
    cfg = dataclasses.replace(cfg, compute_dtype="float32", remat=False)
    model = build_model(cfg)
    base, axes = split(model.init(jax.random.PRNGKey(0)))
    pert, _ = split(model.init(jax.random.PRNGKey(1)))
    ft = jax.tree.map(lambda b, f: b + 0.05 * f, base, pert)
    return model, base, axes, C.compress(base, ft)


def _tokens_batch(model, bs=2, s=8):
    batch = {"tokens": jnp.asarray(np.random.default_rng(7).integers(
        1, model.cfg.vocab_size, size=(bs, s)), jnp.int32)}
    if model.cfg.family == "audio":
        batch["frames"] = jnp.zeros(
            (bs, model.cfg.encoder_frames, model.cfg.d_model), jnp.float32)
    return batch


def _dequant_tree(qparams):
    return jax.tree.map(
        lambda l: Q.dequantize(l, jnp.float32) if Q.is_quant(l) else l,
        qparams, is_leaf=Q.is_quant)


@pytest.mark.parametrize("arch", ["deepseek-7b", "deepseek-moe-16b",
                                  "xlstm-350m", "zamba2-7b",
                                  "whisper-base"])
def test_family_forward_quant_parity(arch):
    """Forward logits on the QuantWeight params (plain, fused-overlay and
    banked paths) match the densely dequantized base — the int8
    representation is the only approximation."""
    from repro.models import delta_overlay as DO
    model, base, _, dm = _family_pair(arch)
    qparams, _, stats = Q.quantize_base(base)
    assert stats["targets"] > 0
    deq = _dequant_tree(qparams)
    batch = _tokens_batch(model)
    fwd = jax.jit(lambda p, b: model.forward(p, b)[0])
    lq = fwd(qparams, batch)
    ld = fwd(deq, batch)
    np.testing.assert_allclose(np.asarray(lq), np.asarray(ld),
                               rtol=2e-3, atol=2e-3)

    # fused single-variant overlay over the quantized base
    flat_q = C.flatten_params(qparams)
    ov = {}
    for p, e in dm.deltas.items():
        if not e.scalar:
            DO.insert_entry(ov, p, DO.from_delta_entry(e))
    if ov:
        fwd_ov = jax.jit(
            lambda p, o, b: model.forward(p, b, overlay=o)[0])
        lqo = fwd_ov(qparams, ov, batch)
        ldo = fwd_ov(deq, ov, batch)
        np.testing.assert_allclose(np.asarray(lqo), np.asarray(ldo),
                                   rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# serving integration: bank admit/evict + lifecycle at base_dtype="int8"
# ---------------------------------------------------------------------------

def _toy_serving(arch="deepseek-7b"):
    cfg = dataclasses.replace(get_config(arch).reduced(), num_layers=2,
                              compute_dtype="float32", remat=False)
    model = build_model(cfg)
    base, _ = split(model.init(jax.random.PRNGKey(0)))
    pert, _ = split(model.init(jax.random.PRNGKey(1)))
    ft1 = jax.tree.map(lambda b, f: b + 0.05 * f, base, pert)
    ft2 = jax.tree.map(lambda b, f: b - 0.05 * f, base, pert)
    return model, base, C.compress(base, ft1), C.compress(base, ft2)


def test_registry_quant_accounting():
    model, base, dm1, _ = _toy_serving()
    reg_fp = VariantRegistry(base, mode="fused")
    reg = VariantRegistry(base, mode="fused", base_dtype="int8")
    assert reg.base_fp == reg_fp.base_fp       # fingerprint is of the FP base
    assert reg.base_dtype == "int8" and reg.quant_stats["targets"] > 0
    assert reg.base_nbytes() < 0.6 * reg_fp.base_nbytes()
    per = reg.base_per_device_nbytes()
    assert sum(per.values()) == reg.base_nbytes()


def test_bank_admit_evict_int8():
    model, base, dm1, dm2 = _toy_serving()
    reg = VariantRegistry(base, mode="fused", bank_size=3,
                          base_dtype="int8")
    reg.register("v1", dm1)
    reg.register("v2", dm2)
    s1 = reg.bank_resolve("v1")
    s2 = reg.bank_resolve("v2")
    assert {s1, s2} == {1, 2}
    reg.evict("v1")
    assert reg.bank.resident() == ["v2"]
    assert reg.bank_resolve("v2") == s2        # hit, slot stable
    assert reg.bank_resolve("v1") == s1        # re-admit reuses the slot
    # decode through the banked kernel over the int8 base
    eng = ServingEngine(model, reg, batch_size=2, prompt_len=8,
                        max_len=32, scheduler="continuous")
    r1 = eng.submit(np.arange(1, 7), variant="v1", max_new_tokens=4)
    r2 = eng.submit(np.arange(1, 7), variant="v2", max_new_tokens=4)
    eng.run_until_drained()
    assert eng.result(r1).status == "done"
    assert len(eng.result(r1).out_tokens) == 4
    assert len(eng.result(r2).out_tokens) == 4


def test_lifecycle_int8(tmp_path):
    """publish → update → rollback at base_dtype='int8', plus the status()
    HBM accounting next to the bank bytes."""
    model, base, dm1, dm2 = _toy_serving()
    dep = Deployment(model, base, root_dir=str(tmp_path), mode="fused",
                     scheduler="continuous", batch_size=2, prompt_len=8,
                     max_len=32, bank_size=4, base_dtype="int8")
    v1 = dep.publish("v", dm1)
    rid = dep.submit(np.arange(1, 7), variant="v", max_new_tokens=4)
    dep.drain()
    assert dep.result(rid).status == "done"
    assert dep.result(rid).served_version == v1
    v2 = dep.update("v", dm2)
    rid2 = dep.submit(np.arange(1, 7), variant="v", max_new_tokens=4)
    dep.drain()
    assert dep.result(rid2).served_version == v2
    vb = dep.rollback("v")
    assert vb == v1
    rid3 = dep.submit(np.arange(1, 7), variant="v", max_new_tokens=4)
    dep.drain()
    assert dep.result(rid3).served_version == v1
    st = dep.status()
    assert st["hbm"]["base_dtype"] == "int8"
    assert st["hbm"]["base_bytes"] > 0 and st["hbm"]["bank_bytes"] > 0
    assert sum(st["hbm"]["base_per_device"].values()) == \
        st["hbm"]["base_bytes"]
    dep.close()


def test_lifecycle_token_agreement_int8_vs_fp(tmp_path):
    """Same workload, fp vs int8 base: greedy tokens agree on (nearly)
    every position — the measured tolerance the benchmark gates at 0.99
    under heavier traffic."""
    model, base, dm1, _ = _toy_serving()
    toks = {}
    for bd in ("fp", "int8"):
        dep = Deployment(model, base, mode="fused",
                         scheduler="continuous", batch_size=2,
                         prompt_len=8, max_len=32, bank_size=4,
                         base_dtype=bd)
        dep.publish("v", dm1)
        rids = [dep.submit(np.arange(1, 7), variant=v, max_new_tokens=6)
                for v in ("__base__", "v")]
        dep.drain()
        toks[bd] = [dep.result(r).out_tokens for r in rids]
        dep.close()
    agree = sum(int(a == b)
                for fa, fb in zip(toks["fp"], toks["int8"])
                for a, b in zip(fa, fb))
    total = sum(len(fa) for fa in toks["fp"])
    assert total == 12
    assert agree / total >= 0.9
