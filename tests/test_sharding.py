"""Sharding resolution: divisibility fallback + rules + property tests."""
import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed import sharding as S


def _mesh(data=4, model=2):
    n = data * model
    if len(jax.devices()) < n:
        pytest.skip("needs >1 device")
    return Mesh(np.asarray(jax.devices()[:n]).reshape(data, model),
                ("data", "model"))


class FakeDev:
    pass


def _fake_mesh(shape, names):
    """Mesh-like for pure resolution tests (no devices needed)."""
    class M:
        axis_names = names
        devices = np.empty(shape, object)
    return M()


def test_resolve_basic():
    mesh = _fake_mesh((16, 16), ("data", "model"))
    rules = S.rules_for("train")
    spec = S.resolve_spec((4096, 2048), ("ffn", "embed"), rules, mesh)
    assert spec == P("model", "data")


def test_resolve_divisibility_fallback():
    mesh = _fake_mesh((16, 16), ("data", "model"))
    rules = S.rules_for("train")
    # 24 heads don't divide 16 -> replicated; head_dim picks up model
    spec = S.resolve_spec((2, 128, 24, 128),
                          ("act_batch", None, "act_kv", "act_hd"),
                          rules, mesh)
    assert spec[2] is None and spec[3] == "model"


def test_resolve_no_axis_reuse():
    mesh = _fake_mesh((16, 16), ("data", "model"))
    rules = S.rules_for("train")
    # experts and ffn both prefer model; only one gets it
    spec = S.resolve_spec((64, 1408, 2048), ("experts", "ffn", "embed"),
                          rules, mesh)
    assert spec == P("model", None, "data")


def test_multi_axis_candidate_single_pod_skips_pod():
    mesh = _fake_mesh((16, 16), ("data", "model"))
    rules = S.rules_for("train")
    spec = S.resolve_spec((256, 4096), ("act_batch", "act_seq"), rules, mesh)
    assert spec[0] == "data"  # ("pod","data") skipped: pod absent


def test_multi_pod_batch_uses_both():
    mesh = _fake_mesh((2, 16, 16), ("pod", "data", "model"))
    rules = S.rules_for("train")
    spec = S.resolve_spec((256, 4096), ("act_batch", "act_seq"), rules, mesh)
    assert spec[0] == ("pod", "data")


def test_long_context_rules_shard_seq():
    mesh = _fake_mesh((16, 16), ("data", "model"))
    rules = S.rules_for("decode", long_context=True)
    spec = S.resolve_spec((1, 524288, 8, 128),
                          (None, "act_seq", "act_kv", "act_hd"), rules, mesh)
    assert spec[1] == "data"


@settings(max_examples=40, deadline=None)
@given(
    d0=st.integers(1, 64).map(lambda i: i * 16),
    d1=st.integers(1, 64).map(lambda i: i * 16),
    ax0=st.sampled_from(["embed", "ffn", "q_heads", "vocab", None]),
    ax1=st.sampled_from(["embed", "ffn", "kv_heads", None]),
)
def test_resolution_always_valid(d0, d1, ax0, ax1):
    """Every resolved spec uses each mesh axis at most once and only on
    dims it divides."""
    mesh = _fake_mesh((16, 16), ("data", "model"))
    rules = S.rules_for("train")
    spec = S.resolve_spec((d0, d1), (ax0, ax1), rules, mesh)
    used = []
    sizes = {"data": 16, "model": 16}
    for dim, part in zip((d0, d1), spec):
        if part is None:
            continue
        parts = part if isinstance(part, tuple) else (part,)
        for pp in parts:
            assert pp not in used
            used.append(pp)
        total = int(np.prod([sizes[pp] for pp in parts]))
        assert dim % total == 0


def test_logical_constraint_noop_without_ctx():
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    y = S.logical_constraint(x, "act_batch", None)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_local_top_k_noop_without_ctx():
    import jax.numpy as jnp
    x = jnp.arange(12.0).reshape(3, 4)
    v, i = S.local_top_k(x, 2, (None, None))
    np.testing.assert_array_equal(np.asarray(i)[:, 0], [3, 3, 3])
