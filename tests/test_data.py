"""Data pipeline: determinism, resumability, structure."""
import numpy as np

from repro.data.pipeline import SyntheticLM, calib_stream, make_batch_iterator


def test_batches_deterministic_in_step():
    src = SyntheticLM(1000, seed=3)
    a = src.lm_batch(17, 4, 64)
    b = src.lm_batch(17, 4, 64)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_different_steps_differ():
    src = SyntheticLM(1000, seed=3)
    a = src.lm_batch(1, 4, 64)
    b = src.lm_batch(2, 4, 64)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_labels_are_shifted_tokens():
    src = SyntheticLM(1000, seed=0)
    batch = src.lm_batch(0, 2, 32)
    np.testing.assert_array_equal(batch["labels"][:, :-1],
                                  batch["tokens"][:, 1:])


def test_iterator_resume_matches():
    it_full = make_batch_iterator(500, 2, 16, seed=1)
    full = [next(it_full) for _ in range(6)]
    it_resumed = make_batch_iterator(500, 2, 16, seed=1, start_step=3)
    resumed = [next(it_resumed) for _ in range(3)]
    for a, b in zip(full[3:], resumed):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_tokens_in_vocab_and_structured():
    src = SyntheticLM(128, seed=5)
    t = src.sample(0, 4, 256)
    assert t.min() >= 0 and t.max() < 128
    # structure: repeated-motif copy exists -> sequence is compressible
    # (non-uniform bigram distribution)
    uniq = len(np.unique(t))
    assert uniq < 128  # Zipf skew


def test_calib_stream_budget():
    batches = list(calib_stream(100, n_samples=50, seq_len=32, batch=5))
    assert len(batches) == 10
    assert batches[0]["tokens"].shape == (5, 32)
