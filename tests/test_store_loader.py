"""Artifact store + hot-swap loader + multi-tenant serving tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import calibration as C
from repro.core import loader as L
from repro.core import store as S
from repro.models import build_model
from repro.models.param import split
from repro.serving import ServingEngine, VariantRegistry


@pytest.fixture(scope="module")
def pair(tmp_path_factory):
    cfg = dataclasses.replace(get_config("qwen3-8b").reduced(),
                              num_layers=2, compute_dtype="float32",
                              remat=False)
    model = build_model(cfg)
    base, _ = split(model.init(jax.random.PRNGKey(0)))
    # synthetic fine-tune: base + small structured noise
    key = jax.random.PRNGKey(7)
    leaves, treedef = jax.tree.flatten(base)
    keys = jax.random.split(key, len(leaves))
    ft_leaves = [l + 0.01 * jax.random.normal(k, l.shape)
                 for l, k in zip(leaves, keys)]
    ft = jax.tree.unflatten(treedef, ft_leaves)
    return model, base, ft


def test_save_load_roundtrip(pair, tmp_path):
    model, base, ft = pair
    dm = C.compress(base, ft)
    fp = S.base_fingerprint(base)
    manifest = S.save_artifact(dm, tmp_path / "v1", base_fp=fp,
                               meta={"name": "v1"})
    assert manifest["artifact_bytes"] > 0
    dm2 = S.load_artifact(tmp_path / "v1", expect_base_fp=fp)
    for k, e in dm.deltas.items():
        np.testing.assert_array_equal(np.asarray(e.packed),
                                      np.asarray(dm2.deltas[k].packed))
        # vectors round-trip via fp16
        np.testing.assert_allclose(np.asarray(e.v_row, np.float32),
                                   np.asarray(dm2.deltas[k].v_row),
                                   rtol=2e-3, atol=2e-3)


def test_wrong_base_rejected(pair, tmp_path):
    model, base, ft = pair
    dm = C.compress(base, ft)
    S.save_artifact(dm, tmp_path / "v1", base_fp="deadbeef00000000")
    with pytest.raises(ValueError):
        S.load_artifact(tmp_path / "v1", expect_base_fp="badc0ffee0000000")


def test_corruption_detected(pair, tmp_path):
    model, base, ft = pair
    dm = C.compress(base, ft)
    S.save_artifact(dm, tmp_path / "v1")
    # corrupt the npz
    import numpy as np_
    data = dict(np_.load(tmp_path / "v1" / "deltas.npz"))
    key = next(k for k in data if k.endswith("__packed"))
    data[key] = data[key] ^ 1
    np_.savez(tmp_path / "v1" / "deltas.npz", **data)
    with pytest.raises(IOError):
        S.load_artifact(tmp_path / "v1")


def test_manifest_persists_sizes_and_detects_truncation(pair, tmp_path):
    """artifact_bytes + per-file sizes live in the on-disk manifest (store
    v2), and load_artifact refuses a truncated payload file."""
    import json
    model, base, ft = pair
    dm = C.compress(base, ft)
    returned = S.save_artifact(dm, tmp_path / "v1")
    on_disk = json.loads((tmp_path / "v1" / "manifest.json").read_text())
    assert on_disk["version"] == S.STORE_VERSION
    assert on_disk["artifact_bytes"] == returned["artifact_bytes"] > 0
    assert set(on_disk["files"]) == {"deltas.npz", "extras.npz"}
    # truncate the deltas payload: a partial copy must be caught before
    # (or instead of) np.load misbehaving
    f = tmp_path / "v1" / "deltas.npz"
    f.write_bytes(f.read_bytes()[:-64])
    with pytest.raises(IOError):
        S.load_artifact(tmp_path / "v1")


def test_loader_kernel_path_matches_reference(pair):
    model, base, ft = pair
    dm = C.compress(base, ft)
    p_kernel, st1 = L.apply_artifact(base, dm, use_kernel=True)
    p_ref, st2 = L.apply_artifact(base, dm, use_kernel=False)
    for a, b in zip(jax.tree.leaves(p_kernel), jax.tree.leaves(p_ref)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-4)
    assert st1["transferred_bytes"] == st2["transferred_bytes"]


def test_loader_transfers_much_less_than_checkpoint(pair, tmp_path):
    model, base, ft = pair
    dm = C.compress(base, ft)
    _, delta_stats = L.apply_artifact(base, dm, use_kernel=False)
    ckpt = tmp_path / "full_fp16.npz"
    S.save_checkpoint_fp16(ft, ckpt)
    _, full_stats = L.load_full_checkpoint(str(ckpt), ft)
    # packed deltas move far fewer bytes (embeddings dominate tiny models,
    # so require >1.3x here; benchmarks measure the real configs)
    assert delta_stats["transferred_bytes"] * 1.3 < \
        full_stats["transferred_bytes"]


def test_multi_tenant_serving_hot_swap(pair, tmp_path):
    model, base, ft = pair
    dm = C.compress(base, ft)
    S.save_artifact(dm, tmp_path / "task_a", base_fp=S.base_fingerprint(base))

    reg = VariantRegistry(base, max_resident=1)
    reg.register("task_a", tmp_path / "task_a")
    eng = ServingEngine(model, reg, batch_size=2, prompt_len=8, max_len=32)

    rids = [eng.submit(np.arange(1, 6), variant="__base__",
                       max_new_tokens=4),
            eng.submit(np.arange(2, 7), variant="task_a", max_new_tokens=4),
            eng.submit(np.arange(3, 8), variant="task_a", max_new_tokens=4)]
    eng.run_until_drained()
    for rid in rids:
        r = eng.result(rid)
        assert r.status == "done"
        assert len(r.out_tokens) == 4
        assert all(0 <= t < model.cfg.padded_vocab for t in r.out_tokens)
    assert reg.stats["swaps"] == 1  # task_a loaded once, then LRU-resident


def test_serving_survives_corrupt_artifact(pair, tmp_path):
    model, base, ft = pair
    reg = VariantRegistry(base)
    reg.register("broken", tmp_path / "nonexistent")
    eng = ServingEngine(model, reg, batch_size=2, prompt_len=8, max_len=32,
                        max_retries=1)
    ok = eng.submit(np.arange(1, 6), variant="__base__", max_new_tokens=2)
    bad = eng.submit(np.arange(1, 6), variant="broken", max_new_tokens=2)
    eng.run_until_drained()
    assert eng.result(ok).status == "done"
    assert eng.result(bad).status == "failed"
    assert eng.metrics["failed"] == 1
