"""Calibration pipeline on the encoder-decoder (whisper) family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import calibration as C
from repro.models import build_model
from repro.models import whisper as W
from repro.train.step import init_train_state, make_train_step


@pytest.fixture(scope="module")
def whisper_pair():
    cfg = dataclasses.replace(get_config("whisper-base").reduced(),
                              num_layers=2, encoder_layers=2,
                              compute_dtype="float32", remat=False)
    model = build_model(cfg)
    from repro.data.pipeline import SyntheticLM
    src = SyntheticLM(cfg.vocab_size, seed=0)

    def batch(step, seed_off=0):
        b = src.lm_batch(step + seed_off, 2, 16)
        rng = np.random.default_rng(step + seed_off)
        b["frames"] = jnp.asarray(
            0.1 * rng.standard_normal((2, cfg.encoder_frames, cfg.d_model)),
            jnp.float32)
        return b

    step = jax.jit(make_train_step(model, peak_lr=5e-3, warmup=5))
    state = init_train_state(model, jax.random.PRNGKey(0))
    for i in range(20):
        state, _ = step(state, batch(i))
    base = state.params
    for i in range(10):
        state, _ = step(state, batch(i, seed_off=500))
    ft = state.params
    batches = [batch(1000 + i) for i in range(3)]
    return model, base, ft, batches


def test_whisper_io_capture_shapes(whisper_pair):
    model, base, ft, batches = whisper_pair
    cfg = model.cfg
    _, aux = W.forward(base, batches[0], cfg, collect_io=True)
    assert "self_attn.wq" in aux["dec_io"]
    x, y = aux["dec_io"]["self_attn.wq"]
    assert x.shape[0] == cfg.num_layers          # stacked over layers
    assert "attn.wq" in aux["enc_io"]
    # Y really is the linear's output for the captured X
    lw = base["dec_layers"]["self_attn"]["wq"][0]
    np.testing.assert_allclose(np.asarray(x[0] @ lw.T), np.asarray(y[0]),
                               rtol=1e-4, atol=1e-4)


def test_whisper_calibration_improves(whisper_pair):
    model, base, ft, batches = whisper_pair
    cfg = model.cfg
    fwd = jax.jit(lambda p, b: W.forward(p, b, cfg)[0])

    def teacher_mse(dm):
        student = C.apply_delta(base, dm)
        return float(np.mean([
            float(jnp.mean((fwd(ft, b) - fwd(student, b)) ** 2))
            for b in batches]))

    dm0 = C.compress(base, ft)
    err0 = teacher_mse(dm0)
    dm, report = C.calibrate_encdec(model, base, ft, batches,
                                    epochs=2, e2e_epochs=2,
                                    lr=1e-3, e2e_lr=1e-3)
    err1 = teacher_mse(dm)
    assert err1 < err0, (err1, err0)
    # axis selection ran for both stacks
    assert any(k.startswith("enc_layers.") for k in report["axis"])
    assert any(k.startswith("dec_layers.") for k in report["axis"])
