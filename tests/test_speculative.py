"""Cross-variant speculative decoding (DESIGN.md §15).

The contract under test, from the inside out:

* ``Model.verify_step`` — k+1 teacher-forced tokens over the live decode
  cache produce the SAME logits as k+1 sequential ``decode_step`` calls,
  and ``verify_rewind`` leaves a cache that continues decoding exactly
  like one that never saw the rejected suffix (attention families keep
  stale masked K/V, so the equivalence is behavioural, not leaf-wise);
* the speculative round — accepted tokens are the variant's own greedy
  chain for any draft length;
* the engine — ``scheduler="speculative"`` emits bit-identical token
  streams to ``scheduler="continuous"`` for mixed-variant traffic across
  the model families, while measuring per-lane acceptance;
* warmup — every ladder rung's executable is AOT-compiled before traffic
  (zero step compiles afterwards), via the extensible warmup registry.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import calibration as C
from repro.models import build_model
from repro.models.param import split
from repro.serving import Deployment, ServingEngine, VariantRegistry
from repro.serving import speculative as SP

ARCHS = ["deepseek-7b", "deepseek-moe-16b", "whisper-base", "xlstm-350m",
         "zamba2-7b"]


def _model(arch, layers=2):
    cfg = get_config(arch).reduced()
    if layers and cfg.family not in ("ssm", "hybrid"):
        # recurrent families have layer-pattern divisibility constraints;
        # their reduced() configs are already tiny
        cfg = dataclasses.replace(cfg, num_layers=layers)
    cfg = dataclasses.replace(cfg, compute_dtype="float32", remat=False)
    model = build_model(cfg)
    base, _ = split(model.init(jax.random.PRNGKey(0)))
    return model, base


def _jit_step(model):
    """The engine decodes through jitted steps; bit-exactness contracts
    are stated in that regime (an eager op-by-op loop can fuse — and
    round — differently from the same ops inside a compiled scan)."""
    return jax.jit(lambda p, t, c: model.decode_step(p, t, c))


def _prefill_batch(model, bs=2, s=6, seed=3):
    cfg = model.cfg
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(1, cfg.vocab_size, size=(bs, s)), jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(bs, cfg.encoder_frames, cfg.d_model)),
            jnp.bfloat16)
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.normal(size=(bs, cfg.num_image_tokens, cfg.d_model)),
            jnp.bfloat16)
    return batch


# ---------------------------------------------------------------------------
# k ladder + acceptance controller
# ---------------------------------------------------------------------------

def test_default_k_ladder():
    assert SP.default_k_ladder(1) == [1]
    assert SP.default_k_ladder(4) == [1, 2, 4]
    assert SP.default_k_ladder(6) == [1, 2, 4, 6]
    with pytest.raises(ValueError):
        SP.default_k_ladder(0)


def test_acceptance_tracker_walks_ladder():
    tr = SP.AcceptanceTracker(4, cooldown=2)
    assert tr.current_k == 4
    for _ in range(10):                    # nothing accepted: step down
        tr.observe(tr.current_k, 0, 4)
    assert tr.current_k == 1
    for _ in range(20):                    # everything accepted: step up
        tr.observe(tr.current_k, tr.current_k * 4, 4)
    assert tr.current_k == 4
    snap = tr.snapshot()
    assert snap["ladder"] == [1, 2, 4]
    assert 0.0 <= snap["acceptance"] <= 1.0
    frozen = SP.AcceptanceTracker(4, adaptive=False, cooldown=1)
    for _ in range(10):
        frozen.observe(4, 0, 4)
    assert frozen.current_k == 4           # adaptive=False pins k


def test_acceptance_tracker_ignores_empty_rounds():
    tr = SP.AcceptanceTracker(2)
    tr.observe(2, 0, 0)
    assert tr.drafted == 0 and tr.acceptance == 0.0


# ---------------------------------------------------------------------------
# verify_step / verify_rewind vs sequential decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCHS)
def test_verify_step_matches_sequential_decode(arch):
    model, base = _model(arch)
    # match the reference's compilation regime to verify_step's: native
    # (pos-mode) verify is plain eager ops — compare against the eager
    # loop; the snap-mode fallback wraps decode_step in a compiled scan —
    # compare against the jitted step (same fusion, hence same rounding).
    # The engine-level tests below cover the only regime that ships.
    if hasattr(model._mod, "verify_step"):
        step = lambda p, t, c: model.decode_step(p, t, c)  # noqa: E731
    else:
        step = _jit_step(model)
    last, cache = model.prefill(base, _prefill_batch(model), 32)
    T = 3
    toks = [jnp.argmax(last, -1).astype(jnp.int32)]
    c, logits = cache, []
    for _ in range(T):
        lg, c = step(base, toks[-1], c)
        logits.append(lg)
        toks.append(jnp.argmax(lg, -1).astype(jnp.int32))
    ref = jnp.stack(logits, axis=1)                      # (B, T, V)
    seq = jnp.stack(toks[:T], axis=1)                    # (B, T)
    got, rewind_state = model.verify_step(base, seq, cache)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    # rewind to keep tokens == a cache that never decoded past keep:
    # the NEXT decode step must be bit-identical (attention families
    # keep stale masked K/V rows, so leaves may legitimately differ)
    B = seq.shape[0]
    for keep in (1, 2, T):
        rw = model.verify_rewind(rewind_state,
                                 jnp.full((B,), keep, jnp.int32))
        c2 = cache
        for j in range(keep):
            _, c2 = step(base, toks[j], c2)
        lg_a, _ = step(base, toks[keep], rw)
        lg_b, _ = step(base, toks[keep], c2)
        np.testing.assert_array_equal(np.asarray(lg_a), np.asarray(lg_b))


def test_verify_rewind_is_per_row():
    """Rows rewind independently: row 0 keeps 1 token, row 1 keeps all."""
    model, base = _model("deepseek-7b")
    step = _jit_step(model)
    last, cache = model.prefill(base, _prefill_batch(model), 32)
    T = 3
    toks = [jnp.argmax(last, -1).astype(jnp.int32)]
    c = cache
    for _ in range(T):
        lg, c = step(base, toks[-1], c)
        toks.append(jnp.argmax(lg, -1).astype(jnp.int32))
    seq = jnp.stack(toks[:T], axis=1)
    _, rewind_state = model.verify_step(base, seq, cache)
    rw = model.verify_rewind(rewind_state, jnp.asarray([1, T], jnp.int32))
    nxt = jnp.stack([toks[1][0], toks[T][1]])
    lg_mix, _ = step(base, nxt, rw)
    c_a = cache
    _, c_a = step(base, toks[0], c_a)
    lg_a, _ = step(base, nxt, c_a)
    c_b = cache
    for j in range(T):
        _, c_b = step(base, toks[j], c_b)
    lg_b, _ = step(base, nxt, c_b)
    np.testing.assert_array_equal(np.asarray(lg_mix[0]), np.asarray(lg_a[0]))
    np.testing.assert_array_equal(np.asarray(lg_mix[1]), np.asarray(lg_b[1]))


def test_spec_round_emits_greedy_chain():
    """ver[:, :n_acc+1] is the model's own greedy continuation and the
    round's cache continues it exactly — for base (all-accept) rows."""
    model, base = _model("deepseek-7b")
    step = _jit_step(model)
    last, cache = model.prefill(base, _prefill_batch(model), 32)
    t0 = jnp.argmax(last, -1).astype(jnp.int32)
    k = 3
    round_fn = jax.jit(SP.make_round_fn(model, k))
    ver, n_acc, next_tok, new_cache = round_fn(base, None,
                                               jnp.zeros_like(t0), t0,
                                               cache)
    # overlay None: draft model == verify model, every draft accepted
    assert np.all(np.asarray(n_acc) == k)
    chain = [t0]
    c = cache
    for _ in range(k + 1):
        lg, c = step(base, chain[-1], c)
        chain.append(jnp.argmax(lg, -1).astype(jnp.int32))
    np.testing.assert_array_equal(np.asarray(ver),
                                  np.asarray(jnp.stack(chain[1:], 1)))
    np.testing.assert_array_equal(np.asarray(next_tok),
                                  np.asarray(chain[k + 1]))
    # the rewound cache continues the chain bit-exactly
    lg_a, _ = step(base, next_tok, new_cache)
    lg_b, _ = step(base, next_tok, c)
    np.testing.assert_array_equal(np.asarray(lg_a), np.asarray(lg_b))


# ---------------------------------------------------------------------------
# engine: token parity with the continuous scheduler
# ---------------------------------------------------------------------------

def _serve(arch, *, speculative, draft_k=3, layers=2):
    model, base = _model(arch, layers=layers)
    cfg = model.cfg
    pert, _ = split(model.init(jax.random.PRNGKey(1)))
    dep = Deployment(model, base, mode="fused",
                     speculative=speculative, draft_k=draft_k,
                     batch_size=3, prompt_len=8, max_len=48, bank_size=4)
    for i, s in enumerate((0.05, -0.05)):
        ft = jax.tree.map(lambda b, f: b + s * f, base, pert)
        dep.publish(f"v{i}", C.compress(base, ft))
    rng = np.random.default_rng(0)
    rids = []
    for i, v in enumerate(["__base__", "v0", "v1", "v0", "__base__", "v1"]):
        rids.append(dep.submit(rng.integers(1, cfg.vocab_size, size=6),
                               variant=v, max_new_tokens=6 + (i % 3)))
    dep.drain()
    toks = [dep.result(r).out_tokens for r in rids]
    return toks, dep


@pytest.mark.parametrize("arch", ARCHS)
def test_speculative_matches_continuous_tokens(arch):
    cont, _ = _serve(arch, speculative=False)
    spec, dep = _serve(arch, speculative=True)
    assert spec == cont
    snap = dep.status()["speculative"]
    assert snap["rounds"] > 0 and snap["drafted"] > 0
    assert 0.0 <= snap["acceptance"] <= 1.0
    # per-request acceptance rides on Deployment.status(rid)
    st = dep.status(0)
    assert 0.0 <= st["acceptance"] <= 1.0
    assert st["ttft_seconds"] is not None and st["ttft_seconds"] >= 0.0
    dep.close()


def test_speculative_parity_any_draft_k():
    """Exactness is k-independent (adaptive k can never break it)."""
    cont, _ = _serve("deepseek-7b", speculative=False)
    for k in (1, 4):
        spec, dep = _serve("deepseek-7b", speculative=True, draft_k=k)
        assert spec == cont, f"draft_k={k}"
        dep.close()


def test_speculative_rejects_windowed_cache():
    model, base = _model("gemma3-12b")   # sliding-window layers
    reg = VariantRegistry(base, mode="fused", bank_size=2)
    with pytest.raises(ValueError, match="windowless"):
        ServingEngine(model, reg, scheduler="speculative")


def test_speculative_requires_continuous_base():
    model, base = _model("deepseek-7b")
    with pytest.raises(ValueError):
        Deployment(model, base, mode="fused", scheduler="group",
                   speculative=True)


# ---------------------------------------------------------------------------
# warmup registry + TTFT surfacing
# ---------------------------------------------------------------------------

def test_warmup_registry_covers_speculative_ladder():
    model, base = _model("deepseek-7b")
    dep = Deployment(model, base, mode="fused", speculative=True,
                     draft_k=4, batch_size=2, prompt_len=8, max_len=48,
                     bank_size=4)
    out = dep.warmup()
    for k in (1, 2, 4):
        assert out[f"spec/spec_k{k}"] in ("compiled", "hit")
        assert out[f"spec-empty/spec_k{k}"] in ("compiled", "hit")
    c0 = dep.metrics["step_compiles"]
    pert, _ = split(model.init(jax.random.PRNGKey(1)))
    dep.publish("v0", C.compress(
        base, jax.tree.map(lambda b, f: b + 0.05 * f, base, pert)))
    rng = np.random.default_rng(0)
    for v in ("__base__", "v0"):
        dep.submit(rng.integers(1, model.cfg.vocab_size, size=6),
                   variant=v, max_new_tokens=6)
    dep.drain()
    assert dep.metrics["step_compiles"] == c0, \
        "speculative traffic must be fully covered by warmup"
    dep.close()


def test_warmup_registry_is_extensible():
    model, base = _model("deepseek-7b")
    reg = VariantRegistry(base, mode="fused", bank_size=2)
    eng = ServingEngine(model, reg, batch_size=2, prompt_len=8, max_len=32)
    with pytest.raises(ValueError, match="unknown warmup pairs"):
        eng.warmup(pairs=("nope",))
    seen = []
    eng.register_warmup("custom", lambda ctx: seen.append(
        sorted(ctx)))                        # ctx is the shared context
    eng.warmup(pairs=("custom",))
    assert seen and "warm" in seen[0] and "cache" in seen[0]
    # default warmup (pairs=None) runs every registered entry
    eng.warmup()
    assert len(seen) == 2


def test_ttft_in_engine_status():
    model, base = _model("deepseek-7b")
    reg = VariantRegistry(base, mode="fused", bank_size=2)
    eng = ServingEngine(model, reg, batch_size=2, prompt_len=8,
                        max_len=32, scheduler="continuous")
    rng = np.random.default_rng(0)
    rid = eng.submit(rng.integers(1, model.cfg.vocab_size, size=6),
                     max_new_tokens=4)
    eng.run_until_drained()
    r = eng.result(rid)
    assert r.first_token_at is not None
    assert r.first_token_at >= r.submitted_at
    ttft = eng.status()["ttft"]
    assert ttft["count"] == 1
    assert ttft["max_seconds"] >= ttft["mean_seconds"] > 0.0
