"""Per-shard Pallas delta kernels under shard_map (DESIGN.md §12).

Three layers of coverage:

* pure planning (fake mesh, no devices; runs in tier-1): spec derivation,
  psum decision, packing-width fallbacks, and the `_pick_block` refusal
  for misaligned shard-local dims;
* 1-device no-mesh fallback (tier-1): outside a mesh context the ops
  wrappers must take the global jit path byte-for-byte — dispatch is
  invisible single-device;
* 4-device execution (sharded-smoke CI job, skip otherwise): kernel- and
  model-level logits parity sweeps (fused + banked, all four families)
  between the shard_map'd per-shard path, the PR-4 GSPMD-partitioned
  path (``no_dispatch`` / engine ``kernel_dispatch="gspmd"``) and the
  unsharded single-device path, plus the acceptance bar — bit-identical
  greedy tokens from the continuous-batching engine under both mesh
  lowerings.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.core import calibration as C
from repro.core import loader as L
from repro.distributed import sharding as S
from repro.kernels import dispatch as D
from repro.kernels import ops as K
from repro.models import build_model
from repro.models import delta_overlay as DO
from repro.models.param import split
from repro.serving import Deployment
from repro.serving.variants import OverlayBank

RULES = S.rules_for("decode")


def _mesh22() -> Mesh:
    if len(jax.devices()) < 4:
        pytest.skip("needs 4 devices (sharded-smoke CI job)")
    return Mesh(np.asarray(jax.devices()[:4]).reshape(2, 2),
                ("data", "model"))


def _fake_mesh(shape, names):
    class M:
        axis_names = names
        devices = np.empty(shape, object)
    return M()


def _rand_entry(rng, n, k, nb=None):
    shp = (n, k // 8) if nb is None else (nb, n, k // 8)
    packed = jnp.asarray(rng.integers(0, 256, size=shp, dtype=np.uint8))
    vr = jnp.asarray(rng.normal(size=(n,) if nb is None
                                else (nb, n)).astype(np.float16))
    vc = jnp.zeros((k,) if nb is None else (nb, k), jnp.float16)
    wb = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
    return packed, vr, vc, wb


# ---------------------------------------------------------------------------
# planning (tier-1: no devices needed)
# ---------------------------------------------------------------------------

def test_plan_row_sharded():
    mesh = _fake_mesh((2, 2), ("data", "model"))
    plan = D.plan_matmul(mesh, RULES, ("ffn", "embed"), m=8, n=32, k=24)
    assert plan.o_part == "model" and plan.i_part is None
    assert plan.m_part == "data"
    assert plan.psum_axes == ()


def test_plan_col_sharded_psums():
    mesh = _fake_mesh((2, 2), ("data", "model"))
    plan = D.plan_matmul(mesh, RULES, ("embed", "ffn"), m=8, n=24, k=32)
    assert plan.o_part is None and plan.i_part == "model"
    assert plan.psum_axes == ("model",)


def test_plan_refuses_misaligned_local_k():
    """K sharded 2-way would leave an 8-element local tile -> 4 bytes of
    packed plane per shard: not a packing-width multiple, so the plan must
    decline (global path) instead of letting _pick_block mis-size."""
    mesh = _fake_mesh((2, 2), ("data", "model"))
    assert D.plan_matmul(mesh, RULES, ("embed", "ffn"), m=4, n=16, k=8) \
        is None


def test_plan_none_without_axes():
    mesh = _fake_mesh((2, 2), ("data", "model"))
    assert D.plan_matmul(mesh, RULES, None, m=8, n=32, k=24) is None


def test_plan_multi_pod_batch_axes():
    mesh = _fake_mesh((2, 2, 2), ("pod", "data", "model"))
    plan = D.plan_matmul(mesh, RULES, ("ffn", "embed"), m=8, n=32, k=24)
    assert plan.m_part == ("pod", "data")


def test_pick_block_refuses_misaligned():
    with pytest.raises(ValueError, match="not a multiple"):
        K._pick_block(12, 512, multiple=8)
    with pytest.raises(ValueError, match="not a multiple"):
        K._pick_block(4, 512, multiple=8)   # dim smaller than the width
    assert K._pick_block(24, 512, multiple=8) == 24
    assert K._pick_block(40, 16, multiple=8) == 8
    # multiple > target: smallest VALID block, not an oversized dim block
    assert K._pick_block(64, 4, multiple=8) == 8


def test_shared_spec_surgery_matches_logical():
    """The ONE spec-surgery helper (delta_overlay.entry_shardings_from_
    weight) agrees with the logical derivation entry_axes resolves to —
    same equivalence the PR-4 loader regression asserts, now at the
    helper level both loader paths share."""
    mesh = _mesh22()
    w_sh = NamedSharding(mesh, P("model", None))
    ent = DO.entry_shardings_from_weight(w_sh, 2)
    ax = DO.entry_axes(("ffn", "embed"))
    assert ent.packed.spec == S.resolve_spec((32, 4), ax.packed, RULES, mesh)
    assert ent.v_row.spec == S.resolve_spec((32,), ax.v_row, RULES, mesh)
    assert ent.v_col.spec == S.resolve_spec((32,), ax.v_col, RULES, mesh)
    assert DO.entry_shardings_from_weight(object(), 2) is None


# ---------------------------------------------------------------------------
# 1-device no-mesh fallback (tier-1)
# ---------------------------------------------------------------------------

def test_no_mesh_state_inactive():
    assert D.state() is None
    with D.no_dispatch():
        assert D.state() is None


def test_no_mesh_waxes_is_global_path():
    """Outside a mesh context, passing waxes must be a no-op: identical
    results to the waxes-free call and to the jnp oracle."""
    from repro.kernels import ref
    rng = np.random.default_rng(3)
    packed, vr, vc, wb = _rand_entry(rng, 32, 24)
    x = jnp.asarray(rng.normal(size=(4, 24)).astype(np.float32))
    base = K.bitlinear_axes(x, packed, vr, vc, wb)
    with_axes = K.bitlinear_axes(x, packed, vr, vc, wb,
                                 waxes=("ffn", "embed"))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(with_axes))
    want = ref.bitlinear_axes_ref(x, packed, vr, vc, wb)
    np.testing.assert_allclose(np.asarray(with_axes), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # banked + unpack_apply take the same fallback
    packed_b, vrb, vcb, wbb = _rand_entry(rng, 32, 24, nb=3)
    vidx = jnp.asarray(rng.integers(0, 3, size=(4,)), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(K.bitlinear_axes_banked(x, vidx, packed_b, vrb, vcb, wbb,
                                           waxes=("ffn", "embed"))),
        np.asarray(K.bitlinear_axes_banked(x, vidx, packed_b, vrb, vcb,
                                           wbb)))
    v = jnp.asarray(rng.normal(size=(32,)).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(K.unpack_apply(packed, v, wb, mode="row",
                                  waxes=("ffn", "embed"))),
        np.asarray(K.unpack_apply(packed, v, wb, mode="row")))


# ---------------------------------------------------------------------------
# 4-device kernel-level parity
# ---------------------------------------------------------------------------

def test_kernel_parity_row_col_banked_unpack():
    mesh = _mesh22()
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 24)).astype(np.float32))

    packed, vr, vc, wb = _rand_entry(rng, 32, 24)
    want = K.bitlinear_axes(x, packed, vr, vc, wb)
    with S.shard_ctx(mesh, RULES):
        got = K.bitlinear_axes(x, packed, vr, vc, wb, waxes=("ffn", "embed"))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)

    # col-sharded contraction: partial sums psum over `model`
    x2 = jnp.asarray(rng.normal(size=(4, 2, 32)).astype(np.float32))
    packed2, vr2, vc2, wb2 = _rand_entry(rng, 24, 32)
    want2 = K.bitlinear_axes(x2, packed2, vr2, vc2, wb2)
    with S.shard_ctx(mesh, RULES):
        got2 = K.bitlinear_axes(x2, packed2, vr2, vc2, wb2,
                                waxes=("embed", "ffn"))
    np.testing.assert_allclose(np.asarray(got2), np.asarray(want2),
                               rtol=2e-5, atol=2e-5)

    packed_b, vrb, vcb, wbb = _rand_entry(rng, 32, 24, nb=3)
    vidx = jnp.asarray(rng.integers(0, 3, size=(8,)), jnp.int32)
    wantb = K.bitlinear_axes_banked(x, vidx, packed_b, vrb, vcb, wbb)
    with S.shard_ctx(mesh, RULES):
        gotb = K.bitlinear_axes_banked(x, vidx, packed_b, vrb, vcb, wbb,
                                       waxes=("ffn", "embed"))
    np.testing.assert_allclose(np.asarray(gotb), np.asarray(wantb),
                               rtol=2e-5, atol=2e-5)

    v = jnp.asarray(rng.normal(size=(32,)).astype(np.float32))
    wantu = K.unpack_apply(packed, v, wb, mode="row")
    with S.shard_ctx(mesh, RULES):
        gotu = K.unpack_apply(packed, v, wb, mode="row",
                              waxes=("ffn", "embed"))
    np.testing.assert_array_equal(np.asarray(gotu), np.asarray(wantu))


# ---------------------------------------------------------------------------
# 4-device model-level sweeps (fused + banked, all four families)
# ---------------------------------------------------------------------------

def _family_pair(arch: str):
    """fp32-compute toy pair; layers=2 where the family allows an override
    (xlstm/zamba keep their reduced super-block counts)."""
    cfg = get_config(arch).reduced()
    if arch in ("deepseek-7b", "deepseek-moe-16b"):
        cfg = dataclasses.replace(cfg, num_layers=2)
    cfg = dataclasses.replace(cfg, compute_dtype="float32", remat=False)
    model = build_model(cfg)
    base, axes = split(model.init(jax.random.PRNGKey(0)))
    pert, _ = split(model.init(jax.random.PRNGKey(1)))
    ft1 = jax.tree.map(lambda b, f: b + 0.05 * f, base, pert)
    ft2 = jax.tree.map(lambda b, f: b - 0.05 * f, base, pert)
    return model, base, axes, C.compress(base, ft1), C.compress(base, ft2)


def _tokens_batch(model, bs=4, s=8):
    batch = {"tokens": jnp.asarray(np.random.default_rng(7).integers(
        1, model.cfg.vocab_size, size=(bs, s)), jnp.int32)}
    if model.cfg.family == "audio":
        batch["frames"] = jnp.zeros(
            (bs, model.cfg.encoder_frames, model.cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ["deepseek-7b", "deepseek-moe-16b",
                                  "xlstm-350m", "zamba2-7b",
                                  "whisper-base"])
def test_waxes_literals_match_param_declarations(arch, monkeypatch):
    """Drift guard for the hardcoded ``waxes=(...)`` call-site literals:
    every axes tuple the model families pass into the delta kernels must
    agree with the ``Param.axes`` declared at init for a weight of that
    shape (the single source of truth ``models/param.split`` recovers).
    A mismatched literal would silently make shard_map reshard the weight
    tile every step — parity stays green, the win evaporates — so this
    runs in tier-1, recording at trace time (no mesh needed).

    ``waxes=None`` records are the intentional GSPMD-fallback sites (the
    vmapped expert path); at least one dispatch-capable site must fire."""
    import repro.kernels.ops as OPS
    model, base, axes, dm1, dm2 = _family_pair(arch)
    flat_axes = DO.flatten_axes(axes)
    flat_base = C.flatten_params(base)
    declared: dict = {}
    for p in dm1.deltas:
        declared.setdefault(tuple(flat_base[p].shape[-2:]),
                            set()).add(tuple(flat_axes[p][-2:]))

    recorded = []
    orig, orig_b = OPS.bitlinear_axes, OPS.bitlinear_axes_banked

    def probe(x, packed, v_row, v_col, w_base, waxes=None):
        recorded.append((tuple(w_base.shape[-2:]), waxes))
        return orig(x, packed, v_row, v_col, w_base, waxes=waxes)

    def probe_b(x, vidx, packed, v_row, v_col, w_base, waxes=None):
        recorded.append((tuple(w_base.shape[-2:]), waxes))
        return orig_b(x, vidx, packed, v_row, v_col, w_base, waxes=waxes)

    monkeypatch.setattr(OPS, "bitlinear_axes", probe)
    monkeypatch.setattr(OPS, "bitlinear_axes_banked", probe_b)

    batch = _tokens_batch(model)
    # fused prefill + decode AND a banked step: every delta call site
    # (incl. the decode-only ones) traces through the probes
    pv, ov, _ = L.device_put_overlay(base, dm1)
    lg, cache = jax.jit(lambda p, o, b: model.prefill(
        p, b, 32, overlay=o))(pv, ov, batch)
    tok = jnp.argmax(lg, -1).astype(jnp.int32)
    jax.jit(lambda p, o, t, c: model.decode_step(
        p, t, c, overlay=o))(pv, ov, tok, cache)
    bank = OverlayBank(base, 3)
    s1, _ = bank.admit("v1", dm1)
    vidx = jnp.asarray([0, s1, s1, 0], jnp.int32)
    jax.jit(lambda p, bk, vi, b: model.prefill(
        p, b, 32, overlay=bk, variant_idx=vi))(base, bank.tree, vidx, batch)

    assert recorded
    assert any(w is not None for _, w in recorded), "no dispatch-capable site"
    for shape, waxes in recorded:
        if waxes is None:       # intentional GSPMD fallback (vmapped experts)
            continue
        assert shape in declared, (shape, waxes)
        assert tuple(waxes) in declared[shape], (shape, waxes,
                                                 declared[shape])


@pytest.mark.parametrize("mode", ["fused", "banked"])
@pytest.mark.parametrize("arch", ["deepseek-7b", "deepseek-moe-16b",
                                  "xlstm-350m", "zamba2-7b"])
def test_family_logits_parity_per_shard_vs_global(arch, mode):
    """Per-shard shard_map'd kernels vs the GSPMD-partitioned global
    kernels vs single-device: logits agree to fp32-reduction tolerance,
    greedy tokens exactly — for single-variant fused overlays and for
    banked mixed-variant batches, across all four families."""
    mesh = _mesh22()
    model, base, axes, dm1, dm2 = _family_pair(arch)
    batch = _tokens_batch(model)

    def run(use_mesh, gspmd=False):
        import contextlib
        stack = contextlib.ExitStack()
        if use_mesh:
            param_sh = S.tree_shardings(base, axes, RULES, mesh)
            params = jax.device_put(base, param_sh)
            stack.enter_context(mesh)
            stack.enter_context(S.shard_ctx(mesh, RULES))
            if gspmd:
                stack.enter_context(D.no_dispatch())
        else:
            params, param_sh = base, None
        with stack:
            if mode == "banked":
                bank = OverlayBank(params, 4,
                                   mesh=mesh if use_mesh else None,
                                   param_axes=axes if use_mesh else None)
                s1, _ = bank.admit("v1", dm1)
                s2, _ = bank.admit("v2", dm2)
                vidx = jnp.asarray([0, s1, s2, s1], jnp.int32)
                pf = jax.jit(lambda p, bk, vi, b: model.prefill(
                    p, b, 32, overlay=bk, variant_idx=vi))
                dc = jax.jit(lambda p, bk, vi, t, c: model.decode_step(
                    p, t, c, overlay=bk, variant_idx=vi))
                lg, cache = pf(params, bank.tree, vidx, batch)
                tok = jnp.argmax(lg, -1).astype(jnp.int32)
                dl, _ = dc(params, bank.tree, vidx, tok, cache)
            else:
                pv, ov, _ = L.device_put_overlay(
                    params, dm1, param_shardings=param_sh)
                pf = jax.jit(lambda p, o, b: model.prefill(
                    p, b, 32, overlay=o))
                dc = jax.jit(lambda p, o, t, c: model.decode_step(
                    p, t, c, overlay=o))
                lg, cache = pf(pv, ov, batch)
                tok = jnp.argmax(lg, -1).astype(jnp.int32)
                dl, _ = dc(pv, ov, tok, cache)
        return np.asarray(lg), np.asarray(dl)

    want_pre, want_dec = run(False)
    got_pre, got_dec = run(True)
    ab_pre, ab_dec = run(True, gspmd=True)
    tol = 1e-4 * max(float(np.max(np.abs(want_pre))), 1.0)
    assert float(np.max(np.abs(got_pre - want_pre))) < tol
    assert float(np.max(np.abs(got_dec - want_dec))) < tol
    for got, want in [(got_pre, want_pre), (got_dec, want_dec),
                      (got_pre, ab_pre), (got_dec, ab_dec)]:
        np.testing.assert_array_equal(got.argmax(-1), want.argmax(-1))


# ---------------------------------------------------------------------------
# 4-device engine acceptance: shard_map vs gspmd, continuous + fused group
# ---------------------------------------------------------------------------

def test_engine_continuous_token_parity_shard_map_vs_gspmd():
    """ACCEPTANCE: the continuous-batching engine on the 4-device mesh
    emits bit-identical greedy tokens whether its fused/banked delta GEMMs
    lower per-shard (shard_map) or via the PR-4 GSPMD path — and both
    match the single-device engine."""
    mesh = _mesh22()
    model, base, axes, dm1, dm2 = _family_pair("deepseek-7b")

    def run(mesh_or_none, kernel_dispatch="shard_map"):
        dep = Deployment(model, base, batch_size=2, prompt_len=8,
                         max_len=32, bank_size=4, mesh=mesh_or_none,
                         param_axes=axes if mesh_or_none else None,
                         kernel_dispatch=kernel_dispatch)
        dep.publish("v1", dm1)
        dep.publish("v2", dm2)
        rids = [dep.submit(np.arange(1, 7), variant=v, max_new_tokens=m)
                for v, m in [("v1", 3), ("__base__", 5), ("v2", 2),
                             ("v1", 4), ("v2", 3)]]
        dep.drain()
        return [dep.result(r).out_tokens for r in rids]

    single = run(None)
    shard_map_toks = run(mesh, "shard_map")
    gspmd_toks = run(mesh, "gspmd")
    assert shard_map_toks == gspmd_toks == single


def test_engine_group_fused_token_parity_shard_map_vs_gspmd():
    """Same acceptance bar for the group scheduler's single-variant fused
    residency (per-variant overlays, non-banked kernels)."""
    mesh = _mesh22()
    model, base, axes, dm1, _ = _family_pair("deepseek-7b")
    from repro.serving import ServingEngine, VariantRegistry

    def run(mesh_or_none, kernel_dispatch="shard_map"):
        kw = {}
        params = base
        if mesh_or_none is not None:
            param_sh = S.tree_shardings(base, axes, RULES, mesh_or_none)
            params = jax.device_put(base, param_sh)
            kw = dict(param_shardings=param_sh, mesh=mesh_or_none,
                      param_axes=axes)
        reg = VariantRegistry(params, mode="fused", max_resident=4, **kw)
        reg.register("v1", dm1)
        eng = ServingEngine(model, reg, batch_size=2, prompt_len=8,
                            max_len=32, scheduler="group",
                            mesh=mesh_or_none,
                            kernel_dispatch=kernel_dispatch)
        rids = [eng.submit(np.arange(1, 7), variant=v, max_new_tokens=3)
                for v in ["v1", "__base__", "v1"]]
        eng.run_until_drained()
        return [eng.result(r).out_tokens for r in rids]

    assert run(mesh, "shard_map") == run(mesh, "gspmd") == run(None)


def test_engine_rejects_unknown_kernel_dispatch():
    model, base, axes, dm1, _ = _family_pair("deepseek-7b")
    from repro.serving import ServingEngine, VariantRegistry
    reg = VariantRegistry(base, mode="fused")
    with pytest.raises(ValueError, match="kernel_dispatch"):
        ServingEngine(model, reg, kernel_dispatch="magic")


def test_dense_reconstruction_per_shard():
    """apply_artifact(param_axes=) inside a mesh context reconstructs
    unstacked Ŵ per-shard (the production dense-residency path the
    registry threads) — bit-identical to the no-mesh reconstruction.
    zamba: its shared attention/MLP delta targets are 2-D (unstacked), so
    the per-shard unpack path genuinely engages (stacked entries stay on
    the vmapped global kernel)."""
    mesh = _mesh22()
    model, base, axes, dm1, _ = _family_pair("zamba2-7b")
    want, _ = L.apply_artifact(base, dm1)
    param_sh = S.tree_shardings(base, axes, RULES, mesh)
    sharded = jax.device_put(base, param_sh)
    with S.shard_ctx(mesh, RULES):
        got, _ = L.apply_artifact(sharded, dm1, param_shardings=param_sh,
                                  param_axes=axes)
    for path, w in C.flatten_params(want).items():
        np.testing.assert_array_equal(
            np.asarray(C.flatten_params(got)[path]), np.asarray(w), path)


# ---------------------------------------------------------------------------
# apply_update on derived shardings (shared spec-surgery helper)
# ---------------------------------------------------------------------------

def test_apply_update_lifts_to_derived_shardings():
    """With param_shardings, apply_update places every patched entry leaf
    on the placement the shared helper derives from the weight sharding —
    the same layout device_put_overlay transfers to — so a patched variant
    starts life sharded."""
    mesh = _mesh22()
    model, base, axes, dm1, _ = _family_pair("deepseek-7b")
    param_sh = S.tree_shardings(base, axes, RULES, mesh)
    flat_sh = C.flatten_params(param_sh)
    path = next(iter(dm1.deltas))
    e = dm1.deltas[path]
    patch = {path: {
        "packed": np.zeros(e.packed.size, np.uint8),
        "v_row": np.zeros(e.v_row.size, np.uint16),
        "v_col": np.zeros(e.v_col.size, np.uint16),
        "use_row": np.zeros(e.use_row.size, bool).reshape(e.use_row.shape),
    }}
    dm2 = L.apply_update(dm1, patch, {}, param_shardings=param_sh)
    want = DO.entry_shardings_from_weight(flat_sh[path], e.packed.ndim)
    got = dm2.deltas[path]
    # is_equivalent_to, not spec equality: jit outputs normalise trailing
    # Nones (P(None, None) -> P())
    assert got.packed.sharding.is_equivalent_to(want.packed,
                                                got.packed.ndim)
    assert got.v_row.sharding.is_equivalent_to(want.v_row, got.v_row.ndim)
    assert got.v_col.sharding.is_equivalent_to(want.v_col, got.v_col.ndim)
    np.testing.assert_array_equal(np.asarray(got.packed),
                                  np.asarray(e.packed))
