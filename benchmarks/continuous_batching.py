"""Mixed-variant continuous batching vs grouped-by-variant serving.

The multi-tenant pain point: under skewed traffic over many variants, a
grouped scheduler (one variant per batch) runs mostly-empty decode batches
— slot occupancy collapses with variant count.  The continuous slot
scheduler (serving/engine.py, DESIGN.md §9) admits ANY queued request into
any free lane and fuses each row's variant from the overlay bank, so
occupancy stays near 1.0 regardless of the traffic mix.

Measures, on identical skewed 8-variant traffic at toy sizes:

* end-to-end drain throughput (tokens/sec incl. prefills) per scheduler —
  acceptance: continuous >= 1.5x grouped;
* decode slot occupancy (tokens emitted / lane-steps available);
* per-request parity: greedy tokens from the mixed-variant banked path
  must equal the grouped PR-1 fused path exactly.
"""
from __future__ import annotations

import time

import jax
import numpy as np


# skewed 8-variant traffic: a few hot tenants, a long tail — the regime
# where grouped batching fragments (most groups hold 1-2 requests)
TRAFFIC = ["v0", "v1", "v0", "v2", "v3", "v0", "v4", "v5",
           "v1", "v6", "v7", "v2", "v0", "v3", "v1", "v4"]
MAX_NEW = 24
BATCH = 16   # grouped-by-variant fills at most 4/16 lanes on this traffic


def _engines(scheduler: str):
    from benchmarks.common import tiny_pair
    from repro.core import calibration as C
    from repro.serving import ServingEngine, VariantRegistry

    model, base, ft, _, _ = tiny_pair("deepseek-7b", layers=2,
                                      base_steps=20, ft_steps=10)
    # 8 distinct variants from one calibration recipe (shared structure —
    # the bank requirement): perturb the fine-tune per tenant
    reg = VariantRegistry(base, mode="fused", max_resident=16, bank_size=9)
    for i in range(8):
        ft_i = jax.tree.map(lambda b, f, s=i: b + (1 + 0.1 * s) * (f - b),
                            base, ft)
        reg.register(f"v{i}", C.compress(base, ft_i))
    eng = ServingEngine(model, reg, batch_size=BATCH, prompt_len=16,
                        max_len=64, scheduler=scheduler)
    return model, reg, eng


def _drain(eng) -> dict:
    before = dict(eng.metrics)
    rids = [eng.submit(np.arange(1, 9), variant=v, max_new_tokens=MAX_NEW)
            for v in TRAFFIC]
    t0 = time.perf_counter()
    eng.run_until_drained()
    dt = time.perf_counter() - t0
    toks = [eng.result(r).out_tokens for r in rids]
    assert all(eng.result(r).status == "done" for r in rids)
    delta = {k: eng.metrics[k] - before[k]
             for k in eng.metrics if isinstance(before[k], (int, float))}
    return {"seconds": dt, "tokens": toks,
            "generated": sum(len(t) for t in toks),
            "metrics": delta}


def run() -> list:
    from benchmarks.common import row

    out = []
    results = {}
    for sched in ("group", "continuous"):
        model, reg, eng = _engines(sched)
        # warm-up outside the timed drain: compile both jit pairs (incl.
        # the admission-merge path — hence two staggered waves) AND make
        # every variant resident (steady-state serving is the claim; cold
        # admit/swap latency is measured by the fused_serving bench)
        warm = [eng.submit(np.arange(1, 9), variant=f"v{i % 8}",
                           max_new_tokens=2 if i < 8 else 4)
                for i in range(BATCH + 1)]
        eng.run_until_drained()
        assert all(eng.result(w).status == "done" for w in warm)
        results[sched] = _drain(eng)
        m = results[sched]["metrics"]
        lane_steps = (m.get("decode_steps", 0) * BATCH
                      if sched == "continuous" else None)
        occ = (results[sched]["generated"] / lane_steps
               if lane_steps else float("nan"))
        tput = results[sched]["generated"] / results[sched]["seconds"]
        out.append(row(
            f"continuous_batching/{sched}",
            results[sched]["seconds"] * 1e6,
            f"tokens={results[sched]['generated']};"
            f"tput_tps={tput:.1f};prefills={m['prefills']};"
            f"decode_s={m['decode_seconds']:.3f};"
            + (f"occupancy={occ:.2f};" if lane_steps else "")
            + f"swaps={reg.stats['swaps']};"
              f"resident_bytes={reg.stats['resident_bytes']}"))

    # per-request parity: identical greedy tokens under either scheduler
    # (aligned by submission order — separate engines, separate rids)
    parity = results["continuous"]["tokens"] == results["group"]["tokens"]
    speedup = results["group"]["seconds"] / results["continuous"]["seconds"]
    out.append(row("continuous_batching/speedup_vs_grouped", 0,
                   f"speedup={speedup:.2f};pass_ge_1_5={speedup >= 1.5};"
                   f"token_parity={parity}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
