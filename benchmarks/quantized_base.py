"""Quantized int8 base under 1-bit deltas (DESIGN.md §16).

The paper keeps ONE resident base under many packed variants; this
benchmark measures what happens when that base is held as symmetric
per-channel int8 + fp16 scales (core/quantize.py) and the fused Pallas
GEMMs dequantize each base tile in the same pass that applies the
±1 sign plane × v_row⊕v_col delta:

* resident base HBM per device — int8 vs fp (acceptance: ≤ 0.6×; the
  shadowed targets themselves land at ~0.25× of an fp32 base);
* greedy-token agreement — the SAME skewed multi-variant workload served
  twice through the continuous scheduler, int8 base vs fp base
  (acceptance: ≥ 0.99 of emitted tokens identical — the measured
  tolerance gate for ~0.4% relative weight error);
* drain throughput — tokens/sec through the banked decode path must not
  collapse under the extra scale operand + in-tile dequant.

Uses the 6-layer reduced pair so the linear stacks (the quantized
targets) dominate the embedding extras, as at production scale.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _serve(model, base, dms, workload, base_dtype: str):
    """One full continuous-scheduler drain at ``base_dtype``; returns
    (registry, engine, {rid: out_tokens})."""
    from repro.serving import ServingEngine, VariantRegistry
    reg = VariantRegistry(base, mode="fused", bank_size=len(dms) + 2,
                          base_dtype=base_dtype)
    for name, dm in dms.items():
        reg.register(name, dm)
    eng = ServingEngine(model, reg, batch_size=4, prompt_len=16,
                        max_len=64, scheduler="continuous")
    rng = np.random.default_rng(0)
    rids = []
    for variant in workload:
        rids.append(eng.submit(rng.integers(1, model.cfg.vocab_size,
                                            size=8),
                               variant=variant, max_new_tokens=8))
    eng.run_until_drained()
    toks = {rid: list(eng.result(rid).out_tokens) for rid in rids}
    return reg, eng, toks


def run() -> list:
    from benchmarks.common import row, tiny_pair
    from repro.core import calibration as C

    model, base, ft, _, _ = tiny_pair("deepseek-7b", layers=6,
                                      base_steps=20, ft_steps=10)
    # three variants along the base->ft segment (distinct deltas, one
    # calibration recipe — the bank template requirement)
    dms = {}
    for i, alpha in enumerate((1.0, 0.6, 0.3)):
        ft_i = jax.tree.map(
            lambda l, b: b + alpha * (l - b) if l.ndim >= 2 else l, ft, base)
        dms[f"v{i}"] = C.compress(base, ft_i)
    # skewed multi-variant traffic: one tenant dominates, base rides along
    workload = (["v0"] * 6 + ["v1"] * 3 + ["v2"] * 2 + ["__base__"])
    out = []

    reg_fp, eng_fp, toks_fp = _serve(model, base, dms, workload, "fp")
    reg_q, eng_q, toks_q = _serve(model, base, dms, workload, "int8")

    # -- resident base bytes per device ------------------------------------
    per_fp = reg_fp.base_per_device_nbytes()
    per_q = reg_q.base_per_device_nbytes()
    ratio = max(per_q[d] / per_fp[d] for d in per_fp)
    qs = reg_q.quant_stats
    out.append(row(
        "quantized_base/resident_bytes", 0,
        f"base_fp={reg_fp.base_nbytes()};base_int8={reg_q.base_nbytes()};"
        f"ratio={ratio:.4f};targets_ratio={qs['ratio']:.4f};"
        f"targets={qs['targets']};pass_resident={ratio <= 0.6}"))

    # -- greedy-token agreement, int8 vs fp base ---------------------------
    agree = total = 0
    for rid in toks_fp:
        for a, b in zip(toks_fp[rid], toks_q[rid]):
            agree += int(a == b)
            total += 1
    rate = agree / max(total, 1)
    out.append(row(
        "quantized_base/token_agreement", 0,
        f"agree={agree};total={total};rate={rate:.4f};"
        f"pass_agreement={rate >= 0.99}"))

    # -- drain throughput (banked decode path) -----------------------------
    def tps(eng):
        m = eng.metrics
        return m["tokens_generated"] / max(m["decode_seconds"], 1e-9)

    t_fp, t_q = tps(eng_fp), tps(eng_q)
    t_ratio = t_q / max(t_fp, 1e-9)
    out.append(row(
        "quantized_base/drain_throughput", 0,
        f"tps_fp={t_fp:.0f};tps_int8={t_q:.0f};ratio={t_ratio:.2f};"
        f"pass_tput={t_ratio >= 0.5}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
