"""Paper Fig. 2 analog: row/col axis-selection counts by module sub-type."""
from __future__ import annotations

from collections import Counter

from benchmarks.common import row, tiny_pair
from repro.core import calibration as C


def run() -> list:
    model, base, ft, _, calib = tiny_pair()
    dm, report = C.calibrate_transformer(model, base, ft, calib,
                                         epochs=2, e2e_epochs=1,
                                         lr=1e-3, e2e_lr=1e-3)
    out = []
    for proj, axes in sorted(report["axis"].items()):
        c = Counter(axes)
        out.append(row(f"axis_stats/{proj}", 0,
                       f"row={c.get('row', 0)};col={c.get('col', 0)}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
