"""Paper Table 2 analog: artifact sizes vs full FP16 checkpoints.

Exact byte accounting for all 10 assigned architectures from abstract
parameter shapes (jax.eval_shape — no allocation), using the same target
selection as the real compressor: packed 1-bit masks + fp16 per-axis
vectors for every attention/MLP/expert projection, fp16 extras for
embeddings/norms/convs.
"""
from __future__ import annotations

import jax

from benchmarks.common import row
from repro.configs import ARCHS, get_config
from repro.core.calibration import flatten_params, is_target
from repro.models import build_model
from repro.models.param import split


def arch_sizes(arch: str) -> dict:
    cfg = get_config(arch)
    model = build_model(cfg)
    params_p = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    struct, _ = split(params_p)
    flat = flatten_params(struct)
    mask = vec = extras = fp16 = 0
    for path, leaf in flat.items():
        n = 1
        for d in leaf.shape:
            n *= d
        fp16 += 2 * n
        if is_target(path, leaf):
            d_out, d_in = leaf.shape[-2], leaf.shape[-1]
            stacked = n // (d_out * d_in)
            mask += n // 8
            vec += 2 * stacked * max(d_out, d_in) + (stacked + 7) // 8
        else:
            extras += 2 * n
    artifact = mask + vec + extras
    return {"artifact_mb": artifact / 1e6, "fp16_mb": fp16 / 1e6,
            "ratio": fp16 / artifact, "mask_mb": mask / 1e6,
            "vec_mb": vec / 1e6, "extras_mb": extras / 1e6}


def run() -> list:
    out = []
    for arch in ARCHS:
        s = arch_sizes(arch)
        out.append(row(
            f"table2/{arch}", 0,
            f"artifact={s['artifact_mb']:.0f}MB;fp16={s['fp16_mb']:.0f}MB;"
            f"ratio={s['ratio']:.2f}x;mask={s['mask_mb']:.0f}MB;"
            f"extras={s['extras_mb']:.0f}MB"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
