"""Async admission pipeline vs synchronous inline admission (DESIGN.md §13).

The serving claim behind the pipeline: publishing a new variant into a
BUSY node must neither stall in-flight decode (DeltaZip keeps
decompression off the serving critical path) nor delay the new variant's
first token behind an inline ingest the node could have overlapped with
the traffic it was already draining.

Scenario, identical for both modes: every decode lane is occupied by base
traffic, then a new variant is PUBLISHED (store-backed: real artifact
write, chunked read-back, sha verification) and a request for it queued.

* **sync** — the request waits for a lane, then pays the full ingest
  (read + verify + H2D + scatter + fence) ON the serving thread;
* **async** — ingest + staging run on the pipeline WHILE the base lanes
  decode; when a lane frees, the only on-thread work is one donated
  scatter dispatch between steps.

Measured, with gates (grep'd by CI bench-smoke):

* publish→first-token for the new variant, sync vs async —
  ``pass_cold_start``: async cuts it (median over interleaved rounds);
* decode-step latency during admission — ``pass_stall_lt_2x``: the worst
  async step that overlaps an admission stays under 2x the steady-state
  (non-overlapped) median step;
* steady-state throughput — ``pass_tput``: async's steady median step
  does not regress past 1.5x sync's median (the ingest thread must not
  tax the decode path);
* ``token_parity``: base AND new-variant greedy tokens are bit-identical
  across the two modes.

Noise handling for small shared CI runners: both deployments are built
and warmed up FRONT and the sync/async rounds are INTERLEAVED, so slow
drift (CPU frequency, noisy neighbours) hits both modes equally instead
of biasing whichever mode ran last; all jits (prefill/decode/scatter)
are warmed before measurement; decode-CALL latency is what the stall
ceiling gates (admission-wave prefill is paid identically by both modes);
the model is widened past the smoke-test reduction so decode steps are
compute-bound — on a busy 1-2 vCPU runner a sub-ms dispatch-bound step
would make a single OS timeslice look like a 5-10x "stall".

Single-CPU hosts: with ONE core, a second thread cannot reduce the
wall-clock of CPU-bound work — the ingest CPU async overlaps into the
decode window is exactly the CPU sync pays serially afterwards, so the
cold-start CUT is physically unobtainable (the pipeline's wins there are
the bounded per-step stall and the non-blocking control plane).  The
cold-start gate therefore demands a strict cut on >= 2 CPUs (where the
ingest thread runs on a spare core, e.g. CI runners) and degrades to a
no-regression bound (async <= 1.10x sync) on 1 CPU; ``host_cpus`` and
the gate form are reported in the row so the reader knows which ran.
"""
from __future__ import annotations

import dataclasses
import os
import pathlib
import statistics
import tempfile
import time

import jax
import numpy as np

PROMPT = np.arange(1, 9)
BASE_TOKENS = 16        # per-lane budget the publish overlaps with
NEW_TOKENS = 8
ROUNDS = 3              # interleaved sync/async publish rounds


def _fine_tune(base, pert, scale: float):
    return jax.tree.map(lambda b, p: b + scale * p, base, pert)


def _make_dep(model, base, dm_warm, root, async_adm: bool):
    from repro.serving import Deployment
    dep = Deployment(model, base, root_dir=root, batch_size=2,
                     prompt_len=16, max_len=64, bank_size=ROUNDS + 3,
                     async_admission=async_adm)
    # warm EVERY compiled path the measurement touches: prefill/decode of
    # base lanes, the admission scatter (a throwaway variant), and — for
    # async — the pipeline's staging machinery
    dep.publish("warm", dm_warm, wait=True)
    rid = dep.submit(PROMPT, variant="warm", max_new_tokens=4)
    dep.submit(PROMPT, variant="__base__", max_new_tokens=4)
    dep.drain()
    assert dep.result(rid).status == "done"
    return dep


def _round(dep, name, dm) -> dict:
    """One publish-into-busy-node round: fill EVERY lane with base
    traffic, publish, queue a request for the new variant, drain.  The
    new variant's request queues behind the running lanes — the window
    async ingest overlaps and sync serialises after."""
    eng = dep.engine
    base_rids = [dep.submit(PROMPT, variant="__base__",
                            max_new_tokens=BASE_TOKENS) for _ in range(2)]
    eng._prefill_admitted(eng._admit_free_slots())
    eng.record_step_times = True
    eng.step_times = []
    t0 = time.perf_counter()
    dep.publish(name, dm)                   # store write + (async) ingest
    rid = dep.submit(PROMPT, variant=name, max_new_tokens=NEW_TOKENS)
    dep.drain()
    eng.record_step_times = False
    assert dep.result(rid).status == "done"
    assert all(dep.result(r).status == "done" for r in base_rids)
    return {
        "cold": dep.result(rid).first_token_at - t0,
        "new_tokens": dep.result(rid).out_tokens,
        "base_tokens": [dep.result(r).out_tokens for r in base_rids],
        "busy": [dt for _, dt, b in eng.step_times if b],
        "idle": [dt for _, dt, b in eng.step_times if not b],
    }


def run() -> list:
    from benchmarks.common import row
    from repro.configs import get_config
    from repro.models import build_model
    from repro.models.param import split
    from repro.core import calibration as C

    # wider than the smoke-test reduction on purpose: decode steps must be
    # compute-bound (several ms) for the stall ceiling to measure ingest
    # interference rather than Python dispatch jitter and OS timeslices
    cfg = dataclasses.replace(get_config("deepseek-7b").reduced(),
                              num_layers=4, d_model=256, head_dim=64,
                              d_ff=1024, compute_dtype="float32",
                              remat=False)
    model = build_model(cfg)
    base, _ = split(model.init(jax.random.PRNGKey(0)))
    pert, _ = split(model.init(jax.random.PRNGKey(1)))
    dm_warm = C.compress(base, _fine_tune(base, pert, 0.03))
    dms = {f"prod{r}": C.compress(base, _fine_tune(base, pert,
                                                   0.05 + 0.02 * r))
           for r in range(ROUNDS)}

    tmp = pathlib.Path(tempfile.mkdtemp())
    deps = {m: _make_dep(model, base, dm_warm, tmp / m, m == "async")
            for m in ("sync", "async")}
    res = {m: [] for m in deps}
    for rnd in range(ROUNDS):               # interleave: drift-neutral
        for mode, dep in deps.items():
            res[mode].append(_round(dep, f"prod{rnd}", dms[f"prod{rnd}"]))
    for dep in deps.values():
        dep.close()

    sync_cold = statistics.median(r["cold"] for r in res["sync"])
    async_cold = statistics.median(r["cold"] for r in res["async"])
    cores = os.cpu_count() or 1
    if cores > 1:
        gate, pass_cold = "cut", async_cold < sync_cold
    else:
        gate, pass_cold = "no_regress_1cpu", async_cold <= 1.10 * sync_cold
    out = [row("admission_overlap/cold_start", async_cold * 1e6,
               f"sync_first_token_s={sync_cold:.4f};"
               f"async_first_token_s={async_cold:.4f};"
               f"speedup={sync_cold / max(async_cold, 1e-9):.2f}x;"
               f"host_cpus={cores};gate={gate};"
               f"pass_cold_start={pass_cold}")]

    # stall ceiling: worst admission-overlapped decode step vs the pooled
    # steady median, best round (trivially passes only if NO step ever
    # overlapped — overlap_steps says whether the claim was exercised)
    steady = statistics.median(dt for r in res["async"] for dt in r["idle"])
    ratios = [max(r["busy"]) / steady for r in res["async"] if r["busy"]]
    overlap_steps = sum(len(r["busy"]) for r in res["async"])
    stall_ratio = min(ratios) if ratios else 0.0
    pass_stall = stall_ratio < 2.0
    out.append(row("admission_overlap/decode_stall",
                   stall_ratio * steady * 1e6,
                   f"steady_step_ms={steady * 1e3:.2f};"
                   f"max_overlap_ratio={stall_ratio:.2f};"
                   f"overlap_steps={overlap_steps};"
                   f"pass_stall_lt_2x={pass_stall}"))

    # parity + steady-state throughput (async must not tax decode)
    parity = all(
        rs["new_tokens"] == ra["new_tokens"]
        and rs["base_tokens"] == ra["base_tokens"]
        for rs, ra in zip(res["sync"], res["async"]))
    sync_steady = statistics.median(
        dt for r in res["sync"] for dt in r["idle"])
    pass_tput = steady <= 1.5 * sync_steady
    out.append(row("admission_overlap/steady_tput", steady * 1e6,
                   f"sync_step_ms={sync_steady * 1e3:.2f};"
                   f"async_step_ms={steady * 1e3:.2f};"
                   f"token_parity={parity};pass_tput={pass_tput}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
