"""Benchmark driver: one section per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV rows.  Sections:
  table1   quality: baseline / BitDelta scalar / per-axis vector
  table2   artifact sizes for all 10 assigned architectures
  load     cold-start: delta apply vs full fp16 checkpoint
  axis     Fig. 2 analog: row/col selection counts per sub-type
  kernel   Pallas kernel byte accounting + correctness
  serving  multi-tenant hot-swap engine throughput
  fused    on-the-fly (packed-overlay) vs swap-then-dense serving
  continuous mixed-variant continuous batching vs grouped-by-variant
  speculative_decoding base-as-draft speculative rounds vs plain
           continuous decode: speedup, acceptance, exact token parity
           (DESIGN.md §15)
  update_latency incremental publish_update + hot-swap vs full republish
  sharded_serving banked decode on a host mesh: parity + per-device bytes
  pod_affinity pod-local overlay banks + affinity routing vs the global
           bank on a (2,2,2) mesh: cross-pod admission bytes, affinity
           hit rate, publish→first-token, token parity (DESIGN.md §17)
  shard_map_kernels per-shard vs GSPMD-partitioned delta kernels: latency
           + kernel/token parity at forced 4 host devices (DESIGN.md §12)
  admission_overlap async vs inline admission on a busy node: publish→
           first-token, decode-stall ceiling, token parity (DESIGN.md §13)
  compile_cache cold vs warm restart-to-first-token through the
           persistent compile cache, in forced subprocesses: speedup,
           zero-warm-compiles, token parity (DESIGN.md §14)
  quantized_base int8 base + fused tile dequant vs fp base: resident
           bytes per device, greedy-token agreement, drain throughput
           (DESIGN.md §16)
  roofline dry-run roofline terms per (arch × shape × mesh)

``--strict`` exits nonzero when any section errors (CI gate — by default
a crash is swallowed into a ``*/ERROR,0,...`` CSV row and the driver
exits 0, which hides regressions).  ``--sections a,b`` runs a subset.
``--json OUT`` additionally writes the rows as machine-readable JSON:
per-section row list with the ``derived`` k=v fields parsed into typed
metrics and a per-section/global pass verdict (every ``pass_*`` field
true and no ERROR rows) — the artifact CI uploads per run.
"""
from __future__ import annotations

import sys
import traceback


def _section(name: str, fn) -> list:
    try:
        return fn()
    except Exception:
        tb = traceback.format_exc().strip().splitlines()[-1]
        return [f"{name}/ERROR,0,{tb[:160]}"]


def serving_bench() -> list:
    import numpy as np
    from benchmarks.common import row, tiny_pair
    from repro.core import calibration as C
    from repro.serving import ServingEngine, VariantRegistry
    model, base, ft, _, _ = tiny_pair()
    reg = VariantRegistry(base, max_resident=2)
    reg.register("v1", C.compress(base, ft))
    reg.register("v2", C.compress(base, ft, scalar=True))
    eng = ServingEngine(model, reg, batch_size=4, prompt_len=16, max_len=64)
    import time
    t0 = time.perf_counter()
    for i in range(12):
        eng.submit(np.arange(1, 9), variant=["__base__", "v1", "v2"][i % 3],
                   max_new_tokens=8)
    eng.run_until_drained()
    dt = time.perf_counter() - t0
    m = eng.metrics
    tput = m["tokens_generated"] / max(m["decode_seconds"], 1e-9)
    return [row("serving/12req_3variants", dt * 1e6,
                f"tokens={m['tokens_generated']};decode_tps={tput:.0f};"
                f"swaps={reg.stats['swaps']};failed={m['failed']}")]


def _parse_derived(derived: str) -> dict:
    """Type the ``k=v;k=v`` derived field of one CSV row: bools, ints and
    floats become native JSON values, everything else stays a string."""
    out: dict = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, _, v = part.partition("=")
        if v in ("True", "False"):
            out[k] = v == "True"
            continue
        try:
            out[k] = int(v)
        except ValueError:
            try:
                out[k] = float(v)
            except ValueError:
                out[k] = v
    return out


def _json_report(by_section: dict) -> dict:
    """Machine-readable run report: per-section parsed rows + pass
    verdicts.  A section passes when it emitted no ERROR row and every
    ``pass_*`` metric it declared is true."""
    sections = {}
    for name, rows in by_section.items():
        parsed = []
        ok = True
        for r in rows:
            rname, _, rest = r.partition(",")
            us, _, derived = rest.partition(",")
            metrics = _parse_derived(derived)
            if "/ERROR," in r:
                ok = False
            if any(k.startswith("pass_") and v is False
                   for k, v in metrics.items()):
                ok = False
            try:
                us_val = float(us)
            except ValueError:
                us_val = 0.0
            parsed.append({"name": rname, "us_per_call": us_val,
                           "metrics": metrics})
        sections[name] = {"rows": parsed, "ok": ok}
    return {"sections": sections,
            "ok": all(s["ok"] for s in sections.values())}


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 if any section emits an ERROR row")
    ap.add_argument("--sections", default=None,
                    help="comma-separated subset of sections to run")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also write the rows as a machine-readable JSON "
                         "report (parsed metrics + per-section pass "
                         "verdicts) to this path")
    args = ap.parse_args()

    from benchmarks import (admission_overlap, axis_stats, compile_cache,
                            continuous_batching, fused_serving, kernel_bench,
                            load_time, pod_affinity, quantized_base,
                            roofline, shard_map_kernels, sharded_serving,
                            speculative_decoding, table1_quality,
                            table2_sizes, update_latency)
    sections = [                                      # cheap first
        ("table2", table2_sizes.run),
        ("kernel", kernel_bench.run),
        ("load_time", load_time.run),
        ("table1", table1_quality.run),
        ("axis_stats", axis_stats.run),
        ("serving", serving_bench),
        ("fused", fused_serving.run),
        ("continuous_batching", continuous_batching.run),
        ("speculative_decoding", speculative_decoding.run),
        ("update_latency", update_latency.run),
        ("admission_overlap", admission_overlap.run),
        ("compile_cache", compile_cache.run),
        ("quantized_base", quantized_base.run),
        ("sharded_serving", sharded_serving.run),
        ("pod_affinity", pod_affinity.run),
        ("shard_map_kernels", shard_map_kernels.run),
        ("roofline", roofline.run),
    ]
    if args.sections:
        wanted = {s.strip() for s in args.sections.split(",")}
        unknown = wanted - {n for n, _ in sections}
        if unknown:
            ap.error(f"unknown sections: {sorted(unknown)}")
        sections = [(n, f) for n, f in sections if n in wanted]
    rows = []
    by_section: dict = {}
    for name, fn in sections:
        by_section[name] = _section(name, fn)
        rows += by_section[name]
    print("name,us_per_call,derived")
    print("\n".join(rows))
    if args.json:
        import json
        import pathlib
        report = _json_report(by_section)
        p = pathlib.Path(args.json)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(report, indent=2))
        print(f"json report -> {p} (ok={report['ok']})", file=sys.stderr)
    errors = [r for r in rows if "/ERROR," in r]
    if args.strict and errors:
        print(f"STRICT: {len(errors)} section error(s)", file=sys.stderr)
        for r in errors:
            print(f"  {r}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
