"""Benchmark driver: one section per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV rows.  Sections:
  table1   quality: baseline / BitDelta scalar / per-axis vector
  table2   artifact sizes for all 10 assigned architectures
  load     cold-start: delta apply vs full fp16 checkpoint
  axis     Fig. 2 analog: row/col selection counts per sub-type
  kernel   Pallas kernel byte accounting + correctness
  serving  multi-tenant hot-swap engine throughput
  fused    on-the-fly (packed-overlay) vs swap-then-dense serving
  continuous mixed-variant continuous batching vs grouped-by-variant
  speculative_decoding base-as-draft speculative rounds vs plain
           continuous decode: speedup, acceptance, exact token parity
           (DESIGN.md §15)
  update_latency incremental publish_update + hot-swap vs full republish
  sharded_serving banked decode on a host mesh: parity + per-device bytes
  shard_map_kernels per-shard vs GSPMD-partitioned delta kernels: latency
           + kernel/token parity at forced 4 host devices (DESIGN.md §12)
  admission_overlap async vs inline admission on a busy node: publish→
           first-token, decode-stall ceiling, token parity (DESIGN.md §13)
  compile_cache cold vs warm restart-to-first-token through the
           persistent compile cache, in forced subprocesses: speedup,
           zero-warm-compiles, token parity (DESIGN.md §14)
  roofline dry-run roofline terms per (arch × shape × mesh)

``--strict`` exits nonzero when any section errors (CI gate — by default
a crash is swallowed into a ``*/ERROR,0,...`` CSV row and the driver
exits 0, which hides regressions).  ``--sections a,b`` runs a subset.
"""
from __future__ import annotations

import sys
import traceback


def _section(name: str, fn) -> list:
    try:
        return fn()
    except Exception:
        tb = traceback.format_exc().strip().splitlines()[-1]
        return [f"{name}/ERROR,0,{tb[:160]}"]


def serving_bench() -> list:
    import numpy as np
    from benchmarks.common import row, tiny_pair
    from repro.core import calibration as C
    from repro.serving import ServingEngine, VariantRegistry
    model, base, ft, _, _ = tiny_pair()
    reg = VariantRegistry(base, max_resident=2)
    reg.register("v1", C.compress(base, ft))
    reg.register("v2", C.compress(base, ft, scalar=True))
    eng = ServingEngine(model, reg, batch_size=4, prompt_len=16, max_len=64)
    import time
    t0 = time.perf_counter()
    for i in range(12):
        eng.submit(np.arange(1, 9), variant=["__base__", "v1", "v2"][i % 3],
                   max_new_tokens=8)
    eng.run_until_drained()
    dt = time.perf_counter() - t0
    m = eng.metrics
    tput = m["tokens_generated"] / max(m["decode_seconds"], 1e-9)
    return [row("serving/12req_3variants", dt * 1e6,
                f"tokens={m['tokens_generated']};decode_tps={tput:.0f};"
                f"swaps={reg.stats['swaps']};failed={m['failed']}")]


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 if any section emits an ERROR row")
    ap.add_argument("--sections", default=None,
                    help="comma-separated subset of sections to run")
    args = ap.parse_args()

    from benchmarks import (admission_overlap, axis_stats, compile_cache,
                            continuous_batching, fused_serving, kernel_bench,
                            load_time, roofline, shard_map_kernels,
                            sharded_serving, speculative_decoding,
                            table1_quality, table2_sizes, update_latency)
    sections = [                                      # cheap first
        ("table2", table2_sizes.run),
        ("kernel", kernel_bench.run),
        ("load_time", load_time.run),
        ("table1", table1_quality.run),
        ("axis_stats", axis_stats.run),
        ("serving", serving_bench),
        ("fused", fused_serving.run),
        ("continuous_batching", continuous_batching.run),
        ("speculative_decoding", speculative_decoding.run),
        ("update_latency", update_latency.run),
        ("admission_overlap", admission_overlap.run),
        ("compile_cache", compile_cache.run),
        ("sharded_serving", sharded_serving.run),
        ("shard_map_kernels", shard_map_kernels.run),
        ("roofline", roofline.run),
    ]
    if args.sections:
        wanted = {s.strip() for s in args.sections.split(",")}
        unknown = wanted - {n for n, _ in sections}
        if unknown:
            ap.error(f"unknown sections: {sorted(unknown)}")
        sections = [(n, f) for n, f in sections if n in wanted]
    rows = []
    for name, fn in sections:
        rows += _section(name, fn)
    print("name,us_per_call,derived")
    print("\n".join(rows))
    errors = [r for r in rows if "/ERROR," in r]
    if args.strict and errors:
        print(f"STRICT: {len(errors)} section error(s)", file=sys.stderr)
        for r in errors:
            print(f"  {r}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
