"""Benchmark driver: one section per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV rows.  Sections:
  table1   quality: baseline / BitDelta scalar / per-axis vector
  table2   artifact sizes for all 10 assigned architectures
  load     cold-start: delta apply vs full fp16 checkpoint
  axis     Fig. 2 analog: row/col selection counts per sub-type
  kernel   Pallas kernel byte accounting + correctness
  serving  multi-tenant hot-swap engine throughput
  fused    on-the-fly (packed-overlay) vs swap-then-dense serving
  continuous mixed-variant continuous batching vs grouped-by-variant
  update_latency incremental publish_update + hot-swap vs full republish
  roofline dry-run roofline terms per (arch × shape × mesh)
"""
from __future__ import annotations

import sys
import traceback


def _section(name: str, fn) -> list:
    try:
        return fn()
    except Exception:
        tb = traceback.format_exc().strip().splitlines()[-1]
        return [f"{name}/ERROR,0,{tb[:160]}"]


def serving_bench() -> list:
    import numpy as np
    from benchmarks.common import row, tiny_pair
    from repro.core import calibration as C
    from repro.serving import ServingEngine, VariantRegistry
    model, base, ft, _, _ = tiny_pair()
    reg = VariantRegistry(base, max_resident=2)
    reg.register("v1", C.compress(base, ft))
    reg.register("v2", C.compress(base, ft, scalar=True))
    eng = ServingEngine(model, reg, batch_size=4, prompt_len=16, max_len=64)
    import time
    t0 = time.perf_counter()
    for i in range(12):
        eng.submit(np.arange(1, 9), variant=["__base__", "v1", "v2"][i % 3],
                   max_new_tokens=8)
    eng.run_until_drained()
    dt = time.perf_counter() - t0
    m = eng.metrics
    tput = m["tokens_generated"] / max(m["decode_seconds"], 1e-9)
    return [row("serving/12req_3variants", dt * 1e6,
                f"tokens={m['tokens_generated']};decode_tps={tput:.0f};"
                f"swaps={reg.stats['swaps']};failed={m['failed']}")]


def main() -> None:
    from benchmarks import (axis_stats, continuous_batching, fused_serving,
                            kernel_bench, load_time, roofline,
                            table1_quality, table2_sizes, update_latency)
    rows = []
    rows += _section("table2", table2_sizes.run)      # cheap first
    rows += _section("kernel", kernel_bench.run)
    rows += _section("load_time", load_time.run)
    rows += _section("table1", table1_quality.run)
    rows += _section("axis_stats", axis_stats.run)
    rows += _section("serving", serving_bench)
    rows += _section("fused", fused_serving.run)
    rows += _section("continuous_batching", continuous_batching.run)
    rows += _section("update_latency", update_latency.run)
    rows += _section("roofline", roofline.run)
    print("name,us_per_call,derived")
    print("\n".join(rows))


if __name__ == "__main__":
    main()
