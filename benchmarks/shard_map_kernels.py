"""Per-shard (shard_map) vs GSPMD-partitioned delta kernels (DESIGN.md §12).

On a forced 4-host-device (2, 2) data×model mesh this measures, at toy
size:

* kernel-level latency + parity: the fused delta GEMM lowered per-shard
  under shard_map (kernels/dispatch.py) vs the PR-4 path of handing the
  global Pallas call to GSPMD — row-sharded and col-sharded (psum)
  weights, plus the banked mixed-variant kernel;
* engine-level ACCEPTANCE: the continuous-batching engine must emit
  bit-identical greedy tokens under ``kernel_dispatch="shard_map"`` and
  ``"gspmd"`` for the same mixed workload (token_parity gates the
  sharded-smoke CI job), with drain latency reported for both.

Host-device emulation: latencies are plumbing numbers, not performance
claims — the point on real hardware is that the per-shard lowering EXISTS
(GSPMD cannot slice an opaque kernel call), not that it wins on a CPU.

jax fixes its device count at first init, so with fewer than 4 visible
devices the measurement re-execs in a subprocess with
``--xla_force_host_platform_device_count=4`` (the dry-run pattern).
"""
from __future__ import annotations

import os
import subprocess
import sys

TRAFFIC = ["v0", "v1", "v0", "v2", "v1", "v0", "v2", "v1"]
MAX_NEW = 8
BATCH = 4
REPS = 20


def _timed(fn, reps=REPS):
    import time

    import jax
    jax.block_until_ready(fn())            # warm / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def _kernel_rows(mesh) -> list:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from benchmarks.common import row
    from repro.distributed import sharding as S
    from repro.kernels import dispatch as D
    from repro.kernels import ops as K

    rules = S.rules_for("decode")
    rng = np.random.default_rng(0)
    rows = []
    cases = {
        # (n, k, waxes): row-sharded weight / col-sharded (psum) weight
        "row_sharded": (256, 128, ("ffn", "embed")),
        "col_sharded_psum": (128, 256, ("embed", "ffn")),
    }
    for name, (n, k, waxes) in cases.items():
        packed = jnp.asarray(rng.integers(0, 256, (n, k // 8), np.uint8))
        vr = jnp.asarray(rng.normal(size=(n,)).astype(np.float16))
        vc = jnp.zeros((k,), jnp.float16)
        wb = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
        x = jnp.asarray(rng.normal(size=(BATCH * 2, k)).astype(np.float32))
        args = (x, packed, vr, vc, wb)

        # one jit per lowering; the dispatch decision is read at TRACE
        # time, so each traces inside its own context and the timed loop
        # runs the compiled executable (apples to apples)
        jit_shard = jax.jit(lambda *a: K.bitlinear_axes(*a, waxes=waxes))
        jit_gspmd = jax.jit(lambda *a: K.bitlinear_axes(*a, waxes=waxes))
        with mesh, S.shard_ctx(mesh, rules):
            got = np.asarray(jit_shard(*args))
            with D.no_dispatch():
                want = np.asarray(jit_gspmd(*args))
        parity = bool(np.allclose(got, want, rtol=2e-5, atol=2e-5))
        us_shard = _timed(lambda: jit_shard(*args))
        us_gspmd = _timed(lambda: jit_gspmd(*args))
        rows.append(row(f"shard_map_kernels/{name}", us_shard,
                        f"gspmd_us={us_gspmd:.0f};kernel_parity={parity}"))
    return rows


def _measure() -> list:
    import time

    import jax
    import numpy as np
    from benchmarks.common import row, tiny_pair
    from repro.core import calibration as C
    from repro.launch.mesh import make_host_mesh
    from repro.models.param import split
    from repro.serving import Deployment

    mesh = make_host_mesh(2, 2)
    rows = _kernel_rows(mesh)

    model, base, ft, _, _ = tiny_pair("deepseek-7b", layers=2,
                                      base_steps=20, ft_steps=10)
    _, param_axes = split(model.init(jax.random.PRNGKey(0)))
    dms = {f"v{i}": C.compress(base, jax.tree.map(
        lambda b, f, s=i: b + (1 + 0.1 * s) * (f - b), base, ft))
        for i in range(3)}

    def run(kernel_dispatch):
        dep = Deployment(model, base, batch_size=BATCH, prompt_len=16,
                         max_len=64, bank_size=5, mesh=mesh,
                         param_axes=param_axes,
                         kernel_dispatch=kernel_dispatch)
        for name, dm in dms.items():
            dep.publish(name, dm)
        warm = [dep.submit(np.arange(1, 9), variant=f"v{i % 3}",
                           max_new_tokens=2) for i in range(BATCH + 1)]
        dep.drain()
        assert all(dep.result(w).status == "done" for w in warm)
        rids = [dep.submit(np.arange(1, 9), variant=v,
                           max_new_tokens=MAX_NEW) for v in TRAFFIC]
        t0 = time.perf_counter()
        dep.drain()
        dt = time.perf_counter() - t0
        return [dep.result(r).out_tokens for r in rids], dt

    toks_shard, dt_shard = run("shard_map")
    toks_gspmd, dt_gspmd = run("gspmd")
    parity = toks_shard == toks_gspmd
    generated = sum(len(t) for t in toks_shard)
    rows.append(row("shard_map_kernels/engine_2x2_continuous",
                    dt_shard * 1e6,
                    f"tokens={generated};gspmd_us={dt_gspmd * 1e6:.0f};"
                    f"token_parity={parity}"))
    return rows


def run() -> list:
    import jax
    if len(jax.devices()) >= 4:
        return _measure()
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", env.get("PYTHONPATH", ""), ".") if p)
    r = subprocess.run([sys.executable, os.path.abspath(__file__)],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    if r.returncode != 0:
        tail = (r.stderr or r.stdout).strip().splitlines()[-1:]
        raise RuntimeError(f"shard_map subprocess failed: {tail}")
    return [ln for ln in r.stdout.splitlines()
            if ln.startswith("shard_map_kernels/")]


if __name__ == "__main__":
    print("\n".join(run()))
