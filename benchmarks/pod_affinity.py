"""Pod-local overlay banks + affinity routing on a (2, 2, 2) mesh
(DESIGN.md §17).

A/B of the same skewed mixed-variant workload on an 8-device
(pod, data, model) host mesh:

* **global** (pod_banks=False) — the PR-5 bank: every slot replicated on
  all devices, so each admission payload lands once per pod;
* **pod-local** (pod_banks=True) — bank slots shard over the pod axis;
  the affinity router steers requests to the pod already holding their
  variant, and an admission scatter writes exactly one pod's shard.

Reported (and strict-gated in CI):

* greedy-token parity between the two bank modes — slot placement is a
  layout/routing decision, never a numerics decision;
* layout-derived admission traffic: bytes landing inside the admitting
  pod vs bytes crossing the pod interconnect — pod-local must move
  <= 0.6x the global bank's cross-pod bytes (it moves zero);
* affinity hit AND miss counters — the skewed traffic must exercise both
  the steering path and the cold-pod admit-on-demand path;
* publish -> first-token latency for a freshly published variant under
  pod-local banks, plus TTFT p50/p99 from the engine reservoir.

jax fixes its device count at first init, so with fewer than 8 visible
devices the measurement re-execs in a subprocess with
``--xla_force_host_platform_device_count=8`` (the dry-run pattern) and
the CSV rows pass through.
"""
from __future__ import annotations

import os
import subprocess
import sys

# skewed traffic: v0 is hot (affinity hits once resident), v1/v2 colder
# (their first touches on a second pod are cold-pod misses)
TRAFFIC = ["v0", "v0", "v1", "v0", "v2", "v0", "v1", "v0",
           "v2", "v0", "v0", "v1"]
MAX_NEW = 8
BATCH = 4


def _measure() -> list:
    import time

    import jax
    import numpy as np
    from benchmarks.common import row, tiny_pair
    from repro.core import calibration as C
    from repro.launch.mesh import make_host_mesh
    from repro.serving import Deployment

    model, base, ft, _, _ = tiny_pair("deepseek-7b", layers=2,
                                      base_steps=20, ft_steps=10)
    from repro.models.param import split
    _, param_axes = split(model.init(jax.random.PRNGKey(0)))
    dms = {f"v{i}": C.compress(base, jax.tree.map(
        lambda b, f, s=i: b + (1 + 0.1 * s) * (f - b), base, ft))
        for i in range(3)}
    mesh = make_host_mesh(2, 2, pod=2)

    def run(pod_banks):
        dep = Deployment(model, base, batch_size=BATCH, prompt_len=16,
                         max_len=64, bank_size=5, mesh=mesh,
                         param_axes=param_axes, pod_banks=pod_banks)
        for name, dm in dms.items():
            dep.publish(name, dm)
        rids = [dep.submit(np.arange(1, 9), variant=v,
                           max_new_tokens=MAX_NEW) for v in TRAFFIC]
        t0 = time.perf_counter()
        dep.drain()
        dt = time.perf_counter() - t0
        toks = [dep.result(r).out_tokens for r in rids]
        assert all(dep.result(r).status == "done" for r in rids)
        return toks, dt, dep

    toks_global, _, dep_global = run(False)
    toks_pod, dt, dep = run(True)
    parity = toks_pod == toks_global
    generated = sum(len(t) for t in toks_pod)

    gstats = dep_global.registry.bank.stats
    pstats = dep.registry.bank.stats
    # cross-pod admission traffic: the layout-derived replication term
    # (global bank: payload x (pods - 1); pod-local: zero)
    cross_g = gstats["admit_bytes_cross_pod"]
    cross_p = pstats["admit_bytes_cross_pod"]
    ratio = cross_p / max(1, cross_g)
    st = dep.status()
    af = st["affinity"]
    per_pod = st["hbm"]["bank_per_pod"]
    pod_vals = sorted(per_pod.values())

    # publish -> first token under pod-local banks: a FRESH variant (cold
    # everywhere) admitted on demand into whichever pod the router picks
    dep.publish("v3", C.compress(base, jax.tree.map(
        lambda b, f: b + 1.4 * (f - b), base, ft)))
    t0 = time.perf_counter()
    rid = dep.submit(np.arange(1, 9), variant="v3", max_new_tokens=2)
    dep.drain()
    pub_ttft = time.perf_counter() - t0
    assert dep.result(rid).status == "done"
    ttft = dep.status()["ttft"]

    return [
        row("pod_affinity/banked_decode_2x2x2",
            dt * 1e6,
            f"tokens={generated};tput_tps={generated / dt:.1f};"
            f"token_parity={parity};pass_token_parity={parity}"),
        row("pod_affinity/admission_bytes", 0,
            f"in_pod={pstats['admit_bytes_in_pod']};"
            f"cross_pod={cross_p};cross_pod_global={cross_g};"
            f"ratio={ratio:.3f};pass_bytes_le_0_6x={ratio <= 0.6}"),
        row("pod_affinity/affinity", 0,
            f"pods={af['pods']};hits={af['hits']};misses={af['misses']};"
            f"hit_rate={af['hit_rate']:.3f};"
            f"pass_hits={af['hits'] > 0};pass_misses={af['misses'] > 0}"),
        row("pod_affinity/bank_per_pod_bytes", 0,
            f"min={pod_vals[0]};max={pod_vals[-1]};"
            f"total={dep.registry.bank.nbytes()};"
            f"global_total={dep_global.registry.bank.nbytes()}"),
        row("pod_affinity/publish_to_first_token", pub_ttft * 1e6,
            f"ttft_p50_s={ttft['p50_seconds']:.4f};"
            f"ttft_p99_s={ttft['p99_seconds']:.4f};"
            f"ttft_n={ttft['count']}"),
    ]


def run() -> list:
    import jax
    if len(jax.devices()) >= 8:
        return _measure()
    # re-exec with forced host devices (mirrors launch/dryrun.py)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", env.get("PYTHONPATH", ""), ".") if p)
    r = subprocess.run([sys.executable, os.path.abspath(__file__)],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    if r.returncode != 0:
        tail = (r.stderr or r.stdout).strip().splitlines()[-1:]
        raise RuntimeError(f"pod_affinity subprocess failed: {tail}")
    return [ln for ln in r.stdout.splitlines()
            if ln.startswith("pod_affinity/")]


if __name__ == "__main__":
    print("\n".join(run()))
