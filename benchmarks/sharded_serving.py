"""Mesh-sharded banked decode at toy size (DESIGN.md §11).

Lowers the mixed-variant continuous-batching path onto a (2, 2) host
(data × model) mesh and reports:

* greedy-token parity: the sharded engine must emit exactly the tokens
  the single-device engine emits for the same mixed workload (sharding is
  a layout decision, not a numerics decision);
* per-device resident bank bytes (the sharded bank splits weight-axis
  tiles across ``model``; vectors and the bank axis are replicated);
* drained throughput on the mesh (host-device emulation — the number is
  a plumbing check, not a performance claim).

jax fixes its device count at first init, so when the current process
sees fewer than 4 devices the measurement runs in a SUBPROCESS with
``--xla_force_host_platform_device_count=4`` (the dry-run pattern) and
the CSV rows are passed through.
"""
from __future__ import annotations

import os
import subprocess
import sys


TRAFFIC = ["v0", "v1", "v0", "v2", "v1", "v0", "v2", "v1"]
MAX_NEW = 8
BATCH = 4


def _measure() -> list:
    import time

    import jax
    import numpy as np
    from benchmarks.common import row, tiny_pair
    from repro.core import calibration as C
    from repro.launch.mesh import make_host_mesh
    from repro.serving import Deployment

    model, base, ft, _, _ = tiny_pair("deepseek-7b", layers=2,
                                      base_steps=20, ft_steps=10)
    from repro.models.param import split
    _, param_axes = split(model.init(jax.random.PRNGKey(0)))
    dms = {f"v{i}": C.compress(base, jax.tree.map(
        lambda b, f, s=i: b + (1 + 0.1 * s) * (f - b), base, ft))
        for i in range(3)}

    def run(mesh):
        dep = Deployment(model, base, batch_size=BATCH, prompt_len=16,
                         max_len=64, bank_size=5, mesh=mesh,
                         param_axes=param_axes if mesh else None)
        for name, dm in dms.items():
            dep.publish(name, dm)
        # warm: compile + make every variant bank-resident
        warm = [dep.submit(np.arange(1, 9), variant=f"v{i % 3}",
                           max_new_tokens=2) for i in range(BATCH + 1)]
        dep.drain()
        assert all(dep.result(w).status == "done" for w in warm)
        rids = [dep.submit(np.arange(1, 9), variant=v,
                           max_new_tokens=MAX_NEW) for v in TRAFFIC]
        t0 = time.perf_counter()
        dep.drain()
        dt = time.perf_counter() - t0
        toks = [dep.result(r).out_tokens for r in rids]
        return toks, dt, dep

    toks_single, _, _ = run(None)
    mesh = make_host_mesh(2, 2)
    toks_mesh, dt, dep = run(mesh)
    parity = toks_mesh == toks_single
    generated = sum(len(t) for t in toks_mesh)
    per_dev = dep.registry.bank.per_device_nbytes()
    dev_vals = sorted(per_dev.values())
    return [
        row("sharded_serving/banked_decode_2x2",
            dt * 1e6,
            f"tokens={generated};tput_tps={generated / dt:.1f};"
            f"devices={len(per_dev)};token_parity={parity}"),
        row("sharded_serving/per_device_bank_bytes", 0,
            f"min={dev_vals[0]};max={dev_vals[-1]};"
            f"total={dep.registry.bank.nbytes()};"
            f"resident_bytes={dep.stats['resident_bytes']}"),
    ]


def run() -> list:
    import jax
    if len(jax.devices()) >= 4:
        return _measure()
    # re-exec with forced host devices (mirrors launch/dryrun.py)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", env.get("PYTHONPATH", ""), ".") if p)
    r = subprocess.run([sys.executable, os.path.abspath(__file__)],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    if r.returncode != 0:
        tail = (r.stderr or r.stdout).strip().splitlines()[-1:]
        raise RuntimeError(f"sharded subprocess failed: {tail}")
    return [ln for ln in r.stdout.splitlines()
            if ln.startswith("sharded_serving/")]


if __name__ == "__main__":
    print("\n".join(run()))
