"""Paper Table 1 analog: quality of {fine-tune, BitDelta scalar, per-axis
vector} on held-out evaluation.

No pretrained LLMs ship offline, so the setting is scaled down (DESIGN.md
§8): base = model trained on distribution A, fine-tune = further training
on distribution B, evaluated by held-out loss and next-token accuracy on
B.  The paper's claim under test: calibrated per-axis vector ≥ scalar
BitDelta, both ≈ the uncompressed fine-tune.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import eval_loss_and_acc, row, tiny_pair
from repro.core import calibration as C


def run() -> list:
    model, base, ft, eval_batches, calib = tiny_pair()
    out = []
    t0 = time.perf_counter()

    loss_ft, acc_ft = eval_loss_and_acc(model, ft, eval_batches)
    loss_base, acc_base = eval_loss_and_acc(model, base, eval_batches)

    dm_vec, rep_vec = C.calibrate_transformer(
        model, base, ft, calib, epochs=3, e2e_epochs=3, lr=1e-3, e2e_lr=1e-3)
    stu_vec = C.apply_delta(base, dm_vec)
    loss_vec, acc_vec = eval_loss_and_acc(model, stu_vec, eval_batches)

    dm_sca, _ = C.calibrate_transformer(
        model, base, ft, calib, scalar=True, e2e_epochs=3,
        lr=1e-3, e2e_lr=1e-3)
    stu_sca = C.apply_delta(base, dm_sca)
    loss_sca, acc_sca = eval_loss_and_acc(model, stu_sca, eval_batches)

    us = (time.perf_counter() - t0) * 1e6
    out.append(row("table1/baseline_ft", us / 4,
                   f"loss={loss_ft:.4f};acc={acc_ft:.4f}"))
    out.append(row("table1/base_model", 0,
                   f"loss={loss_base:.4f};acc={acc_base:.4f}"))
    out.append(row("table1/bitdelta_scalar", 0,
                   f"loss={loss_sca:.4f};acc={acc_sca:.4f}"))
    out.append(row("table1/vector_rowcol", 0,
                   f"loss={loss_vec:.4f};acc={acc_vec:.4f}"))
    gap_closed_vec = (loss_base - loss_vec) / max(loss_base - loss_ft, 1e-9)
    gap_closed_sca = (loss_base - loss_sca) / max(loss_base - loss_ft, 1e-9)
    out.append(row("table1/gap_closed", 0,
                   f"vector={gap_closed_vec:.3f};scalar={gap_closed_sca:.3f}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
