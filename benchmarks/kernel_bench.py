"""Kernel accounting: unpack_apply + bitlinear vs dense baselines.

Interpret-mode wall time on CPU is not TPU-meaningful, so the *derived*
column carries the structural story: HBM bytes per op and the modelled
v5e speedup for the memory-bound regimes the kernels target (decode GEMV,
loader reconstruction).  Correctness vs ref.py is asserted inline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.core import delta as D
from repro.distributed.hlo_analysis import HBM_BW, PEAK_FLOPS
from repro.kernels import ops as K
from repro.kernels import ref as R


def _case(d_out, d_in, mode="row"):
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    wb = (0.1 * jax.random.normal(k1, (d_out, d_in))).astype(jnp.bfloat16)
    dw = 0.01 * jax.random.normal(k2, (d_out, d_in))
    packed = D.pack_signs(D.sign_mask(dw))
    v = D.init_scale(dw, mode).astype(jnp.float32)
    return packed, v, wb


def run() -> list:
    out = []
    d_out, d_in = 1024, 1024
    packed, v, wb = _case(d_out, d_in)

    got = K.unpack_apply(packed, v, wb, mode="row", out_dtype=jnp.float32)
    want = R.unpack_apply_ref(packed, v, wb, "row", dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    t = timeit(lambda: jax.block_until_ready(
        K.unpack_apply(packed, v, wb, mode="row")), n=3)
    # loader path: reads Wb (2B/elt) + mask (1/8 B/elt), writes 2B/elt
    bytes_moved = d_out * d_in * (2 + 2 + 1 / 8)
    t_v5e = bytes_moved / HBM_BW
    out.append(row("kernel/unpack_apply_1024sq", t * 1e6,
                   f"hbm_bytes={int(bytes_moved)};v5e_us={t_v5e*1e6:.1f};"
                   f"vs_dense_copy={(d_out*d_in*4)/bytes_moved:.2f}x"))

    m = 8  # decode GEMV regime
    x = (0.1 * jax.random.normal(jax.random.PRNGKey(3), (m, d_in))
         ).astype(jnp.bfloat16)
    got = K.bitlinear(x, packed, v, wb, mode="row")
    want = R.bitlinear_ref(x, packed, v, wb, "row")
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)
    t = timeit(lambda: jax.block_until_ready(
        K.bitlinear(x, packed, v, wb, mode="row")), n=3)
    # decode is weight-traffic bound: fused = Wb + mask; two-pass dense
    # (reconstruct variant then matmul) = 2 reads + 1 write of W
    fused_bytes = d_out * d_in * (2 + 1 / 8)
    swap_bytes = d_out * d_in * (2 + 2 + 1 / 8) + d_out * d_in * 2
    out.append(row("kernel/bitlinear_decode8", t * 1e6,
                   f"fused_hbm={int(fused_bytes)};"
                   f"vs_dense_reswap={swap_bytes/fused_bytes:.2f}x;"
                   f"delta_overhead_vs_base_only={(fused_bytes)/(d_out*d_in*2):.3f}x"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
