"""Base-as-draft speculative decoding vs plain continuous decoding.

The paper's premise — per-axis 1-bit deltas keep every variant CLOSE to
the shared base — is exactly the high-acceptance regime speculative
decoding wants, and the draft model is FREE: the base weights are already
resident on-device next to every overlay (bank slot 0).  Each round
drafts k tokens per lane on the cheap overlay-free path and verifies all
of them through the lane's banked overlay in ONE call (serving/
speculative.py, DESIGN.md §15), so a lane pays one dispatch + host sync
per up-to-(k+1) emitted tokens instead of one per token.

Measures, on identical skewed 8-variant traffic at toy sizes (variants a
small step from the base — the shipped-delta regime).  The workload is
the MoE family, where the economics are starkest: a banked MoE decode
step pays the banked delta-GEMM machinery once per EXPERT, so a verify
call amortises all of it over k+1 tokens while the drafts skip it
entirely (at toy scale the dense families' banked/plain cost ratio is too
small for drafting to pay — the speedup is family- and scale-dependent,
the exactness is not):

* end-to-end drain throughput per scheduler (continuous vs speculative at
  draft_k=4) and the speedup ratio;
* measured acceptance rate (accepted drafts / offered drafts);
* EXACT per-request token parity — speculative decoding must be a pure
  performance transform, bit-identical greedy streams;
* acceptance: parity always; speedup >= 1.3x whenever the measured
  acceptance rate clears 0.7 (low acceptance legitimately caps the win),
  and never a regression below plain continuous decoding.
"""
from __future__ import annotations

import time

import jax
import numpy as np


TRAFFIC = ["v0", "v1", "v0", "v2", "v3", "v0", "v4", "v5",
           "v1", "v6", "v7", "v2", "v0", "v3", "v1", "v4"]
MAX_NEW = 40    # decode-heavy: the round amortisation is a decode-path
BATCH = 16      # claim, keep the (shared) prefill cost from diluting it
DRAFT_K = 4


def _engine(scheduler: str):
    from benchmarks.common import tiny_pair
    from repro.core import calibration as C
    from repro.serving import ServingEngine, VariantRegistry

    model, base, ft, _, _ = tiny_pair("deepseek-moe-16b", layers=2,
                                      base_steps=20, ft_steps=10)
    reg = VariantRegistry(base, mode="fused", max_resident=16, bank_size=9)
    for i in range(8):
        # each tenant a SMALL distinct step from the base — the frequent-
        # update serving regime the paper targets (and the acceptance the
        # draft/verify loop converts into fewer dispatches)
        ft_i = jax.tree.map(lambda b, f, s=i: b + 0.04 * (1 + 0.1 * s)
                            * (f - b), base, ft)
        reg.register(f"v{i}", C.compress(base, ft_i))
    eng = ServingEngine(model, reg, batch_size=BATCH, prompt_len=16,
                        max_len=64, scheduler=scheduler, draft_k=DRAFT_K,
                        spec_adaptive=False)   # fixed k: measure draft_k=4
    return reg, eng


def _drain(eng) -> dict:
    before = dict(eng.metrics)
    rids = [eng.submit(np.arange(1, 9), variant=v, max_new_tokens=MAX_NEW)
            for v in TRAFFIC]
    t0 = time.perf_counter()
    eng.run_until_drained()
    dt = time.perf_counter() - t0
    toks = [eng.result(r).out_tokens for r in rids]
    assert all(eng.result(r).status == "done" for r in rids)
    delta = {k: eng.metrics[k] - before[k]
             for k in eng.metrics if isinstance(before[k], (int, float))}
    return {"seconds": dt, "tokens": toks,
            "generated": sum(len(t) for t in toks),
            "metrics": delta}


def run() -> list:
    from benchmarks.common import row

    out = []
    results = {}
    for sched in ("continuous", "speculative"):
        reg, eng = _engine(sched)
        # warm outside the timed drain: compile every step executable and
        # make all 8 variants bank-resident (steady-state is the claim)
        eng.warmup()
        warm = [eng.submit(np.arange(1, 9), variant=f"v{i % 8}",
                           max_new_tokens=2 if i < 8 else 4)
                for i in range(BATCH + 1)]
        eng.run_until_drained()
        assert all(eng.result(w).status == "done" for w in warm)
        results[sched] = _drain(eng)
        m = results[sched]["metrics"]
        tput = results[sched]["generated"] / results[sched]["seconds"]
        extra = ""
        if sched == "speculative":
            acc = (m["spec_accepted"] / m["spec_drafted"]
                   if m["spec_drafted"] else 0.0)
            results["acceptance"] = acc
            extra = (f"draft_k={DRAFT_K};rounds={m['spec_rounds']};"
                     f"acceptance={acc:.3f};")
        out.append(row(
            f"speculative_decoding/{sched}",
            results[sched]["seconds"] * 1e6,
            f"tokens={results[sched]['generated']};"
            f"tput_tps={tput:.1f};dispatches={m['decode_steps']};"
            f"decode_s={m['decode_seconds']:.3f};" + extra
            + f"resident_bytes={reg.stats['resident_bytes']}"))

    parity = (results["speculative"]["tokens"]
              == results["continuous"]["tokens"])
    speedup = (results["continuous"]["seconds"]
               / results["speculative"]["seconds"])
    acc = results["acceptance"]
    # the 1.3x bar only binds when acceptance clears 0.7 — below that the
    # traffic genuinely diverges from the base and the win shrinks with
    # it; regression below plain continuous is never acceptable
    pass_13 = speedup >= 1.3 or acc < 0.7
    out.append(row(
        "speculative_decoding/speedup_vs_continuous", 0,
        f"speedup={speedup:.2f};acceptance={acc:.3f};"
        f"pass_ge_1_3={pass_13};"
        f"pass_no_regression={speedup >= 1.0};"
        f"token_parity={parity}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
