"""Shared benchmark utilities: tiny trained base/fine-tune pairs + timing."""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import SyntheticLM
from repro.models import build_model
from repro.train.step import init_train_state, make_train_step


@functools.lru_cache(maxsize=8)
def tiny_pair(arch: str = "deepseek-7b", layers: int = 2,
              base_steps: int = 40, ft_steps: int = 20):
    """Train a reduced model, then fine-tune on a shifted distribution.
    Returns (model, base_params, ft_params, eval_batches, ft_batches)."""
    cfg = dataclasses.replace(get_config(arch).reduced(),
                              num_layers=layers, compute_dtype="float32",
                              remat=False)
    model = build_model(cfg)
    src = SyntheticLM(cfg.vocab_size, seed=0)
    src_ft = SyntheticLM(cfg.vocab_size, seed=77)
    step = jax.jit(make_train_step(model, peak_lr=5e-3, warmup=5))
    state = init_train_state(model, jax.random.PRNGKey(0))
    for i in range(base_steps):
        state, _ = step(state, src.lm_batch(i, 4, 32))
    base_params = state.params
    for i in range(ft_steps):
        state, _ = step(state, src_ft.lm_batch(i, 4, 32))
    ft_params = state.params
    eval_batches = [src_ft.lm_batch(5000 + i, 4, 32) for i in range(8)]
    calib_batches = [src_ft.lm_batch(9000 + i, 4, 32) for i in range(4)]
    return model, base_params, ft_params, eval_batches, calib_batches


def eval_loss_and_acc(model, params, batches) -> tuple[float, float]:
    from repro.train.step import make_eval_step
    ev = jax.jit(make_eval_step(model))
    losses, accs = [], []
    fwd = jax.jit(lambda p, b: model.forward(p, b)[0])
    for b in batches:
        losses.append(float(ev(params, b)["loss"]))
        logits = fwd(params, b)
        pred = jnp.argmax(logits[:, :-0 or None, :], axis=-1)
        accs.append(float(jnp.mean(pred == b["labels"])))
    return sum(losses) / len(losses), sum(accs) / len(accs)


def timeit(fn, n: int = 5, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n


def row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
