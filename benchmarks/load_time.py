"""Paper §3.2 load-time experiment: delta apply vs full checkpoint load.

Measured on-disk on the tiny pair (cold-ish: fresh np.load each time) and
modelled for the full 8B setting from byte counts + this host's measured
disk/apply bandwidths.  The paper reports 0.80 s (delta) vs 2.08 s (full
fp16) on Llama-3.1-8B — the ratio, not the absolute numbers, is the
claim under test.
"""
from __future__ import annotations

import pathlib
import tempfile

import jax

from benchmarks.common import row, timeit, tiny_pair
from repro.core import calibration as C
from repro.core import loader as L
from repro.core import store as S


def run() -> list:
    model, base, ft, _, _ = tiny_pair()
    out = []
    tmp = pathlib.Path(tempfile.mkdtemp(prefix="loadbench_"))

    dm = C.compress(base, ft)
    S.save_artifact(dm, tmp / "delta", base_fp=None)
    ckpt = tmp / "full_fp16.npz"
    S.save_checkpoint_fp16(ft, ckpt)

    def load_full():
        L.load_full_checkpoint(str(ckpt), ft)

    def load_delta():
        dm2 = S.load_artifact(tmp / "delta", verify=False)
        L.apply_artifact(base, dm2, use_kernel=False)

    t_full = timeit(load_full, n=5)
    t_delta = timeit(load_delta, n=5)

    delta_bytes = sum(f.stat().st_size for f in (tmp / "delta").iterdir())
    full_bytes = ckpt.stat().st_size
    out.append(row("load_time/full_fp16", t_full * 1e6,
                   f"bytes={full_bytes}"))
    out.append(row("load_time/delta_apply", t_delta * 1e6,
                   f"bytes={delta_bytes};speedup={t_full/t_delta:.2f}x;"
                   f"bytes_ratio={full_bytes/delta_bytes:.2f}x"))

    # modelled 8B (paper setting): transfer-bound at measured disk bw
    from benchmarks.table2_sizes import arch_sizes
    s = arch_sizes("qwen3-8b")
    disk_bw = full_bytes / t_full  # measured effective load bandwidth
    t8_full = s["fp16_mb"] * 1e6 / disk_bw
    t8_delta = s["artifact_mb"] * 1e6 / disk_bw
    out.append(row("load_time/model_8B_full", t8_full * 1e6,
                   f"modelled;bw={disk_bw/1e6:.0f}MB/s"))
    out.append(row("load_time/model_8B_delta", t8_delta * 1e6,
                   f"modelled;speedup={t8_full/t8_delta:.2f}x"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
