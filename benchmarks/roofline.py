"""§Roofline table: aggregates the dry-run JSONs into the per-cell report.

Reads results/dryrun/*.json (written by repro.launch.dryrun) and emits the
three roofline terms, dominant bottleneck, MODEL_FLOPS ratio and MFU bound
per (arch × shape × mesh).
"""
from __future__ import annotations

import json
import pathlib

from benchmarks.common import row

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load_cells(tag: str = "") -> list:
    cells = []
    suffix = f".{tag}.json" if tag else ".json"
    for p in sorted(RESULTS.glob(f"*{suffix}")):
        if tag == "" and p.name.count(".") > 1:
            continue  # skip tagged variants in the default view
        try:
            cells.append(json.loads(p.read_text()))
        except Exception:
            continue
    return cells


def format_cell(d: dict) -> str:
    if d.get("status") == "skip":
        return f"SKIP({d.get('reason', '')[:40]})"
    if d.get("status") != "ok":
        return "ERROR"
    r = d["roofline"]
    return (f"compute={r['compute_s']:.3f}s;memory={r['memory_s']:.3f}s;"
            f"collective={r['collective_s']:.3f}s;dom={r['dominant'][:-2]};"
            f"useful={r['useful_ratio']:.2f};mfu_bound={r['mfu_bound']:.3f}")


def run() -> list:
    out = []
    for d in load_cells():
        name = f"roofline/{d['arch']}/{d['shape']}/{d['mesh']}"
        out.append(row(name, 0, format_cell(d)))
    if not out:
        out.append(row("roofline/NO_RESULTS", 0,
                       "run: python -m repro.launch.dryrun --all"))
    return out


def markdown_table(cells: list) -> str:
    lines = ["| arch | shape | mesh | compute s | memory s | collective s "
             "| dominant | useful | MFU bound |",
             "|---|---|---|---|---|---|---|---|---|"]
    for d in cells:
        if d.get("status") == "skip":
            lines.append(f"| {d['arch']} | {d['shape']} | {d['mesh']} | — | —"
                         f" | — | SKIP: {d.get('reason','')[:48]} | — | — |")
            continue
        if d.get("status") != "ok":
            lines.append(f"| {d['arch']} | {d['shape']} | {d['mesh']} |"
                         " ERR | | | | | |")
            continue
        r = d["roofline"]
        lines.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} "
            f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
            f"| {r['collective_s']:.3f} | {r['dominant'][:-2]} "
            f"| {r['useful_ratio']:.2f} | {r['mfu_bound']:.3f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print("\n".join(run()))
