"""On-the-fly vs swap-then-dense serving (paper §4, "on-the-fly variant").

Compares the two VariantRegistry residency modes on the axes that matter
for multi-tenant serving:

* resident HBM bytes per variant — fused keeps the packed overlay + fp16
  extras vs a full materialised copy (acceptance: ≤ 1/8 of dense);
* logits parity — fused execution must match the dense-reconstruction
  path within fp16 tolerance (the overlay stores fp16 vectors/extras);
* cold time-to-first-token — swap cost + first prefill for a variant that
  is not yet resident (fused skips dense reconstruction entirely);
* steady-state decode throughput (tokens/sec) per mode.

Uses a 6-layer reduced config so the linear stacks dominate the embedding
extras, as they do at production scale.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def run() -> list:
    from benchmarks.common import row, tiny_pair
    from repro.core import calibration as C
    from repro.core import loader as L
    from repro.serving import ServingEngine, VariantRegistry

    model, base, ft, _, _ = tiny_pair("deepseek-7b", layers=6,
                                      base_steps=20, ft_steps=10)
    dm = C.compress(base, ft)
    out = []

    # -- resident bytes per variant ----------------------------------------
    dense_params, _ = L.apply_artifact(base, dm)
    dense_bytes = sum(l.size * l.dtype.itemsize
                      for l in jax.tree.leaves(dense_params))
    fused_params, overlay, _ = L.device_put_overlay(base, dm)
    fused_bytes = L.fused_resident_bytes(base, fused_params, overlay)
    ratio = fused_bytes / dense_bytes
    out.append(row("fused/resident_bytes_per_variant", 0,
                   f"fused={fused_bytes};dense={dense_bytes};"
                   f"ratio={ratio:.4f};pass_le_1_8={ratio <= 0.125}"))

    # -- logits parity fused vs dense --------------------------------------
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(1, model.cfg.vocab_size,
                                          size=(4, 32)), jnp.int32)}
    fwd_dense = jax.jit(lambda p, b: model.forward(p, b)[0])
    fwd_fused = jax.jit(lambda p, ov, b: model.forward(p, b, overlay=ov)[0])
    ld = fwd_dense(dense_params, batch)
    lf = fwd_fused(fused_params, overlay, batch)
    maxdiff = float(jnp.max(jnp.abs(ld - lf)))
    scale = float(jnp.max(jnp.abs(ld)))
    tol = max(2e-2, 2e-2 * scale)   # fp16 vectors + extras
    out.append(row("fused/logits_parity", 0,
                   f"maxdiff={maxdiff:.2e};scale={scale:.2f};"
                   f"pass_fp16_tol={maxdiff < tol}"))

    # -- cold TTFT + steady decode throughput, per mode --------------------
    for mode in ("dense", "fused"):
        reg = VariantRegistry(base, max_resident=4, mode=mode)
        reg.register("v", dm)
        reg.register("warm", dm)
        eng = ServingEngine(model, reg, batch_size=4, prompt_len=16,
                            max_len=64)
        # warm the compiled paths: base (overlay=None trace) and one
        # variant of the same overlay structure — XLA compiles once per
        # structure, so cold TTFT below measures swap + prefill only
        eng.submit(np.arange(1, 9), variant="__base__", max_new_tokens=2)
        eng.submit(np.arange(1, 9), variant="warm", max_new_tokens=2)
        eng.run_until_drained()
        reg.stats["swap_seconds"] = 0.0
        t0 = time.perf_counter()
        eng.submit(np.arange(1, 9), variant="v", max_new_tokens=1)
        eng.run_until_drained()
        ttft = time.perf_counter() - t0
        # steady state: variant resident, measure decode throughput
        for _ in range(2):
            eng.submit(np.arange(1, 9), variant="v", max_new_tokens=16)
        m0 = dict(eng.metrics)
        eng.run_until_drained()
        toks = eng.metrics["tokens_generated"] - m0["tokens_generated"]
        secs = eng.metrics["decode_seconds"] - m0["decode_seconds"]
        out.append(row(f"fused/{mode}_serving", ttft * 1e6,
                       f"cold_ttft_s={ttft:.3f};"
                       f"decode_tps={toks / max(secs, 1e-9):.0f};"
                       f"swap_s={reg.stats['swap_seconds']:.3f};"
                       f"resident_bytes={reg.stats['resident_bytes']}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
