"""Cold vs warm restart-to-first-token with the persistent compile cache
(DESIGN.md §14).

Restart cost is measured where it is actually paid: in a FRESH process.
The parent spawns the same child twice against one shared cache
directory —

* cold: empty cache; ``Deployment(warmup=True)`` AOT-compiles every step
  pair and the admission scatter, populating the cache;
* warm: same program, same shapes; every executable deserializes.

Each child times Deployment construction + warmup + publish + first
generated token (the restart-to-first-token SLO), then serves a longer
greedy request for the parity check.  The parent gates on:

* ``token_parity=True`` — a deserialized executable must emit exactly
  the tokens the freshly compiled one emits;
* ``warm_compiles=0`` — the warm path performed ZERO XLA compiles
  (engine steps + CachedCallable jits + dispatch memo combined);
* ``pass_ge_5x`` — warm restart at least 5× faster than cold.

CI greps these markers out of the CSV (``--strict`` in benchmarks/run.py
only gates crashes, not semantics).
"""
from __future__ import annotations

import json
import os
import shutil
import subprocess
import sys
import tempfile

_MARK = "CCBENCH:"


def _child(cache_dir: str) -> None:
    import time

    import numpy as np
    from benchmarks.common import tiny_pair
    from repro.core import calibration as C
    from repro.serving import Deployment

    model, base, ft, _, _ = tiny_pair()
    dm = C.compress(base, ft)

    # the restart span: construct + warm + publish + first token
    t0 = time.perf_counter()
    dep = Deployment(model, base, batch_size=2, prompt_len=8, max_len=32,
                     bank_size=4, compile_cache_dir=cache_dir, warmup=True)
    dep.publish("ft", dm)
    rid = dep.submit(np.arange(1, 7), variant="ft", max_new_tokens=1)
    dep.drain()
    span = time.perf_counter() - t0
    first = [int(t) for t in dep.result(rid).out_tokens]

    # parity payload: a longer greedy request (same avals — no compiles)
    rid2 = dep.submit(np.arange(1, 7), variant="ft", max_new_tokens=8)
    dep.drain()
    tokens = [int(t) for t in dep.result(rid2).out_tokens]

    st = dep.status()
    print(_MARK + json.dumps({
        "span_s": span, "first": first, "tokens": tokens,
        "step_compiles": st["steps"]["compiles"],
        "step_cache_hits": st["steps"]["cache_hits"],
        "warmup_s": st["metrics"]["warmup_seconds"],
        "cc": st["compile_cache"],
        "memo_persist_hits": st["dispatch_memo"]["persist_hits"],
        "memo_persist_compiles": st["dispatch_memo"]["persist_compiles"],
    }))


def _spawn(cache_dir: str) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", env.get("PYTHONPATH", ""), ".") if p)
    r = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--child", cache_dir],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    if r.returncode != 0:
        tail = (r.stderr or r.stdout).strip().splitlines()[-1:]
        raise RuntimeError(f"compile_cache child failed: {tail}")
    lines = [ln for ln in r.stdout.splitlines() if ln.startswith(_MARK)]
    if not lines:
        raise RuntimeError("compile_cache child printed no result line")
    return json.loads(lines[-1][len(_MARK):])


def run() -> list:
    from benchmarks.common import row

    cache_dir = tempfile.mkdtemp(prefix="repro-compile-cache-bench-")
    try:
        cold = _spawn(cache_dir)
        warm = _spawn(cache_dir)
    finally:
        shutil.rmtree(cache_dir, ignore_errors=True)

    speedup = cold["span_s"] / max(warm["span_s"], 1e-9)
    parity = (cold["tokens"] == warm["tokens"]
              and cold["first"] == warm["first"])
    warm_compiles = (warm["step_compiles"] + warm["cc"]["compiles"]
                     + warm["memo_persist_compiles"])
    return [
        row("compile_cache/cold_restart_first_token", cold["span_s"] * 1e6,
            f"step_compiles={cold['step_compiles']};"
            f"cc_puts={cold['cc']['puts']};"
            f"warmup_s={cold['warmup_s']:.2f}"),
        row("compile_cache/warm_restart_first_token", warm["span_s"] * 1e6,
            f"warm_compiles={warm_compiles};"
            f"step_cache_hits={warm['step_cache_hits']};"
            f"cc_hits={warm['cc']['hits']};"
            f"deserialize_s={warm['cc']['deserialize_seconds']:.2f}"),
        row("compile_cache/restart_speedup", 0,
            f"speedup={speedup:.1f}x;pass_ge_5x={speedup >= 5.0};"
            f"token_parity={parity}"),
    ]


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--child":
        _child(sys.argv[2])
    else:
        print("\n".join(run()))
