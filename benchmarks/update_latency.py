"""Incremental version update + hot-swap vs full republish (DESIGN.md §10).

The paper's claim is cheap FREQUENT updates; this bench measures the
version-to-version path that makes them frequent in practice:

* artifact bytes — a ``publish_update`` patch (XOR'd packed sign planes +
  zero-run-suppressed fp16 diffs) against a full publish of the same
  weights.  Acceptance: patch < 0.35x full;
* hot-swap latency — wall time from ``update()`` returning to the first
  post-swap request drained, on a deployment whose variant is RESIDENT
  (bank-admitted) at the old version;
* parity — the patch-materialised version must be BIT-IDENTICAL in the
  wire domain to a fresh full publish of the same weights, greedy tokens
  served after the hot-swap must exactly equal a fresh deployment that
  full-published them, and tokens after ``rollback`` must exactly equal
  the pre-update serving;
* rollback latency — a constant-time pointer move, no artifact IO.

Uses random-init weights (not the trained tiny_pair): a barely-trained
toy LM greedily collapses to one token, which would make token parity
trivially true — random-init logits are diverse and weight-sensitive, so
the update visibly CHANGES the served tokens and parity is a real check.
The "incremental" fine-tune continues the first one: a fraction of the
rows move (the BitDelta successive-fine-tune regime), so most packed
bytes XOR to zero and most fp16 wire values are unchanged.
"""
from __future__ import annotations

import dataclasses
import pathlib
import tempfile
import time

import jax
import numpy as np

PROMPT = np.arange(1, 9)
NEW_TOKENS = 12


def _incremental_ft(ft, base, rows_frac: float = 0.125,
                    scale: float = 2.0):
    """Continue a fine-tune: the leading ``rows_frac`` rows of every
    matrix move by ``scale`` of their existing delta; the rest is
    untouched (sparse version-to-version residual)."""

    def upd(l1, lb):
        if l1.ndim < 2:
            return l1
        n = max(1, int(l1.shape[-2] * rows_frac))
        return l1.at[..., :n, :].add(
            scale * (l1[..., :n, :] - lb[..., :n, :]))

    return jax.tree.map(upd, ft, base)


def _deployment(model, base, root=None):
    from repro.serving import Deployment
    return Deployment(model, base, root_dir=root, batch_size=4,
                      prompt_len=16, max_len=64, bank_size=4)


def _serve(dep, variant: str) -> list:
    rid = dep.submit(PROMPT, variant=variant, max_new_tokens=NEW_TOKENS)
    dep.drain()
    assert dep.result(rid).status == "done"
    return dep.result(rid).out_tokens


def _wire_exact(dm_a, dm_b) -> bool:
    """Bit-equality of two DeltaModels in the wire domain (packed planes,
    fp16 vectors/extras, selectors)."""
    for k, ea in dm_a.deltas.items():
        eb = dm_b.deltas[k]
        if not (np.array_equal(np.asarray(ea.packed), np.asarray(eb.packed))
                and np.array_equal(np.asarray(ea.v_row, np.float16),
                                   np.asarray(eb.v_row, np.float16))
                and np.array_equal(np.asarray(ea.v_col, np.float16),
                                   np.asarray(eb.v_col, np.float16))
                and np.array_equal(np.asarray(ea.use_row),
                                   np.asarray(eb.use_row))):
            return False
    return all(np.array_equal(np.asarray(va, np.float16),
                              np.asarray(dm_b.extras[k], np.float16))
               for k, va in dm_a.extras.items())


def run() -> list:
    from benchmarks.common import row
    from repro.configs import get_config
    from repro.core import calibration as C
    from repro.models import build_model
    from repro.models.param import split

    cfg = dataclasses.replace(get_config("deepseek-7b").reduced(),
                              num_layers=2, compute_dtype="float32",
                              remat=False)
    model = build_model(cfg)
    base, _ = split(model.init(jax.random.PRNGKey(0)))
    pert, _ = split(model.init(jax.random.PRNGKey(1)))
    ft = jax.tree.map(lambda b, p: b + 0.05 * p, base, pert)
    dm1 = C.compress(base, ft)
    ft2 = _incremental_ft(ft, base)
    dm2 = C.compress(base, ft2)

    tmp = pathlib.Path(tempfile.mkdtemp())
    dep = _deployment(model, base, tmp / "store")
    v1 = dep.publish("prod", dm1)
    tokens_v1 = _serve(dep, "prod")      # warm: compiled paths + resident

    # -- incremental publish + hot-swap of the resident variant ------------
    t0 = time.perf_counter()
    v2 = dep.update("prod", dm2)
    publish_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    tokens_v2 = _serve(dep, "prod")
    swap_serve_s = time.perf_counter() - t0

    full_bytes = dep.store.artifact_bytes("prod", v1)
    patch_bytes = dep.store.artifact_bytes("prod", v2)
    ratio = patch_bytes / full_bytes
    out = [row("update_latency/bytes", publish_s * 1e6,
               f"full={full_bytes};patch={patch_bytes};ratio={ratio:.3f};"
               f"pass_bytes_lt_0_35={ratio < 0.35}")]

    # -- parity vs a fresh full publish of the same new weights ------------
    fresh = _deployment(model, base)
    fresh.publish("prod", dm2)
    parity = _wire_exact(dep.store.load("prod", v2), dm2) and \
        tokens_v2 == _serve(fresh, "prod")
    out.append(row("update_latency/hot_swap", swap_serve_s * 1e6,
                   f"publish_s={publish_s:.3f};"
                   f"first_drain_s={swap_serve_s:.3f};"
                   f"token_parity={parity};"
                   f"update_changed_tokens={tokens_v2 != tokens_v1}"))

    # -- rollback: constant-time pointer move, exact old tokens ------------
    t0 = time.perf_counter()
    v_back = dep.rollback("prod")
    rollback_s = time.perf_counter() - t0
    rb_parity = _serve(dep, "prod") == tokens_v1
    out.append(row("update_latency/rollback", rollback_s * 1e6,
                   f"to_version={v_back};rollback_s={rollback_s:.5f};"
                   f"rollback_parity={rb_parity}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
