"""Serving launcher: multi-tenant engine over synthetic delta variants.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
        --variants 3 --requests 12 --mode fused --scheduler continuous

--mode fused keeps variants resident as packed delta overlays (on-the-fly
fused GEMMs, ~1/16 the HBM per variant); --mode dense materialises full
copies (the classic hot-swap path).  --scheduler continuous serves MIXED
variants in one decode batch via the overlay bank (requires --mode fused;
DESIGN.md §9); group batches one variant at a time.
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--variants", type=int, default=3)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--mode", choices=("dense", "fused"), default="dense")
    ap.add_argument("--scheduler", choices=("group", "continuous"),
                    default="group")
    ap.add_argument("--max-resident", type=int, default=0,
                    help="0 -> 2 for dense, 8 for fused")
    args = ap.parse_args()
    if args.scheduler == "continuous" and args.mode != "fused":
        ap.error("--scheduler continuous requires --mode fused "
                 "(mixed batches serve from the packed overlay bank)")

    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.core import calibration as C
    from repro.models import build_model
    from repro.models.param import split
    from repro.serving import ServingEngine, VariantRegistry

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    base, _ = split(model.init(jax.random.PRNGKey(0)))

    max_resident = args.max_resident or (8 if args.mode == "fused" else 2)
    reg = VariantRegistry(base, max_resident=max_resident, mode=args.mode,
                          bank_size=args.variants + 1)
    for i in range(args.variants):
        key = jax.random.PRNGKey(100 + i)
        leaves, treedef = jax.tree.flatten(base)
        keys = jax.random.split(key, len(leaves))
        ft = jax.tree.unflatten(treedef, [
            l + 0.005 * jax.random.normal(k, l.shape, l.dtype)
            if l.ndim >= 2 else l for l, k in zip(leaves, keys)])
        reg.register(f"v{i}", C.compress(base, ft))

    eng = ServingEngine(model, reg, batch_size=args.batch, prompt_len=16,
                        max_len=64, scheduler=args.scheduler)
    rng = np.random.default_rng(0)
    names = reg.registered()
    for i in range(args.requests):
        eng.submit(rng.integers(1, cfg.vocab_size, size=8),
                   variant=names[i % len(names)],
                   max_new_tokens=args.new_tokens)
    eng.run_until_drained()
    print("metrics:", eng.metrics)
    print("registry:", reg.stats)


if __name__ == "__main__":
    main()
