"""Serving launcher: versioned multi-tenant deployment over synthetic
delta variants, driven through the serving/api.Deployment control plane.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
        --variants 3 --requests 12 --mode fused --scheduler continuous \
        --updates 1

--mode fused keeps variants resident as packed delta overlays (on-the-fly
fused GEMMs, ~1/16 the HBM per variant); --mode dense materialises full
copies (the classic hot-swap path).  --scheduler continuous serves MIXED
variants in one decode batch via the overlay bank (requires --mode fused;
DESIGN.md §9); group batches one variant at a time.  --updates N performs
N incremental publish_update + hot-swap cycles on the first variant
mid-workload (DESIGN.md §10), then rolls the last one back.

--speculative layers base-as-draft speculative decoding on the continuous
scheduler (DESIGN.md §15): each round drafts --draft-k tokens per lane
with the resident base weights and verifies all of them through the
lane's banked variant overlay in ONE call — token streams stay bit-exact
with plain continuous decode, and the printed acceptance rate shows how
often base and variant agree (the paper's small-delta premise)::

    PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b \
        --reduced --mode fused --speculative --draft-k 4 \
        --variants 3 --requests 12 --warmup

--mesh DATA,MODEL serves the whole deployment data×model-parallel
(DESIGN.md §11): base weights and every overlay/bank leaf land
tensor-parallel over ``model``, decode lanes span ``data``.  Needs
DATA*MODEL visible devices, e.g.::

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        python -m repro.launch.serve --arch qwen3-8b --reduced \
        --mode fused --scheduler continuous --mesh 2,2
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--variants", type=int, default=3)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--mode", choices=("dense", "fused"), default="dense")
    ap.add_argument("--base-dtype", choices=("fp", "int8"), default="fp",
                    help="resident base-weight dtype: int8 quantizes every "
                         "shadowed target (symmetric per-channel, "
                         "core/quantize.py) and the fused GEMMs dequantize "
                         "per tile — ~0.5x resident base HBM (DESIGN.md "
                         "§16)")
    ap.add_argument("--scheduler", choices=("group", "continuous"),
                    default="group")
    ap.add_argument("--max-resident", type=int, default=0,
                    help="0 -> 2 for dense, 8 for fused")
    ap.add_argument("--updates", type=int, default=0,
                    help="incremental update+hot-swap cycles on variant v0")
    ap.add_argument("--store-dir", default=None,
                    help="persist artifacts here (default: in-memory)")
    ap.add_argument("--mesh", default=None,
                    metavar="DATA,MODEL | POD,DATA,MODEL",
                    help="serve on a (data, model) mesh — or, with three "
                         "values, a (pod, data, model) mesh (default: "
                         "single device)")
    ap.add_argument("--pod-banks", action="store_true",
                    help="pod-local overlay banks + affinity routing "
                         "(DESIGN.md §17): bank slots shard over the "
                         "mesh's pod axis, requests steer to the pod "
                         "already holding their variant (requires a "
                         "3-value --mesh and --scheduler continuous)")
    ap.add_argument("--admission-pacing", type=float, default=0.002,
                    metavar="SECONDS",
                    help="async-admission ingest pacing: worker sleep "
                         "between artifact module streams (0 disables; "
                         "default 0.002)")
    ap.add_argument("--kernel-dispatch", choices=("shard_map", "gspmd"),
                    default="shard_map",
                    help="mesh-mode delta-GEMM lowering: per-shard "
                         "shard_map kernels (default) or the PR-4 "
                         "GSPMD-partitioned global kernels")
    ap.add_argument("--speculative", action="store_true",
                    help="base-as-draft speculative decoding on the "
                         "continuous scheduler (requires --mode fused; "
                         "DESIGN.md §15) — bit-exact tokens, fewer "
                         "dispatches per emitted token")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="speculative draft length (adaptive ladder "
                         "down-shifts under low acceptance)")
    ap.add_argument("--async-admission", action="store_true",
                    help="ingest+stage variant artifacts on a background "
                         "pipeline and commit between decode steps "
                         "(publish/update return without blocking; "
                         "requires --scheduler continuous)")
    ap.add_argument("--warmup", action="store_true",
                    help="AOT-compile every decode step pair before "
                         "serving (DESIGN.md §14) — with --compile-cache "
                         "a warm restart deserializes instead of "
                         "recompiling")
    ap.add_argument("--compile-cache", default=None, metavar="DIR",
                    help="persistent compile-cache directory (also "
                         "honours REPRO_COMPILE_CACHE_DIR)")
    args = ap.parse_args()
    if args.speculative:
        if args.mode != "fused":
            ap.error("--speculative requires --mode fused (verify runs "
                     "through the packed overlay bank)")
        args.scheduler = "continuous"   # Deployment(speculative=True)
                                        # upgrades it to "speculative"
    if args.scheduler == "continuous" and args.mode != "fused":
        ap.error("--scheduler continuous requires --mode fused "
                 "(mixed batches serve from the packed overlay bank)")
    if args.async_admission and args.scheduler != "continuous":
        ap.error("--async-admission requires --scheduler continuous "
                 "(staged overlays commit into the overlay bank between "
                 "decode steps)")

    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.core import calibration as C
    from repro.models import build_model
    from repro.models.param import split
    from repro.serving import Deployment

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_host_mesh
        try:
            parts = [int(p) for p in args.mesh.split(",")]
            if len(parts) == 2:
                pod, (data, model_par) = 0, parts
            elif len(parts) == 3:
                pod, data, model_par = parts
            else:
                raise ValueError(args.mesh)
        except ValueError:
            ap.error("--mesh expects DATA,MODEL or POD,DATA,MODEL, "
                     "e.g. --mesh 2,2 or --mesh 2,2,2")
        mesh = make_host_mesh(data, model_par, pod=pod)
    if args.pod_banks:
        if mesh is None or "pod" not in mesh.axis_names:
            ap.error("--pod-banks needs a 3-value --mesh POD,DATA,MODEL")
        if args.scheduler != "continuous":
            ap.error("--pod-banks requires --scheduler continuous "
                     "(the affinity router lives in the slot scheduler)")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    base, param_axes = split(model.init(jax.random.PRNGKey(0)))

    def fine_tune(seed: int, scale: float = 0.005):
        key = jax.random.PRNGKey(seed)
        leaves, treedef = jax.tree.flatten(base)
        keys = jax.random.split(key, len(leaves))
        return jax.tree.unflatten(treedef, [
            l + scale * jax.random.normal(k, l.shape, l.dtype)
            if l.ndim >= 2 else l for l, k in zip(leaves, keys)])

    max_resident = args.max_resident or (8 if args.mode == "fused" else 2)
    dep = Deployment(model, base, root_dir=args.store_dir,
                     mode=args.mode, scheduler=args.scheduler,
                     batch_size=args.batch, prompt_len=16, max_len=64,
                     max_resident=max_resident,
                     bank_size=args.variants + 2,
                     mesh=mesh, param_axes=param_axes if mesh else None,
                     kernel_dispatch=args.kernel_dispatch,
                     async_admission=args.async_admission,
                     speculative=args.speculative, draft_k=args.draft_k,
                     warmup=args.warmup,
                     compile_cache_dir=args.compile_cache,
                     base_dtype=args.base_dtype,
                     pod_banks=args.pod_banks,
                     admission_pacing_s=args.admission_pacing)
    if args.base_dtype == "int8":
        qs = dep.registry.quant_stats
        print(f"int8 base: {qs['targets']} targets, "
              f"{qs['fp_bytes']} -> {qs['int8_bytes']} bytes "
              f"(ratio {qs['ratio']:.3f})")
    tunes = {}
    for i in range(args.variants):
        tunes[f"v{i}"] = fine_tune(100 + i)
        dep.publish(f"v{i}", C.compress(base, tunes[f"v{i}"]))

    rng = np.random.default_rng(0)
    names = dep.variants()
    for i in range(args.requests):
        dep.submit(rng.integers(1, cfg.vocab_size, size=8),
                   variant=names[i % len(names)],
                   max_new_tokens=args.new_tokens)
    dep.drain()

    for u in range(args.updates):
        # continue v0's fine-tune a little and ship it as a patch
        ft = jax.tree.map(
            lambda l, b: l + 0.2 * (l - b) if l.ndim >= 2 else l,
            tunes["v0"], base)
        tunes["v0"] = ft
        v = dep.update("v0", C.compress(base, ft))
        print(f"update {u}: v0 -> version {v}")
        for _ in range(args.batch):
            dep.submit(rng.integers(1, cfg.vocab_size, size=8),
                       variant="v0", max_new_tokens=args.new_tokens)
        dep.drain()
    if args.updates:
        v = dep.rollback("v0")
        print(f"rollback: v0 -> version {v}")
        dep.submit(rng.integers(1, cfg.vocab_size, size=8), variant="v0",
                   max_new_tokens=args.new_tokens)
        dep.drain()

    print("metrics:", dep.metrics)
    print("registry:", dep.stats)
    st = dep.status()
    if "speculative" in st:
        sp = st["speculative"]
        print(f"speculative: acceptance={sp['acceptance']:.3f} "
              f"rounds={sp['rounds']} current_k={sp['current_k']} "
              f"ttft_mean={st['ttft']['mean_seconds']:.4f}s")
    print("compiles:", st["steps"])
    if st["compile_cache"] is not None:
        print("compile-cache:", st["compile_cache"])
    if dep.admission is not None:
        print("admission:", dep.admission.stats)
    print("hbm:", {k: st["hbm"][k] for k in ("base_dtype", "base_bytes",
                                             "bank_bytes")})
    if mesh is not None:
        print("base per-device bytes:", st["hbm"]["base_per_device"])
        if dep.registry.bank is not None:
            print("bank per-device bytes:",
                  st["hbm"]["bank_per_device"])
    if args.pod_banks:
        af = st["affinity"]
        print(f"affinity: pods={af['pods']} hits={af['hits']} "
              f"misses={af['misses']} hit_rate={af['hit_rate']:.3f}")
        print("bank per-pod bytes:", st["hbm"]["bank_per_pod"])
        print("bank residents per pod:",
              st["hbm"]["bank_resident_per_pod"])
        bank = dep.registry.bank
        if bank is not None:
            print(f"admission bytes: in-pod="
                  f"{bank.stats['admit_bytes_in_pod']} cross-pod="
                  f"{bank.stats['admit_bytes_cross_pod']}")
    print(f"ttft: p50={st['ttft']['p50_seconds']:.4f}s "
          f"p99={st['ttft']['p99_seconds']:.4f}s "
          f"(n={st['ttft']['count']})")
    dep.close()


if __name__ == "__main__":
    main()
