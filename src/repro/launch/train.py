"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --reduced \
        --steps 50 --ckpt-dir /tmp/ckpts [--grad-compress]

Full (non-reduced) configs are for real accelerator fleets; on this CPU
container use --reduced.  The loop auto-resumes from the newest valid
checkpoint in --ckpt-dir (fault tolerance contract in train/loop.py).
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpts")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--grad-compress", action="store_true")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.models import build_model
    from repro.train.loop import LoopConfig, Trainer

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    lcfg = LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                      batch_size=args.batch, seq_len=args.seq,
                      peak_lr=args.lr, grad_compress=args.grad_compress)
    trainer = Trainer(model, args.ckpt_dir, lcfg)
    res = trainer.run()
    print(f"completed={res['completed']} "
          f"loss {res['losses'][0]:.4f} -> {res['losses'][-1]:.4f}")


if __name__ == "__main__":
    main()
