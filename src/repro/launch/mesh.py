"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — required because the dry-run
forces 512 host devices via XLA_FLAGS before first jax init, while smoke
tests must see a single device.
"""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """(data=16, model=16) single pod (256 chips) or
    (pod=2, data=16, model=16) across two pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — run "
            f"under launch/dryrun.py (it forces host platform devices)")
    import numpy as np
    dev_array = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over whatever devices exist (tests / local runs).

    ``pod > 0`` prepends a pod axis — (pod, data, model) — the 3-axis
    shape pod-local overlay banks and affinity routing run on
    (DESIGN.md §17), e.g. (2, 2, 2) under 8 forced host devices."""
    import numpy as np
    shape = (pod, data, model) if pod else (data, model)
    axes = ("pod", "data", "model") if pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(jax.devices())}")
    return jax.sharding.Mesh(np.asarray(devices).reshape(shape), axes)
