"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST be the process entry point (python -m repro.launch.dryrun ...): the
first two lines force 512 host platform devices BEFORE any jax import so
``jax.make_mesh`` can build the production meshes.  Never set this flag
globally — smoke tests and benchmarks must see 1 device.

Per cell this:
  1. builds the mesh ((16,16) data×model, or (2,16,16) pod×data×model),
  2. resolves parameter/batch/cache shardings from the logical rules,
  3. ``jax.jit(step).lower(abstract args).compile()``,
  4. records memory_analysis, cost_analysis and the parsed collective
     schedule to results/dryrun/<cell>.json.

The decode_fused / decode_banked serving cells lower their fused delta
GEMMs as shard_map'd PER-SHARD Pallas kernels (kernels/dispatch.py —
DESIGN.md §12) at both meshes; ``--opt gspmd_kernels`` restores the PR-4
GSPMD-partitioned global-kernel lowering for comparison.

The driver (--all) runs each cell in a SUBPROCESS so an XLA failure or OOM
in one cell cannot kill the sweep, and finished cells are skipped on
restart (the dry-run is itself fault-tolerant / resumable).
"""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

import argparse
import json
import pathlib
import subprocess
import sys
import time
import traceback


RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             opt_flags: tuple = (), cache_dir=None) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.configs import (BANKED_SLOTS, SHAPES, get_config,
                               cell_skip_reason)
    from repro.distributed import hlo_analysis as H
    from repro.distributed.sharding import (rules_for, shard_ctx,
                                            tree_shardings)
    from repro.launch.mesh import make_production_mesh
    from repro.models import build_model
    from repro.models.param import split
    from repro.optim.adamw import AdamWState
    from repro.train.step import (TrainState, make_banked_decode_step,
                                  make_decode_step, make_fused_decode_step,
                                  make_prefill_step, make_train_step)

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    skip = cell_skip_reason(cfg, shape)
    meta = {"arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "seq_len": shape.seq_len, "global_batch": shape.global_batch,
            "kind": shape.kind, "opt_flags": list(opt_flags)}
    if skip:
        return {**meta, "status": "skip", "reason": skip}

    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    long_ctx = shape_name == "long_500k"
    rules = rules_for(shape.kind, long_context=long_ctx)

    t0 = time.time()
    params_p = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    params_struct, params_axes = split(params_p)
    param_sh = tree_shardings(params_struct, params_axes, rules, mesh)

    batch_struct = model.input_specs(shape.seq_len, shape.global_batch,
                                     kind=shape.kind)
    batch_sh = tree_shardings(batch_struct, model.batch_pspecs(shape.kind),
                              rules, mesh)
    repl = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

    if shape.kind == "train":
        opt_struct = AdamWState(
            mu=jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                            params_struct),
            nu=jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                            params_struct),
            count=jax.ShapeDtypeStruct((), jnp.int32))
        opt_sh = AdamWState(mu=param_sh, nu=param_sh, count=repl)
        state_struct = TrainState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            params=params_struct, opt=opt_struct)
        state_sh = TrainState(step=repl, params=param_sh, opt=opt_sh)
        step_fn = make_train_step(model, param_axes=params_axes)
        args = (state_struct, batch_struct)
        shardings = (state_sh, batch_sh)
        # pin output shardings: new state must land exactly on the input
        # layout (grads then reduce-scatter into the FSDP shards instead of
        # all-reducing full tensors); metrics are replicated scalars
        with mesh, shard_ctx(mesh, rules):
            _, metrics_struct = jax.eval_shape(step_fn, *args)
        out_shardings = (state_sh, jax.tree.map(lambda _: repl,
                                                metrics_struct))
    else:
        out_shardings = None
        # serving: bf16 params
        serve_struct = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype),
            params_struct)
        if shape.kind == "prefill":
            step_fn = make_prefill_step(model, max_len=shape.seq_len)
            args = (serve_struct, batch_struct)
            shardings = (param_sh, batch_sh)
        else:  # decode
            # sequence-shard the KV cache over `model` whenever kv heads
            # don't divide the axis (hillclimb A: 5× decode win); can be
            # forced/disabled via --opt kv_seq_shard / no_kv_seq_shard
            kv_auto = (cfg.num_kv_heads % 16 != 0 and not long_ctx
                       and cfg.family not in ("ssm", "hybrid"))
            kv_seq = ("kv_seq_shard" in opt_flags
                      or (kv_auto and "no_kv_seq_shard" not in opt_flags))
            cache_struct = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            cache_sh = tree_shardings(cache_struct,
                                      model.cache_pspecs(
                                          long_ctx, kv_seq_shard=kv_seq),
                                      rules, mesh)
            token_struct = batch_struct["tokens"]
            token_sh = batch_sh["tokens"]

            def quantize_cell(serve_struct, flat, delta_paths, param_sh):
                # --opt quantized_base: int8 base under the fused delta
                # GEMMs (DESIGN.md §16) — target leaves become abstract
                # QuantWeight twins (int8 payload + fp16 scales) and their
                # shardings get the same spec surgery the registry applies
                from repro.core.calibration import (flatten_params,
                                                    unflatten_like)
                from repro.core.quantize import (quant_sharding,
                                                 quantize_struct)
                qflat = quantize_struct(flat, delta_paths)
                serve_struct = unflatten_like(serve_struct, qflat)
                psh_flat = flatten_params(param_sh)
                for p in delta_paths:
                    psh_flat[p] = quant_sharding(psh_flat[p], flat[p].ndim)
                return serve_struct, unflatten_like(param_sh, psh_flat)

            if shape.fused:
                # single-variant on-the-fly serving cell: decode against
                # ONE packed overlay on its derived shardings — inside
                # shard_ctx the fused delta GEMMs lower as shard_map'd
                # per-shard Pallas kernels (kernels/dispatch.py,
                # DESIGN.md §12; --opt gspmd_kernels pins the PR-4
                # GSPMD-partitioned lowering for comparison)
                from repro.core.calibration import (flatten_params,
                                                    is_target)
                from repro.models import delta_overlay as DO
                flat = flatten_params(serve_struct)
                delta_paths = sorted(p for p, l in flat.items()
                                     if is_target(p, l))
                ov_struct = DO.overlay_struct(flat, delta_paths)
                ov_axes = DO.overlay_pspecs(params_axes, delta_paths)
                ov_sh = tree_shardings(ov_struct, ov_axes, rules, mesh)
                if "quantized_base" in opt_flags:
                    serve_struct, param_sh = quantize_cell(
                        serve_struct, flat, delta_paths, param_sh)
                step_fn = make_fused_decode_step(model)
                args = (serve_struct, ov_struct, token_struct, cache_struct)
                shardings = (param_sh, ov_sh, token_sh, cache_sh)
            elif shape.banked:
                # mixed-variant serving cell: decode against a banked
                # overlay whose leaves land on their derived shardings
                # (weight-axis tiles, replicated bank axis) — validates
                # the DESIGN.md §11 collective schedule: batch lanes over
                # `data`, fused delta GEMMs over `model`, no per-step
                # weight or overlay all-gathers
                from repro.core.calibration import (flatten_params,
                                                    is_target)
                from repro.models import delta_overlay as DO
                flat = flatten_params(serve_struct)
                delta_paths = sorted(p for p, l in flat.items()
                                     if is_target(p, l))
                ds = set(delta_paths)
                extra_paths = sorted(p for p in flat if p not in ds)
                bank_struct = DO.overlay_struct(
                    flat, delta_paths, extra_paths, bank_size=BANKED_SLOTS)
                bank_axes = DO.overlay_pspecs(
                    params_axes, delta_paths, extra_paths, bank=True)
                bank_sh = tree_shardings(bank_struct, bank_axes, rules,
                                         mesh)
                vidx_struct = jax.ShapeDtypeStruct(
                    (shape.global_batch,), jnp.int32)
                if "quantized_base" in opt_flags:
                    serve_struct, param_sh = quantize_cell(
                        serve_struct, flat, delta_paths, param_sh)
                step_fn = make_banked_decode_step(model)
                args = (serve_struct, bank_struct, vidx_struct,
                        token_struct, cache_struct)
                shardings = (param_sh, bank_sh, token_sh, token_sh,
                             cache_sh)
            else:
                step_fn = make_decode_step(model)
                args = (serve_struct, token_struct, cache_struct)
                shardings = (param_sh, token_sh, cache_sh)

    jit_kwargs = {"in_shardings": shardings}
    if out_shardings is not None:
        jit_kwargs["out_shardings"] = out_shardings
    import contextlib

    from repro.kernels import dispatch as _dp
    # serving cells lower the shard_map'd per-shard delta kernels by
    # default (the shard_ctx below activates kernels/dispatch.py);
    # --opt gspmd_kernels pins the PR-4 GSPMD-partitioned lowering
    dp_ctx = (_dp.no_dispatch() if "gspmd_kernels" in opt_flags
              else contextlib.nullcontext())
    cc_cache, cc_how = None, None
    if cache_dir:
        # --populate-cache: the dry-run doubles as the fleet's cache
        # warmer (DESIGN.md §14) — a later serve/warmup with the same
        # env + avals deserializes instead of compiling.  set_default
        # lets the dispatch-layer shard_map kernels persist too.
        from repro.core import compile_cache as CCm
        cc_cache = CCm.CompileCache(cache_dir)
        CCm.set_default(cc_cache)
        cc_parts = ("dryrun-cell", arch, shape_name, meta["mesh"],
                    tuple(sorted(opt_flags)), CCm.aval_fp(args),
                    CCm.sharding_fp(shardings),
                    CCm.sharding_fp(out_shardings))
    with mesh, shard_ctx(mesh, rules), dp_ctx:
        compiled = hlo_text = None
        if cc_cache is not None:
            compiled = cc_cache.get(cc_parts)
            if compiled is not None:
                try:
                    hlo_text = compiled.as_text()
                    cc_how, t_lower = "hit", 0.0
                    t_compile = cc_cache.stats["deserialize_seconds"]
                except Exception:
                    compiled = None   # loadable but not inspectable
        if compiled is None:
            lowered = jax.jit(step_fn, **jit_kwargs).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            hlo_text = compiled.as_text()
            if cc_cache is not None:
                cc_how = "compiled"
                cc_cache.put(cc_parts, compiled)
        summary = H.cost_summary(compiled, hlo_text)
        # trip-count-aware static analysis (cost_analysis counts while
        # bodies once — useless for scanned models); this is the roofline
        # source of truth
        from repro.distributed import hlo_cost as HCOST
        tc = HCOST.analyze(hlo_text)
        summary["flops"] = tc["flops"]
        summary["bytes_accessed"] = tc["bytes"]
        summary["collectives"] = tc["collectives"]
        summary["top_flop_ops"] = tc["top_flop_ops"]

    n_chips = 512 if multi_pod else 256

    # MODEL_FLOPS: 6·N·D train / 2·N·D forward, N = active matmul params
    from repro.configs.base import param_counts
    pc = param_counts(cfg)
    n_matmul = pc["active"] - cfg.vocab_size * cfg.d_model  # embed lookup free
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * n_matmul * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * n_matmul * tokens
    else:  # decode: one token per sequence
        model_flops = 2 * n_matmul * shape.global_batch
    terms = H.roofline_terms(summary["flops"], summary["bytes_accessed"],
                             summary["collectives"]["total_wire_bytes"],
                             model_flops_per_device=model_flops / n_chips)
    if cc_how is not None:
        meta["cache"] = cc_how
    return {**meta, "status": "ok", "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2), "n_chips": n_chips,
            "model_flops_total": model_flops,
            "params_total": pc["total"], "params_active": pc["active"],
            "cost": summary, "roofline": terms,
            "hlo_bytes": len(hlo_text)}


def cell_path(arch, shape, multi_pod, tag="") -> pathlib.Path:
    mesh = "multi" if multi_pod else "single"
    suffix = f".{tag}" if tag else ""
    return RESULTS_DIR / f"{arch}__{shape}__{mesh}{suffix}.json"


def run_all(multi_pod_only=None, force=False, tag="",
            cache_dir=None) -> int:
    """Subprocess-per-cell sweep; resumable. Returns #failures."""
    from repro.configs import cells
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    failures = 0
    todo = []
    for arch, shape, skip in cells():
        for mp in ((False, True) if multi_pod_only is None
                   else (multi_pod_only,)):
            todo.append((arch, shape, mp, skip))
    for i, (arch, shape, mp, skip) in enumerate(todo):
        out = cell_path(arch, shape, mp, tag)
        if out.exists() and not force:
            print(f"[{i+1}/{len(todo)}] skip-done {out.name}")
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--out", str(out)]
        if mp:
            cmd.append("--multi-pod")
        if tag:
            cmd += ["--tag", tag]
        if cache_dir:
            cmd += ["--populate-cache", str(cache_dir)]
        print(f"[{i+1}/{len(todo)}] {arch} × {shape} × "
              f"{'multi' if mp else 'single'} ...", flush=True)
        t0 = time.time()
        r = subprocess.run(cmd, capture_output=True, text=True,
                           env={**os.environ,
                                "PYTHONPATH": os.environ.get("PYTHONPATH", "")})
        dt = time.time() - t0
        if r.returncode != 0:
            failures += 1
            err = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if mp else "16x16", "status": "error",
                   "stderr": r.stderr[-4000:], "elapsed_s": round(dt, 1)}
            out.write_text(json.dumps(err, indent=2))
            print(f"    FAILED in {dt:.0f}s: {r.stderr.strip().splitlines()[-1] if r.stderr.strip() else '?'}")
        else:
            print(f"    ok in {dt:.0f}s")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out")
    ap.add_argument("--tag", default="", help="result filename suffix "
                    "(perf-iteration variants)")
    ap.add_argument("--opt", action="append", default=[],
                    help="optimization flags (repeatable), e.g. "
                         "--opt kv_seq_shard")
    ap.add_argument("--populate-cache", default=None, metavar="DIR",
                    help="persist every compiled cell executable into "
                         "this compile-cache dir (DESIGN.md §14) so a "
                         "matching serve --warmup deserializes it")
    args = ap.parse_args()

    if args.all:
        sys.exit(1 if run_all(force=args.force, tag=args.tag,
                              cache_dir=args.populate_cache) else 0)

    assert args.arch and args.shape, "--arch/--shape required without --all"
    try:
        res = run_cell(args.arch, args.shape, args.multi_pod,
                       opt_flags=tuple(args.opt),
                       cache_dir=args.populate_cache)
    except Exception:
        res = {"arch": args.arch, "shape": args.shape,
               "mesh": "2x16x16" if args.multi_pod else "16x16",
               "status": "error", "traceback": traceback.format_exc()[-6000:]}
    out = (pathlib.Path(args.out) if args.out
           else cell_path(args.arch, args.shape, args.multi_pod, args.tag))
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(res, indent=2))
    print(json.dumps({k: res[k] for k in res
                      if k in ("arch", "shape", "mesh", "status",
                               "compile_s", "cache")}))
    if res["status"] == "error":
        print(res.get("traceback", res.get("reason", "")), file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
