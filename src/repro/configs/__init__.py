"""Architecture registry + assigned input-shape grid.

``get_config(arch_id)`` returns the full published config; ``SHAPES`` is the
assigned shape set (incl. the fused single-variant and banked mixed-variant
decode serving shapes).  ``cells()`` enumerates the 60 (arch × shape)
dry-run cells, with per-cell eligibility (see DESIGN.md §4 for skip
rationale).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Iterator, Optional

from repro.configs.base import ModelConfig, param_counts  # noqa: F401

_ARCH_MODULES = {
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "xlstm-350m": "xlstm_350m",
    "deepseek-7b": "deepseek_7b",
    "qwen3-8b": "qwen3_8b",
    "starcoder2-3b": "starcoder2_3b",
    "gemma3-12b": "gemma3_12b",
    "zamba2-7b": "zamba2_7b",
    "internvl2-76b": "internvl2_76b",
    "whisper-base": "whisper_base",
}

ARCHS = tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"
    banked: bool = False  # decode against a banked overlay (mixed-variant
                          # serving cell — DESIGN.md §11); bank size below
    fused: bool = False   # decode against ONE packed overlay (single-
                          # variant on-the-fly serving cell: the shard_map
                          # delta-kernel hot path — DESIGN.md §12)

BANKED_SLOTS = 4   # dry-run bank size: base + 3 resident variants


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "decode_fused": ShapeSpec("decode_fused", 32768, 128, "decode",
                              fused=True),
    "decode_banked": ShapeSpec("decode_banked", 32768, 128, "decode",
                               banked=True),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def cell_skip_reason(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    """None if the (arch, shape) cell should run; else a skip reason."""
    if shape.name == "long_500k":
        if cfg.family == "audio":
            return "enc-dec with 30s audio frontend; decoder never sees 500k"
        if not cfg.is_sub_quadratic():
            return "pure full-attention arch; long_500k needs sub-quadratic"
    return None


def cells() -> Iterator[tuple[str, str, Optional[str]]]:
    """Yield (arch, shape, skip_reason) for all 60 cells."""
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            yield arch, shape.name, cell_skip_reason(cfg, shape)
