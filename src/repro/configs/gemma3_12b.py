"""gemma3-12b — 5:1 local:global attention, 128k context.

[hf:google/gemma-3-*; unverified-tier]  Assignment config:
48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144.
Pattern: 5 sliding-window (1024) local layers per 1 global layer; local
layers use rope_theta=10k, global layers 1M.  head_dim=256, qk-norm.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    qk_norm=True,
    local_global_pattern=5,
    sliding_window=1024,
    rope_theta=1_000_000.0,
    rope_theta_local=10000.0,
    max_seq_len=131072,
    tie_embeddings=True,
)
