"""moonshot-v1-16b-a3b — Moonlight-16B-A3B MoE (64 routed experts, top-6).

[hf:moonshotai/Moonlight-16B-A3B; hf-tier]  Assignment config:
48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840, MoE 64e top-6.
DeepSeek-V3-style fine-grained MoE: 2 shared experts + first layer dense.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    moe_d_ff=1408,
    vocab_size=163840,
    num_experts=64,
    num_shared_experts=2,
    top_k=6,
    moe_first_dense=1,
    rope_theta=50000.0,
    max_seq_len=8192,
)
