"""zamba2-7b — Mamba2 backbone + shared attention blocks.

[arXiv:2411.15242; unverified-tier]  Assignment config:
81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64.
A single shared (attention + MLP) block is re-applied every 6 Mamba2
blocks, consuming [h, h_embed_orig] concat (concat_embed).  Weight sharing
means the shared block contributes ONE delta re-used at every application
point — see DESIGN.md §4.
Mamba2: d_inner = 2·d_model = 7168, head_dim 64 → 112 SSM heads.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_heads=112,
    ssm_conv=4,
    attn_every=6,
    concat_embed=True,
    max_seq_len=4096,
)
