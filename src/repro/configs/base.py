"""Unified model configuration covering all assigned architecture families.

One ``ModelConfig`` describes a model from any family (dense / moe / ssm /
hybrid / vlm / audio); family-specific fields are ignored where not
applicable.  ``reduced()`` produces the CPU smoke-test variant of the same
family (small widths, few layers/experts, tiny vocab) per the assignment.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // num_heads
    max_seq_len: int = 8192

    # --- positional / attention flavour ---
    rope_theta: float = 10000.0
    qk_norm: bool = False             # qwen3 / gemma3
    sliding_window: int = 0           # >0: local attention window
    local_global_pattern: int = 0     # gemma3: N local layers per 1 global
    rope_theta_local: float = 10000.0 # gemma3 local layers use smaller base
    attn_logit_softcap: float = 0.0

    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_first_dense: int = 0          # first K layers use dense MLP
    moe_d_ff: int = 0                 # expert hidden dim (d_ff used if 0)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # --- SSM / hybrid ---
    ssm_state: int = 0                # mamba2 state size per head
    ssm_heads: int = 0
    ssm_conv: int = 4
    mlstm_ratio: int = 0              # xlstm: mLSTM blocks per sLSTM block+1 (7 -> 7:1)
    attn_every: int = 0               # zamba2: shared attn block every N mamba blocks
    concat_embed: bool = False        # zamba2: concat original embedding into attn input

    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    encoder_frames: int = 0           # stub frontend sequence length
    cross_attention: bool = False

    # --- vlm ---
    num_image_tokens: int = 0         # stub frontend patch-embedding count

    # --- numerics ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # --- distribution ---
    remat: bool = True
    scan_layers: bool = True

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # -- derived -----------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the unembed matmul and
        logits always shard over the model axis (whisper's 51865 would
        otherwise replicate multi-GB logits per device)."""
        return (self.vocab_size + 255) // 256 * 256

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def is_sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (see DESIGN.md §4)."""
        return self.family in ("ssm", "hybrid") or self.local_global_pattern > 0

    def has_decoder(self) -> bool:
        return True  # no encoder-only archs in the assignment

    # -- reduced smoke config ------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Same family, tiny dims — used by CPU smoke tests only."""
        def shrink(v, lo, hi):
            return 0 if v == 0 else max(lo, min(v, hi))
        return dataclasses.replace(
            self,
            num_layers=min(self.num_layers, 4 if self.family != "hybrid" else 7),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads < self.num_heads else 4,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            moe_d_ff=64 if self.num_experts else 0,
            vocab_size=256,
            max_seq_len=128,
            num_experts=shrink(self.num_experts, 4, 8),
            num_shared_experts=min(self.num_shared_experts, 1),
            top_k=shrink(self.top_k, 2, 2),
            # dropless in smoke configs: capacity == group size makes routing
            # independent of batch/seq composition (prefill == forward)
            capacity_factor=(8.0 if self.num_experts else self.capacity_factor),
            moe_first_dense=min(self.moe_first_dense, 1),
            # keep num_layers a multiple of the (reduced) layer pattern
            local_global_pattern=min(self.local_global_pattern, 1),
            sliding_window=shrink(self.sliding_window, 16, 16),
            ssm_state=shrink(self.ssm_state, 16, 16),
            ssm_heads=shrink(self.ssm_heads, 2, 2),
            mlstm_ratio=shrink(self.mlstm_ratio, 3, 3),
            attn_every=shrink(self.attn_every, 3, 3),
            encoder_layers=min(self.encoder_layers, 2),
            encoder_frames=shrink(self.encoder_frames, 16, 16),
            num_image_tokens=shrink(self.num_image_tokens, 8, 8),
            remat=False,
            scan_layers=True,
        )


# ---------------------------------------------------------------------------
# Parameter counting (roofline MODEL_FLOPS = 6·N·D needs N and N_active)
# ---------------------------------------------------------------------------

def param_counts(cfg: ModelConfig) -> dict:
    """Approximate total and active parameter counts (embedding included)."""
    d, ff, L = cfg.d_model, cfg.d_ff, cfg.num_layers
    attn = d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d

    def dense_mlp(hidden):
        return 3 * d * hidden  # gated (gate, up, down)

    total = active = 0
    if cfg.family in ("dense", "vlm"):
        total = L * (attn + dense_mlp(ff))
        active = total
    elif cfg.family == "moe":
        e_ff = cfg.expert_d_ff
        n_dense = cfg.moe_first_dense
        n_moe = L - n_dense
        per_moe = (attn + cfg.num_experts * dense_mlp(e_ff)
                   + cfg.num_shared_experts * dense_mlp(e_ff)
                   + d * cfg.num_experts)
        per_moe_active = (attn + cfg.top_k * dense_mlp(e_ff)
                          + cfg.num_shared_experts * dense_mlp(e_ff)
                          + d * cfg.num_experts)
        total = n_dense * (attn + dense_mlp(ff)) + n_moe * per_moe
        active = n_dense * (attn + dense_mlp(ff)) + n_moe * per_moe_active
    elif cfg.family == "ssm":
        # xlstm block: up-proj 2x + qkv-ish + down; rough but consistent
        per = 2 * d * 2 * d + 3 * (2 * d) * (2 * d) // 4 + 2 * d * d
        total = L * per
        active = total
    elif cfg.family == "hybrid":
        d_inner = 2 * d
        mamba = d * (2 * d_inner) + d_inner * d + d_inner * (2 * cfg.ssm_state)
        n_attn = L // max(cfg.attn_every, 1)
        shared = attn + dense_mlp(ff)  # ONE shared block, reused
        total = L * mamba + shared
        active = L * mamba + n_attn * shared // max(n_attn, 1) * n_attn
        active = total  # weight sharing: all params active across the pass
    elif cfg.family == "audio":
        enc = cfg.encoder_layers * (attn + 2 * d * ff)
        dec = L * (2 * attn + 2 * d * ff)  # self + cross attention
        total = enc + dec
        active = total
    emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    total += emb
    active += emb
    return {"total": int(total), "active": int(active)}
