"""internvl2-76b — InternViT + LLM backbone (backbone only; ViT stubbed).

[arXiv:2404.16821; unverified-tier]  Assignment config:
80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
Per the assignment, [vlm] entries specify the transformer BACKBONE; the
vision frontend is a STUB — input_specs() provides precomputed patch
embeddings (num_image_tokens × d_model) prepended to the token stream.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    num_image_tokens=256,
    rope_theta=500000.0,
    max_seq_len=32768,
)
