"""deepseek-moe-16b — fine-grained MoE, 2 shared + 64 routed top-6.

[arXiv:2401.06066; hf-tier]  Assignment config:
28L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=102400, MoE 64e top-6.
First layer dense (first_k_dense_replace=1 in the HF config).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    moe_d_ff=1408,
    vocab_size=102400,
    num_experts=64,
    num_shared_experts=2,
    top_k=6,
    moe_first_dense=1,
    rope_theta=10000.0,
    max_seq_len=4096,
)
