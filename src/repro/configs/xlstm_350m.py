"""xlstm-350m — sLSTM + mLSTM blocks (xLSTM[7:1]).

[arXiv:2405.04517; unverified-tier]  Assignment config:
24L d_model=1024 4H (GQA kv=4) d_ff=0 vocab=50304.
d_ff=0: xLSTM blocks carry their own up/down projections (factor-2 up for
mLSTM, post-FFN 4/3 for sLSTM per the paper); no separate MLP block.
mlstm_ratio=7 → repeating pattern of 7 mLSTM blocks then 1 sLSTM block.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    mlstm_ratio=7,
    ssm_conv=4,
    max_seq_len=8192,
)
