"""whisper-base — encoder-decoder; conv audio frontend stubbed.

[arXiv:2212.04356; unverified-tier]  Assignment config:
6L d_model=512 8H (GQA kv=8) d_ff=2048 vocab=51865; enc-dec.
Frontend stub: input_specs() provides precomputed frame embeddings
(encoder_frames=1500 × d_model) standing in for the two conv1d layers.
Positions: sinusoidal (no RoPE), matching Whisper.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,          # decoder layers
    encoder_layers=6,
    encoder_frames=1500,
    cross_attention=True,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    max_seq_len=4096,
)
