"""Sharded AdamW.

Moments are plain pytrees mirroring the parameters, so they inherit the
parameter shardings (FSDP over `data`, TP over `model`) — no extra rules.
Weight decay is masked off 1-D params (norm scales, biases, per-axis delta
vectors) as is standard.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    mu: Any
    nu: Any
    count: jax.Array


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params),
                      count=jnp.zeros((), jnp.int32))


def _decay_mask(path_leaf) -> bool:
    """True if weight decay applies: only >=2-D weight matrices."""
    return path_leaf.ndim >= 2


def adamw_update(params, grads, state: AdamWState, *, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1,
                 grad_clip_norm: float = 1.0):
    """Returns (new_params, new_state, metrics)."""
    # global-norm clip (fp32)
    gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, grad_clip_norm / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    count = state.count + 1
    c1 = 1 - b1 ** count.astype(jnp.float32)
    c2 = 1 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        step = (m_new / c1) / (jnp.sqrt(v_new / c2) + eps)
        if _decay_mask(p):
            step = step + weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * step
        return p_new.astype(p.dtype), m_new, v_new

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return (new_params, AdamWState(mu=new_mu, nu=new_nu, count=count),
            {"grad_norm": gnorm})
