"""Fault-tolerant training loop: checkpoint / auto-resume / elastic.

Contract:
* deterministic data — batch i is a pure function of (seed, i), so a
  restart at step N replays exactly the stream a non-failed run would
  have seen;
* auto-resume — on start, the newest VALID checkpoint is restored (torn
  checkpoints from a dead writer are skipped by the manager);
* preemption-safe — ``interrupt_at`` (tests) and SIGTERM both exit after
  finishing the current step + an emergency save;
* elastic — ``remesh(data_parallel)`` recomputes shardings for a smaller
  data axis (straggler / failed-pod drop-and-continue: the assignment's
  elastic-scaling requirement at the sharding level; real fleets re-slice
  through the same entry point);
* optional 1-bit-with-error-feedback gradient compression
  (distributed/compression.py) for the cross-pod exchange.
"""
from __future__ import annotations

import dataclasses
import signal
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import SyntheticLM
from repro.distributed import compression as GC
from repro.models.model_zoo import Model
from repro.train.step import TrainState, init_train_state, make_train_step


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    batch_size: int = 4
    seq_len: int = 64
    peak_lr: float = 3e-4
    warmup: int = 10
    seed: int = 0
    grad_compress: bool = False


class Trainer:
    def __init__(self, model: Model, ckpt_dir: str,
                 loop_cfg: Optional[LoopConfig] = None):
        self.model = model
        self.cfg = loop_cfg or LoopConfig()
        self.ckpt = CheckpointManager(ckpt_dir)
        self.data = SyntheticLM(model.cfg.vocab_size, self.cfg.seed)
        self._interrupted = False

        self.ef_transform = None
        self.ef_state = None
        if self.cfg.grad_compress:
            self.ef_transform, self._ef_init = GC.make_ef_transform()

        step_fn = make_train_step(model, peak_lr=self.cfg.peak_lr,
                                  warmup=self.cfg.warmup,
                                  total_steps=self.cfg.total_steps)
        if self.cfg.grad_compress:
            # wrap: train step with EF state threaded through
            base_loss_step = make_train_step(
                model, peak_lr=self.cfg.peak_lr, warmup=self.cfg.warmup,
                total_steps=self.cfg.total_steps)

            def step_with_ef(state, ef, batch):
                from repro.optim.adamw import adamw_update
                from repro.optim.schedule import cosine_schedule
                from repro.train.step import make_loss_fn
                loss_fn = make_loss_fn(model)
                (total, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(state.params, batch)
                grads, ef = self.ef_transform(grads, ef)
                lr = cosine_schedule(state.step, self.cfg.warmup,
                                     self.cfg.total_steps, self.cfg.peak_lr)
                params, opt, om = adamw_update(state.params, grads,
                                               state.opt, lr=lr)
                new_state = TrainState(step=state.step + 1, params=params,
                                       opt=opt)
                return new_state, ef, {**metrics, **om, "total_loss": total}

            self._step = jax.jit(step_with_ef)
        else:
            self._step = jax.jit(step_fn)

    # -- signals ---------------------------------------------------------------
    def _install_sigterm(self):
        def handler(signum, frame):
            self._interrupted = True
        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass  # not main thread (tests)

    # -- main -----------------------------------------------------------------
    def run(self, interrupt_at: Optional[int] = None) -> dict:
        """Train to total_steps; resumes from the newest valid checkpoint.
        interrupt_at simulates preemption after that step (tests)."""
        self._install_sigterm()
        model = self.model
        state = init_train_state(model, jax.random.PRNGKey(self.cfg.seed))
        restored_step, state = self.ckpt.restore_latest(state)
        start = int(state.step) if restored_step is not None else 0
        if self.cfg.grad_compress:
            grads_template = state.params
            self.ef_state = self._ef_init(grads_template)

        losses = []
        step = start
        for step in range(start, self.cfg.total_steps):
            batch = self.data.lm_batch(step, self.cfg.batch_size,
                                       self.cfg.seq_len)
            if self.cfg.grad_compress:
                state, self.ef_state, metrics = self._step(
                    state, self.ef_state, batch)
            else:
                state, metrics = self._step(state, batch)
            losses.append(float(metrics["loss"]))
            done = step + 1
            if done % self.cfg.ckpt_every == 0 or done == self.cfg.total_steps:
                self.ckpt.save(done, state)
            if interrupt_at is not None and done >= interrupt_at:
                self._interrupted = True
            if self._interrupted:
                self.ckpt.save(done, state)   # emergency save
                return {"state": state, "losses": losses,
                        "completed": done, "interrupted": True}
        return {"state": state, "losses": losses,
                "completed": self.cfg.total_steps, "interrupted": False}


# ---------------------------------------------------------------------------
# elastic re-meshing
# ---------------------------------------------------------------------------

def remesh(model: Model, state: TrainState, old_mesh, new_data: int,
           new_model: int, rules: dict):
    """Recompute shardings for a resized mesh and resharde the state —
    drop-and-continue after losing hosts.  Returns (mesh, state_shardings).

    (On real hardware the caller would jax.device_put the state onto the
    new shardings; in tests we verify the spec trees resolve and stay
    consistent.)"""
    import numpy as np
    from jax.sharding import Mesh
    from repro.distributed.sharding import tree_shardings
    from repro.models.param import split
    from repro.optim.adamw import AdamWState
    devices = np.asarray(jax.devices()[:new_data * new_model]).reshape(
        new_data, new_model)
    mesh = Mesh(devices, ("data", "model"))
    params_p = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_struct, p_axes = split(params_p)
    p_sh = tree_shardings(p_struct, p_axes, rules, mesh)
    repl = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    state_sh = TrainState(
        step=repl, params=p_sh,
        opt=AdamWState(mu=p_sh, nu=p_sh, count=repl))
    return mesh, state_sh
