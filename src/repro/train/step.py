"""Training / serving step functions (pure; pjit-ready).

``make_train_step(model)`` returns step(state, batch) -> (state, metrics);
``make_prefill_step`` / ``make_decode_step`` are the serving equivalents.
All are mesh-agnostic — shardings are applied by the caller (launch/ or
tests) via jax.jit in/out shardings + the shard_ctx rule context.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.model_zoo import Model
from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.schedule import cosine_schedule


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    step: jax.Array
    params: Any
    opt: AdamWState


def init_train_state(model: Model, rng) -> TrainState:
    from repro.models.param import split
    params, _ = split(model.init(rng))
    return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                      opt=adamw_init(params))


def lm_loss(logits: jax.Array, labels: jax.Array,
            mask: Optional[jax.Array] = None) -> jax.Array:
    """Masked next-token cross-entropy (labels already shifted by the data
    pipeline; -100 labels are ignored)."""
    valid = labels >= 0 if mask is None else mask
    labels_safe = jnp.maximum(labels, 0)
    lt = logits.astype(jnp.float32)
    ll = jax.nn.log_softmax(lt, axis=-1)
    nll = -jnp.take_along_axis(ll, labels_safe[..., None], axis=-1)[..., 0]
    nll = jnp.where(valid, nll, 0.0)
    return nll.sum() / jnp.maximum(valid.sum(), 1)


def make_loss_fn(model: Model, aux_weight: Optional[float] = None):
    aux_w = (model.cfg.router_aux_weight if aux_weight is None else aux_weight)
    compute_dtype = jnp.dtype(model.cfg.compute_dtype)

    def loss_fn(params, batch):
        # cast params to the compute dtype ONCE, before the layer stack —
        # FSDP all-gathers then move bf16 (half the wire bytes of fp32);
        # grads flow through the cast and accumulate fp32
        params = jax.tree.map(
            lambda w: w.astype(compute_dtype)
            if w.dtype == jnp.float32 and w.ndim >= 2 else w, params)
        logits, aux = model.forward(params, batch)
        labels = batch["labels"]
        s_lbl = labels.shape[1]
        # frontends may prepend positions (vlm image tokens): align tail
        logits = logits[:, -s_lbl:, :]
        loss = lm_loss(logits, labels)
        total = loss + aux_w * aux.get("moe_aux", 0.0)
        return total, {"loss": loss, "moe_aux": aux.get("moe_aux", 0.0)}

    return loss_fn


def make_train_step(model: Model, *, peak_lr: float = 3e-4,
                    warmup: int = 100, total_steps: int = 10_000,
                    weight_decay: float = 0.1,
                    grad_transform: Optional[Callable] = None,
                    param_axes=None):
    """grad_transform(grads) -> grads hook: gradient compression plugs in
    here (distributed/compression.py).

    param_axes: logical-axes tree matching params — when given, gradients
    are sharding-constrained to the parameter layout right after autodiff,
    which turns GSPMD's full weight-grad all-reduces into reduce-scatters
    into the FSDP shards (≈2× less gradient wire traffic)."""
    loss_fn = make_loss_fn(model)

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        (total, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params, batch)
        if param_axes is not None:
            from repro.distributed.sharding import _axes_leaf
            from repro.distributed.sharding import logical_constraint as lc
            grads = jax.tree.map(lambda ax, g: lc(g, *ax), param_axes,
                                 grads, is_leaf=_axes_leaf)
        if grad_transform is not None:
            grads = grad_transform(grads)
        lr = cosine_schedule(state.step, warmup, total_steps, peak_lr)
        params, opt, opt_metrics = adamw_update(
            state.params, grads, state.opt, lr=lr,
            weight_decay=weight_decay)
        new_state = TrainState(step=state.step + 1, params=params, opt=opt)
        return new_state, {**metrics, **opt_metrics, "lr": lr,
                           "total_loss": total}

    return train_step


def make_eval_step(model: Model):
    loss_fn = make_loss_fn(model)

    def eval_step(params, batch) -> dict:
        _, metrics = loss_fn(params, batch)
        return metrics

    return eval_step


# ---------------------------------------------------------------------------
# serving steps
# ---------------------------------------------------------------------------

def make_prefill_step(model: Model, max_len: int, cache_dtype=jnp.bfloat16):
    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len, cache_dtype=cache_dtype)
    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, token, cache):
        return model.decode_step(params, token, cache)
    return decode_step


def make_banked_decode_step(model: Model):
    """Mixed-variant decode: every batch row fuses its own overlay-bank
    slot's packed delta (slot 0 = base) — the sharded serving hot path
    the dry-run decode_banked cells lower (DESIGN.md §11)."""
    def banked_decode_step(params, bank, variant_idx, token, cache):
        return model.decode_step(params, token, cache, overlay=bank,
                                 variant_idx=variant_idx)
    return banked_decode_step


def make_fused_decode_step(model: Model):
    """Single-variant on-the-fly decode: the whole batch fuses ONE packed
    delta overlay into every GEMM (residency mode "fused", DESIGN.md §6) —
    the dry-run decode_fused cells lower this with the overlay leaves on
    their derived shardings, exercising the shard_map'd per-shard delta
    kernels (DESIGN.md §12)."""
    def fused_decode_step(params, overlay, token, cache):
        return model.decode_step(params, token, cache, overlay=overlay)
    return fused_decode_step
