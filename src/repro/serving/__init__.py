from repro.serving.admission import AdmissionPipeline  # noqa: F401
from repro.serving.api import Deployment  # noqa: F401
from repro.serving.engine import ServingEngine  # noqa: F401
from repro.serving.variants import VariantRegistry  # noqa: F401
