"""Async admission pipeline: artifact ingest off the serving thread.

The synchronous lifecycle (PR 3) admits a variant INLINE: the first
request for a new version pays the full chain on the serving thread —
chunked store read, XOR patch chain, sha verification, host→device
transfer, bank scatter — and every in-flight decode lane stalls for the
duration.  DeltaZip keeps decompression off the serving critical path for
exactly this reason; BitDelta-sized artifacts only pay off operationally
if admitting one never pauses traffic.

This module threads a SECOND execution timeline through the stack
(DESIGN.md §13): a background ingest worker runs stages (1)-(2), the
serving thread keeps only stage (3):

1. **ingest** (worker thread): ``VariantStore.load`` → chunked per-module
   npz streaming (``store.iter_artifact_modules``, bounded ``readinto``
   reads — peak host RAM O(largest module)), XOR patch-chain walk, and
   per-module sha verification, all host-side; the worker yields the host
   between module streams (``pacing_s``) so co-located decode keeps its
   step-latency SLO even when ingest and dispatch share CPUs;
2. **stage** (worker thread): ``loader.stage_overlay_transfer`` begins
   per-module ``jax.device_put`` WITHOUT a fence — H2D copies ride in
   flight as jax futures and overlap whatever the serving thread is
   executing;
3. **commit** (serving thread, between decode steps): the engine's
   ``drain(max_admits=1)`` hook performs the one donated bank scatter
   (``VariantRegistry._bank_admit(block=False)``) — jax data dependencies
   order the next decode after the scatter, so the only on-thread cost is
   dispatch.

Tickets move ``queued → staging → staged → admitted | failed``.  A failed
ticket is CONSUMED by the first ``poll`` that observes it (the caller
re-queues with its own retry budget, mirroring the sync path's
``max_retries`` semantics).  While a ticket is live its version key is
marked ``staging`` on the overlay bank, so ``evict``/``rollback`` of a
mid-ingest variant raise cleanly instead of racing the commit.

Thread model: ONE daemon ingest worker (lazy-started) plus the
serving/user thread.  The worker touches only the store (RLock'd), the
registry's read-mostly version tables, and jax dispatch (thread-safe);
every bank mutation happens on the serving thread inside ``drain``/
``wait``.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Optional

from repro.core import loader as L


@dataclasses.dataclass
class AdmissionTicket:
    """One variant version moving through the ingest pipeline, bound for
    ONE pod's bank shard (tickets are keyed per (vkey, pod): a pod-local
    bank admits the same version into two pods as two independent
    ingests, DESIGN.md §17 — the artifact re-reads from the store rather
    than copying device-to-device across pods)."""
    nameish: str                      # caller-facing request string
    name: str
    version: object                   # None for unversioned registrations
    vkey: str                         # bank/resident key (name@vN)
    pod: int = 0                      # target pod's slot range
    state: str = "queued"             # queued|staging|staged|admitted|failed
    error: Optional[str] = None
    dm: object = None                 # staged DeltaModel (device futures)
    futures: list = dataclasses.field(default_factory=list)
    enqueued_at: float = 0.0
    staged_at: float = 0.0


_LIVE = ("queued", "staging", "staged")


class AdmissionPipeline:
    """Background ingest + between-step commit for overlay-bank admission.

    ``prefetch`` enqueues ingest of a variant's current version (publish/
    update call it so staging overlaps the traffic that is still draining);
    ``poll`` reports progress (auto-prefetching unseen variants — the
    engine's admission loop is the other entry point); ``drain`` commits
    staged overlays into the bank, at most ``max_admits`` scatters per
    call, bounding the on-thread work per decode step; ``wait`` blocks
    until a variant (or everything) has settled — the ``wait=`` escape
    hatch of the non-blocking control-plane verbs."""

    def __init__(self, registry, *, pacing_s: float = 0.002):
        self.registry = registry
        # SLO pacing: the worker sleeps ``pacing_s`` between module streams
        # of the chunked artifact read (store.iter_artifact_modules), so on
        # hosts where ingest and decode share CPUs no single decode step
        # absorbs the whole ingest.  Costs ~pacing_s x module-count of
        # extra staging wall-time — which the pipeline hides anyway — and
        # nothing on hosts with spare cores.  0 disables.
        self.pacing_s = pacing_s
        self._cond = threading.Condition()
        # (vkey, pod) -> ticket: per-pod tickets (DESIGN.md §17)
        self._tickets: dict[tuple, AdmissionTicket] = {}
        self._work: collections.deque = collections.deque()
        self._worker: Optional[threading.Thread] = None
        self._closed = False
        self.stats = {"prefetches": 0, "staged": 0, "commits": 0,
                      "failures": 0, "stage_seconds": 0.0}

    # -- enqueue -----------------------------------------------------------
    def prefetch(self, nameish: str, pod: int = 0) -> Optional[str]:
        """Begin ingest of ``nameish``'s CURRENT version (or an explicit
        ``name@vN``) toward ``pod``'s bank shard.  Idempotent: already-
        resident-in-pod versions and live tickets return immediately.
        Returns the version key (None for the base, which needs no
        admission)."""
        if nameish == "__base__":
            return None
        name, version = self.registry._parse(nameish)   # KeyError: unknown
        vkey = self.registry._vkey(name, version)
        bank = self.registry.bank
        if bank is not None and bank.holds(vkey, pod):
            return vkey                                  # already admitted
        with self._cond:
            if self._closed:
                raise RuntimeError("admission pipeline is closed")
            t = self._tickets.get((vkey, pod))
            if t is not None and t.state in _LIVE:
                return vkey
            t = AdmissionTicket(nameish=nameish, name=name, version=version,
                                vkey=vkey, pod=pod,
                                enqueued_at=time.perf_counter())
            self._tickets[(vkey, pod)] = t
            # mark BEFORE the worker can observe the ticket: evict/rollback
            # must refuse from the moment ingest is promised
            self.registry._ensure_bank().mark_staging(vkey, pod)
            self._work.append((vkey, pod))
            self.stats["prefetches"] += 1
            self._ensure_worker()
            self._cond.notify_all()
        return vkey

    # -- progress ----------------------------------------------------------
    def poll(self, nameish: str, pod: int = 0) -> str:
        """Pipeline state for ``nameish`` toward ``pod``: ``admitted``
        once its version is bank-resident in that pod, else the live
        ticket state (``queued``/``staging``/``staged``), auto-prefetching
        variants never seen.  A FAILED ticket is consumed here — deleted
        so a later poll re-ingests — and its error re-raised for the
        caller's retry logic."""
        name, version = self.registry._parse(nameish)
        vkey = self.registry._vkey(name, version)
        bank = self.registry.bank
        if bank is not None and bank.holds(vkey, pod):
            return "admitted"
        with self._cond:
            t = self._tickets.get((vkey, pod))
            if t is not None and t.state == "failed":
                del self._tickets[(vkey, pod)]
                raise RuntimeError(t.error)
        if t is None:
            self.prefetch(nameish, pod)
            return "queued"
        return t.state

    def staging(self, name: str) -> bool:
        """A version of ``name`` is mid-pipeline (queued/staging/staged —
        not yet committed, not failed).  Rollback/evict guard."""
        with self._cond:
            return any(t.name == name and t.state in _LIVE
                       for t in self._tickets.values())

    def admitting(self) -> list:
        """Version keys currently mid-pipeline (status surfacing; a key
        ingesting toward several pods appears once)."""
        with self._cond:
            return sorted({t.vkey for t in self._tickets.values()
                           if t.state in _LIVE})

    def in_flight(self) -> int:
        with self._cond:
            return sum(1 for t in self._tickets.values()
                       if t.state in _LIVE)

    def wait_progress(self, timeout: float) -> None:
        """Block the serving thread until a ticket is ready to commit (or
        has failed), at most ``timeout`` seconds — the engine's idle wait
        when every queued request is behind ingest (no busy spin)."""
        with self._cond:
            if any(t.state in ("staged", "failed")
                   for t in self._tickets.values()):
                return
            self._cond.wait(timeout)

    # -- commit (serving thread) -------------------------------------------
    def drain(self, max_admits: int = 1) -> int:
        """Commit up to ``max_admits`` staged overlays into the bank (one
        donated scatter each, dispatched WITHOUT a device fence).  Called
        by the engine between decode steps — ``max_admits=1`` bounds the
        per-step on-thread work to one scatter dispatch.  Returns the
        number of commits."""
        done = 0
        while done < max_admits:
            with self._cond:
                t = next((t for t in self._tickets.values()
                          if t.state == "staged"), None)
            if t is None or not self._commit(t):
                break
            done += 1
        return done

    def _commit(self, t: AdmissionTicket) -> bool:
        """One staged ticket → bank scatter.  RuntimeError (bank full,
        every slot pinned) leaves the ticket staged for a later drain;
        any other failure fails the ticket."""
        try:
            self.registry._bank_admit(t.vkey, t.dm, block=False, pod=t.pod)
        except RuntimeError:
            return False          # transient capacity pressure: retry later
        except Exception as e:
            with self._cond:
                t.state, t.error = "failed", str(e)
                self.registry._ensure_bank().unmark_staging(t.vkey, t.pod)
                self.stats["failures"] += 1
                self._cond.notify_all()
            return False
        with self._cond:
            t.state = "admitted"
            # residency is now visible via the bank itself; the ticket is
            # done (poll checks bank slots first)
            del self._tickets[(t.vkey, t.pod)]
            self.registry.bank.unmark_staging(t.vkey, t.pod)
            self.stats["commits"] += 1
            self._cond.notify_all()
        return True

    def wait(self, nameish: Optional[str] = None, *,
             timeout: float = 30.0) -> None:
        """Block until ``nameish`` (or, with None, every live ticket) has
        been committed or failed — committing staged tickets on THIS
        thread, so waiting works with or without an engine drain loop.
        Raises the ingest error of a failed ticket; TimeoutError on
        deadline."""
        vkey = None
        if nameish is not None and nameish != "__base__":
            name, version = self.registry._parse(nameish)
            vkey = self.registry._vkey(name, version)
        deadline = time.monotonic() + timeout
        while True:
            self.drain(max_admits=1 << 30)
            with self._cond:
                if vkey is not None:
                    live = [t for t in self._tickets.values()
                            if t.vkey == vkey]       # any pod's ticket
                    if not live:
                        return                      # committed (or never live)
                    failed = next((t for t in live
                                   if t.state == "failed"), None)
                    if failed is not None:
                        del self._tickets[(failed.vkey, failed.pod)]
                        raise RuntimeError(failed.error)
                else:
                    failed = next((t for t in self._tickets.values()
                                   if t.state == "failed"), None)
                    if failed is not None:
                        del self._tickets[(failed.vkey, failed.pod)]
                        raise RuntimeError(failed.error)
                    if not self._tickets:
                        return
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError(
                        f"admission of {nameish or 'all variants'} did not "
                        f"settle within {timeout:.1f}s")
                self._cond.wait(min(remaining, 0.05))

    def close(self) -> None:
        """Stop the ingest worker (idempotent).  Live tickets are left
        un-committed; the daemon thread exits at its next wakeup."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        if self._worker is not None:
            self._worker.join(timeout=5.0)
            self._worker = None

    # -- ingest worker -----------------------------------------------------
    def _pace(self) -> None:
        """Yield the host between module streams (see ``pacing_s``)."""
        if self.pacing_s > 0:
            time.sleep(self.pacing_s)

    def _ensure_worker(self) -> None:
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(
                target=self._run, name="admission-ingest", daemon=True)
            self._worker.start()

    def _run(self) -> None:
        while True:
            with self._cond:
                while not self._work and not self._closed:
                    self._cond.wait(1.0)
                if self._closed:
                    return
                key = self._work.popleft()
                t = self._tickets.get(key)
                if t is None or t.state != "queued":
                    continue
                t.state = "staging"
            try:
                t0 = time.perf_counter()
                # stages (1)+(2): chunked store read + patch chain + sha
                # verify (host-side), then unfenced per-module H2D
                # transfers — all off the serving thread
                dm = self.registry._load(t.name, t.version,
                                         pacer=self._pace)
                dm_dev, futures = L.stage_overlay_transfer(
                    dm, param_shardings=self.registry.param_shardings)
                with self._cond:
                    t.dm, t.futures = dm_dev, futures
                    t.state, t.staged_at = "staged", time.perf_counter()
                    self.stats["staged"] += 1
                    self.stats["stage_seconds"] += t.staged_at - t0
                    self._cond.notify_all()
            except Exception as e:      # noqa: BLE001 — ticket carries it
                with self._cond:
                    t.state, t.error = "failed", str(e)
                    self.stats["failures"] += 1
                    bank = self.registry.bank
                    if bank is not None:
                        bank.unmark_staging(t.vkey, t.pod)
                    self._cond.notify_all()
