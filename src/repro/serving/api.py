"""Deployment: the versioned variant lifecycle as ONE control plane.

The paper's headline claim is cheap *frequent* model updates — which makes
publishing, updating, hot-swapping and rolling back variants a first-class
serving concern, not an exercise in wiring ``VariantStore`` +
``VariantRegistry`` + ``ServingEngine`` by hand (DeltaZip's lesson: serving
many deltas is a lifecycle problem, not just a kernel problem).

One facade, six verbs::

    dep = Deployment(model, base_params, root_dir="/srv/variants")
    v1  = dep.publish("support-bot", dm)          # full artifact, version 1
    rid = dep.submit(prompt, variant="support-bot")
    v2  = dep.update("support-bot", dm_next)      # XOR/RLE patch, hot-swap
    dep.drain()
    dep.status(rid)                               # {"status": "done", ...}
    dep.rollback("support-bot")                   # constant-time pointer move

Semantics callers can rely on:

* ``publish`` writes a full store-v3 artifact and points serving at it;
* ``update`` writes an incremental patch (typically a small fraction of a
  full publish — the version-to-version residual is small) and atomically
  moves the serving pointer: requests admitted after the call serve the
  new version, in-flight requests finish on the version they pinned;
* ``rollback`` moves the pointer back without touching artifacts — if the
  old version is still bank-resident the next admission is a cache hit;
* ``submit``/``drain``/``status``/``result`` are the data plane — callers
  never see registry residency modes, bank slots, or engine scheduling.

A ``Deployment`` without a store (``root_dir=None``) keeps versions
in-memory only — useful for tests and benchmarks; the lifecycle semantics
are identical, minus crash durability.

Mesh-sharded deployments (DESIGN.md §11): pass ``mesh`` (axes
("data", "model"), e.g. from ``launch.mesh.make_host_mesh``) plus
``param_axes`` (the logical-axes tree from ``models.param.split``) — the
base params are placed tensor-parallel under the serving rules, every
overlay/bank leaf lands on its derived sharding, and the engine runs
data×model-parallel step jits whose fused delta GEMMs lower as
shard_map'd per-shard Pallas kernels (``kernel_dispatch="gspmd"``
restores the PR-4 GSPMD lowering — DESIGN.md §12).  The
control/data-plane surface is unchanged.
"""
from __future__ import annotations

from typing import Optional

from repro.core import store as S
from repro.core.calibration import DeltaModel
from repro.serving.engine import Request, ServingEngine
from repro.serving.variants import VariantRegistry


class Deployment:
    """Versioned multi-tenant serving node: one resident base model, a
    store of variant version lineages, and a continuous-batching engine,
    behind a publish/update/rollback/submit/drain/status surface."""

    def __init__(self, model, base_params, *,
                 root_dir=None, store: Optional[S.VariantStore] = None,
                 mode: str = "fused", scheduler: str = "continuous",
                 batch_size: int = 4, prompt_len: int = 32,
                 max_len: int = 128, bank_size: int = 8,
                 max_resident: int = 8, max_retries: int = 1,
                 param_shardings=None, use_kernel: bool = True,
                 mesh=None, param_axes=None,
                 kernel_dispatch: str = "shard_map",
                 async_admission: bool = False,
                 speculative: bool = False, draft_k: int = 4,
                 eager: bool = False, warmup: bool = False,
                 compile_cache_dir=None, base_dtype: str = "fp",
                 pod_banks: bool = False,
                 admission_pacing_s: float = 0.002):
        if store is not None and root_dir is not None:
            raise ValueError("pass either store or root_dir, not both")
        if base_dtype not in ("fp", "int8"):
            raise ValueError(f"unknown base dtype {base_dtype!r}")
        if pod_banks and (speculative or scheduler == "speculative"):
            raise ValueError(
                "pod_banks=True does not compose with the speculative "
                "scheduler (verify rounds lack per-pod slot translation); "
                "use scheduler='continuous'")
        if speculative:
            if scheduler not in ("continuous", "speculative"):
                raise ValueError(
                    "speculative=True layers on the continuous slot "
                    "scheduler; drop scheduler='group'")
            scheduler = "speculative"
        if scheduler in ("continuous", "speculative") and mode != "fused":
            # mirror launch/serve.py: the continuous slot scheduler admits
            # through the overlay bank, which is fused-only — accepting
            # mode="dense" here would silently serve fused residents
            raise ValueError(
                f"scheduler={scheduler!r} requires mode='fused' (mixed "
                "batches serve from the packed overlay bank); use "
                "scheduler='group' for dense residency")
        if mesh is not None:
            if param_axes is None:
                raise ValueError(
                    "a sharded deployment needs param_axes (the logical "
                    "axes tree from models.param.split) with the mesh")
            import jax
            from repro.distributed.sharding import rules_for, tree_shardings
            if param_shardings is None:
                param_shardings = tree_shardings(
                    base_params, param_axes, rules_for("decode"), mesh)
            # the ONE resident base lands tensor-parallel; every variant
            # (dense copy, fused overlay, bank slot) inherits from it
            base_params = jax.device_put(base_params, param_shardings)
        self.model = model
        # base_dtype="int8": the registry quantizes every shadowed target
        # weight (core/quantize.py) AFTER fingerprinting the fp base —
        # artifacts stay calibrated/verified against full precision, while
        # the resident base (and its shardings) go int8+scale.  The store
        # keeps the FP param_shardings: patch-chain walks materialise fp
        # deltas, not quantized bases.
        # pod_banks=True (DESIGN.md §17): the overlay bank shards its slot
        # axis over the mesh's "pod" axis (bank_size slots PER POD) and the
        # engine's affinity router steers requests to the pod holding their
        # variant; False keeps the globally-replicated bank (A/B baseline)
        self.registry = VariantRegistry(
            base_params, param_shardings=param_shardings,
            max_resident=max_resident, use_kernel=use_kernel,
            mode=mode, bank_size=bank_size, mesh=mesh,
            param_axes=param_axes, base_dtype=base_dtype,
            pod_banks=pod_banks)
        if store is None and root_dir is not None:
            store = S.VariantStore(root_dir, base_fp=self.registry.base_fp)
        if store is not None and store.base_fp is None:
            store.base_fp = self.registry.base_fp
        if store is not None and param_shardings is not None \
                and store.param_shardings is None:
            # incremental patches then materialise shard-local (the store's
            # chain walk applies them on the derived leaf placements)
            store.param_shardings = param_shardings
        self.store = store
        # persistent compile cache (core/compile_cache.py): explicit dir
        # builds a deployment-scoped cache; None lets the engine/bank
        # fall back to the REPRO_COMPILE_CACHE_DIR ambient default
        self.compile_cache = None
        if compile_cache_dir is not None:
            from repro.core.compile_cache import CompileCache
            self.compile_cache = CompileCache(compile_cache_dir)
        self.registry.compile_cache = self.compile_cache
        # restart hydration is LAZY by default: a store-backed node
        # registers a name's version lineage on FIRST reference (request
        # admission, explicit ``name@vN``, rollback) via the registry's
        # hydrator hook — so restart time is dominated by warmup, not by
        # walking every persisted lineage index.  ``eager=True`` restores
        # the PR-3 behaviour of hydrating everything up front.
        self._hydrated: set = set()
        if store is not None:
            if eager:
                for name in store.names():
                    self._hydrate(name)
            else:
                self.registry.hydrator = self._hydrate
        self.admission = None
        if async_admission:
            if scheduler not in ("continuous", "speculative"):
                raise ValueError(
                    "async_admission requires the continuous slot "
                    "scheduler (staged overlays commit into the overlay "
                    "bank between decode steps)")
            from repro.serving.admission import AdmissionPipeline
            # admission_pacing_s: ingest-worker sleep between module
            # streams (SLO pacing, serving/admission.py); 0 disables
            self.admission = AdmissionPipeline(
                self.registry, pacing_s=admission_pacing_s)
            self.registry.admission = self.admission
        self.engine = ServingEngine(
            model, self.registry, batch_size=batch_size,
            prompt_len=prompt_len, max_len=max_len,
            max_retries=max_retries, scheduler=scheduler, mesh=mesh,
            kernel_dispatch=kernel_dispatch, admission=self.admission,
            compile_cache=self.compile_cache, draft_k=draft_k)
        if warmup:
            # AOT-compile every step pair for the declared shapes BEFORE
            # traffic; with a compile cache this is a deserialize on a
            # warm restart (DESIGN.md §14)
            self.engine.warmup()

    def _hydrate(self, name: str) -> bool:
        """Register every persisted version of ``name`` from the store
        (idempotent per name; False when the store doesn't know it).
        Installed as ``registry.hydrator`` under lazy hydration, so an
        unknown-name resolution retries once after this runs."""
        if self.store is None or name in self._hydrated:
            return False
        try:
            versions = self.store.versions(name)
        except Exception:
            return False
        self._hydrated.add(name)
        for v in versions:
            self.registry.set_version(name, v, self._store_ref(name, v))
        self.registry.set_version(name, self.store.latest(name))
        return True

    # -- control plane -----------------------------------------------------
    def publish(self, name: str, dm: DeltaModel, *,
                mode: Optional[str] = None,
                meta: Optional[dict] = None, wait: bool = False) -> int:
        """Publish ``dm`` as the next FULL version of ``name`` and point
        serving at it.  Returns the new version id.

        With async admission the call is NON-BLOCKING: ingest + staging of
        the new version starts immediately on the pipeline (overlapping
        any in-flight decode) and the version commits into the bank
        between decode steps; ``wait=True`` blocks until it is resident
        (the escape hatch for callers that need the old synchronous
        contract)."""
        if mode == "dense" and self.engine.scheduler in ("continuous",
                                                         "speculative"):
            raise ValueError(
                "per-variant mode='dense' cannot serve under the "
                "continuous scheduler (overlay-bank admission is "
                "fused-only)")
        if self.store is not None:
            v = self.store.publish(name, dm, meta=meta)
            artifact = self._store_ref(name, v)
        else:
            v = self.registry.next_version(name)
            artifact = dm
        self.registry.set_version(name, v, artifact, mode=mode)
        self._after_swap(name, wait)
        return v

    def update(self, name: str, dm: DeltaModel, *,
               meta: Optional[dict] = None, wait: bool = False) -> int:
        """Incremental publish + atomic hot-swap: ``dm`` becomes the next
        version — shipped as an XOR/RLE patch against the current latest
        when a store backs this deployment — and the serving pointer moves.
        Requests admitted after this call serve the new version; in-flight
        requests finish on the old version's pinned bank slot.  With async
        admission the patch-chain walk and staging run off-thread
        (``wait=True`` blocks until the new version is bank-resident)."""
        if self.store is not None:
            v = self.store.publish_update(name, dm, meta=meta)
            artifact = self._store_ref(name, v)
        else:
            if not self.registry.has_variant(name):
                raise KeyError(f"unknown variant {name!r}; publish first")
            v = self.registry.next_version(name)
            artifact = dm
        self.registry.set_version(name, v, artifact)
        self._after_swap(name, wait)
        return v

    def rollback(self, name: str, to_version: Optional[int] = None, *,
                 wait: bool = False) -> int:
        """Constant-time pointer move back to ``to_version`` (default:
        previous version).  Artifacts are untouched; if the target version
        is still device-resident the next admission is a cache hit.

        Raises RuntimeError while a version of ``name`` is mid-ingest on
        the async admission pipeline: rolling back under a staging
        admission would race the commit — wait for it to land (or fail)
        first."""
        if self.admission is not None and self.admission.staging(name):
            raise RuntimeError(
                f"variant {name!r} has a version mid-admission; wait for "
                "it to land before rolling back")
        if self.store is not None:
            v = self.store.rollback(name, to_version)
            # the registry may not have seen this version yet (e.g. a
            # fresh Deployment over an existing store directory)
            self.registry.set_version(name, v, self._store_ref(name, v))
        else:
            v = self.registry.rollback(name, to_version)
        self._after_swap(name, wait)
        return v

    def _after_swap(self, name: str, wait: bool) -> None:
        """Post-pointer-move admission policy: async deployments start
        ingest of the new current version IMMEDIATELY (staging overlaps
        in-flight decode — publish→first-token no longer pays the inline
        load); ``wait=True`` restores the blocking contract on both
        paths."""
        if self.admission is not None:
            self.admission.prefetch(name)
            if wait:
                self.admission.wait(name)
        elif wait:
            if self.engine.scheduler in ("continuous", "speculative"):
                self.registry.bank_resolve(name)
            else:
                self.registry.resolve(name)

    def warmup(self) -> dict:
        """AOT-compile all step pairs for this deployment's shapes now
        (same as constructing with ``warmup=True``); returns the
        per-pair outcome ("hit" | "compiled")."""
        return self.engine.warmup()

    def current(self, name: str) -> Optional[int]:
        """Version the serving pointer resolves to right now."""
        return self.registry.current_version(name)

    def versions(self, name: str) -> list:
        return (self.store.versions(name) if self.store is not None
                else self.registry.versions(name))

    def variants(self) -> list:
        """Servable variant names.  Under lazy hydration the registry
        only knows referenced names, so the store's directory listing
        (names only — no index/artifact reads) fills in the rest."""
        names = set(self.registry.registered())
        if self.store is not None:
            names.update(self.store.names())
        return ["__base__"] + sorted(names - {"__base__"})

    def admitting(self) -> list:
        """Version keys currently mid-ingest on the async admission
        pipeline (empty for synchronous deployments)."""
        return [] if self.admission is None else self.admission.admitting()

    def close(self) -> None:
        """Stop the async admission worker (no-op for synchronous
        deployments).  Idempotent; tests and benchmarks call it so ingest
        threads never outlive their deployment."""
        if self.admission is not None:
            self.admission.close()

    def _store_ref(self, name: str, version: int):
        """Lazy materialisation closure: the registry loads (and the store
        caches) the version only when a request actually needs it.  The
        closure advertises ``accepts_pacer`` so a background ingest can
        thread its SLO-pacing hook down to the streamed artifact read."""
        store = self.store

        def ref(pacer=None):
            return store.load(name, version, pacer=pacer)
        ref.accepts_pacer = True
        return ref

    # -- data plane --------------------------------------------------------
    def submit(self, tokens, variant: str = "__base__",
               max_new_tokens: int = 16) -> int:
        """Queue a request.  ``variant`` names a published variant (serves
        its CURRENT version at admission time), ``name@vN`` pins an
        explicit version, '__base__' serves the base model."""
        return self.engine.submit(tokens, variant=variant,
                                  max_new_tokens=max_new_tokens)

    def drain(self, max_rounds: int = 1000) -> dict:
        """Serve until the queue and all decode lanes are empty; returns
        engine metrics."""
        return self.engine.run_until_drained(max_rounds)

    def result(self, rid: int) -> Request:
        return self.engine.result(rid)

    def status(self, rid: Optional[int] = None) -> dict:
        """With ``rid``: lifecycle view of one request — never raises.
        ``version`` is the variant version the request resolved at
        admission (stable across later updates/rollbacks); ``status``
        may be ``admitting`` (mid-ingest on the async pipeline).
        Without ``rid``: the engine observability snapshot — scheduler
        occupancy, step-executable / compile-cache / dispatch-memo
        counters (DESIGN.md §14)."""
        if rid is None:
            return self.engine.status()
        r = self.engine.request(rid)
        if r is None:
            return {"status": "unknown", "rid": rid}
        out = {"status": r.status, "rid": rid, "variant": r.variant,
               "version": r.served_version,
               "tokens_generated": len(r.out_tokens),
               "first_token_at": r.first_token_at,
               "ttft_seconds": (None if r.first_token_at is None
                                else r.first_token_at - r.submitted_at),
               "error": r.error}
        if r.drafted:
            # speculative lanes: fraction of offered drafts this request
            # accepted (its base/variant agreement rate)
            out["acceptance"] = r.accepted / r.drafted
        return out

    def pending(self) -> int:
        return self.engine.pending()

    def active(self) -> int:
        return self.engine.active()

    @property
    def metrics(self) -> dict:
        return self.engine.metrics

    @property
    def stats(self) -> dict:
        """Registry swap/residency counters (hits, swaps, resident bytes)."""
        return self.registry.stats
