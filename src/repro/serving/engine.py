"""Serving engine: slot-scheduled continuous batching over packed deltas.

Two schedulers (DESIGN.md §9):

* ``continuous`` (mixed-variant slot scheduler) — the engine keeps ONE
  persistent decode batch of ``batch_size`` SLOTS.  Each slot carries its
  own request, variant index (into the registry's OverlayBank — slot 0 =
  base), decode position and token budget.  Every step: free slots admit
  queued requests (prefill-on-admit, cache rows merged in), every active
  slot appends its pending token (one host sync per step), exhausted slots
  retire IMMEDIATELY and free their lane, and one jitted decode serves the
  whole heterogeneous batch through the banked fused delta GEMMs.  Requires
  fused (packed-overlay) residency for every variant.

* ``group`` (compatibility mode, dense residency path) — pending requests
  are grouped BY VARIANT (FIFO head decides), one prefill/decode pair per
  overlay structure; a group decodes to the max budget in the group.

Variants resolve to (params, overlay) in group mode: dense residents pass a
materialised copy with overlay None; fused residents pass the shared base
params plus a packed delta overlay fused into every GEMM on the fly
(serving/variants.py — residency modes and the OverlayBank).

Fault tolerance: a variant whose artifact fails to load has its requests
re-queued up to ``max_retries`` then failed individually — the engine and
other tenants keep serving.

Versioned variants (DESIGN.md §10): admission resolves the variant's
CURRENT version and pins that VERSION KEY for the request's lifetime, so
a hot-swap (``registry.set_version``) mid-flight leaves running lanes on
the version they started with while new admissions serve the new one;
``Request.served_version`` records the resolution.

Mesh-sharded serving (DESIGN.md §11): with ``mesh`` the engine jits every
step pair (plain, fused, banked) with EXPLICIT in/out shardings — batch
rows (tokens, variant_idx, cache act_batch dims, logits) data-parallel so
the continuous-batching slot lanes span the ``data`` axis, params and
overlay/bank leaves tensor-parallel on their weight axes (no per-step
weight collectives: serve rules replicate weights over ``data``).  The
persistent decode cache is pinned to its sharding via out_shardings, so
step N+1 sees exactly the layout step N produced — no resharding, no
recompiles.  Calls run under ``shard_ctx`` so model-internal logical
constraints activate.

Per-shard kernels (DESIGN.md §12): because the steps trace inside
``shard_ctx``, the fused/banked delta GEMMs lower as shard_map'd Pallas
kernels on each device's own weight/overlay tile (kernels/dispatch.py)
instead of trusting GSPMD to partition the opaque kernel call;
``kernel_dispatch="gspmd"`` pins the PR-4 global-kernel lowering for A/B
parity and latency comparisons.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.core import compile_cache as CC
from repro.distributed.sharding import (resolve_spec, rules_for, shard_ctx,
                                        tree_shardings)
from repro.models.model_zoo import Model
from repro.serving.variants import VariantRegistry


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray            # prompt (prompt_len,)
    variant: str = "__base__"
    max_new_tokens: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)
    status: str = "queued"        # queued | running | done | failed
    retries: int = 0
    error: Optional[str] = None
    served_version: Optional[int] = None   # variant version resolved at
                                           # admission (None: base or
                                           # unversioned registration)
    first_token_at: Optional[float] = None   # perf_counter at the first
                                             # emitted token (TTFT metric:
                                             # benchmarks/admission_overlap)
    submitted_at: float = 0.0     # perf_counter at submit() — with
                                  # first_token_at this is the TTFT the
                                  # engine/Deployment status surfaces
    drafted: int = 0              # speculative scheduler: draft tokens
    accepted: int = 0             # offered / accepted for THIS request
                                  # (the per-lane acceptance rate)
    route_pod: Optional[int] = None   # affinity router's sticky pod choice
                                      # (per-pod admission tickets must not
                                      # re-ingest on every poll)


@dataclasses.dataclass
class _Slot:
    """One lane of the persistent continuous-batching decode batch."""
    request: Request
    variant_slot: int             # GLOBAL bank slot index (base slot of
                                  # the lane's pod for base rows)
    remaining: int                # tokens still owed
    vkey: str = "__base__"        # pinned version key — unpinned at retire
                                  # even if the variant was hot-swapped
                                  # mid-flight
    pod: int = 0                  # pod whose bank shard holds the slot
                                  # (pin/unpin are per-pod)


class ServingEngine:
    """Fixed-shape batched serving: batch slots of ``batch_size``, prompts
    padded to ``prompt_len``, KV capacity ``max_len``.

    scheduler: "continuous" (mixed-variant slot scheduler over the overlay
    bank) or "group" (grouped-by-variant compatibility mode — required for
    dense residency).

    mesh: optional ``jax.sharding.Mesh`` with ("data", "model") axes (and
    optionally "pod") — every step jit gains explicit in/out shardings
    (batch data-parallel, weights/overlays model-parallel) and runs under
    the serving rule context.  Requires registry.param_shardings.

    kernel_dispatch: "shard_map" (default) lowers the fused/banked delta
    GEMMs as per-shard Pallas kernels under shard_map (kernels/dispatch.py
    — each device runs its own weight tile's kernel, DESIGN.md §12);
    "gspmd" restores the PR-4 behaviour of handing the global kernel to
    GSPMD to partition (the A/B baseline — on a real TPU mesh the opaque
    kernel call cannot be partitioned, so this mode exists for parity and
    latency comparison, benchmarks/shard_map_kernels.py).  Both modes must
    emit bit-identical greedy tokens.  Ignored without a mesh."""

    def __init__(self, model: Model, registry: VariantRegistry, *,
                 batch_size: int = 4, prompt_len: int = 32,
                 max_len: int = 128, max_retries: int = 1,
                 greedy: bool = True, scheduler: str = "group",
                 mesh=None, kernel_dispatch: str = "shard_map",
                 admission=None, compile_cache=None,
                 draft_k: int = 4, spec_adaptive: bool = True):
        if scheduler not in ("group", "continuous", "speculative"):
            raise ValueError(f"unknown scheduler {scheduler!r}")
        if kernel_dispatch not in ("shard_map", "gspmd"):
            raise ValueError(f"unknown kernel_dispatch {kernel_dispatch!r}")
        if admission is not None and scheduler == "group":
            raise ValueError(
                "async admission requires scheduler='continuous' (staged "
                "overlays commit into the overlay bank between decode "
                "steps; the group scheduler admits dense residents inline)")
        if scheduler == "speculative":
            from repro.models.transformer import layer_pattern
            if model.cfg.family in ("dense", "moe", "vlm") and any(
                    e["window"] > 0 for e in layer_pattern(model.cfg)):
                raise ValueError(
                    "scheduler='speculative' requires windowless KV "
                    "caches: sliding-window layers ring-buffer their "
                    "writes, so rewinding rejected draft tokens would "
                    "clobber in-window history (DESIGN.md §15)")
        # pod-local banks (DESIGN.md §17): lanes split evenly across pods
        # (act_batch shards pod-major, so lane i belongs to pod
        # i // (batch_size // pods)); the affinity router below steers
        # requests to lanes whose pod already holds their variant
        self._pods = getattr(registry, "pods", 1)
        if self._pods > 1:
            if scheduler == "speculative":
                raise ValueError(
                    "scheduler='speculative' does not support pod-local "
                    "banks (pod_banks=True): drafting serves the base "
                    "through shared params, but verify rounds would need "
                    "per-pod slot translation the round fn lacks — use "
                    "scheduler='continuous'")
            if mesh is None:
                raise ValueError(
                    "pod-local banks need the engine's mesh (the lane->"
                    "pod mapping comes from the act_batch sharding)")
            if batch_size % self._pods:
                raise ValueError(
                    f"batch_size={batch_size} must divide evenly across "
                    f"{self._pods} pods (lanes block-partition pod-major)")
        self.model = model
        self.registry = registry
        self.batch_size = batch_size
        self.prompt_len = prompt_len
        self.max_len = max_len
        self.max_retries = max_retries
        self.scheduler = scheduler
        self.mesh = mesh
        self.kernel_dispatch = kernel_dispatch
        # optional serving/admission.AdmissionPipeline: variants are
        # ingested+staged off-thread and committed between decode steps
        # (drain hook in _serve_continuous) instead of loaded inline at
        # bank_acquire; queued requests behind ingest report "admitting"
        self.admission = admission
        self._queue: collections.deque[Request] = collections.deque()
        self._done: dict[int, Request] = {}
        self._next_rid = 0

        # one compiled pair per overlay STRUCTURE: dense variants trace
        # with overlay=None, fused variants with their entry tree — the
        # packed deltas ride in as ordinary jit arguments
        def prefill_fn(params, overlay, batch):
            return model.prefill(params, batch, max_len, overlay=overlay)

        def decode_fn(params, overlay, token, cache):
            logits, cache = model.decode_step(params, token, cache,
                                              overlay=overlay)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

        # banked pair: ONE compiled prefill/decode serves every mix of
        # resident variants — the bank tree and per-row variant_idx are
        # plain jit arguments, so admissions/evictions never recompile
        def prefill_banked_fn(params, bank, vidx, batch):
            return model.prefill(params, batch, max_len, overlay=bank,
                                 variant_idx=vidx)

        def decode_banked_fn(params, bank, vidx, token, cache):
            logits, cache = model.decode_step(params, token, cache,
                                              overlay=bank,
                                              variant_idx=vidx)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

        self._fns = {"prefill": prefill_fn, "decode": decode_fn,
                     "prefill_banked": prefill_banked_fn,
                     "decode_banked": decode_banked_fn}
        # arg roles drive the explicit in_shardings on a mesh; vidx shards
        # exactly like the token vector (one entry per batch lane)
        self._roles = {"prefill": ("params", "overlay", "batch"),
                       "decode": ("params", "overlay", "token", "cache"),
                       "prefill_banked": ("params", "overlay", "token",
                                          "batch"),
                       "decode_banked": ("params", "overlay", "token",
                                         "token", "cache")}
        # speculative rounds (serving/speculative.py): one executable per
        # draft length on the adaptive ladder — each k is a compile-time
        # scan length.  Same signature/roles as decode_banked, so the
        # sharded staging + compile cache + warmup machinery carry over.
        self.spec = None
        if scheduler == "speculative":
            from repro.serving import speculative as SPEC
            self.spec = SPEC.AcceptanceTracker(draft_k,
                                               adaptive=spec_adaptive)
            for k in self.spec.ladder:
                self._fns[f"spec_k{k}"] = SPEC.make_round_fn(model, k)
                self._roles[f"spec_k{k}"] = ("params", "overlay", "token",
                                             "token", "cache")
        # executable store: ONE AOT-compiled executable per (kind,
        # overlay structure) — the wrapped→lowered→compiled split
        # (DESIGN.md §14).  The overlay is the only argument whose
        # STRUCTURE varies between calls of one kind; every other aval
        # is fixed by the engine's shape contract, and the Compiled
        # object itself validates avals at call time, so a violated
        # assumption raises instead of mis-serving.
        self._exe: dict = {}
        # persistent compile cache (core/compile_cache.py): explicit
        # handle wins, else the process-ambient REPRO_COMPILE_CACHE_DIR
        # default; None serves compile-per-process like before
        self.compile_cache = (compile_cache if compile_cache is not None
                              else CC.get_default())
        self.warmed = False
        if mesh is not None:
            if registry.param_shardings is None:
                raise ValueError(
                    "a sharded engine needs registry.param_shardings "
                    "(resolve them with distributed.sharding."
                    "tree_shardings under the serve rules)")
            self._rules = rules_for(
                "decode", pod_banks=getattr(registry, "pod_banks", False))
            cache_struct = jax.eval_shape(
                lambda: model.init_cache(batch_size, max_len))
            self._cache_sh = tree_shardings(cache_struct,
                                            model.cache_pspecs(),
                                            self._rules, mesh)
            tok_spec = resolve_spec((batch_size,), ("act_batch",),
                                    self._rules, mesh)
            self._tok_sh = NamedSharding(mesh, tok_spec)
            # prefill logits (B, V): batch rows follow the lanes; the
            # vocab dim is gathered for the host-side argmax
            self._logits_sh = NamedSharding(
                mesh, PartitionSpec(*(list(tok_spec) + [None])))
            self._batch_axes = model.batch_pspecs("prefill")
        # continuous-scheduler state (persists across run_until_drained
        # calls: the decode batch is a long-lived object)
        self._slots: list[Optional[_Slot]] = [None] * batch_size
        self._cache = None
        self._next_tok = None
        # each idle lane serves ITS POD's base slot (slot p*bank_size —
        # zero deltas = exact base); a single-pod/global bank keeps the
        # historical all-zeros vector
        self._base_vidx = np.array(
            [self._lane_pod(i) * registry.bank_size if self._pods > 1
             else 0 for i in range(batch_size)], np.int32)
        self._variant_idx = self._base_vidx.copy()
        self._variant_idx_dev = None     # device copy, rebuilt on change
        self._merge_jit = None           # built on first admission merge
        # bounded TTFT reservoir behind the p50/p99 status() reports:
        # first _ttft_cap samples fill it, later ones overwrite in
        # arrival order (deterministic sliding window, no RNG)
        self._ttft_cap = 1024
        self._ttft_samples: list = []
        self.metrics = {"batches": 0, "tokens_generated": 0,
                        "prefills": 0, "failed": 0, "admitted": 0,
                        "retired": 0, "decode_steps": 0,
                        "prefill_seconds": 0.0, "decode_seconds": 0.0,
                        "async_admits": 0,
                        "step_compiles": 0, "step_cache_hits": 0,
                        "step_compile_seconds": 0.0,
                        "warmup_seconds": 0.0,
                        "spec_rounds": 0, "spec_drafted": 0,
                        "spec_accepted": 0,
                        "ttft_count": 0, "ttft_seconds_sum": 0.0,
                        "ttft_seconds_max": 0.0,
                        "affinity_hits": 0, "affinity_misses": 0}
        # warmup registry (extensible — register_warmup): each entry
        # builds its step pairs from the shared abstract-twin context, so
        # new step kinds (e.g. the speculative ladder) warm through the
        # same AOT/persistent-cache path as the core pairs
        self._warmup_reg = {"plain": self._warm_plain,
                            "fused": self._warm_fused,
                            "banked": self._warm_banked}
        if self.spec is not None:
            self._warmup_reg["speculative"] = self._warm_speculative
        # benchmark hook (benchmarks/admission_overlap.py): with
        # record_step_times=True every decode step appends
        # (perf_counter_at_end, seconds, admission_in_flight) — the
        # stall-ceiling evidence
        self.record_step_times = False
        self.step_times: list = []

    # -- sharded step dispatch -----------------------------------------------
    def _arg_sharding(self, role: str, arg):
        """Explicit sharding for one step argument by role (mesh mode)."""
        if role == "params":
            return self.registry.param_shardings
        if role == "overlay":
            # overlay/bank leaves were committed to their derived
            # placements by loader.device_put_overlay / OverlayBank —
            # pin exactly those (None for the dense overlay-free trace)
            return jax.tree.map(lambda l: l.sharding, arg)
        if role == "token":
            return self._tok_sh
        if role == "cache":
            return self._cache_sh
        if role == "batch":
            return {k: NamedSharding(
                self.mesh, resolve_spec(v.shape, self._batch_axes[k],
                                        self._rules, self.mesh))
                for k, v in arg.items()}
        raise ValueError(role)

    def _trace_ctx(self):
        """Context the step functions LOWER inside: mesh + serving-rule
        shard_ctx (so logical constraints apply and kernels/dispatch.py
        sees the pair at trace time) + the kernel-dispatch pin.  The
        contexts decide how the trace lowers; the resulting executable
        is context-free at call time, which is what lets a deserialized
        one skip tracing entirely."""
        if self.mesh is None:
            return contextlib.nullcontext()
        from repro.kernels import dispatch as _dp
        stack = contextlib.ExitStack()
        stack.enter_context(self.mesh)
        stack.enter_context(shard_ctx(self.mesh, self._rules))
        if self.kernel_dispatch == "gspmd":
            # "shard_map" lets kernels/dispatch.py lower per-shard
            # kernels; "gspmd" pins the PR-4 global-kernel path for A/B
            stack.enter_context(_dp.no_dispatch())
        return stack

    def _stage_jit(self, kind: str, args):
        """The WRAPPED stage: the step jit, with explicit in/out
        shardings on a mesh (batch lanes data-parallel, weights and
        overlays model-parallel, cache pinned in place)."""
        if self.mesh is None:
            return jax.jit(self._fns[kind])
        in_sh = tuple(self._arg_sharding(role, arg)
                      for role, arg in zip(self._roles[kind], args))
        if kind.startswith("prefill"):
            out_sh = (self._logits_sh, self._cache_sh)
        elif kind.startswith("spec_k"):
            # (ver (B,T), n_acc (B,), next_tok (B,), cache): the token
            # matrix shards its rows like the lane vector, T replicated
            out_sh = (self._logits_sh, self._tok_sh, self._tok_sh,
                      self._cache_sh)
        else:
            out_sh = (self._tok_sh, self._cache_sh)
        return jax.jit(self._fns[kind], in_shardings=in_sh,
                       out_shardings=out_sh)

    def _persist_parts(self, kind: str, args) -> tuple:
        """Persistent-cache key parts for one step executable: the model
        config (two architectures can share avals but not programs), the
        engine's shape contract, the dispatch mode, mesh + sharding
        fingerprints, and every argument's avals.  Library versions,
        backend, devices and a source-tree hash ride in
        ``CompileCache.key`` — a stale entry can only miss."""
        in_sh = "none"
        if self.mesh is not None:
            in_sh = CC.sharding_fp(tuple(
                self._arg_sharding(role, arg)
                for role, arg in zip(self._roles[kind], args)))
        return ("engine-step", kind, repr(self.model.cfg),
                self.batch_size, self.prompt_len, self.max_len,
                self.kernel_dispatch, CC.mesh_fp(self.mesh), in_sh,
                tuple(CC.aval_fp(a) for a in args))

    def _get_exe(self, kind: str, args):
        """One step executable through the staged path: in-process hit →
        persistent-cache deserialize → ``lower().compile()`` (persisted
        for the next restart).  The in-process key flattens just the
        overlay tree — not the full params+cache pytrees — on the
        per-token hot path."""
        key = (kind, jax.tree_util.tree_structure(args[1]))
        exe = self._exe.get(key)
        if exe is not None:
            return exe
        cc = self.compile_cache
        if cc is not None:
            exe = cc.get(self._persist_parts(kind, args))
            if exe is not None:
                self.metrics["step_cache_hits"] += 1
                self._exe[key] = exe
                return exe
        jitted = self._stage_jit(kind, args)
        t0 = time.perf_counter()
        with self._trace_ctx():
            exe = jitted.lower(*args).compile()
        self.metrics["step_compiles"] += 1
        self.metrics["step_compile_seconds"] += time.perf_counter() - t0
        if cc is not None:
            cc.put(cc.key(*self._persist_parts(kind, args)), exe)
        self._exe[key] = exe
        return exe

    def _call(self, kind: str, *args):
        """Run one step executable (resolving it on first use)."""
        return self._get_exe(kind, args)(*args)

    # -- API -----------------------------------------------------------------
    def submit(self, tokens, variant: str = "__base__",
               max_new_tokens: int = 16) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(Request(rid=rid, tokens=np.asarray(tokens),
                                   variant=variant,
                                   max_new_tokens=max_new_tokens,
                                   submitted_at=time.perf_counter()))
        return rid

    def _note_first_token(self, r: Request) -> None:
        """Stamp TTFT at a request's first emitted token and fold it into
        the engine aggregates ``status()`` surfaces."""
        if r.first_token_at is not None:
            return
        r.first_token_at = time.perf_counter()
        ttft = r.first_token_at - r.submitted_at
        n = self.metrics["ttft_count"]
        self.metrics["ttft_count"] = n + 1
        self.metrics["ttft_seconds_sum"] += ttft
        self.metrics["ttft_seconds_max"] = max(
            self.metrics["ttft_seconds_max"], ttft)
        if len(self._ttft_samples) < self._ttft_cap:
            self._ttft_samples.append(ttft)
        else:
            self._ttft_samples[n % self._ttft_cap] = ttft

    def result(self, rid: int) -> Request:
        return self._done[rid]

    def request(self, rid: int) -> Optional[Request]:
        """The Request object wherever it lives (done, in a decode slot,
        or still queued); None for unknown rids.  Never raises."""
        if rid in self._done:
            return self._done[rid]
        for s in self._slots:
            if s is not None and s.request.rid == rid:
                return s.request
        for r in self._queue:
            if r.rid == rid:
                return r
        return None

    def status(self, rid: Optional[int] = None):
        """With ``rid``: that request's lifecycle string (queued |
        admitting | running | done | failed | unknown — never raises;
        ``admitting`` means the variant is mid-ingest on the async
        admission pipeline).  Without ``rid``: the ENGINE observability
        snapshot — scheduler occupancy, step-executable counters,
        persistent-compile-cache and dispatch-memo stats (the restart
        SLO evidence benchmarks/compile_cache.py gates on)."""
        if rid is not None:
            r = self.request(rid)
            return "unknown" if r is None else r.status
        from repro.kernels import dispatch as _dp
        cc = self.compile_cache
        n_ttft = self.metrics["ttft_count"]
        reg = self.registry
        bank = reg.bank
        snap = {
            "scheduler": self.scheduler,
            "pending": self.pending(),
            "active": self.active(),
            "warmed": self.warmed,
            "steps": {"executables": len(self._exe),
                      "compiles": self.metrics["step_compiles"],
                      "cache_hits": self.metrics["step_cache_hits"],
                      "compile_seconds":
                          self.metrics["step_compile_seconds"]},
            "compile_cache": None if cc is None else dict(cc.stats),
            "dispatch_memo": _dp.memo_info(),
            # resident HBM accounting: the base weights (int8 halves this,
            # DESIGN.md §16) NEXT TO the overlay bank — the two terms of
            # the per-device serving footprint
            "hbm": {
                "base_dtype": getattr(reg, "base_dtype", "fp"),
                "base_bytes": reg.base_nbytes(),
                "base_per_device": reg.base_per_device_nbytes(),
                "bank_bytes": bank.nbytes() if bank is not None else 0,
                "bank_per_device": (bank.per_device_nbytes()
                                    if bank is not None else {}),
                # per-pod rollup (DESIGN.md §17): bank bytes + resident
                # slot keys by pod — empty dicts before the first admit
                "bank_per_pod": (bank.per_pod_nbytes()
                                 if bank is not None else {}),
                "bank_resident_per_pod": (bank.pod_resident()
                                          if bank is not None else {}),
            },
            # affinity router counters: a hit steered a request to a pod
            # already holding its variant's slot (zero admission bytes)
            "affinity": {
                "pods": self._pods,
                "hits": self.metrics["affinity_hits"],
                "misses": self.metrics["affinity_misses"],
                "hit_rate": (self.metrics["affinity_hits"]
                             / max(1, self.metrics["affinity_hits"]
                                   + self.metrics["affinity_misses"])),
            },
            # TTFT aggregates (submit -> first emitted token), fed by
            # Request.first_token_at — benchmarks read latency from here
            # instead of poking request internals; percentiles come from
            # the bounded reservoir (_ttft_samples)
            "ttft": {"count": n_ttft,
                     "mean_seconds": (self.metrics["ttft_seconds_sum"]
                                      / n_ttft if n_ttft else 0.0),
                     "max_seconds": self.metrics["ttft_seconds_max"],
                     "p50_seconds": (float(np.percentile(
                         self._ttft_samples, 50))
                         if self._ttft_samples else 0.0),
                     "p99_seconds": (float(np.percentile(
                         self._ttft_samples, 99))
                         if self._ttft_samples else 0.0)},
            "metrics": dict(self.metrics),
        }
        if self.spec is not None:
            snap["speculative"] = self.spec.snapshot()
        return snap

    def pending(self) -> int:
        return len(self._queue)

    def active(self) -> int:
        return sum(1 for s in self._slots if s is not None)

    def register_warmup(self, name: str, builder) -> None:
        """Register (or replace) a warmup entry: ``builder(ctx)`` is
        called from ``warmup()`` with the shared abstract-twin context
        (see ``_warmup_ctx``) and warms its step kinds via
        ``ctx["warm"](tag, kind, args)``.  This is how new step kinds
        join the AOT/persistent-cache path without editing ``warmup()``
        — the speculative ladder registers itself exactly this way."""
        self._warmup_reg[name] = builder

    def warmup(self, pairs=None) -> dict:
        """AOT-compile the step executables for the declared shapes
        BEFORE accepting traffic (ROADMAP "compile-once serving").
        ``pairs`` selects entries from the warmup REGISTRY
        (``register_warmup``); None warms every registered entry — by
        default the plain pair (base model / dense residents), the fused
        pair (single-variant packed overlay + params view), the banked
        pair (the continuous scheduler's overlay bank + per-row
        variant_idx) plus the admission cache-merge, and — under
        ``scheduler="speculative"`` — one speculative round per draft
        length on the adaptive ladder, in bank-resident AND bank-empty
        flavours.  With a persistent compile cache attached, a warm
        restart resolves every entry by DESERIALIZING — zero compiles on
        the path to the first token; cold, the compiles happen here
        instead of inside the first request's latency.

        The overlay/bank abstract twins derive from the base params'
        calibration targets (``core/calibration.is_target`` — the same
        recipe ``compress`` uses), so runtime trees of compressed
        variants hit the warmed executables structurally; on a mesh
        every twin leaf carries the same derived sharding the runtime
        device-put places it on.  Returns {pair/kind: "compiled" |
        "hit"} ("hit": resolved without a fresh compile — in-process or
        persistent)."""
        pairs = tuple(self._warmup_reg) if pairs is None else tuple(pairs)
        unknown = [p for p in pairs if p not in self._warmup_reg]
        if unknown:
            raise ValueError(
                f"unknown warmup pairs {unknown!r}; registered: "
                f"{sorted(self._warmup_reg)} (add new step kinds with "
                "register_warmup)")
        t0 = time.perf_counter()
        ctx = self._warmup_ctx()
        for name in pairs:
            self._warmup_reg[name](ctx)
        self.metrics["warmup_seconds"] += time.perf_counter() - t0
        self.warmed = True
        return ctx["outcomes"]

    def _warmup_ctx(self) -> dict:
        """Shared abstract-twin context the warmup builders draw from:
        the base params, fixed-shape batch/token/cache stand-ins, the
        delta/extra path split, and the ``warm`` closure that resolves
        one executable and records "compiled" | "hit"."""
        from repro.core.calibration import flatten_params, is_target

        reg = self.registry
        base = reg.base_params
        bs = self.batch_size
        base_flat = flatten_params(base)
        delta_paths = sorted(p for p, l in base_flat.items()
                             if is_target(p, l))
        ds = set(delta_paths)
        extra_paths = sorted(p for p in base_flat if p not in ds)
        outcomes: dict = {}

        def warm(tag, kind, args):
            c0 = self.metrics["step_compiles"]
            self._get_exe(kind, args)
            outcomes[f"{tag}/{kind}"] = (
                "compiled" if self.metrics["step_compiles"] > c0
                else "hit")

        return {"base": base, "base_flat": base_flat, "ds": ds,
                "delta_paths": delta_paths, "extra_paths": extra_paths,
                "batch": self._prompt_batch({}),
                "token": jnp.zeros((bs,), jnp.int32),
                "vidx": jnp.zeros((bs,), jnp.int32),
                "cache": jax.eval_shape(
                    lambda: self.model.init_cache(bs, self.max_len)),
                "warm": warm, "outcomes": outcomes}

    def _warm_plain(self, ctx) -> None:
        warm = ctx["warm"]
        warm("plain", "prefill", (ctx["base"], None, ctx["batch"]))
        warm("plain", "decode", (ctx["base"], None, ctx["token"],
                                 ctx["cache"]))

    def _warm_fused(self, ctx) -> None:
        from repro.core.calibration import flatten_params, unflatten_like
        from repro.models import delta_overlay as DO
        if not ctx["delta_paths"]:
            return
        ds = ctx["ds"]
        base_flat = ctx["base_flat"]
        # params VIEW: target paths alias the base weight, every other
        # leaf is the variant's fp16 extra (loader.device_put_overlay's
        # layout)
        view = unflatten_like(ctx["base"], {
            p: (l if p in ds
                else jax.ShapeDtypeStruct(l.shape, jnp.float16))
            for p, l in base_flat.items()})
        ov = DO.overlay_struct(base_flat, ctx["delta_paths"])
        if self.mesh is not None:
            ov = self._shard_struct(
                ov, ctx["delta_paths"],
                {p: DO.entry_shardings_from_weight(
                    sh, base_flat[p].ndim)
                 for p, sh in flatten_params(
                     self.registry.param_shardings).items() if p in ds})
        warm = ctx["warm"]
        warm("fused", "prefill", (view, ov, ctx["batch"]))
        warm("fused", "decode", (view, ov, ctx["token"], ctx["cache"]))

    def _bank_struct(self, ctx):
        """Abstract twin of the runtime overlay bank (structure + avals +
        derived shardings) — the banked and speculative warmup entries
        share it."""
        from repro.models import delta_overlay as DO
        # pod-local banks stack every pod's slot range on the one bank axis
        nb = self.registry.bank_size * getattr(self.registry, "pods", 1)
        bank = DO.overlay_struct(ctx["base_flat"], ctx["delta_paths"],
                                 ctx["extra_paths"], bank_size=nb)
        if self.mesh is not None:
            bank = self._shard_struct(
                bank, ctx["delta_paths"] + ctx["extra_paths"],
                DO.overlay_shardings(
                    self.registry.param_axes, ctx["base_flat"],
                    ctx["delta_paths"], ctx["extra_paths"], self._rules,
                    self.mesh, bank_size=nb))
        return bank

    def _warm_banked(self, ctx) -> None:
        if not ctx["delta_paths"]:
            return
        bank = self._bank_struct(ctx)
        warm = ctx["warm"]
        base, token, cache = ctx["base"], ctx["token"], ctx["cache"]
        vidx, batch = ctx["vidx"], ctx["batch"]
        # pre-first-admission state: the continuous scheduler serves
        # base-only traffic with bank=None until a variant lands
        warm("banked-empty", "prefill_banked", (base, None, vidx, batch))
        warm("banked-empty", "decode_banked",
             (base, None, vidx, token, cache))
        warm("banked", "prefill_banked", (base, bank, vidx, batch))
        warm("banked", "decode_banked", (base, bank, vidx, token, cache))
        if self.scheduler in ("continuous", "speculative"):
            if self._merge_jit is None:
                self._merge_jit = self._make_merge()
            ctx["outcomes"]["banked/merge"] = self._merge_jit.aot(
                cache, cache,
                jax.ShapeDtypeStruct((self.batch_size,), jnp.bool_))

    def _warm_speculative(self, ctx) -> None:
        """One speculative round per ladder rung (each k is its own scan
        length, hence its own executable), in both the bank-resident and
        the pre-first-admission (bank=None) flavours — the two new step
        shapes the scheduler dispatches."""
        warm = ctx["warm"]
        base, token, cache = ctx["base"], ctx["token"], ctx["cache"]
        vidx = ctx["vidx"]
        bank = self._bank_struct(ctx) if ctx["delta_paths"] else None
        for k in self.spec.ladder:
            warm("spec-empty", f"spec_k{k}",
                 (base, None, vidx, token, cache))
            if bank is not None:
                warm("spec", f"spec_k{k}",
                     (base, bank, vidx, token, cache))

    @staticmethod
    def _shard_struct(struct: dict, paths, flat_shardings: dict) -> dict:
        """Attach per-leaf shardings to an abstract overlay/bank tree so
        ``_arg_sharding('overlay', ...)`` reads from the twin exactly
        what the runtime device-put trees carry."""
        from repro.models import delta_overlay as DO

        def node_at(tree, path):
            for part in path.split("."):
                tree = tree[part]
            return tree

        out: dict = {}
        for p in paths:
            leaf = node_at(struct, p)
            sh = flat_shardings[p]
            if isinstance(leaf, DO.OverlayEntry):
                leaf = DO.OverlayEntry(*(
                    jax.ShapeDtypeStruct(f.shape, f.dtype, sharding=s)
                    for f, s in ((leaf.packed, sh.packed),
                                 (leaf.v_row, sh.v_row),
                                 (leaf.v_col, sh.v_col))))
            else:
                leaf = jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                            sharding=sh)
            DO.insert_entry(out, p, leaf)
        return out

    def run_until_drained(self, max_rounds: int = 1000) -> dict:
        if self.scheduler == "speculative":
            self._serve_speculative(max_rounds)
            return self.metrics
        if self.scheduler == "continuous":
            self._serve_continuous(max_rounds)
            return self.metrics
        rounds = 0
        while self._queue and rounds < max_rounds:
            self._serve_one_group()
            rounds += 1
        return self.metrics

    # -- internals -------------------------------------------------------------
    def _take_group(self) -> list:
        """Pop up to batch_size requests of the same variant (FIFO head
        decides the variant — simple fairness).  Scanning stops as soon as
        the group is full; skipped requests go back to the front in their
        original order."""
        if not self._queue:
            return []
        variant = self._queue[0].variant
        group, skipped = [], []
        while self._queue and len(group) < self.batch_size:
            r = self._queue.popleft()
            if r.variant == variant:
                group.append(r)
            else:
                skipped.append(r)
        self._queue.extendleft(reversed(skipped))
        return group

    def _serve_one_group(self) -> None:
        group = self._take_group()
        if not group:
            return
        variant = group[0].variant
        try:
            params, overlay = self.registry.resolve(variant)
            # group admission resolves the serving pointer ONCE — the whole
            # group serves the version current at this moment
            version = self.registry.current_version(variant)
        except Exception as e:  # artifact failure: re-queue or fail
            for r in group:
                r.retries += 1
                if r.retries > self.max_retries:
                    r.status, r.error = "failed", str(e)
                    self._done[r.rid] = r
                    self.metrics["failed"] += 1
                else:
                    self._queue.append(r)
            return
        for r in group:
            r.served_version = version

        batch = self._prompt_batch(
            {i: r for i, r in enumerate(group)})

        t0 = time.perf_counter()
        last_logits, cache = self._call("prefill", params, overlay, batch)
        jax.block_until_ready(last_logits)
        self.metrics["prefill_seconds"] += time.perf_counter() - t0
        self.metrics["prefills"] += 1

        next_tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
        n_steps = max(r.max_new_tokens for r in group)
        t0 = time.perf_counter()
        for step in range(n_steps):
            # ONE host sync per step: per-slot int(next_tok[i]) forces a
            # device round-trip per slot per token — pull the whole token
            # vector once and append from the host buffer
            host_tok = np.asarray(next_tok)
            n_active = 0
            for i, r in enumerate(group):
                # retired slots (past their own max_new_tokens) still
                # occupy a batch lane but neither emit nor count
                if step < r.max_new_tokens:
                    r.out_tokens.append(int(host_tok[i]))
                    self._note_first_token(r)
                    n_active += 1
            self.metrics["tokens_generated"] += n_active
            if step + 1 >= n_steps:
                break   # every slot has its full budget: skip the decode
                        # whose output nobody would consume
            next_tok, cache = self._call("decode", params, overlay,
                                         next_tok, cache)
        jax.block_until_ready(next_tok)
        self.metrics["decode_seconds"] += time.perf_counter() - t0

        for r in group:
            r.status = "done"
            self._done[r.rid] = r
        self.metrics["batches"] += 1

    # -- continuous slot scheduler (mixed-variant batches) -------------------
    def _merge_admitted(self, old, fresh, admit_rows: list):
        """Merge freshly prefilled cache rows into the persistent batch
        cache.  The batch axis of every cache leaf is located via the
        model's cache_pspecs ("act_batch" logical axis) — per-row slot_pos
        and pos make every leaf row-separable, so admission is a pure
        select along that axis.  One jitted call per admission wave."""
        if old is None:
            return fresh
        mask = np.zeros(self.batch_size, bool)
        mask[admit_rows] = True
        if self._merge_jit is None:
            self._merge_jit = self._make_merge()
        return self._merge_jit(old, fresh, jnp.asarray(mask))

    def _make_merge(self):
        """The admission cache-merge jit, staged through the persistent
        cache like the step pairs (it compiles on the SECOND admission
        wave — steady-state latency, not first-token, but a restart
        should not re-pay it either)."""
        bs = self.batch_size
        specs = jax.tree.leaves(self.model.cache_pspecs(),
                                is_leaf=lambda x: isinstance(x, tuple))

        def merge(old, fresh, mask):
            old_leaves, treedef = jax.tree_util.tree_flatten(old)
            fresh_leaves, _ = jax.tree_util.tree_flatten(fresh)
            assert len(specs) == len(old_leaves) == len(fresh_leaves), \
                "cache_pspecs out of sync with the cache structure"
            out = []
            for o, f, sp in zip(old_leaves, fresh_leaves, specs):
                shape = [1] * o.ndim
                shape[sp.index("act_batch")] = bs
                out.append(jnp.where(mask.reshape(shape), f, o))
            return jax.tree_util.tree_unflatten(treedef, out)

        return CC.CachedCallable(
            jax.jit(merge),
            ("engine-merge", repr(self.model.cfg), bs, self.max_len,
             CC.mesh_fp(self.mesh)),
            cache=self.compile_cache)

    def _lane_pod(self, i: int) -> int:
        """Pod owning batch lane ``i``: act_batch shards pod-major over
        ("pod", "data"), so lanes block-partition into contiguous per-pod
        ranges."""
        return i // (self.batch_size // self._pods)

    def _route_pod(self, r: Request, free: list) -> int:
        """Affinity router (DESIGN.md §17): steer the request to a pod
        with a free lane that ALREADY holds its variant's bank slot
        (hit — no admission traffic at all); cold variants go to the
        free-est pod and admit on demand there (miss).  The choice is
        STICKY per request — the async pipeline's tickets are per
        (variant, pod), so re-routing a mid-ingest request would start a
        second ingest instead of finishing the first."""
        if self._pods == 1:
            return 0
        if r.route_pod is not None:
            return r.route_pod
        free_per_pod = collections.Counter(self._lane_pod(i) for i in free)
        holding = ([] if r.variant == "__base__"
                   else self.registry.bank_pods_holding(r.variant))
        warm = [p for p in sorted(free_per_pod) if p in holding]
        if warm:
            pod = warm[0]
        else:
            pod = max(sorted(free_per_pod), key=lambda p: free_per_pod[p])
        if r.variant != "__base__":
            self.metrics["affinity_hits" if pod in holding
                         else "affinity_misses"] += 1
        r.route_pod = pod
        return pod

    def _admit_free_slots(self) -> list:
        """Pop queued requests into free lanes: route each request to a
        pod (affinity first, _route_pod), resolve its variant to a bank
        slot IN THAT POD (loading + admitting the artifact on a miss) and
        pin it for the request's lifetime.  Artifact failures re-queue up
        to max_retries then fail; a fully-pinned bank re-queues the head
        and waits for retirements."""
        newly: list = []
        skipped: list = []
        free = [i for i in range(self.batch_size) if self._slots[i] is None]
        while free and self._queue:
            r = self._queue.popleft()
            pod = self._route_pod(r, free)
            if not any(self._lane_pod(i) == pod for i in free):
                # sticky pod's lanes all busy: hold the request until a
                # retirement frees one (re-routing would thrash per-pod
                # admission tickets and bank slots)
                skipped.append(r)
                continue
            if self.admission is not None and r.variant != "__base__":
                # async path: never load on the serving thread — consult
                # the pipeline (auto-prefetching unseen variants) and skip
                # the request while its version is still ingesting
                try:
                    state = self.admission.poll(r.variant, pod=pod)
                except Exception as e:   # ingest failed: same retry budget
                    r.retries += 1       # as the sync artifact-load path
                    if r.retries > self.max_retries:
                        r.status, r.error = "failed", str(e)
                        self._done[r.rid] = r
                        self.metrics["failed"] += 1
                    else:
                        r.status = "queued"
                        self._queue.append(r)
                    continue
                if state != "admitted":
                    r.status = "admitting"
                    skipped.append(r)
                    continue
            try:
                # admission-time resolution: a queued request follows the
                # serving pointer at THIS moment — a version published (or
                # rolled back) while it waited is what it serves.  The
                # acquire pins the resolved VERSION KEY, so a later swap
                # cannot evict the bank slot this lane decodes from.
                vslot, vkey = self.registry.bank_acquire(r.variant, pod)
            except RuntimeError:
                # every bank slot pinned by in-flight requests: transient
                # capacity pressure — retry after retirements free pins
                self._queue.appendleft(r)
                break
            except Exception as e:
                r.retries += 1
                if r.retries > self.max_retries:
                    r.status, r.error = "failed", str(e)
                    self._done[r.rid] = r
                    self.metrics["failed"] += 1
                else:
                    self._queue.append(r)
                continue
            i = next(j for j in free if self._lane_pod(j) == pod)
            free.remove(i)
            r.served_version = self.registry.current_version(r.variant)
            self._slots[i] = _Slot(request=r, variant_slot=vslot,
                                   remaining=r.max_new_tokens, vkey=vkey,
                                   pod=pod)
            self._variant_idx[i] = vslot
            self._variant_idx_dev = None
            r.status = "running"
            newly.append(i)
            self.metrics["admitted"] += 1
        # skipped (mid-admission) requests return to the FRONT in their
        # original order: admission order stays FIFO once staging lands
        self._queue.extendleft(reversed(skipped))
        return newly

    def _prefill_admitted(self, newly: list) -> None:
        """Prefill-on-admit: one fixed-shape (batch_size, prompt_len)
        prefill per admission wave; only the newly admitted rows of the
        resulting cache/logits are merged into the persistent batch."""
        bs = self.batch_size
        pvidx = self._base_vidx.copy()
        for i in newly:
            pvidx[i] = self._slots[i].variant_slot
        batch = self._prompt_batch(
            {i: self._slots[i].request for i in newly})
        bank = self.registry.bank.tree if self.registry.bank else None
        t0 = time.perf_counter()
        last_logits, fresh = self._call(
            "prefill_banked", self.registry.base_params, bank,
            jnp.asarray(pvidx), batch)
        jax.block_until_ready(last_logits)
        self.metrics["prefill_seconds"] += time.perf_counter() - t0
        self.metrics["prefills"] += 1
        first_tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
        if self._next_tok is None:
            self._next_tok = first_tok
            self._cache = fresh
            return
        mask = np.zeros(bs, bool)
        mask[newly] = True
        self._next_tok = jnp.where(jnp.asarray(mask), first_tok,
                                   self._next_tok)
        self._cache = self._merge_admitted(self._cache, fresh, newly)

    def _retire(self, i: int) -> None:
        """Release lane ``i``: mark its request done, unpin the bank slot
        it decoded from, and free the lane for the next admission wave."""
        s = self._slots[i]
        s.request.status = "done"
        self._done[s.request.rid] = s.request
        self.registry.bank_unpin(s.vkey, s.pod)
        self._slots[i] = None
        self._variant_idx[i] = self._base_vidx[i]
        self._variant_idx_dev = None
        self.metrics["retired"] += 1

    def _serve_continuous(self, max_rounds: int) -> None:
        # max_rounds bounds STALLED rounds (no admission, no token, no
        # failure), not decode steps — productive rounds are already
        # bounded by the submitted token budgets, so a large workload
        # drains fully instead of stranding requests mid-flight
        stalls = 0
        while (self._queue or self.active()) and stalls < max_rounds:
            # admission drain hook: commit AT MOST ONE staged overlay per
            # step (one donated scatter dispatch, no fence) — the bounded
            # on-thread cost of async admission (DESIGN.md §13)
            drained = 0
            if self.admission is not None:
                drained = self.admission.drain(max_admits=1)
                self.metrics["async_admits"] += drained
            failed0 = self.metrics["failed"]
            newly = self._admit_free_slots()
            if newly:
                self._prefill_admitted(newly)
            if not self.active():
                if not self._queue:
                    break
                # admissions failed this round; retry (counts as a stall
                # unless requests were failed — retries terminate)
                if self.metrics["failed"] > failed0 or drained:
                    stalls = 0
                elif self.admission is not None \
                        and self.admission.in_flight():
                    # every queued request is behind ingest and no lane is
                    # decoding: sleep on pipeline progress, don't busy-spin
                    # (terminates: ingest stages, fails, or commits once
                    # retirements release pins)
                    self.admission.wait_progress(0.05)
                    stalls = 0
                else:
                    stalls += 1
                continue
            stalls = 0
            # ONE host sync per step: every active slot has exactly one
            # pending token in next_tok — append from the host buffer
            host_tok = np.asarray(self._next_tok)
            retired = []
            for i, s in enumerate(self._slots):
                if s is None:
                    continue
                s.request.out_tokens.append(int(host_tok[i]))
                self._note_first_token(s.request)
                s.remaining -= 1
                self.metrics["tokens_generated"] += 1
                if s.remaining <= 0:
                    retired.append(i)
            # retire exhausted slots IMMEDIATELY — their lanes are free for
            # the next admission wave instead of padding to the batch max
            for i in retired:
                self._retire(i)
            if not (self.active() or self._queue):
                break           # drained: skip the dangling decode
            if not self.active():
                continue        # lanes empty but queue pending: admit next
            bank = self.registry.bank.tree if self.registry.bank else None
            if self._variant_idx_dev is None:
                self._variant_idx_dev = jnp.asarray(self._variant_idx)
            admission_busy = drained > 0 or (
                self.admission is not None
                and self.admission.in_flight() > 0)
            t0 = time.perf_counter()
            self._next_tok, self._cache = self._call(
                "decode_banked", self.registry.base_params, bank,
                self._variant_idx_dev, self._next_tok, self._cache)
            jax.block_until_ready(self._next_tok)
            dt = time.perf_counter() - t0
            self.metrics["decode_seconds"] += dt
            self.metrics["decode_steps"] += 1
            if self.record_step_times:
                # steps overlapping admission inherit the scatter the jax
                # dependency chain ordered before them — exactly the stall
                # the benchmark's 2x ceiling gates
                self.step_times.append(
                    (time.perf_counter(), dt, admission_busy))
        self.metrics["batches"] += 1

    def _serve_speculative(self, max_rounds: int) -> None:
        """The continuous slot scheduler with the per-token decode swapped
        for base-as-draft speculative ROUNDS (serving/speculative.py): the
        same admission / prefill-on-admit / retire machinery, but each
        jitted call drafts k tokens on the base weights and verifies them
        through the lane's banked overlay, emitting up to k+1 tokens per
        dispatch.  Token streams are bit-exact with scheduler="continuous"
        for any k (the round accepts only the variant's own greedy chain).

        ``self._next_tok`` holds each lane's PENDING token — already part
        of the variant's chain (prefill argmax or a verify correction) but
        not yet appended; the loop top emits it, then the round extends
        the chain by n_acc matched drafts + the next correction."""
        stalls = 0
        while (self._queue or self.active()) and stalls < max_rounds:
            drained = 0
            if self.admission is not None:
                drained = self.admission.drain(max_admits=1)
                self.metrics["async_admits"] += drained
            failed0 = self.metrics["failed"]
            newly = self._admit_free_slots()
            if newly:
                self._prefill_admitted(newly)
            if not self.active():
                if not self._queue:
                    break
                if self.metrics["failed"] > failed0 or drained:
                    stalls = 0
                elif self.admission is not None \
                        and self.admission.in_flight():
                    self.admission.wait_progress(0.05)
                    stalls = 0
                else:
                    stalls += 1
                continue
            stalls = 0
            # emit the pending token (one host sync), retire exhausted
            host_tok = np.asarray(self._next_tok)
            for i, s in enumerate(self._slots):
                if s is None:
                    continue
                s.request.out_tokens.append(int(host_tok[i]))
                self._note_first_token(s.request)
                s.remaining -= 1
                self.metrics["tokens_generated"] += 1
                if s.remaining <= 0:
                    self._retire(i)
            if not (self.active() or self._queue):
                break           # drained: skip the dangling round
            if not self.active():
                continue        # lanes empty but queue pending: admit next
            params, bank = self.registry.spec_resolve()
            if self._variant_idx_dev is None:
                self._variant_idx_dev = jnp.asarray(self._variant_idx)
            k = self.spec.current_k
            admission_busy = drained > 0 or (
                self.admission is not None
                and self.admission.in_flight() > 0)
            t0 = time.perf_counter()
            ver, n_acc, self._next_tok, self._cache = self._call(
                f"spec_k{k}", params, bank, self._variant_idx_dev,
                self._next_tok, self._cache)
            jax.block_until_ready(self._next_tok)
            dt = time.perf_counter() - t0
            self.metrics["decode_seconds"] += dt
            self.metrics["decode_steps"] += 1
            self.metrics["spec_rounds"] += 1
            if self.record_step_times:
                self.step_times.append(
                    (time.perf_counter(), dt, admission_busy))
            # second host sync of the round: the accepted prefixes
            host_ver = np.asarray(ver)
            host_n = np.asarray(n_acc)
            acc_total = 0
            lanes = 0
            for i, s in enumerate(self._slots):
                if s is None:
                    continue
                lanes += 1
                n = int(host_n[i])
                acc_total += n
                r = s.request
                r.drafted += k
                r.accepted += n
                take = min(n, s.remaining)
                for j in range(take):
                    r.out_tokens.append(int(host_ver[i, j]))
                self.metrics["tokens_generated"] += take
                s.remaining -= take
                if s.remaining <= 0:
                    # budget exhausted inside the round: the pending
                    # correction token is beyond max_new_tokens — drop it
                    self._retire(i)
            self.metrics["spec_drafted"] += k * lanes
            self.metrics["spec_accepted"] += acc_total
            self.spec.observe(k, acc_total, lanes)
        self.metrics["batches"] += 1

    def _prompt_batch(self, requests: dict) -> dict:
        """Fixed-shape (batch_size, prompt_len) prefill batch: row i holds
        requests[i]'s prompt tail, zero-padded; unmapped rows stay zero.
        The ONE place prompt padding happens — both schedulers must build
        bit-identical batches or their tokens diverge."""
        bs = self.batch_size
        toks = np.zeros((bs, self.prompt_len), np.int32)
        for i, r in requests.items():
            p = r.tokens[-self.prompt_len:]
            toks[i, :len(p)] = p
        batch = {"tokens": jnp.asarray(toks)}
        batch.update(self._frontend_stub(bs))
        return batch

    def _frontend_stub(self, bs: int) -> dict:
        cfg = self.model.cfg
        if cfg.family == "audio":
            return {"frames": jnp.zeros((bs, cfg.encoder_frames,
                                         cfg.d_model), jnp.float32)}
        if cfg.family == "vlm":
            return {"image_embeds": jnp.zeros(
                (bs, cfg.num_image_tokens, cfg.d_model), jnp.float32)}
        return {}
