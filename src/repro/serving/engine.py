"""Serving engine: batched prefill+decode over hot-swappable variants.

Request lifecycle: submit(prompt tokens, variant) → queued → engine groups
pending requests BY VARIANT (one compiled prefill/decode pair serves every
variant — same shapes, different params) → prefill fills a fixed-slot KV
cache → decode steps run round-robin across variant groups → finished
sequences retire and their slots are reused.

Variants resolve to (params, overlay): dense residents pass a materialised
copy with overlay None; fused residents pass the shared base params plus a
packed delta overlay that the model fuses into every GEMM on the fly
(serving/variants.py — residency modes).

Fault tolerance: a variant whose artifact fails to load has its requests
re-queued up to ``max_retries`` then failed individually — the engine and
other tenants keep serving.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model_zoo import Model
from repro.serving.variants import VariantRegistry


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray            # prompt (prompt_len,)
    variant: str = "__base__"
    max_new_tokens: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)
    status: str = "queued"        # queued | running | done | failed
    retries: int = 0
    error: Optional[str] = None


class ServingEngine:
    """Fixed-shape batched serving: batch slots of ``batch_size``, prompts
    padded to ``prompt_len``, KV capacity ``max_len``."""

    def __init__(self, model: Model, registry: VariantRegistry, *,
                 batch_size: int = 4, prompt_len: int = 32,
                 max_len: int = 128, max_retries: int = 1,
                 greedy: bool = True):
        self.model = model
        self.registry = registry
        self.batch_size = batch_size
        self.prompt_len = prompt_len
        self.max_len = max_len
        self.max_retries = max_retries
        self._queue: collections.deque[Request] = collections.deque()
        self._done: dict[int, Request] = {}
        self._next_rid = 0

        # one compiled pair per overlay STRUCTURE: dense variants trace
        # with overlay=None, fused variants with their entry tree — the
        # packed deltas ride in as ordinary jit arguments
        def prefill_fn(params, overlay, batch):
            return model.prefill(params, batch, max_len, overlay=overlay)

        def decode_fn(params, overlay, token, cache):
            logits, cache = model.decode_step(params, token, cache,
                                              overlay=overlay)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

        self._prefill = jax.jit(prefill_fn)
        self._decode = jax.jit(decode_fn)
        self.metrics = {"batches": 0, "tokens_generated": 0,
                        "prefills": 0, "failed": 0,
                        "prefill_seconds": 0.0, "decode_seconds": 0.0}

    # -- API -----------------------------------------------------------------
    def submit(self, tokens, variant: str = "__base__",
               max_new_tokens: int = 16) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(Request(rid=rid, tokens=np.asarray(tokens),
                                   variant=variant,
                                   max_new_tokens=max_new_tokens))
        return rid

    def result(self, rid: int) -> Request:
        return self._done[rid]

    def pending(self) -> int:
        return len(self._queue)

    def run_until_drained(self, max_rounds: int = 1000) -> dict:
        rounds = 0
        while self._queue and rounds < max_rounds:
            self._serve_one_group()
            rounds += 1
        return self.metrics

    # -- internals -------------------------------------------------------------
    def _take_group(self) -> list:
        """Pop up to batch_size requests of the same variant (FIFO head
        decides the variant — simple fairness).  Scanning stops as soon as
        the group is full; skipped requests go back to the front in their
        original order."""
        if not self._queue:
            return []
        variant = self._queue[0].variant
        group, skipped = [], []
        while self._queue and len(group) < self.batch_size:
            r = self._queue.popleft()
            if r.variant == variant:
                group.append(r)
            else:
                skipped.append(r)
        self._queue.extendleft(reversed(skipped))
        return group

    def _serve_one_group(self) -> None:
        group = self._take_group()
        if not group:
            return
        variant = group[0].variant
        try:
            params, overlay = self.registry.resolve(variant)
        except Exception as e:  # artifact failure: re-queue or fail
            for r in group:
                r.retries += 1
                if r.retries > self.max_retries:
                    r.status, r.error = "failed", str(e)
                    self._done[r.rid] = r
                    self.metrics["failed"] += 1
                else:
                    self._queue.append(r)
            return

        bs = self.batch_size
        toks = np.zeros((bs, self.prompt_len), np.int32)
        lengths = np.zeros(bs, np.int32)
        for i, r in enumerate(group):
            p = r.tokens[-self.prompt_len:]
            toks[i, :len(p)] = p
            lengths[i] = len(p)
        batch = {"tokens": jnp.asarray(toks)}
        batch.update(self._frontend_stub(bs))

        t0 = time.perf_counter()
        last_logits, cache = self._prefill(params, overlay, batch)
        jax.block_until_ready(last_logits)
        self.metrics["prefill_seconds"] += time.perf_counter() - t0
        self.metrics["prefills"] += 1

        next_tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
        n_steps = max(r.max_new_tokens for r in group)
        t0 = time.perf_counter()
        for step in range(n_steps):
            # retired slots (past their own max_new_tokens) still occupy a
            # batch lane but neither emit tokens nor count toward metrics
            n_active = 0
            for i, r in enumerate(group):
                if step < r.max_new_tokens:
                    r.out_tokens.append(int(next_tok[i]))
                    n_active += 1
            next_tok, cache = self._decode(params, overlay, next_tok, cache)
            self.metrics["tokens_generated"] += n_active
        jax.block_until_ready(next_tok)
        self.metrics["decode_seconds"] += time.perf_counter() - t0

        for r in group:
            r.status = "done"
            self._done[r.rid] = r
        self.metrics["batches"] += 1

    def _frontend_stub(self, bs: int) -> dict:
        cfg = self.model.cfg
        if cfg.family == "audio":
            return {"frames": jnp.zeros((bs, cfg.encoder_frames,
                                         cfg.d_model), jnp.float32)}
        if cfg.family == "vlm":
            return {"image_embeds": jnp.zeros(
                (bs, cfg.num_image_tokens, cfg.d_model), jnp.float32)}
        return {}
