"""Cross-variant speculative decoding: base-as-draft, banked k-token verify.

The base model is already resident on every device next to each fused
variant overlay (bank slot 0 = base) — it is a free draft model, and the
paper's premise (per-axis 1-bit deltas keep variants CLOSE to the base;
BitDelta/DeltaZip in PAPERS.md make the same observation) is exactly the
high-acceptance regime speculative decoding wants.  One round per lane:

  draft   k plain ``decode_step``s with the BASE weights (overlay None —
          the pure-XLA path, no banked kernel) chained inside one scan;
          the draft's cache writes are DISCARDED (the verify pass rebuilds
          them with the variant's own K/V),
  verify  ONE banked ``verify_step`` over [pending, d_1..d_k] (T = k+1
          teacher-forced tokens, per-row positions over the live cache)
          with the lane's variant overlay + per-row variant_idx — the same
          banked delta GEMMs as continuous decode, amortised over k+1
          tokens per call,
  accept  the longest prefix where draft == variant-greedy, PLUS the
          variant's own next token (``n_acc`` matches, ``n_acc + 1``
          chain tokens) — so the emitted stream is the variant's greedy
          chain BY CONSTRUCTION, bit-exact with ``scheduler="continuous"``
          for any k and any acceptance rate,
  rewind  the cache retreats to the state after consuming exactly
          ``n_acc + 1`` tokens (``Model.verify_rewind``).

Everything lives in ONE jitted function per k: the engine pays a single
dispatch + host sync per round for up to k+1 emitted tokens, versus one
per token under continuous decode — that call-amortisation (plus drafting
on the cheap overlay-free path) is where the speedup comes from, and the
acceptance rate is what buys it (DESIGN.md §15 derives the model).

Why the emitted tokens are exact: verify logits[:, j] condition on
seq[:, :j+1] = [pending, d_1..d_j].  For j < n_acc every d_i in that
prefix equals the variant's greedy token v_i (that is what the cumulative
match means), so v_{j+1} = argmax(logits[:, j]) is the variant's own
chain; the first mismatch position contributes the variant's CORRECTED
token and everything after it is discarded along with its cache writes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def default_k_ladder(draft_k: int) -> list:
    """Compile-time draft lengths the adaptive controller may pick:
    powers of two up to ``draft_k`` plus ``draft_k`` itself (each k is a
    separate scan length, hence a separate executable — the engine warms
    and caches every rung)."""
    if draft_k < 1:
        raise ValueError(f"draft_k must be >= 1, got {draft_k}")
    ladder = {1 << i for i in range((draft_k).bit_length())
              if (1 << i) <= draft_k}
    ladder.add(draft_k)
    return sorted(ladder)


def make_round_fn(model, k: int):
    """Build the jit-able speculative round for draft length ``k``.

    Signature matches the engine's banked decode step — (base_params,
    bank, variant_idx, pending_token, cache), roles ("params", "overlay",
    "token", "token", "cache") — so the engine's sharded staging, compile
    cache and warmup machinery apply unchanged.  Returns

      ver      (B, k+1) int32  variant greedy tokens: ver[:, j] follows
               the teacher-forced prefix [pending, d_1..d_j]
      n_acc    (B,)     int32  accepted draft count in [0, k]
      next_tok (B,)     int32  the next pending token, ver[b, n_acc[b]]
      cache                    rewound to pos + n_acc + 1
    """

    def spec_round(params, bank, vidx, token, cache):
        def draft_body(carry, _):
            tok, c = carry
            logits, c2 = model.decode_step(params, tok, c)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return (nxt, c2), nxt

        # draft on the base: overlay None keeps the GEMMs on the plain
        # XLA path (no per-step bank gather); the drafted cache is dropped
        (_, _), drafts = jax.lax.scan(draft_body, (token, cache), None,
                                      length=k)
        drafts = jnp.swapaxes(drafts, 0, 1)             # (B, k)
        seq = jnp.concatenate([token[:, None], drafts], axis=1)
        logits, rewind_state = model.verify_step(params, seq, cache,
                                                 overlay=bank,
                                                 variant_idx=vidx)
        ver = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # (B, k+1)
        match = (drafts == ver[:, :k]).astype(jnp.int32)
        n_acc = jnp.cumprod(match, axis=1).sum(axis=1)        # (B,)
        next_tok = jnp.take_along_axis(ver, n_acc[:, None], axis=1)[:, 0]
        new_cache = model.verify_rewind(rewind_state, n_acc + 1)
        return ver, n_acc, next_tok, new_cache

    return spec_round


class AcceptanceTracker:
    """Engine-wide adaptive draft-length controller + acceptance stats.

    Tracks an EMA of the per-round acceptance FRACTION (accepted drafts /
    offered drafts over active lanes) and walks ``current_k`` along the
    compile-time ladder: persistent low acceptance wastes draft+verify
    work on tokens that get thrown away (step down), persistent
    near-perfect acceptance means rounds are shorter than they could be
    (step up).  Adjustments are cooldown-gated so a single outlier round
    cannot thrash between executables."""

    def __init__(self, draft_k: int, *, ema_decay: float = 0.7,
                 low: float = 0.4, high: float = 0.85, cooldown: int = 4,
                 adaptive: bool = True):
        self.ladder = default_k_ladder(draft_k)
        self.current_k = draft_k
        self.ema = 1.0          # optimistic start: the paper's premise is
        self.ema_decay = ema_decay   # base/variant streams mostly agree
        self.low = low
        self.high = high
        self.cooldown = cooldown
        self.adaptive = adaptive
        self.rounds = 0
        self.drafted = 0
        self.accepted = 0
        self._since_adjust = 0

    def observe(self, k: int, accepted: int, lanes: int) -> None:
        """One round's outcome: ``lanes`` active lanes were offered ``k``
        drafts each and accepted ``accepted`` in total."""
        self.rounds += 1
        if lanes <= 0:
            return
        self.drafted += k * lanes
        self.accepted += accepted
        frac = accepted / float(k * lanes)
        self.ema = self.ema_decay * self.ema + (1 - self.ema_decay) * frac
        self._since_adjust += 1
        if not self.adaptive or self._since_adjust < self.cooldown:
            return
        i = self.ladder.index(self.current_k)
        if self.ema < self.low and i > 0:
            self.current_k = self.ladder[i - 1]
            self._since_adjust = 0
        elif self.ema > self.high and i < len(self.ladder) - 1:
            self.current_k = self.ladder[i + 1]
            self._since_adjust = 0

    @property
    def acceptance(self) -> float:
        """Lifetime acceptance rate (accepted / drafted)."""
        return self.accepted / self.drafted if self.drafted else 0.0

    def snapshot(self) -> dict:
        return {"current_k": self.current_k, "ladder": list(self.ladder),
                "acceptance_ema": self.ema, "acceptance": self.acceptance,
                "rounds": self.rounds, "drafted": self.drafted,
                "accepted": self.accepted}
