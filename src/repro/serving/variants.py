"""Multi-tenant variant registry: many fine-tunes over one resident base.

The deployment story of the paper: a serving node keeps ONE base model
resident and a library of compressed delta artifacts on disk; requests
name a variant; the registry serves it under one of two residency modes
(DESIGN.md §6):

* ``dense`` — swap-then-dense: the artifact is reconstructed into a full
  materialised copy of the params (``loader.apply_artifact``).  Fastest
  steady-state matmuls, but each resident variant costs a full model of
  HBM, so ``max_resident`` stays small.
* ``fused`` — on-the-fly: the variant stays PACKED on device as a delta
  overlay (``loader.device_put_overlay``); forward fuses it into each
  GEMM.  ~1/16 the resident bytes of a dense copy, so ``max_resident``
  can grow ~10× on the same budget and cold-start skips reconstruction.

``resolve(name)`` returns ``(params, overlay)`` — overlay is None for the
base and for dense residents.  Modes mix freely in one registry (default
from the constructor, per-variant override at ``register``).

For MIXED-VARIANT batches (the continuous-batching scheduler,
serving/engine.py) the registry additionally maintains an
:class:`OverlayBank`: fused residents stacked along a leading bank axis,
slot 0 reserved for the base, with pin/unpin guarding in-flight variants
and slot reuse on eviction.  ``bank_resolve(name)`` admits a variant and
returns its slot index — the per-batch-row ``variant_idx`` the banked
kernels consume (DESIGN.md §9).
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import loader as L
from repro.core import store as S
from repro.core.calibration import DeltaModel, flatten_params
from repro.models import delta_overlay as DO


@functools.partial(jax.jit, static_argnames=("vec_dtype",))
def _bank_write(flat: dict, deltas: dict, extras: dict, slot, *,
                vec_dtype) -> dict:
    """Write one variant into bank slot ``slot`` as a SINGLE compiled
    update: canonicalise every DeltaEntry (fp16 axis vectors, zeroed
    unselected axis), fp16-round every extras leaf, and scatter them at
    the slot index.  One dispatch per admission instead of a few hundred
    eager ``.at[].set`` calls — cold-admit latency is part of TTFT."""
    out = dict(flat)
    for path, e in deltas.items():
        ent = DO.from_delta_entry(e, vec_dtype=vec_dtype)
        bank = flat[path]
        idx = (slice(None),) * DO.bank_axis(path) + (slot,)
        out[path] = DO.OverlayEntry(
            packed=bank.packed.at[idx].set(ent.packed),
            v_row=bank.v_row.at[idx].set(ent.v_row.astype(bank.v_row.dtype)),
            v_col=bank.v_col.at[idx].set(ent.v_col.astype(bank.v_col.dtype)))
    for path, v in extras.items():
        bank = flat[path]
        idx = (slice(None),) * DO.bank_axis(path) + (slot,)
        out[path] = bank.at[idx].set(
            v.astype(jnp.float16).astype(bank.dtype))
    return out


class OverlayBank:
    """Stacked fused residents: one banked overlay tree whose leaves carry a
    leading bank axis of ``size`` slots (DESIGN.md §9).

    * slot 0 is the BASE: zero delta vectors (Ŵ = W_b exactly) and base
      extras — ``variant_idx == 0`` means "serve this row from the base";
    * slots 1..size-1 hold fused variants (packed masks, fp16 axis vectors,
      fp16-rounded extras), admitted/evicted with slot reuse;
    * pinned variants (in-flight requests) are never evicted — ``evict``
      raises and LRU pressure skips them.

    The bank is allocated at full size on first admit; resident-byte
    accounting is therefore per-bank, not per-variant — ``nbytes()`` is the
    device footprint the registry reports.
    """

    def __init__(self, base_params, size: int, *, vec_dtype=jnp.float16):
        if size < 2:
            raise ValueError("bank needs >= 2 slots (base + 1 variant)")
        self.size = size
        self.vec_dtype = vec_dtype
        self._base_flat = flatten_params(base_params)
        self._flat: Optional[dict] = None   # path -> banked leaf
        self.tree: Optional[dict] = None    # nested view of _flat
        self._slots: dict[str, int] = {}
        self._pins: dict[str, int] = {}
        self._lru: "collections.OrderedDict[str, None]" = \
            collections.OrderedDict()
        self._free = list(range(size - 1, 0, -1))   # pop() -> lowest slot
        self.stats = {"admits": 0, "evictions": 0}

    # -- structure ---------------------------------------------------------
    def _ensure_tree(self, dm: DeltaModel) -> None:
        if self._flat is not None:
            if set(dm.deltas) != self._template_deltas or \
                    set(dm.extras) != self._template_extras:
                raise ValueError(
                    "variant structure differs from the bank template "
                    "(all banked variants must share one calibration "
                    "recipe)")
            return
        flat = {}
        for path, e in dm.deltas.items():
            ent = DO.from_delta_entry(e, vec_dtype=self.vec_dtype)
            flat[path] = DO.bank_zeros(path, ent, self.size)
        for path in dm.extras:
            flat[path] = DO.bank_extra_base(path, self._base_flat[path],
                                            self.size)
        self._flat = flat
        self._template_deltas = set(dm.deltas)
        self._template_extras = set(dm.extras)
        self._rebuild()

    def _rebuild(self) -> None:
        tree: dict = {}
        for path, leaf in self._flat.items():
            DO.insert_entry(tree, path, leaf)
        self.tree = tree

    # -- lifecycle ---------------------------------------------------------
    def slot_of(self, name: str) -> int:
        if name == "__base__":
            return 0
        return self._slots[name]

    def resident(self) -> list:
        return list(self._lru)

    def has_capacity(self) -> bool:
        """A new variant can be admitted: a free slot exists or some
        resident is unpinned (evictable).  Lets callers refuse BEFORE
        paying the artifact load."""
        return bool(self._free) or any(self._pins.get(c, 0) == 0
                                       for c in self._lru)

    def admit(self, name: str, dm: DeltaModel) -> tuple[int, int]:
        """Place ``dm`` into a slot (reusing evicted slots, evicting the
        LRU unpinned resident when full).  Returns (slot, payload_bytes)."""
        if name == "__base__":
            return 0, 0
        if name in self._slots:
            self._lru.move_to_end(name)
            return self._slots[name], 0
        self._ensure_tree(dm)
        if not self._free:
            for cand in self._lru:
                if self._pins.get(cand, 0) == 0:
                    # slot is reassigned immediately: skip the device-side
                    # clear (admit overwrites every leaf of the slot)
                    self._release(cand, clear=False)
                    break
            else:
                raise RuntimeError(
                    "overlay bank full: every resident is pinned by an "
                    "in-flight request")
        slot = self._free.pop()
        payload = sum(int(e.packed.size) + 2 * int(e.v_row.size)
                      + 2 * int(e.v_col.size) for e in dm.deltas.values())
        payload += sum(2 * int(v.size) for v in dm.extras.values())
        self._flat = _bank_write(self._flat, dict(dm.deltas),
                                 dict(dm.extras), jnp.int32(slot),
                                 vec_dtype=self.vec_dtype)
        self._slots[name] = slot
        self._lru[name] = None
        self.stats["admits"] += 1
        self._rebuild()
        return slot, payload

    def pin(self, name: str) -> None:
        if name != "__base__":
            self._pins[name] = self._pins.get(name, 0) + 1

    def unpin(self, name: str) -> None:
        if name != "__base__" and name in self._pins:
            self._pins[name] = max(0, self._pins[name] - 1)

    def pinned(self, name: str) -> bool:
        return self._pins.get(name, 0) > 0

    def evict(self, name: str) -> None:
        """Free a slot for reuse; refuses while the variant is pinned
        (mid-flight requests reference its slot index)."""
        if name not in self._slots:
            return
        if self.pinned(name):
            raise RuntimeError(
                f"variant {name!r} is pinned by in-flight requests; "
                "retire them before evicting")
        self._release(name, clear=True)

    def _release(self, name: str, *, clear: bool) -> None:
        """Drop a resident and recycle its slot.  ``clear=False`` skips
        the device-side zeroing — correct when the slot is reassigned in
        the same admit (every leaf overwritten), and it keeps the
        eviction-under-pressure path off the eager per-leaf updates
        ``_bank_write`` exists to avoid."""
        slot = self._slots.pop(name)
        self._lru.pop(name, None)
        self._pins.pop(name, None)
        if clear:
            for path in self._template_deltas:
                self._flat[path] = DO.bank_clear_entry(
                    path, self._flat[path], slot)
            for path in self._template_extras:
                self._flat[path] = DO.bank_set_extra_base(
                    path, self._flat[path], slot, self._base_flat[path])
            self._rebuild()
        self._free.append(slot)
        self.stats["evictions"] += 1

    def nbytes(self) -> int:
        if self._flat is None:
            return 0
        return DO.overlay_nbytes(self._flat)


@dataclasses.dataclass
class _Resident:
    params: object
    overlay: Optional[dict]        # None => dense materialisation
    nbytes: int                    # HBM added on top of the resident base


class VariantRegistry:
    def __init__(self, base_params, *, param_shardings=None,
                 max_resident: int = 2, use_kernel: bool = True,
                 mode: str = "dense", bank_size: int = 8):
        if mode not in ("dense", "fused"):
            raise ValueError(f"unknown residency mode {mode!r}")
        self.base_params = base_params
        self.param_shardings = param_shardings
        self.use_kernel = use_kernel
        self.max_resident = max_resident
        self.mode = mode
        self.bank_size = bank_size
        self.bank: Optional[OverlayBank] = None   # created on first use
        self._bank_evictions_seen = 0
        self._artifacts: dict[str, object] = {}   # name -> dir or DeltaModel
        self._modes: dict[str, str] = {}          # per-variant override
        self._resident: "collections.OrderedDict[str, _Resident]" = \
            collections.OrderedDict()
        self.stats = {"swaps": 0, "hits": 0, "swap_seconds": 0.0,
                      "transferred_bytes": 0, "load_failures": 0,
                      "resident_bytes": 0, "evictions": 0}
        self._base_fp = S.base_fingerprint(base_params)
        self._dense_nbytes = sum(
            leaf.size * leaf.dtype.itemsize
            for leaf in jax.tree.leaves(base_params))

    # -- registration ------------------------------------------------------
    def register(self, name: str, artifact, mode: Optional[str] = None
                 ) -> None:
        """artifact: directory path (lazy-loaded) or a DeltaModel.
        ``mode`` overrides the registry default for this variant."""
        if mode is not None and mode not in ("dense", "fused"):
            raise ValueError(f"unknown residency mode {mode!r}")
        self._artifacts[name] = artifact
        if mode is not None:
            self._modes[name] = mode

    def registered(self) -> list:
        return ["__base__"] + sorted(self._artifacts)

    def variant_mode(self, name: str) -> str:
        return self._modes.get(name, self.mode)

    # -- resolution --------------------------------------------------------
    def resolve(self, name: str):
        """(params, overlay) for a variant, LRU-cached on device;
        '__base__' serves the resident base (overlay None)."""
        if name == "__base__":
            return self.base_params, None
        if name in self._resident:
            self._resident.move_to_end(name)
            self.stats["hits"] += 1
            r = self._resident[name]
            return r.params, r.overlay
        if name not in self._artifacts:
            raise KeyError(f"unknown variant {name!r}")
        dm = self._load(name)
        if self.variant_mode(name) == "fused":
            params, overlay, st = L.device_put_overlay(
                self.base_params, dm, param_shardings=self.param_shardings)
            nbytes = L.fused_resident_bytes(self.base_params, params, overlay)
        else:
            params, st = L.apply_artifact(
                self.base_params, dm, param_shardings=self.param_shardings,
                use_kernel=self.use_kernel)
            overlay, nbytes = None, self._dense_nbytes
        self.stats["swaps"] += 1
        self.stats["swap_seconds"] += st["seconds"]
        self.stats["transferred_bytes"] += st["transferred_bytes"]
        resident = _Resident(params, overlay, nbytes)
        self._resident[name] = resident
        self.stats["resident_bytes"] += nbytes
        while len(self._resident) > self.max_resident:
            _, evicted = self._resident.popitem(last=False)   # evict LRU
            self.stats["resident_bytes"] -= evicted.nbytes
            self.stats["evictions"] += 1
        # serve from the local handle: max_resident=0 (cache-nothing) may
        # have evicted the entry we just built
        return resident.params, resident.overlay

    def params_for(self, name: str):
        """Back-compat dense accessor: materialised params for a variant.
        Raises for fused-mode variants — use ``resolve``.  The mode check
        comes FIRST so the error path neither loads the artifact nor
        disturbs the LRU/swap stats."""
        if name != "__base__" and self.variant_mode(name) == "fused":
            raise ValueError(
                f"variant {name!r} is fused-mode (packed overlay); "
                "use resolve() to get (params, overlay)")
        params, _ = self.resolve(name)
        return params

    # -- banked resolution (mixed-variant batches) -------------------------
    def bank_resolve(self, name: str) -> int:
        """Admit ``name`` into the overlay bank (created on demand) and
        return its bank slot index — the per-row ``variant_idx`` value.
        '__base__' is always slot 0.  Swap/residency stats migrate to the
        bank: ``resident_bytes`` tracks the bank allocation (charged when
        the bank grows, not per admitted variant)."""
        if self.bank is None:
            self.bank = OverlayBank(self.base_params, self.bank_size)
        if name == "__base__":
            return 0
        if name in self.bank._slots:
            self.stats["hits"] += 1
            return self.bank.admit(name, None)[0]   # LRU touch, no payload
        if name not in self._artifacts:
            raise KeyError(f"unknown variant {name!r}")
        if self.bank.tree is not None and not self.bank.has_capacity():
            # refuse BEFORE the disk load: a fully-pinned bank would
            # otherwise re-read + re-verify the artifact every scheduler
            # step while waiting for a retirement to free a pin
            raise RuntimeError(
                "overlay bank full: every resident is pinned by an "
                "in-flight request")
        dm = self._load(name)
        before = self.bank.nbytes()
        t0 = time.perf_counter()
        slot, payload = self.bank.admit(name, dm)
        jax.block_until_ready(jax.tree.leaves(self.bank.tree)[0])
        self.stats["swaps"] += 1
        self.stats["swap_seconds"] += time.perf_counter() - t0
        self.stats["transferred_bytes"] += payload
        self.stats["resident_bytes"] += self.bank.nbytes() - before
        self.stats["evictions"] += (self.bank.stats["evictions"]
                                    - self._bank_evictions_seen)
        self._bank_evictions_seen = self.bank.stats["evictions"]
        return slot

    def bank_pin(self, name: str) -> None:
        if self.bank is not None:
            self.bank.pin(name)

    def bank_unpin(self, name: str) -> None:
        if self.bank is not None:
            self.bank.unpin(name)

    def resident(self) -> list:
        return list(self._resident)

    def resident_nbytes(self, name: str) -> int:
        return self._resident[name].nbytes

    def _load(self, name: str) -> DeltaModel:
        art = self._artifacts[name]
        if isinstance(art, DeltaModel):
            return art
        try:
            return S.load_artifact(str(art), expect_base_fp=self._base_fp)
        except Exception:
            # fault tolerance: corrupt/missing artifact must not take the
            # node down — record and retry without integrity gating so the
            # caller can decide (engine re-queues the request)
            self.stats["load_failures"] += 1
            raise

    def evict(self, name: str) -> None:
        # pin check FIRST: refusing a pinned (mid-flight) banked variant
        # must not half-evict — the dense resident and stats stay intact
        if self.bank is not None and self.bank.pinned(name):
            raise RuntimeError(
                f"variant {name!r} is pinned by in-flight requests; "
                "retire them before evicting")
        r = self._resident.pop(name, None)
        if r is not None:
            self.stats["resident_bytes"] -= r.nbytes
            self.stats["evictions"] += 1
        if self.bank is not None and name in self.bank._slots:
            # bank bytes stay allocated — the slot is reusable, not freed
            self.bank.evict(name)
            self.stats["evictions"] += 1
            self._bank_evictions_seen = self.bank.stats["evictions"]
