"""Multi-tenant variant registry: many fine-tunes over one resident base.

The deployment story of the paper: a serving node keeps ONE base model
resident and a library of compressed delta artifacts on disk; requests
name a variant; the registry hot-swaps (or serves from an LRU of
materialised variants).  Swap cost = packed transfer + fused unpack —
benchmarked against full-checkpoint loads in benchmarks/load_time.py.
"""
from __future__ import annotations

import collections
import time
from typing import Callable, Optional

import jax

from repro.core import loader as L
from repro.core import store as S
from repro.core.calibration import DeltaModel


class VariantRegistry:
    def __init__(self, base_params, *, param_shardings=None,
                 max_resident: int = 2, use_kernel: bool = True):
        self.base_params = base_params
        self.param_shardings = param_shardings
        self.use_kernel = use_kernel
        self.max_resident = max_resident
        self._artifacts: dict[str, object] = {}   # name -> dir or DeltaModel
        self._resident: "collections.OrderedDict[str, object]" = \
            collections.OrderedDict()
        self.stats = {"swaps": 0, "hits": 0, "swap_seconds": 0.0,
                      "transferred_bytes": 0, "load_failures": 0}
        self._base_fp = S.base_fingerprint(base_params)

    # -- registration ------------------------------------------------------
    def register(self, name: str, artifact) -> None:
        """artifact: directory path (lazy-loaded) or a DeltaModel."""
        self._artifacts[name] = artifact

    def registered(self) -> list:
        return ["__base__"] + sorted(self._artifacts)

    # -- resolution --------------------------------------------------------
    def params_for(self, name: str):
        """Materialised params for a variant (LRU-cached); '__base__'
        serves the base model."""
        if name == "__base__":
            return self.base_params
        if name in self._resident:
            self._resident.move_to_end(name)
            self.stats["hits"] += 1
            return self._resident[name]
        if name not in self._artifacts:
            raise KeyError(f"unknown variant {name!r}")
        dm = self._load(name)
        params, st = L.apply_artifact(
            self.base_params, dm, param_shardings=self.param_shardings,
            use_kernel=self.use_kernel)
        self.stats["swaps"] += 1
        self.stats["swap_seconds"] += st["seconds"]
        self.stats["transferred_bytes"] += st["transferred_bytes"]
        self._resident[name] = params
        while len(self._resident) > self.max_resident:
            self._resident.popitem(last=False)   # evict LRU
        return params

    def _load(self, name: str) -> DeltaModel:
        art = self._artifacts[name]
        if isinstance(art, DeltaModel):
            return art
        try:
            return S.load_artifact(str(art), expect_base_fp=self._base_fp)
        except Exception:
            # fault tolerance: corrupt/missing artifact must not take the
            # node down — record and retry without integrity gating so the
            # caller can decide (engine re-queues the request)
            self.stats["load_failures"] += 1
            raise

    def evict(self, name: str) -> None:
        self._resident.pop(name, None)
