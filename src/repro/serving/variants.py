"""Multi-tenant variant registry: many fine-tunes over one resident base.

The deployment story of the paper: a serving node keeps ONE base model
resident and a library of compressed delta artifacts on disk; requests
name a variant; the registry serves it under one of two residency modes
(DESIGN.md §6):

* ``dense`` — swap-then-dense: the artifact is reconstructed into a full
  materialised copy of the params (``loader.apply_artifact``).  Fastest
  steady-state matmuls, but each resident variant costs a full model of
  HBM, so ``max_resident`` stays small.
* ``fused`` — on-the-fly: the variant stays PACKED on device as a delta
  overlay (``loader.device_put_overlay``); forward fuses it into each
  GEMM.  ~1/16 the resident bytes of a dense copy, so ``max_resident``
  can grow ~10× on the same budget and cold-start skips reconstruction.

``resolve(name)`` returns ``(params, overlay)`` — overlay is None for the
base and for dense residents.  Modes mix freely in one registry (default
from the constructor, per-variant override at ``register``).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional

import jax

from repro.core import loader as L
from repro.core import store as S
from repro.core.calibration import DeltaModel


@dataclasses.dataclass
class _Resident:
    params: object
    overlay: Optional[dict]        # None => dense materialisation
    nbytes: int                    # HBM added on top of the resident base


class VariantRegistry:
    def __init__(self, base_params, *, param_shardings=None,
                 max_resident: int = 2, use_kernel: bool = True,
                 mode: str = "dense"):
        if mode not in ("dense", "fused"):
            raise ValueError(f"unknown residency mode {mode!r}")
        self.base_params = base_params
        self.param_shardings = param_shardings
        self.use_kernel = use_kernel
        self.max_resident = max_resident
        self.mode = mode
        self._artifacts: dict[str, object] = {}   # name -> dir or DeltaModel
        self._modes: dict[str, str] = {}          # per-variant override
        self._resident: "collections.OrderedDict[str, _Resident]" = \
            collections.OrderedDict()
        self.stats = {"swaps": 0, "hits": 0, "swap_seconds": 0.0,
                      "transferred_bytes": 0, "load_failures": 0,
                      "resident_bytes": 0, "evictions": 0}
        self._base_fp = S.base_fingerprint(base_params)
        self._dense_nbytes = sum(
            leaf.size * leaf.dtype.itemsize
            for leaf in jax.tree.leaves(base_params))

    # -- registration ------------------------------------------------------
    def register(self, name: str, artifact, mode: Optional[str] = None
                 ) -> None:
        """artifact: directory path (lazy-loaded) or a DeltaModel.
        ``mode`` overrides the registry default for this variant."""
        if mode is not None and mode not in ("dense", "fused"):
            raise ValueError(f"unknown residency mode {mode!r}")
        self._artifacts[name] = artifact
        if mode is not None:
            self._modes[name] = mode

    def registered(self) -> list:
        return ["__base__"] + sorted(self._artifacts)

    def variant_mode(self, name: str) -> str:
        return self._modes.get(name, self.mode)

    # -- resolution --------------------------------------------------------
    def resolve(self, name: str):
        """(params, overlay) for a variant, LRU-cached on device;
        '__base__' serves the resident base (overlay None)."""
        if name == "__base__":
            return self.base_params, None
        if name in self._resident:
            self._resident.move_to_end(name)
            self.stats["hits"] += 1
            r = self._resident[name]
            return r.params, r.overlay
        if name not in self._artifacts:
            raise KeyError(f"unknown variant {name!r}")
        dm = self._load(name)
        if self.variant_mode(name) == "fused":
            params, overlay, st = L.device_put_overlay(
                self.base_params, dm, param_shardings=self.param_shardings)
            nbytes = L.fused_resident_bytes(self.base_params, params, overlay)
        else:
            params, st = L.apply_artifact(
                self.base_params, dm, param_shardings=self.param_shardings,
                use_kernel=self.use_kernel)
            overlay, nbytes = None, self._dense_nbytes
        self.stats["swaps"] += 1
        self.stats["swap_seconds"] += st["seconds"]
        self.stats["transferred_bytes"] += st["transferred_bytes"]
        resident = _Resident(params, overlay, nbytes)
        self._resident[name] = resident
        self.stats["resident_bytes"] += nbytes
        while len(self._resident) > self.max_resident:
            _, evicted = self._resident.popitem(last=False)   # evict LRU
            self.stats["resident_bytes"] -= evicted.nbytes
            self.stats["evictions"] += 1
        # serve from the local handle: max_resident=0 (cache-nothing) may
        # have evicted the entry we just built
        return resident.params, resident.overlay

    def params_for(self, name: str):
        """Back-compat dense accessor: materialised params for a variant.
        Raises for fused-mode variants — use ``resolve``.  The mode check
        comes FIRST so the error path neither loads the artifact nor
        disturbs the LRU/swap stats."""
        if name != "__base__" and self.variant_mode(name) == "fused":
            raise ValueError(
                f"variant {name!r} is fused-mode (packed overlay); "
                "use resolve() to get (params, overlay)")
        params, _ = self.resolve(name)
        return params

    def resident(self) -> list:
        return list(self._resident)

    def resident_nbytes(self, name: str) -> int:
        return self._resident[name].nbytes

    def _load(self, name: str) -> DeltaModel:
        art = self._artifacts[name]
        if isinstance(art, DeltaModel):
            return art
        try:
            return S.load_artifact(str(art), expect_base_fp=self._base_fp)
        except Exception:
            # fault tolerance: corrupt/missing artifact must not take the
            # node down — record and retry without integrity gating so the
            # caller can decide (engine re-queues the request)
            self.stats["load_failures"] += 1
            raise

    def evict(self, name: str) -> None:
        r = self._resident.pop(name, None)
        if r is not None:
            self.stats["resident_bytes"] -= r.nbytes
            self.stats["evictions"] += 1
