"""Multi-tenant variant registry: many fine-tunes over one resident base.

The deployment story of the paper: a serving node keeps ONE base model
resident and a library of compressed delta artifacts on disk; requests
name a variant; the registry serves it under one of two residency modes
(DESIGN.md §6):

* ``dense`` — swap-then-dense: the artifact is reconstructed into a full
  materialised copy of the params (``loader.apply_artifact``).  Fastest
  steady-state matmuls, but each resident variant costs a full model of
  HBM, so ``max_resident`` stays small.
* ``fused`` — on-the-fly: the variant stays PACKED on device as a delta
  overlay (``loader.device_put_overlay``); forward fuses it into each
  GEMM.  ~1/16 the resident bytes of a dense copy, so ``max_resident``
  can grow ~10× on the same budget and cold-start skips reconstruction.

``resolve(name)`` returns ``(params, overlay)`` — overlay is None for the
base and for dense residents.  Modes mix freely in one registry (default
from the constructor, per-variant override at ``register``).

For MIXED-VARIANT batches (the continuous-batching scheduler,
serving/engine.py) the registry additionally maintains an
:class:`OverlayBank`: fused residents stacked along a leading bank axis,
slot 0 reserved for the base, with pin/unpin guarding in-flight variants
and slot reuse on eviction.  ``bank_resolve(name)`` admits a variant and
returns its slot index — the per-batch-row ``variant_idx`` the banked
kernels consume (DESIGN.md §9).

Variants are VERSIONED (DESIGN.md §10): residents and bank slots are
keyed per version (``name@vN``), ``set_version`` atomically moves the
serving pointer (hot-swap), ``rollback`` moves it back in constant time.
Requests address a plain name (current version at admission) or an
explicit ``name@vN``.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import threading
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import compile_cache as CCm
from repro.core import loader as L
from repro.core import store as S
from repro.core.calibration import DeltaModel, flatten_params
from repro.models import delta_overlay as DO


def _bank_write(flat: dict, deltas: dict, extras: dict, slot, *,
                vec_dtype) -> dict:
    """Write one variant into bank slot ``slot`` as a SINGLE compiled
    update: canonicalise every DeltaEntry (fp16 axis vectors, zeroed
    unselected axis), fp16-round every extras leaf, and scatter them at
    the slot index.  One dispatch per admission instead of a few hundred
    eager ``.at[].set`` calls — cold-admit latency is part of TTFT.

    Jitted per bank (below) with the bank dict DONATED: admission updates
    the resident bank in place instead of doubling its HBM footprint, and
    on a mesh the out_shardings pin every leaf to its derived placement so
    the scatter runs shard-local (the bank axis is replicated — each
    device updates its own weight-tile's slot, no collectives)."""
    out = dict(flat)
    for path, e in deltas.items():
        ent = DO.from_delta_entry(e, vec_dtype=vec_dtype)
        bank = flat[path]
        idx = (slice(None),) * DO.bank_axis(path) + (slot,)
        out[path] = DO.OverlayEntry(
            packed=bank.packed.at[idx].set(ent.packed),
            v_row=bank.v_row.at[idx].set(ent.v_row.astype(bank.v_row.dtype)),
            v_col=bank.v_col.at[idx].set(ent.v_col.astype(bank.v_col.dtype)))
    for path, v in extras.items():
        bank = flat[path]
        idx = (slice(None),) * DO.bank_axis(path) + (slot,)
        out[path] = bank.at[idx].set(
            v.astype(jnp.float16).astype(bank.dtype))
    return out


def _make_bank_write(out_shardings=None):
    """The donated admission-scatter jit — ONE place states the
    static/donation contract for both the shared single-device jit and
    the per-bank mesh jits (which differ only by out_shardings)."""
    kwargs = {} if out_shardings is None else \
        {"out_shardings": out_shardings}
    return jax.jit(_bank_write, static_argnames=("vec_dtype",),
                   donate_argnames=("flat",), **kwargs)


# shared compile cache for every single-device bank (same toy shapes across
# tests/benchmarks hit one trace); mesh banks build a per-instance jit in
# ``_ensure_tree`` because their out_shardings are bank-specific
_bank_write_jit = _make_bank_write()


class OverlayBank:
    """Stacked fused residents: one banked overlay tree whose leaves carry a
    leading bank axis of ``size`` slots (DESIGN.md §9).

    * slot 0 is the BASE: zero delta vectors (Ŵ = W_b exactly) and base
      extras — ``variant_idx == 0`` means "serve this row from the base";
    * slots 1..size-1 hold fused variants (packed masks, fp16 axis vectors,
      fp16-rounded extras), admitted/evicted with slot reuse;
    * pinned variants (in-flight requests) are never evicted — ``evict``
      raises and LRU pressure skips them.

    The bank is allocated at full size on first admit; resident-byte
    accounting is therefore per-bank, not per-variant — ``nbytes()`` is the
    device footprint the registry reports (``per_device_nbytes()`` breaks
    it down by device on a mesh).

    With ``mesh`` + ``param_axes`` every banked leaf is allocated as a
    SHARDED array on its derived placement (delta_overlay.overlay_shardings
    — weight-axis sharded tiles, replicated bank axis) and admission runs
    as one jitted donated scatter whose out_shardings keep the bank in
    place (DESIGN.md §11).

    POD-LOCAL banks (``pods > 1``, DESIGN.md §17): the bank axis grows to
    ``pods * size`` slots and shards over the mesh's "pod" axis — pod p
    owns the GLOBAL slot range [p*size, (p+1)*size), its base slot is
    p*size, and an admission scatter lands only on pod p's devices.  Slot
    table, pin set, LRU and free list are all kept PER POD, so two pods
    admit (and evict) independently; every slot index this class returns
    is the GLOBAL index the banked kernels consume (the shard_map dispatch
    translates it to the pod-local slot, kernels/dispatch.py).
    """

    def __init__(self, base_params, size: int, *, vec_dtype=jnp.float16,
                 mesh=None, param_axes=None, rules=None,
                 compile_cache=None, pods: int = 1):
        if size < 2:
            raise ValueError("bank needs >= 2 slots (base + 1 variant)")
        if mesh is not None and param_axes is None:
            raise ValueError("a sharded bank needs param_axes (from "
                             "models.param.split) alongside the mesh")
        if pods < 1:
            raise ValueError("pods must be >= 1")
        self.size = size                    # slots PER POD (incl. base)
        self.pods = pods
        self.total_slots = size * pods      # bank-axis length
        self.vec_dtype = vec_dtype
        self.mesh = mesh
        self._param_axes = param_axes
        # pods the MESH spans (1 without a "pod" axis) — the replication
        # count of a globally-replicated bank, hence the cross-pod term of
        # the admission byte accounting below
        self._mesh_pods = 1
        if mesh is not None:
            from repro.distributed.sharding import _axis_size
            self._mesh_pods = _axis_size(mesh, "pod") or 1
        if pods > 1 and pods != self._mesh_pods:
            raise ValueError(
                f"pod-local bank with pods={pods} needs a mesh whose "
                f"'pod' axis has that size (mesh spans {self._mesh_pods})")
        if rules is None and mesh is not None:
            from repro.distributed.sharding import rules_for
            rules = rules_for("decode", pod_banks=pods > 1)
        self._rules = rules
        self.shardings: Optional[dict] = None   # path -> leaf shardings
        self._base_flat = flatten_params(base_params)
        self._flat: Optional[dict] = None   # path -> banked leaf
        self.tree: Optional[dict] = None    # nested view of _flat
        # admission scatter staged through the persistent compile cache:
        # the first admit after a restart is on the restart-to-first-
        # token path, so its compile is worth a deserialize too
        self._cc = compile_cache
        self._write = self._staged_write(_bank_write_jit)
        # per-pod residency state; LOCAL slot ids (0 = the pod's base)
        self._pod_slots: list = [dict() for _ in range(pods)]
        self._pins: list = [dict() for _ in range(pods)]
        self._lru: list = [collections.OrderedDict() for _ in range(pods)]
        self._free: list = [list(range(size - 1, 0, -1))
                            for _ in range(pods)]   # pop() -> lowest slot
        # variants mid-ingest on the admission pipeline: not yet in a slot,
        # but eviction/rollback must see them (DESIGN.md §13).  Keyed
        # (pod, vkey) — per-pod tickets admit the same version into two
        # pods concurrently (DESIGN.md §17)
        self._staging: set = set()
        self.stats = {"admits": 0, "evictions": 0,
                      # layout-derived admission traffic split: one
                      # payload copy lands in the admitting pod; a
                      # globally-replicated bank writes (mesh_pods - 1)
                      # more copies across the pod interconnect, a
                      # pod-sharded bank writes none
                      "admit_bytes_in_pod": 0,
                      "admit_bytes_cross_pod": 0}

    @property
    def _slots(self) -> dict:
        """Back-compat merged view: {vkey -> GLOBAL slot} across pods
        (``vkey in bank._slots`` predates per-pod tables)."""
        out: dict = {}
        for p, table in enumerate(self._pod_slots):
            for name, local in table.items():
                out.setdefault(name, self._global(p, local))
        return out

    def _global(self, pod: int, local: int) -> int:
        return pod * self.size + local

    def base_slot(self, pod: int = 0) -> int:
        """GLOBAL slot serving base semantics for ``pod`` (slot p*size —
        all-zero delta + base extras, never admitted or evicted)."""
        return pod * self.size

    def _staged_write(self, jitted, *, sh_fp: bool = False):
        """Route the admission-scatter jit through the compile cache with
        ``vec_dtype`` baked as its static; no cache attached → plain jit."""
        parts = ("bank-write", self.size, self.pods, CCm.mesh_fp(self.mesh),
                 CCm.sharding_fp(self.shardings) if sh_fp else "none")
        wrapped = CCm.CachedCallable(
            jitted, parts,
            cache=self._cc if self._cc is not None else "ambient")
        return functools.partial(wrapped, vec_dtype=self.vec_dtype)

    # -- structure ---------------------------------------------------------
    def _ensure_tree(self, dm: DeltaModel) -> None:
        if self._flat is not None:
            if set(dm.deltas) != self._template_deltas or \
                    set(dm.extras) != self._template_extras:
                raise ValueError(
                    "variant structure differs from the bank template "
                    "(all banked variants must share one calibration "
                    "recipe)")
            return
        flat = {}
        for path, e in dm.deltas.items():
            ent = DO.from_delta_entry(e, vec_dtype=self.vec_dtype)
            flat[path] = DO.bank_zeros(path, ent, self.total_slots)
        for path in dm.extras:
            flat[path] = DO.bank_extra_base(path, self._base_flat[path],
                                            self.total_slots)
        if self.mesh is not None:
            self.shardings = DO.overlay_shardings(
                self._param_axes, self._base_flat, sorted(dm.deltas),
                sorted(dm.extras), self._rules, self.mesh,
                bank_size=self.total_slots)
            flat = {path: jax.device_put(leaf, self.shardings[path])
                    for path, leaf in flat.items()}
            self._write = self._staged_write(
                _make_bank_write(out_shardings=self.shardings),
                sh_fp=True)
        self._flat = flat
        self._template_deltas = set(dm.deltas)
        self._template_extras = set(dm.extras)
        self._rebuild()

    def _rebuild(self) -> None:
        tree: dict = {}
        for path, leaf in self._flat.items():
            DO.insert_entry(tree, path, leaf)
        self.tree = tree

    # -- lifecycle ---------------------------------------------------------
    def holds(self, name: str, pod: Optional[int] = None) -> bool:
        """Variant resident in ``pod`` (any pod when None)."""
        if pod is not None:
            return name in self._pod_slots[pod]
        return any(name in t for t in self._pod_slots)

    def pods_holding(self, name: str) -> list:
        """Pods where ``name`` is bank-resident — the affinity router's
        steering signal (serving/engine.py)."""
        return [p for p, t in enumerate(self._pod_slots) if name in t]

    def slot_of(self, name: str, pod: int = 0) -> int:
        if name == "__base__":
            return self.base_slot(pod)
        return self._global(pod, self._pod_slots[pod][name])

    def resident(self, pod: Optional[int] = None) -> list:
        if pod is not None:
            return list(self._lru[pod])
        seen: dict = {}
        for lru in self._lru:
            for name in lru:
                seen.setdefault(name, None)
        return list(seen)

    def pod_resident(self) -> dict:
        """{pod -> [resident vkeys]} — status()['hbm'] observability."""
        return {p: list(lru) for p, lru in enumerate(self._lru)}

    def has_capacity(self, pod: int = 0) -> bool:
        """A new variant can be admitted into ``pod``: a free slot exists
        or some resident is unpinned (evictable).  Lets callers refuse
        BEFORE paying the artifact load."""
        return bool(self._free[pod]) or any(
            self._pins[pod].get(c, 0) == 0 for c in self._lru[pod])

    def admit(self, name: str, dm: DeltaModel,
              pod: int = 0) -> tuple[int, int]:
        """Place ``dm`` into a slot of ``pod`` (reusing evicted slots,
        evicting the pod's LRU unpinned resident when full).  Returns
        (GLOBAL slot, payload_bytes)."""
        if name == "__base__":
            return self.base_slot(pod), 0
        table = self._pod_slots[pod]
        if name in table:
            self._lru[pod].move_to_end(name)
            return self._global(pod, table[name]), 0
        self._ensure_tree(dm)
        if not self._free[pod]:
            for cand in self._lru[pod]:
                if self._pins[pod].get(cand, 0) == 0:
                    # slot is reassigned immediately: skip the device-side
                    # clear (admit overwrites every leaf of the slot)
                    self._release(cand, pod, clear=False)
                    break
            else:
                raise RuntimeError(
                    f"overlay bank (pod {pod}) full: every resident is "
                    "pinned by an in-flight request")
        local = self._free[pod].pop()
        gslot = self._global(pod, local)
        payload = sum(int(e.packed.size) + 2 * int(e.v_row.size)
                      + 2 * int(e.v_col.size) for e in dm.deltas.values())
        payload += sum(2 * int(v.size) for v in dm.extras.values())
        self._flat = self._write(self._flat, dict(dm.deltas),
                                 dict(dm.extras), jnp.int32(gslot))
        table[name] = local
        self._lru[pod][name] = None
        self.stats["admits"] += 1
        # layout-derived traffic: a pod-sharded bank axis puts the slot on
        # exactly one pod; replicated puts a copy on every mesh pod
        copies = 1 if self.pods > 1 else self._mesh_pods
        self.stats["admit_bytes_in_pod"] += payload
        self.stats["admit_bytes_cross_pod"] += payload * (copies - 1)
        self._rebuild()
        return gslot, payload

    def admit_async(self, name: str, dm: DeltaModel, pod: int = 0):
        """``admit`` without the caller-side device fence: returns
        ``(slot, payload_bytes, fence)`` where ``fence()`` blocks until
        the admission scatter has landed.  The async admission pipeline
        dispatches the scatter between decode steps and lets jax data
        dependencies order the next decode after it — the fence is only
        for callers (tests, stats) that need a wall-clock boundary."""
        slot, payload = self.admit(name, dm, pod)
        leaves = jax.tree.leaves(self.tree) if self.tree is not None else []
        if leaves:
            def fence(leaf=leaves[0]):
                jax.block_until_ready(leaf)
        else:
            def fence():
                return None
        return slot, payload, fence

    # -- staging marks (async admission pipeline, DESIGN.md §13) -----------
    def mark_staging(self, name: str, pod: int = 0) -> None:
        self._staging.add((pod, name))

    def unmark_staging(self, name: str, pod: int = 0) -> None:
        self._staging.discard((pod, name))

    def staging(self, name: str, pod: Optional[int] = None) -> bool:
        if pod is not None:
            return (pod, name) in self._staging
        return any(n == name for _, n in self._staging)

    def pin(self, name: str, pod: int = 0) -> None:
        if name != "__base__":
            pins = self._pins[pod]
            pins[name] = pins.get(name, 0) + 1

    def unpin(self, name: str, pod: int = 0) -> None:
        pins = self._pins[pod]
        if name != "__base__" and name in pins:
            pins[name] = max(0, pins[name] - 1)

    def pinned(self, name: str, pod: Optional[int] = None) -> bool:
        if pod is not None:
            return self._pins[pod].get(name, 0) > 0
        return any(p.get(name, 0) > 0 for p in self._pins)

    def evict(self, name: str, pod: Optional[int] = None) -> None:
        """Free ``name``'s slot in ``pod`` (every holding pod when None)
        for reuse; refuses while the variant is pinned (mid-flight
        requests reference its slot index) or still staging on the
        admission pipeline (its slot does not exist yet — evicting
        mid-ingest would race the commit)."""
        pods = [pod] if pod is not None else self.pods_holding(name)
        if self.staging(name, pod):
            raise RuntimeError(
                f"variant {name!r} is staging on the admission pipeline; "
                "wait for the admission to land before evicting")
        for p in pods:
            if name in self._pod_slots[p] and self.pinned(name, p):
                raise RuntimeError(
                    f"variant {name!r} is pinned by in-flight requests "
                    f"(pod {p}); retire them before evicting")
        for p in pods:
            if name in self._pod_slots[p]:
                self._release(name, p, clear=True)

    def _release(self, name: str, pod: int, *, clear: bool) -> None:
        """Drop a resident from ``pod`` and recycle its slot.
        ``clear=False`` skips the device-side zeroing — correct when the
        slot is reassigned in the same admit (every leaf overwritten), and
        it keeps the eviction-under-pressure path off the eager per-leaf
        updates ``_bank_write`` exists to avoid."""
        local = self._pod_slots[pod].pop(name)
        gslot = self._global(pod, local)
        self._lru[pod].pop(name, None)
        self._pins[pod].pop(name, None)
        if clear:
            for path in self._template_deltas:
                self._flat[path] = DO.bank_clear_entry(
                    path, self._flat[path], gslot)
            for path in self._template_extras:
                self._flat[path] = DO.bank_set_extra_base(
                    path, self._flat[path], gslot, self._base_flat[path])
            self._rebuild()
        self._free[pod].append(local)
        self.stats["evictions"] += 1

    def nbytes(self) -> int:
        if self._flat is None:
            return 0
        return DO.overlay_nbytes(self._flat)

    def per_device_nbytes(self) -> dict:
        """{device -> resident bank bytes} from the actual shard layout —
        the capacity-planning number on a mesh (each device holds its
        weight-tile's slice of every slot plus the replicated vectors;
        under pod-local rules only its own pod's slot range)."""
        out: dict = {}
        if self._flat is None:
            return out
        for leaf in jax.tree.leaves(self._flat):
            for shard in leaf.addressable_shards:
                key = str(shard.device)
                out[key] = out.get(key, 0) + (
                    shard.data.size * shard.data.dtype.itemsize)
        return out

    def _device_pod(self) -> dict:
        """{device str -> pod index} from the mesh layout ({} without a
        pod axis — everything is pod 0)."""
        if self.mesh is None or "pod" not in self.mesh.axis_names:
            return {}
        import numpy as np
        ax = self.mesh.axis_names.index("pod")
        out: dict = {}
        for idx in np.ndindex(self.mesh.devices.shape):
            out[str(self.mesh.devices[idx])] = idx[ax]
        return out

    def per_pod_nbytes(self) -> dict:
        """{pod -> resident bank bytes} — per_device_nbytes rolled up by
        the mesh's pod coordinate (status()['hbm'], DESIGN.md §17).  A
        pod-sharded bank shows each pod holding only its slot range; a
        replicated bank shows the full footprint in every pod."""
        dev_pod = self._device_pod()
        out: dict = {}
        for dev, nbytes in self.per_device_nbytes().items():
            p = dev_pod.get(dev, 0)
            out[p] = out.get(p, 0) + nbytes
        return out


@dataclasses.dataclass
class _Resident:
    params: object
    overlay: Optional[dict]        # None => dense materialisation
    nbytes: int                    # HBM added on top of the resident base


_MISSING = object()


class VariantRegistry:
    """Versioned serving-side variant table.

    Every variant is a lineage of VERSIONS with one CURRENT pointer — the
    serving pointer.  Residents (dense copies, fused overlays) and bank
    slots are keyed by version key ``name@vN`` (plain ``name`` for
    unversioned back-compat registrations), so two versions of one variant
    coexist on device during a hot-swap: in-flight requests finish on the
    version they pinned while new admissions resolve through the moved
    pointer.  ``set_version`` IS the hot-swap; ``rollback`` is the same
    pointer move in reverse, and usually re-admits as a bank/LRU hit
    because stale versions are left resident (unpinned) until capacity
    pressure reuses their slots."""

    def __init__(self, base_params, *, param_shardings=None,
                 max_resident: int = 2, use_kernel: bool = True,
                 mode: str = "dense", bank_size: int = 8,
                 mesh=None, param_axes=None, base_dtype: str = "fp",
                 pod_banks: bool = False):
        if mode not in ("dense", "fused"):
            raise ValueError(f"unknown residency mode {mode!r}")
        if base_dtype not in ("fp", "int8"):
            raise ValueError(f"unknown base dtype {base_dtype!r}")
        # pod-local overlay banks (DESIGN.md §17): the bank's slot space
        # splits per pod of the mesh's "pod" axis; off (the default) keeps
        # the globally-replicated bank — the A/B baseline
        self.pod_banks = pod_banks
        self.pods = 1
        if pod_banks:
            if mesh is None:
                raise ValueError(
                    "pod_banks=True needs a mesh with a 'pod' axis "
                    "(launch.mesh.make_host_mesh(pod=...))")
            from repro.distributed.sharding import _axis_size
            p = _axis_size(mesh, "pod")
            if p is None:
                raise ValueError(
                    "pod_banks=True but the mesh has no 'pod' axis")
            self.pods = p
        # fingerprint and dense-copy accounting come from the FP base —
        # artifacts are calibrated against (and verified by) the full-
        # precision weights, and a dense resident reconstructs to fp
        self._base_fp = S.base_fingerprint(base_params)
        self._dense_nbytes = sum(
            leaf.size * leaf.dtype.itemsize
            for leaf in jax.tree.leaves(base_params))
        self.base_dtype = base_dtype
        self.quant_stats = None
        if base_dtype == "int8":
            from repro.core import quantize as Q
            base_params, qsh, self.quant_stats = Q.quantize_base(
                base_params, param_shardings)
            if qsh is not None:
                base_params = jax.device_put(base_params, qsh)
                param_shardings = qsh
        self.base_params = base_params
        self.param_shardings = param_shardings
        self.mesh = mesh
        self.param_axes = param_axes
        self.use_kernel = use_kernel
        self.max_resident = max_resident
        self.mode = mode
        self.bank_size = bank_size
        self.bank: Optional[OverlayBank] = None   # created on first use
        # serving thread and the admission ingest worker both touch the
        # bank lazily — creation must be raced-once (DESIGN.md §13)
        self._bank_lock = threading.Lock()
        # attached by serving/api.Deployment when async admission is on;
        # evict/rollback consult it for mid-ingest variants
        self.admission = None
        # lazy-hydration hook (serving/api.Deployment): called with a
        # base variant name when _parse misses; True -> retry the parse
        self.hydrator = None
        # optional core/compile_cache.CompileCache for the bank's
        # admission-scatter executable (None -> process-ambient default)
        self.compile_cache = None
        self._bank_evictions_seen = 0
        self._versions: dict[str, dict] = {}   # name -> {version: artifact}
        self._current: dict[str, Optional[int]] = {}   # serving pointer
        self._modes: dict[str, str] = {}          # per-variant override
        self._resident: "collections.OrderedDict[str, _Resident]" = \
            collections.OrderedDict()
        self.stats = {"swaps": 0, "hits": 0, "swap_seconds": 0.0,
                      "transferred_bytes": 0, "load_failures": 0,
                      "resident_bytes": 0, "evictions": 0}

    @property
    def base_fp(self) -> str:
        return self._base_fp

    # -- base residency accounting -----------------------------------------
    def base_nbytes(self) -> int:
        """Total resident base-weight bytes (int8 payloads + scales when
        quantized — QuantWeight leaves flatten to both)."""
        return sum(int(leaf.size) * leaf.dtype.itemsize
                   for leaf in jax.tree.leaves(self.base_params))

    def base_per_device_nbytes(self) -> dict:
        """{device -> resident base-weight bytes} from the actual shard
        layout — the companion to ``OverlayBank.per_device_nbytes`` so
        status() reports base HBM next to bank HBM (DESIGN.md §16).
        Host (numpy) leaves are charged to the default device."""
        out: dict = {}
        for leaf in jax.tree.leaves(self.base_params):
            shards = getattr(leaf, "addressable_shards", None)
            if shards:
                for shard in shards:
                    key = str(shard.device)
                    out[key] = out.get(key, 0) + (
                        shard.data.size * shard.data.dtype.itemsize)
            else:
                key = str(jax.devices()[0])
                out[key] = out.get(key, 0) + (
                    int(leaf.size) * leaf.dtype.itemsize)
        return out

    # -- names and versions ------------------------------------------------
    def _parse(self, nameish: str) -> tuple:
        """Resolve a request-facing variant string to (name, version):
        a plain name follows the current serving pointer; an explicit
        ``name@vN`` pins that version regardless of the pointer.

        Unknown names consult the ``hydrator`` hook once before raising
        — serving/api.Deployment installs it under LAZY restart
        hydration, so a store-backed name (or an unregistered version of
        a known name) registers its persisted lineage on first
        reference instead of at construction."""
        try:
            return self._parse_known(nameish)
        except KeyError:
            if self.hydrator is None:
                raise
            base = nameish.rpartition("@v")[0] if "@v" in nameish \
                else nameish
            if not self.hydrator(base):
                raise
            return self._parse_known(nameish)

    def _parse_known(self, nameish: str) -> tuple:
        if nameish == "__base__" or nameish in self._versions:
            return nameish, self._current.get(nameish)
        if "@v" in nameish:
            name, _, tail = nameish.rpartition("@v")
            if name in self._versions and tail.isdigit() \
                    and int(tail) in self._versions[name]:
                return name, int(tail)
        raise KeyError(f"unknown variant {nameish!r}")

    @staticmethod
    def _vkey(name: str, version) -> str:
        """Device-residency key: residents and bank slots are PER VERSION."""
        return name if version is None else f"{name}@v{version}"

    # -- registration ------------------------------------------------------
    def register(self, name: str, artifact, mode: Optional[str] = None
                 ) -> None:
        """Back-compat unversioned registration: artifact is a directory
        path (lazy-loaded) or a DeltaModel; ``mode`` overrides the registry
        default for this variant.  Versioned lifecycles use
        ``set_version`` (typically via serving/api.Deployment)."""
        self.set_version(name, None, artifact, mode=mode)

    def set_version(self, name: str, version, artifact=None,
                    mode: Optional[str] = None):
        """Register ``artifact`` under (name, version) if given, then
        atomically move the serving pointer: THIS is publish/update/
        rollback at the registry level.  Resolutions and admissions after
        this call serve ``version``; in-flight requests keep decoding the
        version they pinned.  The previous version's dense/fused resident
        is dropped (its HBM frees now); its bank slot is left as an
        unpinned LRU resident so rolling back re-admits as a hit.

        artifact: directory path, DeltaModel, or a zero-arg callable
        returning a DeltaModel (lazy store materialisation)."""
        if mode is not None:
            if mode not in ("dense", "fused"):
                raise ValueError(f"unknown residency mode {mode!r}")
            self._modes[name] = mode
        vers = self._versions.setdefault(name, {})
        if artifact is not None:
            vers[version] = artifact
        elif version not in vers:
            raise KeyError(
                f"variant {name!r} has no registered version {version}")
        prev = self._current.get(name, _MISSING)
        self._current[name] = version
        if prev is not _MISSING and prev != version:
            old_key = self._vkey(name, prev)
            r = self._resident.pop(old_key, None)
            if r is not None:
                self.stats["resident_bytes"] -= r.nbytes
                self.stats["evictions"] += 1
        return version

    def rollback(self, name: str, to_version=None):
        """Constant-time pointer move to an already-registered version
        (default: the highest version id below the current pointer)."""
        if name not in self._versions:
            raise KeyError(f"unknown variant {name!r}")
        if to_version is None:
            cur = self._current.get(name)
            older = [v for v in self._versions[name]
                     if v is not None and (cur is None or v < cur)]
            if not older:
                raise ValueError(
                    f"variant {name!r} has no version below {cur}")
            to_version = max(older)
        return self.set_version(name, to_version)

    def registered(self) -> list:
        return ["__base__"] + sorted(self._versions)

    def versions(self, name: str) -> list:
        if name not in self._versions:
            raise KeyError(f"unknown variant {name!r}")
        return sorted(v for v in self._versions[name] if v is not None)

    def current_version(self, nameish: str):
        """Version the serving pointer (or an explicit ``name@vN``)
        resolves to right now; None for the base and unversioned
        registrations."""
        return self._parse(nameish)[1]

    def next_version(self, name: str) -> int:
        """Next monotonic version id for ``name`` (1 for a fresh name;
        rollbacks never reuse ids)."""
        known = [v for v in self._versions.get(name, {}) if v is not None]
        return max(known, default=0) + 1

    def has_variant(self, name: str) -> bool:
        return name in self._versions

    def variant_mode(self, nameish: str) -> str:
        name = self._parse(nameish)[0] if nameish != "__base__" else nameish
        return self._modes.get(name, self.mode)

    # -- resolution --------------------------------------------------------
    def resolve(self, nameish: str):
        """(params, overlay) for a variant's CURRENT version (or an
        explicit ``name@vN``), LRU-cached on device per version key;
        '__base__' serves the resident base (overlay None)."""
        if nameish == "__base__":
            return self.base_params, None
        name, version = self._parse(nameish)
        vkey = self._vkey(name, version)
        if vkey in self._resident:
            self._resident.move_to_end(vkey)
            self.stats["hits"] += 1
            r = self._resident[vkey]
            return r.params, r.overlay
        dm = self._load(name, version)
        if self.variant_mode(name) == "fused":
            params, overlay, st = L.device_put_overlay(
                self.base_params, dm, param_shardings=self.param_shardings)
            nbytes = L.fused_resident_bytes(self.base_params, params, overlay)
        else:
            # dense reconstruction under a mesh runs inside the serve-rule
            # shard_ctx so the unpack kernel lowers per-shard for
            # unstacked weights (kernels/dispatch.py; stacked entries
            # stay on the vmapped global kernel)
            import contextlib

            from repro.distributed.sharding import rules_for, shard_ctx
            ctx = (shard_ctx(self.mesh, rules_for("decode"))
                   if self.mesh is not None else contextlib.nullcontext())
            with ctx:
                params, st = L.apply_artifact(
                    self.base_params, dm,
                    param_shardings=self.param_shardings,
                    param_axes=self.param_axes,
                    use_kernel=self.use_kernel)
            overlay, nbytes = None, self._dense_nbytes
        self.stats["swaps"] += 1
        self.stats["swap_seconds"] += st["seconds"]
        self.stats["transferred_bytes"] += st["transferred_bytes"]
        resident = _Resident(params, overlay, nbytes)
        self._resident[vkey] = resident
        self.stats["resident_bytes"] += nbytes
        while len(self._resident) > self.max_resident:
            _, evicted = self._resident.popitem(last=False)   # evict LRU
            self.stats["resident_bytes"] -= evicted.nbytes
            self.stats["evictions"] += 1
        # serve from the local handle: max_resident=0 (cache-nothing) may
        # have evicted the entry we just built
        return resident.params, resident.overlay

    def params_for(self, name: str):
        """Back-compat dense accessor: materialised params for a variant.
        Raises for fused-mode variants — use ``resolve``.  The mode check
        comes FIRST so the error path neither loads the artifact nor
        disturbs the LRU/swap stats."""
        if name != "__base__" and self.variant_mode(name) == "fused":
            raise ValueError(
                f"variant {name!r} is fused-mode (packed overlay); "
                "use resolve() to get (params, overlay)")
        params, _ = self.resolve(name)
        return params

    # -- banked resolution (mixed-variant batches) -------------------------
    def _ensure_bank(self) -> OverlayBank:
        """Lazily create the overlay bank, raced-once: the serving thread
        (bank_resolve) and the admission ingest worker (mark_staging at
        enqueue) may both arrive first."""
        with self._bank_lock:
            if self.bank is None:
                self.bank = OverlayBank(self.base_params, self.bank_size,
                                        mesh=self.mesh,
                                        param_axes=self.param_axes,
                                        compile_cache=self.compile_cache,
                                        pods=self.pods)
            return self.bank

    def _bank_admit(self, vkey: str, dm: DeltaModel, *,
                    block: bool = True, pod: int = 0) -> int:
        """Scatter ``dm`` into the bank under ``vkey`` and book the swap
        stats (one shared path for synchronous bank_resolve and the async
        admission pipeline's commit).  ``block=False`` skips the device
        fence — the scatter is dispatched and jax data dependencies order
        the next decode step after it, so the serving thread never waits
        on the copy."""
        bank = self._ensure_bank()
        before = bank.nbytes()
        t0 = time.perf_counter()
        slot, payload, fence = bank.admit_async(vkey, dm, pod)
        if block:
            fence()
        self.stats["swaps"] += 1
        self.stats["swap_seconds"] += time.perf_counter() - t0
        self.stats["transferred_bytes"] += payload
        self.stats["resident_bytes"] += bank.nbytes() - before
        self.stats["evictions"] += (bank.stats["evictions"]
                                    - self._bank_evictions_seen)
        self._bank_evictions_seen = bank.stats["evictions"]
        return slot

    def bank_resolve(self, nameish: str, pod: int = 0) -> int:
        """Admit the CURRENT version of ``nameish`` (or an explicit
        ``name@vN``) into ``pod``'s slot range of the overlay bank
        (created on demand) and return its GLOBAL bank slot index — the
        per-row ``variant_idx`` value.  '__base__' is pod's base slot
        (slot 0 for a global bank).  Swap/residency stats migrate to the
        bank: ``resident_bytes`` tracks the bank allocation (charged when
        the bank grows, not per admitted variant)."""
        bank = self._ensure_bank()
        if nameish == "__base__":
            return bank.base_slot(pod)
        name, version = self._parse(nameish)
        vkey = self._vkey(name, version)
        if bank.holds(vkey, pod):
            self.stats["hits"] += 1
            return bank.admit(vkey, None, pod)[0]  # LRU touch, no payload
        if bank.tree is not None and not bank.has_capacity(pod):
            # refuse BEFORE the disk load: a fully-pinned bank would
            # otherwise re-read + re-verify the artifact every scheduler
            # step while waiting for a retirement to free a pin
            raise RuntimeError(
                f"overlay bank (pod {pod}) full: every resident is pinned "
                "by an in-flight request")
        dm = self._load(name, version)
        return self._bank_admit(vkey, dm, block=True, pod=pod)

    def bank_acquire(self, nameish: str, pod: int = 0) -> tuple:
        """Admit AND pin in one step: returns (slot, version_key).  The
        caller unpins with the returned KEY, not the request's variant
        name — the serving pointer may move while the request is in
        flight (hot-swap), and the pin must stay on the version the
        request is actually decoding."""
        slot = self.bank_resolve(nameish, pod)
        vkey = "__base__" if nameish == "__base__" \
            else self._vkey(*self._parse(nameish))
        self.bank.pin(vkey, pod)
        return slot, vkey

    def bank_pods_holding(self, nameish: str) -> list:
        """Pods where the variant's CURRENT version is bank-resident —
        the affinity router's steering signal (empty when unadmitted or
        no bank yet)."""
        if self.bank is None:
            return []
        return self.bank.pods_holding(self._bank_key(nameish))

    def spec_resolve(self) -> tuple:
        """The speculative scheduler's weight resolution (DESIGN.md §15):
        (draft_params, verify_bank).  Drafting serves the BASE — bank
        slot 0's semantics — through the shared base params with overlay
        None (the plain-XLA path: a draft step must not pay the banked
        kernel it exists to amortise); verification serves every lane's
        variant through the SAME overlay bank and per-row variant_idx the
        continuous scheduler decodes with, so admission, pinning,
        hot-swap and rollback behave identically under both schedulers.
        ``verify_bank`` is None until the first variant admission (the
        base-only traffic regime, matching the engine's banked-empty
        executables)."""
        return self.base_params, (self.bank.tree if self.bank else None)

    def _bank_key(self, nameish: str) -> str:
        """Map a caller-facing name to its bank/resident key: version keys
        and unversioned names pass through; plain names of versioned
        variants follow the serving pointer."""
        if nameish == "__base__":
            return nameish
        if self.bank is not None and nameish in self.bank._slots:
            return nameish
        if nameish in self._resident:
            return nameish
        try:
            return self._vkey(*self._parse(nameish))
        except KeyError:
            return nameish

    def bank_pin(self, nameish: str, pod: int = 0) -> None:
        if self.bank is not None:
            self.bank.pin(self._bank_key(nameish), pod)

    def bank_unpin(self, nameish: str, pod: int = 0) -> None:
        if self.bank is not None:
            self.bank.unpin(self._bank_key(nameish), pod)

    def resident(self) -> list:
        return list(self._resident)

    def resident_nbytes(self, nameish: str) -> int:
        return self._resident[self._bank_key(nameish)].nbytes

    def _load(self, name: str, version=None, pacer=None) -> DeltaModel:
        art = self._versions[name][version]
        if isinstance(art, DeltaModel):
            return art
        try:
            if callable(art):
                # lazy store materialisation; pacing callables advertise
                # themselves (Deployment._store_ref) — arbitrary user
                # callables keep the plain zero-arg contract
                if pacer is not None and getattr(art, "accepts_pacer",
                                                 False):
                    return art(pacer=pacer)
                return art()
            return S.load_artifact(str(art), expect_base_fp=self._base_fp,
                                   pacer=pacer)
        except Exception:
            # fault tolerance: corrupt/missing artifact must not take the
            # node down — record and retry without integrity gating so the
            # caller can decide (engine re-queues the request)
            self.stats["load_failures"] += 1
            raise

    def evict(self, nameish: str) -> None:
        """Evict a variant's device residency by name (current version),
        explicit ``name@vN``, or raw version key."""
        key = self._bank_key(nameish)
        # staging/pin checks FIRST: refusing a mid-ingest or pinned
        # (mid-flight) banked variant must not half-evict — the dense
        # resident and stats stay intact
        if self.bank is not None and self.bank.staging(key):
            raise RuntimeError(
                f"variant {key!r} is staging on the admission pipeline; "
                "wait for the admission to land before evicting")
        if self.bank is not None and self.bank.pinned(key):
            raise RuntimeError(
                f"variant {key!r} is pinned by in-flight requests; "
                "retire them before evicting")
        r = self._resident.pop(key, None)
        if r is not None:
            self.stats["resident_bytes"] -= r.nbytes
            self.stats["evictions"] += 1
        if self.bank is not None and key in self.bank._slots:
            # bank bytes stay allocated — the slot is reusable, not freed;
            # a pod-local bank may hold the key in several pods: evict
            # releases every holding pod's slot
            before = self.bank.stats["evictions"]
            self.bank.evict(key)
            self.stats["evictions"] += self.bank.stats["evictions"] - before
            self._bank_evictions_seen = self.bank.stats["evictions"]
