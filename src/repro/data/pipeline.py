"""Deterministic data pipeline: synthetic token streams + calibration sets.

No external corpora ship offline, so the pipeline generates *structured*
synthetic language (Zipfian unigrams + a Markov bigram mixture + copy
motifs) — enough signal that models train, fine-tunes diverge measurably,
and the paper's calibration procedure has realistic activations to match
(C4 stand-in; DESIGN.md §8).

Deterministic: every batch is a pure function of (seed, step), so a
restarted job resumes mid-epoch without data skew — the fault-tolerance
contract checkpointing relies on.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    """Zipf + Markov synthetic language over a given vocab."""
    vocab_size: int
    seed: int = 0
    zipf_a: float = 1.3
    motif_len: int = 8

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))

    def _zipf_probs(self) -> np.ndarray:
        ranks = np.arange(1, self.vocab_size + 1, dtype=np.float64)
        p = ranks ** -self.zipf_a
        return p / p.sum()

    def sample(self, step: int, batch: int, seq_len: int) -> np.ndarray:
        """(batch, seq_len) int32 tokens; pure function of (seed, step)."""
        rng = self._rng(step)
        probs = self._zipf_probs()
        toks = rng.choice(self.vocab_size, size=(batch, seq_len), p=probs)
        # Markov-ish structure: with p=0.3 repeat of (t-1 + fixed offset)
        offs = rng.integers(1, 17)
        rep = rng.random((batch, seq_len)) < 0.3
        shifted = (np.roll(toks, 1, axis=1) + offs) % self.vocab_size
        toks = np.where(rep, shifted, toks)
        # copy motifs: short spans repeated later in the sequence
        if seq_len >= 4 * self.motif_len:
            for b in range(batch):
                src = rng.integers(0, seq_len // 2 - self.motif_len)
                dst = rng.integers(seq_len // 2, seq_len - self.motif_len)
                toks[b, dst:dst + self.motif_len] = \
                    toks[b, src:src + self.motif_len]
        return toks.astype(np.int32)

    def lm_batch(self, step: int, batch: int, seq_len: int) -> dict:
        toks = self.sample(step, batch, seq_len + 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_batch_iterator(vocab_size: int, batch: int, seq_len: int,
                        seed: int = 0, start_step: int = 0
                        ) -> Iterator[dict]:
    """Resumable batch stream (pass the restored step to resume exactly)."""
    src = SyntheticLM(vocab_size, seed)
    step = start_step
    while True:
        yield src.lm_batch(step, batch, seq_len)
        step += 1


def calib_stream(vocab_size: int, n_samples: int, seq_len: int,
                 seed: int = 1234, batch: int = 5) -> Iterator[dict]:
    """Calibration sampler: the paper's 50-sample layer cache / 150-sample
    end-to-end budget maps to n_samples sequences here."""
    src = SyntheticLM(vocab_size, seed)
    for step in range(0, max(1, n_samples // batch)):
        yield src.lm_batch(10_000 + step, batch, seq_len)
