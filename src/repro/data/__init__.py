from repro.data.pipeline import (SyntheticLM, calib_stream,  # noqa: F401
                                 make_batch_iterator)
