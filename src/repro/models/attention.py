"""Attention: GQA with chunked (flash-style) softmax, KV caches, decode.

Memory discipline: full (S×T) logits are never materialised for long
sequences — ``flash_attention`` scans over KV chunks with an online
softmax, so live memory is O(S·chunk).  Decode-time attention computes
(B,H,T) logits directly (tiny), and for sequence-sharded caches
(long_500k) relies on GSPMD turning the fp32 max/sum reductions over the
sharded T dim into the distributed two-pass flash-decode (pmax/psum)
schedule — see DESIGN.md §5.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, rmsnorm
from repro.models.param import Param, dense_init, ones_init

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def attn_init(key, cfg) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d = cfg.d_model
    p = {
        "wq": dense_init(k1, (cfg.q_dim, d), ("q_heads", "embed")),
        "wk": dense_init(k2, (cfg.kv_dim, d), ("kv_heads", "embed")),
        "wv": dense_init(k3, (cfg.kv_dim, d), ("kv_heads", "embed")),
        "wo": dense_init(k4, (d, cfg.q_dim), ("embed", "q_heads")),
    }
    if cfg.qk_norm:
        p["q_norm"] = ones_init((cfg.head_dim,), (None,))
        p["k_norm"] = ones_init((cfg.head_dim,), (None,))
    return p


def qkv_project(p: dict, x: jax.Array, cfg, positions: jax.Array,
                theta, ov=None, vidx=None
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x (B,S,D) -> q (B,S,Hq,hd), k/v (B,S,Hkv,hd), RoPE'd (if theta).

    Sharding strategy (picked by divisibility against the live mesh):
    * head-TP when num_heads divides the model axis — constrain the FLAT
      projection outputs (fused heads×head_dim over `model`); constraining
      reshaped 4D per-head tensors makes GSPMD emit involuntary
      full-rematerialisation copies when counts don't divide.
    * sequence-TP (context parallelism) otherwise (whisper hq=8,
      starcoder2 hq=24 vs a 16-way axis): shard the q sequence over
      `model`; KV is gathered chunk-wise by the flash scan.  Head-dim
      sharded contraction is never allowed — it psums full logits.
    """
    from repro.distributed.sharding import ctx_axis_size, ctx_forward_only
    from repro.distributed.sharding import logical_constraint as _lc
    from repro.models.layers import _oget, linear, psel
    b, s, _ = x.shape
    ms = ctx_axis_size("model") or 1
    q = linear(x, p["wq"], _oget(ov, "wq"), vidx, waxes=("q_heads", "embed"))
    k = linear(x, p["wk"], _oget(ov, "wk"), vidx, waxes=("kv_heads", "embed"))
    v = linear(x, p["wv"], _oget(ov, "wv"), vidx, waxes=("kv_heads", "embed"))
    if cfg.num_heads % ms == 0 and cfg.num_kv_heads % ms == 0:
        # full head-TP
        q = _lc(q, "act_batch", "act_seq", "act_heads")
        k = _lc(k, "act_batch", "act_seq", "act_heads")
        v = _lc(v, "act_batch", "act_seq", "act_heads")
    elif cfg.num_heads % ms == 0 and s > 1:
        # GQA with kv ∤ TP (qwen3 kv=8, gemma3 kv=8, internvl kv=8):
        # shard q heads, replicate K/V — ONE (B,S,kv_dim) gather per layer.
        # Sequence-TP here makes GSPMD re-gather K/V per flash chunk
        # (measured 2.7 TB/step on qwen3 train)
        q = _lc(q, "act_batch", "act_seq", "act_heads")
        k = _lc(k, "act_batch", "act_seq", None)
        v = _lc(v, "act_batch", "act_seq", None)
    elif (s % ms == 0 and s > 1
          and (ctx_forward_only() or cfg.q_dim % ms != 0)):
        # head count indivisible: sequence-TP — but ONLY for forward-only
        # workloads (prefill) or when flat-q can't shard either; under
        # autodiff GSPMD re-gathers K/V per flash chunk in the backward
        # (measured 8× on starcoder2 train)
        q = _lc(q, "act_batch", "act_seq_tp", None)
        k = _lc(k, "act_batch", "act_seq_tp", None)
        v = _lc(v, "act_batch", "act_seq_tp", None)
    elif cfg.q_dim % ms == 0 and s > 1:
        # training fallback: shard the FLAT q_dim (starcoder2 24×128=3072);
        # GSPMD multi-dim-tiles (heads, head_dim) after the reshape.
        # K/V replicated.
        q = _lc(q, "act_batch", "act_seq", "act_heads")
        k = _lc(k, "act_batch", "act_seq", None)
        v = _lc(v, "act_batch", "act_seq", None)
    # else (decode s=1 / odd lengths): unconstrained — forcing replication
    # makes GSPMD all-gather the TP-sharded weights per layer
    q = q.reshape(b, s, cfg.num_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(q, psel(p["q_norm"], _oget(ov, "q_norm"), vidx, lead=2),
                    cfg.norm_eps)
        k = rmsnorm(k, psel(p["k_norm"], _oget(ov, "k_norm"), vidx, lead=2),
                    cfg.norm_eps)
    if theta is not None:
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    return q, k, v


# ---------------------------------------------------------------------------
# flash attention (train / prefill)
# ---------------------------------------------------------------------------

def _pick_chunk(t: int, chunk: int) -> int:
    chunk = min(chunk, t)
    while t % chunk:
        chunk //= 2
    return chunk


def _mask_for(s, chunk, idx, q_pos, kv_offset, causal, window):
    k_pos = kv_offset + idx * chunk + jnp.arange(chunk)
    mask = jnp.ones((s, chunk), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    mask &= (q_pos[:, None] - k_pos[None, :] < window) | (window <= 0)
    return mask


def _kv_chunk(arr, idx, chunk):
    """(b, t, hkv, hd) -> (b, chunk, hkv, hd) at chunk index idx (traced)."""
    b, t, hkv, hd = arr.shape
    return jax.lax.dynamic_slice(arr, (0, idx * chunk, 0, 0),
                                 (b, chunk, hkv, hd))


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _flash(causal: bool, chunk: int, kv_offset: int,
           q, k, v, window, q_pos):
    out, _ = _flash_fwd(causal, chunk, kv_offset, q, k, v, window, q_pos)
    return out


def _flash_fwd(causal, chunk, kv_offset, q, k, v, window, q_pos):
    """KV chunks are dynamic-sliced from the natural (b, t, hkv, hd)
    layout — no physical chunk-major transpose (those showed up as
    hundreds of GB of copy/transpose traffic in the HLO byte audit)."""
    b, s, hq, hd = q.shape
    _, t, hkv, _ = k.shape
    g = hq // hkv
    n_chunks = t // chunk
    # operands stay in storage dtype (bf16): dots accumulate fp32 via
    # preferred_element_type — fp32 pre-casts double attention HBM reads
    qf = ((q.astype(jnp.float32) * hd ** -0.5).astype(k.dtype)
          .reshape(b, s, hkv, g, hd))

    def step(carry, idx):
        m, l, o = carry
        k_blk = _kv_chunk(k, idx, chunk).reshape(b, chunk, hkv, hd)
        v_blk = _kv_chunk(v, idx, chunk).reshape(b, chunk, hkv, hd)
        logits = jnp.einsum("bskgh,bckh->bskgc", qf, k_blk,
                            preferred_element_type=jnp.float32)
        mask = _mask_for(s, chunk, idx, q_pos, kv_offset, causal, window)
        logits = jnp.where(mask[None, :, None, None, :], logits, NEG_INF)
        blk_max = jnp.max(logits, axis=-1)
        new_m = jnp.maximum(m, blk_max)
        alpha = jnp.exp(m - new_m)
        p_exp = jnp.exp(logits - new_m[..., None])
        new_l = l * alpha + jnp.sum(p_exp, axis=-1)
        upd = jnp.einsum("bskgc,bckh->bskgh", p_exp.astype(v.dtype), v_blk,
                         preferred_element_type=jnp.float32)
        new_o = o * alpha[..., None] + upd
        return (new_m, new_l, new_o), None

    m0 = jnp.full((b, s, hkv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, s, hkv, g), jnp.float32)
    o0 = jnp.zeros((b, s, hkv, g, hd), jnp.float32)
    (m, l, o), _ = jax.lax.scan(step, (m0, l0, o0), jnp.arange(n_chunks))
    out = (o / jnp.maximum(l, 1e-30)[..., None])
    res = (q, k, v, window, q_pos, out, m, l)
    return out.reshape(b, s, hq, hd).astype(q.dtype), res


def _flash_bwd(causal, chunk, kv_offset, res, dout):
    """FlashAttention-2 style backward: recompute p per KV chunk from the
    saved (m, l); O(S·chunk) live memory, no stored logits."""
    q, k, v, window, q_pos, out, m, l = res
    b, s, hq, hd = q.shape
    _, t, hkv, _ = k.shape
    g = hq // hkv
    n_chunks = t // chunk
    scale = hd ** -0.5
    qf = ((q.astype(jnp.float32) * scale).astype(k.dtype)
          .reshape(b, s, hkv, g, hd))
    do = dout.astype(jnp.float32).reshape(b, s, hkv, g, hd)
    do_lp = do.astype(k.dtype)
    l_safe = jnp.maximum(l, 1e-30)
    # delta_i = rowsum(dO ⊙ O)
    delta = jnp.sum(do * out, axis=-1)                      # (b,s,hkv,g)

    def step(dq_acc, idx):
        k_blk = _kv_chunk(k, idx, chunk).reshape(b, chunk, hkv, hd)
        v_blk = _kv_chunk(v, idx, chunk).reshape(b, chunk, hkv, hd)
        logits = jnp.einsum("bskgh,bckh->bskgc", qf, k_blk,
                            preferred_element_type=jnp.float32)
        mask = _mask_for(s, chunk, idx, q_pos, kv_offset, causal, window)
        logits = jnp.where(mask[None, :, None, None, :], logits, NEG_INF)
        p = jnp.exp(logits - m[..., None]) / l_safe[..., None]
        p_lp = p.astype(k.dtype)
        dv_blk = jnp.einsum("bskgc,bskgh->bckh", p_lp, do_lp,
                            preferred_element_type=jnp.float32)
        dp = jnp.einsum("bskgh,bckh->bskgc", do_lp, v_blk,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - delta[..., None])                    # (b,s,hkv,g,c)
        ds_lp = ds.astype(k.dtype)
        dq_acc = dq_acc + jnp.einsum("bskgc,bckh->bskgh", ds_lp, k_blk,
                                     preferred_element_type=jnp.float32)
        dk_blk = jnp.einsum("bskgc,bskgh->bckh", ds_lp, qf,
                            preferred_element_type=jnp.float32)
        # dk/dv leave as scan outputs (stacked chunk-major) — accumulating
        # via dynamic-update-slice into a sequence-sharded buffer makes
        # GSPMD all-gather the accumulator every iteration
        return dq_acc, (dk_blk, dv_blk)

    dq0 = jnp.zeros((b, s, hkv, g, hd), jnp.float32)
    dq, (dk_c, dv_c) = jax.lax.scan(step, dq0, jnp.arange(n_chunks))
    dq = (dq * scale).reshape(b, s, hq, hd).astype(q.dtype)
    dk = dk_c.swapaxes(0, 1).reshape(b, t, hkv, hd).astype(k.dtype)
    dv = dv_c.swapaxes(0, 1).reshape(b, t, hkv, hd).astype(v.dtype)
    return (dq, dk, dv,
            jnp.zeros_like(res[3]), jnp.zeros_like(res[4]))


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window=0,
                    q_offset=0, kv_offset=0,
                    chunk: int = 512) -> jax.Array:
    """Online-softmax attention, scanning over KV chunks.

    q: (B, S, Hq, hd); k, v: (B, T, Hkv, hd); GQA via head grouping.
    window > 0 limits attention to the last ``window`` keys (inclusive of
    self); it may be a *traced* scalar (gemma3 scans a per-layer window
    array) — window <= 0 disables it dynamically.  Offsets give absolute
    positions of q[0] / k[0].

    Custom VJP: the backward recomputes attention probabilities per KV
    chunk from the saved (m, l) running-softmax stats, so neither pass ever
    materialises (S × T) logits — O(S·chunk) live memory both ways.
    Returns (B, S, Hq, hd) in q.dtype; softmax in fp32.
    """
    t = k.shape[1]
    chunk = _pick_chunk(t, chunk)
    window = jnp.asarray(window, jnp.int32)
    q_pos = q_offset + jnp.arange(q.shape[1])
    return _flash(causal, chunk, int(kv_offset), q, k, v, window, q_pos)


def attention_ref(q, k, v, *, causal=True, window=0, q_offset=0, kv_offset=0):
    """Dense reference attention (tests only)."""
    b, s, hq, hd = q.shape
    _, t, hkv, _ = k.shape
    g = hq // hkv
    qf = (q.astype(jnp.float32) * hd ** -0.5).reshape(b, s, hkv, g, hd)
    logits = jnp.einsum("bskgh,btkh->bskgt", qf, k.astype(jnp.float32))
    q_pos = q_offset + jnp.arange(s)
    k_pos = kv_offset + jnp.arange(t)
    window = jnp.asarray(window, jnp.int32)
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    mask &= (q_pos[:, None] - k_pos[None, :] < window) | (window <= 0)
    logits = jnp.where(mask[None, :, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bskgt,btkh->bskgh", w, v.astype(jnp.float32))
    return out.reshape(b, s, hq, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# decode attention (single new token against a cache)
# ---------------------------------------------------------------------------

def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     slot_pos: jax.Array, pos: jax.Array,
                     window: int = 0) -> jax.Array:
    """q: (B, 1, Hq, hd); caches (B, T, Hkv, hd); slot_pos — absolute
    position stored in each cache slot (−1 = empty), shape (T,) shared or
    (B, T) per batch row; pos — current absolute position, scalar shared or
    (B,) per row (continuous batching admits slots at different times, so
    each batch lane carries its own position — DESIGN.md §9).

    Cache operands stay in their storage dtype (bf16) — the dots accumulate
    fp32 via preferred_element_type; pre-casting the cache to fp32 doubles
    the dominant HBM read of the whole decode step.  Softmax stats fp32.
    When T is sequence-sharded, GSPMD lowers the reductions to the
    distributed flash-decode (pmax + psum) schedule.
    """
    b, _, hq, hd = q.shape
    _, t, hkv, _ = k_cache.shape
    g = hq // hkv
    qf = ((q.astype(jnp.float32) * hd ** -0.5)
          .astype(k_cache.dtype).reshape(b, hkv, g, hd))
    logits = jnp.einsum("bkgh,btkh->bkgt", qf, k_cache,
                        preferred_element_type=jnp.float32)
    sp = jnp.broadcast_to(jnp.asarray(slot_pos, jnp.int32), (b, t))
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))[:, None]
    valid = (sp >= 0) & (sp <= pos_b)
    if window > 0:
        valid &= sp > pos_b - window
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    p_norm = (p / jnp.maximum(l, 1e-30)).astype(v_cache.dtype)
    out = jnp.einsum("bkgt,btkh->bkgh", p_norm, v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, hq, hd).astype(q.dtype)


def verify_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     slot_pos: jax.Array, pos: jax.Array,
                     window: int = 0) -> jax.Array:
    """T-query generalisation of ``decode_attention`` for speculative
    verify (DESIGN.md §15): q (B, S, Hq, hd) holds S teacher-forced
    queries per row, where query t sits at absolute position pos[b] + t.
    Caches/slot_pos/pos as in ``decode_attention``.

    Each query slice must reproduce ``decode_attention`` BIT-EXACTLY —
    the engine's draft/verify parity contract (accepted tokens equal the
    non-speculative greedy chain) rides on it — so the arithmetic is the
    same: fp32-accumulated dots over storage-dtype operands, fp32 softmax
    stats, exact-zero masking via NEG_INF (exp underflows to 0.0 for
    masked slots, so stale post-rewind entries contribute nothing).
    """
    b, s, hq, hd = q.shape
    _, t, hkv, _ = k_cache.shape
    g = hq // hkv
    qf = ((q.astype(jnp.float32) * hd ** -0.5)
          .astype(k_cache.dtype).reshape(b, s, hkv, g, hd))
    logits = jnp.einsum("bskgh,btkh->bskgt", qf, k_cache,
                        preferred_element_type=jnp.float32)
    sp = jnp.broadcast_to(jnp.asarray(slot_pos, jnp.int32), (b, t))
    qpos = (jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))[:, None]
            + jnp.arange(s, dtype=jnp.int32)[None, :])       # (B, S)
    valid = (sp[:, None, :] >= 0) & (sp[:, None, :] <= qpos[:, :, None])
    if window > 0:
        valid &= sp[:, None, :] > qpos[:, :, None] - window
    logits = jnp.where(valid[:, :, None, None, :], logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    p_norm = (p / jnp.maximum(l, 1e-30)).astype(v_cache.dtype)
    out = jnp.einsum("bskgt,btkh->bskgh", p_norm, v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, s, hq, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------

def make_kv_cache(batch: int, max_len: int, n_kv: int, head_dim: int,
                  dtype=jnp.bfloat16) -> dict:
    """One layer's cache.  ``slot_pos`` records the absolute position held
    in each slot (supports ring buffers for sliding-window layers), PER
    BATCH ROW — continuous batching (DESIGN.md §9) admits/retires rows
    independently, so lanes disagree about which positions are valid."""
    return {
        "k": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
        "slot_pos": jnp.full((batch, max_len), -1, jnp.int32),
    }


def _row_pos(pos, b: int) -> jax.Array:
    """Normalise a scalar-or-(B,) position to (B,) int32."""
    return jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))


def cache_insert(cache: dict, k_new: jax.Array, v_new: jax.Array,
                 pos: jax.Array, ring: bool = False) -> dict:
    """Insert (B, n, Hkv, hd) at absolute position(s) starting at ``pos``.

    ring=True wraps writes modulo the cache length (sliding-window layers).
    ``pos`` may be scalar (all rows aligned — prefill) or (B,) per row
    (continuous decode, lanes at different depths).
    """
    b, t = cache["k"].shape[:2]
    n = k_new.shape[1]
    dtype = cache["k"].dtype
    if not ring and n > 1:
        # prefill path: contiguous write at static offset 0 expected
        k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(dtype), (0, pos, 0, 0))
        v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(dtype), (0, pos, 0, 0))
        sp_rows = jnp.broadcast_to(pos + jnp.arange(n, dtype=jnp.int32),
                                   (b, n))
        sp = jax.lax.dynamic_update_slice(cache["slot_pos"], sp_rows, (0, pos))
        return {"k": k, "v": v, "slot_pos": sp}
    # single-token (or ring) writes; per-row positions scatter per lane
    pos_b = _row_pos(pos, b)
    idx = (pos_b % t) if ring else jnp.clip(pos_b, 0, t - 1)
    rows = jnp.arange(b)
    k = cache["k"].at[rows, idx].set(k_new[:, 0].astype(dtype))
    v = cache["v"].at[rows, idx].set(v_new[:, 0].astype(dtype))
    sp = cache["slot_pos"].at[rows, idx].set(pos_b)
    return {"k": k, "v": v, "slot_pos": sp}


def cache_insert_multi(cache: dict, k_new: jax.Array, v_new: jax.Array,
                       pos) -> dict:
    """Teacher-forced multi-token insert: (B, n, Hkv, hd) lands at PER-ROW
    absolute positions pos[b]..pos[b]+n-1 (speculative verify — lanes sit
    at different depths, so the prefill path's scalar-offset
    dynamic_update_slice cannot serve).  Non-ring caches only: slot index
    == absolute position, which is what makes rewind a pure ``pos``
    retreat (stale slots mask out via slot_pos <= pos and are overwritten
    before they could become readable again)."""
    b, t = cache["k"].shape[:2]
    n = k_new.shape[1]
    dtype = cache["k"].dtype
    pos_b = _row_pos(pos, b)
    posn = pos_b[:, None] + jnp.arange(n, dtype=jnp.int32)[None, :]
    idx = jnp.clip(posn, 0, t - 1)
    rows = jnp.arange(b)[:, None]
    k = cache["k"].at[rows, idx].set(k_new.astype(dtype))
    v = cache["v"].at[rows, idx].set(v_new.astype(dtype))
    sp = cache["slot_pos"].at[rows, idx].set(posn)
    return {"k": k, "v": v, "slot_pos": sp}


def cache_insert_stacked(caches: dict, layer_idx, k_new: jax.Array,
                         v_new: jax.Array, pos, ring: bool = False) -> dict:
    """In-place-style single-token insert into a STACKED (L, B, T, H, hd)
    cache at (layer_idx, b, pos_b).  Used by the decode scan, which carries
    the whole stacked cache: the scatter update is one token per lane (KB),
    so XLA aliases the carry buffer instead of copying the cache every
    layer (scan-ys stacking rewrites the full cache per step — measured as
    the dominant decode byte term before this change)."""
    b, t = caches["k"].shape[1:3]
    pos_b = _row_pos(pos, b)
    idx = (pos_b % t) if ring else jnp.clip(pos_b, 0, t - 1)
    dtype = caches["k"].dtype
    rows = jnp.arange(b)
    k = caches["k"].at[layer_idx, rows, idx].set(k_new[:, 0].astype(dtype))
    v = caches["v"].at[layer_idx, rows, idx].set(v_new[:, 0].astype(dtype))
    sp = caches["slot_pos"].at[layer_idx, rows, idx].set(pos_b)
    return {"k": k, "v": v, "slot_pos": sp}


def cache_insert_stacked_multi(caches: dict, layer_idx, k_new: jax.Array,
                               v_new: jax.Array, pos) -> dict:
    """``cache_insert_multi`` against a STACKED (L, B, T, H, hd) cache at
    (layer_idx, b, pos[b]..pos[b]+n-1) — the speculative verify analogue
    of ``cache_insert_stacked`` (the write is n tokens per lane, still KB
    against the full cache, so XLA aliases the scan carry)."""
    b, t = caches["k"].shape[1:3]
    n = k_new.shape[1]
    dtype = caches["k"].dtype
    pos_b = _row_pos(pos, b)
    posn = pos_b[:, None] + jnp.arange(n, dtype=jnp.int32)[None, :]
    idx = jnp.clip(posn, 0, t - 1)
    rows = jnp.arange(b)[:, None]
    k = caches["k"].at[layer_idx, rows, idx].set(k_new.astype(dtype))
    v = caches["v"].at[layer_idx, rows, idx].set(v_new.astype(dtype))
    sp = caches["slot_pos"].at[layer_idx, rows, idx].set(posn)
    return {"k": k, "v": v, "slot_pos": sp}


def cache_layer_view(caches: dict, layer_idx) -> dict:
    """Read one layer's (B, T, H, hd) slice from a stacked cache."""
    lk = caches["k"].shape
    k = jax.lax.dynamic_slice(
        caches["k"], (layer_idx, 0, 0, 0, 0), (1,) + lk[1:])[0]
    v = jax.lax.dynamic_slice(
        caches["v"], (layer_idx, 0, 0, 0, 0), (1,) + lk[1:])[0]
    sp = jax.lax.dynamic_slice(
        caches["slot_pos"], (layer_idx, 0, 0), (1, lk[1], lk[2]))[0]
    return {"k": k, "v": v, "slot_pos": sp}


def prefill_ring(cache: dict, k_all: jax.Array, v_all: jax.Array,
                 window: int) -> dict:
    """Fill a ring cache of size ``window`` with the last ``window`` of a
    full prefill (S >= window assumed handled by caller slicing)."""
    s = k_all.shape[1]
    w = cache["k"].shape[1]
    start = max(0, s - w)
    k_tail = k_all[:, start:start + w]
    v_tail = v_all[:, start:start + w]
    n = k_tail.shape[1]
    positions = jnp.arange(start, start + n, dtype=jnp.int32)
    slots = positions % w
    k = cache["k"].at[:, slots].set(k_tail.astype(cache["k"].dtype))
    v = cache["v"].at[:, slots].set(v_tail.astype(cache["v"].dtype))
    sp = cache["slot_pos"].at[:, slots].set(positions)
    return {"k": k, "v": v, "slot_pos": sp}
