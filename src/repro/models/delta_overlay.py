"""Delta overlay: per-module packed deltas that ride alongside base params.

The paper's §4 on-the-fly variant: instead of materialising a dense copy of
every resident fine-tune (``core/loader.apply_artifact``), a variant is kept
on device as a pytree of :class:`OverlayEntry` — packed sign mask + per-axis
fp16 scale vectors — that MIRRORS the params tree structure.  Model forwards
accept the overlay as an optional argument and dispatch any matmul whose
module has an entry to the fused delta GEMM (``kernels/ops.bitlinear_axes``),
so the dense Ŵ is never written to HBM: ~1/16 the resident bytes of a dense
fp16 copy per variant.

Canonical form (one kernel, no static axis mode):
  v_eff[n, k] = v_row[n] + v_col[k]
with the UNSELECTED axis vector zeroed per matrix (scalar entries broadcast
their per-matrix scalar into v_row).  The axis choice therefore stays plain
array data, so stacked entries (leading layer/expert dims) ride through
``lax.scan`` / ``vmap`` exactly like the base weights they shadow.

Structure contract: the overlay is a nested dict following the params tree
(``overlay["layers"]["attn"]["wq"] -> OverlayEntry``); entries under scanned
stacks carry the same leading layer dim as the stacked weight.  Missing keys
mean "serve this module from the base weight" — ``oget`` resolves both.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OverlayEntry:
    """One target matrix (stack): packed mask + canonical axis vectors."""
    packed: jax.Array            # (..., d_out, d_in//8) uint8
    v_row: jax.Array             # (..., d_out) — zero where col-selected
    v_col: jax.Array             # (..., d_in) — zero where row-selected

    def nbytes(self) -> int:
        return (self.packed.size * self.packed.dtype.itemsize
                + self.v_row.size * self.v_row.dtype.itemsize
                + self.v_col.size * self.v_col.dtype.itemsize)


def from_delta_entry(entry, vec_dtype=jnp.float16) -> OverlayEntry:
    """Canonicalise a calibration ``DeltaEntry`` for on-the-fly execution.

    Row-selected matrices keep v_row and zero v_col (and vice versa);
    scalar (BitDelta) entries broadcast the per-matrix scalar into v_row.
    Vectors are stored fp16 on device (the paper's artifact precision).
    """
    packed = entry.packed
    d_out = packed.shape[-2]
    lead = packed.shape[:-2]
    if entry.scalar:
        v_row = jnp.broadcast_to(
            entry.v_row.astype(jnp.float32)[..., None], lead + (d_out,))
        v_col = jnp.zeros(lead + (packed.shape[-1] * 8,), jnp.float32)
    else:
        sel = entry.use_row[..., None]
        v_row = jnp.where(sel, entry.v_row.astype(jnp.float32), 0.0)
        v_col = jnp.where(sel, 0.0, entry.v_col.astype(jnp.float32))
    return OverlayEntry(packed=packed, v_row=v_row.astype(vec_dtype),
                        v_col=v_col.astype(vec_dtype))


def insert_entry(tree: dict, path: str, entry: OverlayEntry) -> None:
    """Insert an entry at a dot-path, mirroring the params tree structure
    (the single definition of the overlay path scheme)."""
    node = tree
    parts = path.split(".")
    for part in parts[:-1]:
        node = node.setdefault(part, {})
    node[parts[-1]] = entry


def overlay_from_deltas(deltas: dict, vec_dtype=jnp.float16) -> dict:
    """{flat path -> DeltaEntry} -> nested overlay tree mirroring params."""
    tree: dict = {}
    for path, entry in deltas.items():
        insert_entry(tree, path, from_delta_entry(entry, vec_dtype=vec_dtype))
    return tree


def oget(overlay, key: str):
    """Resolve one level of an overlay tree; None/absent/empty -> None."""
    if not overlay:
        return None
    sub = overlay.get(key) if isinstance(overlay, dict) else None
    if isinstance(sub, dict) and not sub:
        return None
    return sub


def overlay_nbytes(overlay) -> int:
    """Device-resident bytes of an overlay tree."""
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree.leaves(overlay))


# ---------------------------------------------------------------------------
# banked overlays (mixed-variant batches — DESIGN.md §9)
#
# A BANKED overlay tree mirrors the params tree like a single-variant
# overlay, but every leaf is stacked along a bank axis of ``bank_size``
# slots.  Slot 0 is the base: zero vectors (zero delta) for OverlayEntry
# leaves, the base leaf value for extras leaves.  Model forwards take a
# per-batch-row ``variant_idx`` selecting the slot each row fuses.
#
# Bank-axis placement: leaves under a scanned layer stack keep the stack
# dim leading (lax.scan slices axis 0), so the bank axis sits at position 1
# there and at position 0 everywhere else — the same convention by which
# DeltaEntry/OverlayEntry leaves carry leading layer/expert dims.
# ---------------------------------------------------------------------------

STACKED_TOP_KEYS = frozenset({"layers", "pre_layers", "enc_layers",
                              "dec_layers", "mlstm", "slstm", "mamba"})


def bank_axis(path: str) -> int:
    """Bank-axis position for a dot-path: after the scan-stack dim if the
    leaf lives under a stacked top-level group, else leading."""
    return 1 if path.split(".")[0] in STACKED_TOP_KEYS else 0


def entry_slot(entry, v: int):
    """One bank slot of a banked OverlayEntry whose bank axis has become
    leading (after scan/stack slicing) — the per-variant entry shape."""
    if entry is None:
        return None
    return OverlayEntry(packed=entry.packed[v], v_row=entry.v_row[v],
                        v_col=entry.v_col[v])


def _with_bank_dim(a: jax.Array, axis: int, size: int) -> tuple:
    return a.shape[:axis] + (size,) + a.shape[axis:]


def _bank_slot_index(axis: int, slot: int) -> tuple:
    return (slice(None),) * axis + (slot,)


def bank_zeros(path: str, entry: OverlayEntry, size: int) -> OverlayEntry:
    """All-slots-zero banked entry shaped after one variant's entry (slot 0
    = base stays all-zero forever: zero vectors mean Ŵ = W_b exactly)."""
    ax = bank_axis(path)
    z = lambda a: jnp.zeros(_with_bank_dim(a, ax, size), a.dtype)
    return OverlayEntry(packed=z(entry.packed), v_row=z(entry.v_row),
                        v_col=z(entry.v_col))


def bank_extra_base(path: str, base_leaf: jax.Array, size: int) -> jax.Array:
    """Banked extras leaf with every slot holding the base value (so
    unassigned slots serve base semantics)."""
    ax = bank_axis(path)
    return jnp.broadcast_to(jnp.expand_dims(base_leaf, ax),
                            _with_bank_dim(base_leaf, ax, size)) + 0


def bank_clear_entry(path: str, bank: OverlayEntry, slot: int
                     ) -> OverlayEntry:
    idx = _bank_slot_index(bank_axis(path), slot)
    return OverlayEntry(
        packed=bank.packed.at[idx].set(jnp.zeros_like(bank.packed[idx])),
        v_row=bank.v_row.at[idx].set(0),
        v_col=bank.v_col.at[idx].set(0))


def bank_set_extra_base(path: str, bank: jax.Array, slot: int,
                        base_leaf: jax.Array) -> jax.Array:
    idx = _bank_slot_index(bank_axis(path), slot)
    return bank.at[idx].set(base_leaf.astype(bank.dtype))


# ---------------------------------------------------------------------------
# mesh sharding of overlays (DESIGN.md §11)
#
# Overlay leaves inherit their placement from the base weight they shadow:
# the packed sign plane keeps the weight's logical axes on every unpacked
# dim (the packed d_in//8 byte dim is replicated — it is 8x smaller and the
# fused kernel reads it whole per tile), v_row / v_col follow the single
# weight axis they scale, extras ARE fine-tuned weight leaves and keep the
# weight's own axes, and the bank axis resolves through the "bank" rule:
# replicated by default (every device holds every slot's shard of its own
# weight tile — admission is then a collective-free local scatter), or
# pod-sharded under pod-local bank rules (rules_for(..., pod_banks=True):
# each pod holds only its own slot range, so an admission scatter writes a
# single pod's devices — DESIGN.md §17).  ``distributed/sharding.py`` owns
# the logical->mesh mapping; this module only derives the logical axes.
# ---------------------------------------------------------------------------

def entry_shardings_from_weight(weight_sharding, w_ndim: int):
    """Overlay-leaf placements by SPEC SURGERY on the shadowed weight's
    resolved NamedSharding: OverlayEntry(packed=, v_row=, v_col=) of
    NamedShardings — the allocation-level twin of :func:`entry_axes`
    (tests/test_sharded_serving.py asserts the two derivations agree).

    * packed keeps the weight's spec with the byte dim replicated (it is
      8x smaller; the shard_map dispatch slices it per-shard at run time);
    * v_row keeps the spec entries of the dims it copies ((lead..., d_out));
    * v_col keeps (lead..., d_in).

    The ONE shared derivation for every consumer that starts from a
    resolved weight sharding instead of logical axes — ``loader.
    device_put_overlay`` (variant transfer) and ``loader.apply_update``
    (incremental patches) both route here.  Returns None when the sharding
    carries no inspectable spec (single-device placements).

    A QUANTIZED base leaf arrives as a QuantWeight-of-shardings (the
    registry upgrades target shardings via ``quantize.quant_sharding``);
    the overlay shadows the weight's placement, which the int8 payload
    carries verbatim."""
    try:
        if getattr(weight_sharding, "__quant_leaf__", False):
            weight_sharding = weight_sharding.q
        from jax.sharding import NamedSharding, PartitionSpec
        spec = list(weight_sharding.spec) + [None] * w_ndim
        spec = spec[:w_ndim]
        mesh = weight_sharding.mesh
        return OverlayEntry(
            packed=NamedSharding(mesh, PartitionSpec(*(spec[:-1] + [None]))),
            v_row=NamedSharding(mesh, PartitionSpec(*spec[:-1])),
            v_col=NamedSharding(mesh,
                                PartitionSpec(*(spec[:-2] + spec[-1:]))))
    except Exception:
        return None


def _is_axes(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None)))
                                        for e in x)


def flatten_axes(param_axes) -> dict:
    """{dot-path -> logical-axes tuple} view of a ``param.split`` axes
    tree: ``calibration.flatten_params`` with axis tuples as leaves (they
    are pytree nodes and would otherwise be exploded)."""
    from repro.core.calibration import flatten_params
    return flatten_params(param_axes, is_leaf=_is_axes)


def _insert_bank(axes: tuple, path: str) -> tuple:
    ax = bank_axis(path)
    return axes[:ax] + ("bank",) + axes[ax:]


def entry_axes(weight_axes: tuple, *, path: str = "",
               bank: bool = False) -> OverlayEntry:
    """Logical axes for one overlay entry, derived from the shadowed
    weight's ``(*lead, out_ax, in_ax)`` axes."""
    *lead, out_ax, in_ax = weight_axes
    packed = tuple(lead) + (out_ax, None)   # packed byte dim: replicated
    v_row = tuple(lead) + (out_ax,)
    v_col = tuple(lead) + (in_ax,)
    if bank:
        packed, v_row, v_col = (_insert_bank(t, path)
                                for t in (packed, v_row, v_col))
    return OverlayEntry(packed=packed, v_row=v_row, v_col=v_col)


def extra_axes(weight_axes: tuple, *, path: str = "",
               bank: bool = False) -> tuple:
    """Extras leaves are fine-tuned copies of base leaves: same axes, plus
    the replicated bank axis when banked."""
    return _insert_bank(tuple(weight_axes), path) if bank \
        else tuple(weight_axes)


def overlay_pspecs(param_axes, delta_paths, extra_paths=(), *,
                   bank: bool = False) -> dict:
    """Logical-axes tree mirroring an overlay (or banked overlay) tree.

    ``param_axes`` is the axes tree from ``models.param.split``;
    ``delta_paths`` / ``extra_paths`` name the modules the overlay carries
    (extras ride in the tree only when banked — the per-variant path swaps
    them into the params view instead).  Resolve against a mesh with
    ``distributed.sharding.tree_shardings`` (rule "bank" -> replicated).
    """
    flat = flatten_axes(param_axes)
    tree: dict = {}
    for path in delta_paths:
        insert_entry(tree, path, entry_axes(flat[path], path=path, bank=bank))
    for path in extra_paths:
        insert_entry(tree, path, extra_axes(flat[path], path=path, bank=bank))
    return tree


def overlay_struct(flat_shapes: dict, delta_paths, extra_paths=(), *,
                   bank_size=None, vec_dtype=jnp.float16) -> dict:
    """ShapeDtypeStruct tree mirroring an overlay tree (abstract twin of
    ``overlay_from_deltas`` / a bank — dry-run and in_shardings use).

    ``flat_shapes``: {path -> array or ShapeDtypeStruct} of BASE weights.
    With ``bank_size`` the leaves grow the bank axis at ``bank_axis(path)``
    and extras are included (base-dtype, as ``bank_extra_base`` stores
    them)."""
    def sds(shape, dtype):
        return jax.ShapeDtypeStruct(tuple(shape), dtype)

    tree: dict = {}
    for path in delta_paths:
        w = flat_shapes[path]
        lead = w.shape[:-2]
        d_out, d_in = w.shape[-2], w.shape[-1]
        packed = lead + (d_out, d_in // 8)
        v_row = lead + (d_out,)
        v_col = lead + (d_in,)
        if bank_size is not None:
            ax = bank_axis(path)
            packed, v_row, v_col = (s[:ax] + (bank_size,) + s[ax:]
                                    for s in (packed, v_row, v_col))
        insert_entry(tree, path, OverlayEntry(
            packed=sds(packed, jnp.uint8), v_row=sds(v_row, vec_dtype),
            v_col=sds(v_col, vec_dtype)))
    if bank_size is not None:
        for path in extra_paths:
            w = flat_shapes[path]
            ax = bank_axis(path)
            shape = w.shape[:ax] + (bank_size,) + w.shape[ax:]
            insert_entry(tree, path, sds(shape, w.dtype))
    return tree


def overlay_shardings(param_axes, flat_shapes: dict, delta_paths,
                      extra_paths, rules: dict, mesh, *,
                      bank_size=None) -> dict:
    """Flat {path -> OverlayEntry-of-NamedSharding | NamedSharding} for
    every overlay leaf, resolved through the logical rules (the one
    derivation the sharded bank, the engine in_shardings and the dry-run
    serving cells all share)."""
    from repro.distributed.sharding import tree_shardings
    axes = overlay_pspecs(param_axes, delta_paths,
                          extra_paths if bank_size is not None else (),
                          bank=bank_size is not None)
    struct = overlay_struct(flat_shapes, delta_paths, extra_paths,
                            bank_size=bank_size)
    sh_tree = tree_shardings(struct, axes, rules, mesh)
    paths = list(delta_paths) + (list(extra_paths)
                                 if bank_size is not None else [])
    flat: dict = {}
    for path in paths:
        node = sh_tree
        for part in path.split("."):
            node = node[part]
        flat[path] = node
    return flat
