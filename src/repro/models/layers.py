"""Common building blocks: norms, RoPE, embeddings, gated MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.param import Param, dense_init, ones_init


def cast_to(x: jax.Array, dtype_name: str) -> jax.Array:
    return x.astype(jnp.dtype(dtype_name))


# ---------------------------------------------------------------------------
# shared linear: every family's projection matmul routes through here so a
# delta overlay entry (models/delta_overlay.py) can swap the dense GEMM for
# the fused on-the-fly delta GEMM without touching call sites
# ---------------------------------------------------------------------------

def linear(x: jax.Array, w: jax.Array, ov=None, vidx=None,
           waxes=None) -> jax.Array:
    """y = x @ Ŵᵀ where Ŵ = w without an overlay entry, else the variant
    weight v ⊙ unpack(B) + w applied on the fly (never densified).

    With ``vidx`` (per-batch-row int32 variant indices, 0 = base) the
    overlay entry is BANKED — leaves carry a leading bank axis and every
    row fuses its own variant's delta in one mixed-variant GEMM
    (DESIGN.md §9).

    ``waxes`` — the weight's logical axes as declared at init (e.g.
    ``("ffn", "embed")``) — is the mesh/axes context the model families
    thread down: inside an active mesh the fused delta GEMM then lowers
    as a shard_map'd per-shard Pallas kernel on the weight's own tiling
    (kernels/dispatch.py, DESIGN.md §12) instead of leaning on GSPMD to
    partition the opaque kernel call.

    ``w`` may be a ``core/quantize.QuantWeight`` (int8 base + fp16
    per-output-channel scale).  The no-overlay path factors EXACTLY —
    x @ Ŵᵀ = (x @ qᵀ) ⊙ scale, per-channel scales commute out of the
    contraction — so the dense fp base is never materialised; overlay
    paths hand the QuantWeight to the kernels, which dequantize per
    tile (DESIGN.md §16)."""
    if ov is None:
        if getattr(w, "__quant_leaf__", False):
            return (x @ w.q.T.astype(x.dtype)) * w.scale.astype(x.dtype)
        return x @ w.T.astype(x.dtype)
    from repro.kernels import ops as K
    if vidx is None:
        return K.bitlinear_axes(x, ov.packed, ov.v_row, ov.v_col, w,
                                waxes=waxes)
    return K.bitlinear_axes_banked(x, vidx, ov.packed, ov.v_row, ov.v_col,
                                   w, waxes=waxes)


def psel(w: jax.Array, bank, vidx, *, lead: int = 1) -> jax.Array:
    """Per-row parameter select for BANKED extras (norm scales, biases,
    convs — fine-tuned leaves that are not delta targets).

    ``bank`` is (V, *w.shape) with slot 0 holding the base value; returns
    ``w`` untouched when unbanked, else ``bank[vidx]`` with ``lead``
    singleton axes inserted after the batch dim so the result broadcasts
    against (B, S, ...) activations."""
    if bank is None or vidx is None:
        return w
    sel = jnp.take(bank, vidx, axis=0)
    return sel.reshape(sel.shape[0], *([1] * lead), *sel.shape[1:])


def _oget(ov, key):
    from repro.models.delta_overlay import oget
    return oget(ov, key)


# ---------------------------------------------------------------------------
# RMSNorm (fp32 internally)
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int) -> Param:
    return ones_init((d,), (None,))


import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with fp32 statistics and an x.dtype data path — both ways.

    Autodiff through an fp32 variance branch creates an fp32 (B,S,D)
    cotangent that promotes the whole residual-stream gradient (and every
    TP backward all-reduce riding on it) to fp32 — measured as 2× the
    collective wire bytes on TP cells.  The hand-written VJP keeps all
    (B,S,D) tensors in x.dtype; only rowwise statistics are fp32.
    """
    return _rms_fwd(x, scale, eps)[0]


def _rms_stats(x, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return jax.lax.rsqrt(var + eps)              # fp32 (..., 1)


def _rms_fwd(x, scale, eps):
    inv = _rms_stats(x, eps)
    y = x * inv.astype(x.dtype) * scale.astype(x.dtype)
    return y, (x, scale, inv)


def _rms_bwd(eps, res, dy):
    x, scale, inv = res
    d = x.shape[-1]
    sc = scale.astype(x.dtype)
    # t = Σ_D dy·scale·x   (fp32 rowwise scalar)
    t = jnp.sum((dy * sc).astype(jnp.float32) * x.astype(jnp.float32),
                axis=-1, keepdims=True)
    coef = (inv ** 3 * (t / d)).astype(x.dtype)  # (..., 1)
    dx = dy * sc * inv.astype(x.dtype) - x * coef
    # scale broadcasts as a suffix of x.shape (may be multi-dim, e.g.
    # per-head (H, hd) norms): reduce the leading broadcast dims
    lead = tuple(range(x.ndim - scale.ndim))
    dscale = jnp.sum((dy * x).astype(jnp.float32) * inv,
                     axis=lead).astype(scale.dtype)
    return dx.astype(x.dtype), dscale


rmsnorm.defvjp(_rms_fwd, _rms_bwd)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x: jax.Array, positions: jax.Array, theta) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S).

    theta may be a python float or a traced scalar (gemma3 per-layer base).
    """
    hd = x.shape[-1]
    theta = jnp.asarray(theta, jnp.float32)
    freqs = theta ** (-jnp.arange(0, hd, 2, dtype=jnp.float32) / hd)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d: int) -> jax.Array:
    """Whisper-style absolute sinusoidal embeddings (S, d)."""
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    div = jnp.exp(-jnp.log(10000.0) * jnp.arange(0, d, 2, jnp.float32) / d)
    ang = pos * div
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------

def embed_init(key, vocab: int, d: int) -> Param:
    return dense_init(key, (vocab, d), ("vocab", "embed"), scale=1.0)


def embed_lookup(table: jax.Array, tokens: jax.Array, dtype: str,
                 bank=None, vidx=None) -> jax.Array:
    """Token embedding; with a banked extras table (V, vocab, d) and per-row
    variant indices, each batch row looks up its own variant's table."""
    if bank is None or vidx is None:
        return cast_to(jnp.take(table, tokens, axis=0), dtype)
    idx = vidx.reshape(vidx.shape[0], *([1] * (tokens.ndim - 1)))
    return cast_to(bank[idx, tokens], dtype)


def unembed_logits(x: jax.Array, table: jax.Array, bank=None,
                   vidx=None) -> jax.Array:
    """logits = x @ tableᵀ; with a banked table each row contracts against
    its own variant's (fine-tuned, fp16-rounded) unembedding.

    Banked path is a masked select over the V bank slots (same pattern as
    the banked MoE router): the table is read at most V times per step —
    never gathered per ROW, which would cost B copies of (vocab, d) and
    break the traffic-independent-of-batch-mix invariant (DESIGN.md §9) —
    and each row's logits come from the identical matmul the per-variant
    path runs, so greedy tokens match it exactly."""
    if bank is None or vidx is None:
        return x @ table.T.astype(x.dtype)
    logits = x @ bank[0].T.astype(x.dtype)               # slot 0 = base
    for v in range(1, bank.shape[0]):
        lv = x @ bank[v].T.astype(x.dtype)
        logits = jnp.where((vidx == v)[:, None, None], lv, logits)
    return logits


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU)
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, d_ff: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_ff, d), ("ffn", "embed")),
        "w_up": dense_init(k2, (d_ff, d), ("ffn", "embed")),
        "w_down": dense_init(k3, (d, d_ff), ("embed", "ffn")),
    }


def mlp_apply(p: dict, x: jax.Array, ov=None, vidx=None,
              ffn_ax: str = "ffn") -> jax.Array:
    """``ffn_ax`` names the hidden dim's logical axis — "ffn" for the
    standard gated MLP, "ffn_small" for replicated shared experts — so the
    per-shard kernel dispatch sees the same axes the weights were
    initialised (and placed) with."""
    h = (jax.nn.silu(linear(x, p["w_gate"], _oget(ov, "w_gate"), vidx,
                            waxes=(ffn_ax, "embed")))
         * linear(x, p["w_up"], _oget(ov, "w_up"), vidx,
                  waxes=(ffn_ax, "embed")))
    return linear(h, p["w_down"], _oget(ov, "w_down"), vidx,
                  waxes=("embed", ffn_ax))


# ---------------------------------------------------------------------------
# Non-gated MLP (whisper)
# ---------------------------------------------------------------------------

def mlp2_init(key, d: int, d_ff: int) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "w_in": dense_init(k1, (d_ff, d), ("ffn", "embed")),
        "w_out": dense_init(k2, (d, d_ff), ("embed", "ffn")),
    }


def mlp2_apply(p: dict, x: jax.Array, ov=None, vidx=None) -> jax.Array:
    return linear(jax.nn.gelu(linear(x, p["w_in"], _oget(ov, "w_in"), vidx,
                                     waxes=("ffn", "embed"))),
                  p["w_out"], _oget(ov, "w_out"), vidx,
                  waxes=("embed", "ffn"))
