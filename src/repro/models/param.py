"""Parameter containers: arrays carry their logical sharding axes at init.

Every model init builds a pytree of :class:`Param` (array + logical axis
names); ``split(params)`` separates it into (arrays, logical_specs) so the
distribution layer (repro.distributed.sharding) can map logical axes to mesh
axes without models knowing about meshes.

Logical axis vocabulary (see distributed/sharding.py for the mesh mapping):
  "embed"    d_model dims
  "q_heads"  fused num_heads*head_dim output dims
  "kv_heads" fused num_kv_heads*head_dim output dims
  "ffn"      MLP hidden dims
  "vocab"    vocabulary dims
  "experts"  MoE expert dims
  "ssm"      SSM inner dims
  "layers"   stacked scan dims (never sharded)
  None       replicated small dims (norm scales, per-axis delta vectors, ...)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Param:
    """Array + logical axes.  Registered as a pytree (axes static) so
    ``jax.eval_shape(model.init, rng)`` yields abstract Param trees for the
    dry-run without allocating."""
    value: jax.Array
    axes: tuple = dataclasses.field(metadata=dict(static=True))


def dense_init(key, shape: Sequence[int], axes: Sequence[Optional[str]],
               scale: Optional[float] = None, dtype=jnp.float32) -> Param:
    """Variance-scaling normal init: std = scale or 1/sqrt(fan_in).

    Weight convention: (d_out, d_in) — fan_in is the last dim.
    """
    fan_in = shape[-1]
    std = scale if scale is not None else fan_in ** -0.5
    val = (std * jax.random.normal(key, tuple(shape), jnp.float32)).astype(dtype)
    assert len(axes) == len(shape), (axes, shape)
    return Param(val, tuple(axes))


def zeros_init(shape, axes, dtype=jnp.float32) -> Param:
    return Param(jnp.zeros(tuple(shape), dtype), tuple(axes))


def ones_init(shape, axes, dtype=jnp.float32) -> Param:
    return Param(jnp.ones(tuple(shape), dtype), tuple(axes))


def is_param(x) -> bool:
    return isinstance(x, Param)


def split(params):
    """(tree of Param) -> (tree of arrays, tree of logical-axis tuples)."""
    arrays = jax.tree.map(lambda p: p.value, params, is_leaf=is_param)
    specs = jax.tree.map(lambda p: p.axes, params, is_leaf=is_param)
    return arrays, specs


def stack_layers(keyed_init, key, n: int):
    """Initialise ``n`` copies of a block and stack each leaf along a new
    leading "layers" axis (the scan dim)."""
    keys = jax.random.split(key, n)
    per_layer = [keyed_init(k) for k in keys]
    def _stack(*ps):
        vals = jnp.stack([p.value for p in ps])
        return Param(vals, ("layers",) + ps[0].axes)
    return jax.tree.map(_stack, *per_layer, is_leaf=is_param)


def count_params(arrays) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(arrays))
