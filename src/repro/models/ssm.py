"""SSM / recurrent sequence-mixing primitives.

Three cell families, each with a *chunkwise-parallel* training form and a
*recurrent* single-step form (decode path; also the test oracle):

* mLSTM (xLSTM): matrix memory C ∈ R^(hd×hd), exponential input gate,
  sigmoid forget gate, max-stabilizer m.  Chunkwise form is exactly
  equivalent to the recurrence (the stabilizer cancels in the output).
* sLSTM (xLSTM): scalar memory with hidden-state recurrence (R·h_{t-1}
  feeds the gates) — inherently sequential, implemented as lax.scan over
  time (the xLSTM paper accepts this non-parallelizability).
* Mamba2 (SSD): scalar-decay state S ∈ R^(P×N) per head; chunkwise SSD
  with causal decay matrices, no stabilizer needed (log dA ≤ 0).

Sequence layout: (B, S, H, ·); states carry (B, H, ·).
All internal math fp32; outputs cast back to input dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _pick_chunk(s: int, target: int = 256) -> int:
    c = min(target, s)
    while s % c:
        c -= 1
    return c


# ===========================================================================
# mLSTM
# ===========================================================================

def mlstm_init_state(b: int, h: int, hd: int) -> dict:
    return {
        "C": jnp.zeros((b, h, hd, hd), jnp.float32),
        "n": jnp.zeros((b, h, hd), jnp.float32),
        "m": jnp.zeros((b, h), jnp.float32),
    }


def mlstm_step(state: dict, q, k, v, i_gate, f_gate) -> tuple[dict, jax.Array]:
    """One recurrent step.  q,k,v: (B,H,hd); gates: (B,H) pre-activations."""
    qf = q.astype(jnp.float32) * (q.shape[-1] ** -0.5)
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    lf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))
    li = i_gate.astype(jnp.float32)
    m_new = jnp.maximum(lf + state["m"], li)
    f_act = jnp.exp(lf + state["m"] - m_new)[..., None]
    i_act = jnp.exp(li - m_new)[..., None]
    C = f_act[..., None] * state["C"] + i_act[..., None] * (
        kf[..., :, None] * vf[..., None, :])
    n = f_act * state["n"] + i_act * kf
    num = jnp.einsum("bhkv,bhk->bhv", C, qf)
    den = jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf))
    den = jnp.maximum(den, jnp.exp(-m_new))[..., None]
    h_out = (num / den).astype(q.dtype)
    return {"C": C, "n": n, "m": m_new}, h_out


def mlstm_chunkwise(q, k, v, i_gate, f_gate, state: dict | None = None,
                    chunk: int = 256) -> tuple[jax.Array, dict]:
    """Parallel chunkwise mLSTM over a full sequence.

    q,k,v: (B,S,H,hd); gates: (B,S,H).  Returns (h (B,S,H,hd), final state).
    """
    b, s, h, hd = q.shape
    c = _pick_chunk(s, chunk)
    nc = s // c
    if state is None:
        state = mlstm_init_state(b, h, hd)

    def to_chunks(x):
        return x.reshape(b, nc, c, *x.shape[2:]).swapaxes(0, 1)

    qf = to_chunks(q.astype(jnp.float32) * hd ** -0.5)   # (nc,B,c,H,hd)
    kf = to_chunks(k.astype(jnp.float32))
    vf = to_chunks(v.astype(jnp.float32))
    li = to_chunks(i_gate.astype(jnp.float32))           # (nc,B,c,H)
    lf = to_chunks(jax.nn.log_sigmoid(f_gate.astype(jnp.float32)))

    causal = jnp.tril(jnp.ones((c, c), bool))

    def chunk_step(carry, inp):
        C_p, n_p, m_p = carry
        qc, kc, vc, lic, lfc = inp
        bcum = jnp.cumsum(lfc, axis=1)                    # (B,c,H) inclusive
        a = lic - bcum                                    # a_s = ĩ_s − b_s
        rm = jnp.maximum(m_p[:, None, :],
                         jax.lax.cummax(a, axis=1))       # (B,c,H)
        # intra-chunk decay D_{is} = exp(a_s − rm_i), s ≤ i
        dmat = jnp.exp(a[:, None, :, :] - rm[:, :, None, :])      # (B,i,s,H)
        dmat = jnp.where(causal[None, :, :, None], dmat, 0.0)
        scores = jnp.einsum("bihd,bshd->bish", qc, kc)            # (B,i,s,H)
        w = scores * dmat
        o_intra = jnp.einsum("bish,bshd->bihd", w, vc)
        nd_intra = jnp.sum(w, axis=2)                             # (B,i,H)
        # inter-chunk (carry) contribution
        g = jnp.exp(m_p[:, None, :] - rm)                         # (B,i,H)
        o_inter = g[..., None] * jnp.einsum("bhkv,bihk->bihv", C_p, qc)
        nd_inter = g * jnp.einsum("bhk,bihk->bih", n_p, qc)
        m_i = bcum + rm
        num = o_intra + o_inter
        den = jnp.maximum(jnp.abs(nd_intra + nd_inter), jnp.exp(-m_i))
        h_c = num / den[..., None]
        # carry update:
        # m_next = b_tot + max(m_p, max_s a_s)
        # C_next = exp(b_tot + m_p − m_next)·C_p
        #        + Σ_s exp(b_tot − b_s + ĩ_s − m_next)·k_s v_sᵀ
        b_tot = bcum[:, -1, :]                                    # (B,H)
        rm_c = rm[:, -1, :]
        m_new = b_tot + rm_c
        decay_carry = jnp.exp(b_tot + m_p - m_new)                # (B,H)
        kv_w = jnp.exp((b_tot[:, None, :] - bcum + lic) - m_new[:, None, :])
        C_new = decay_carry[..., None, None] * C_p + \
            jnp.einsum("bsh,bshk,bshv->bhkv", kv_w, kc, vc)
        n_new = decay_carry[..., None] * n_p + \
            jnp.einsum("bsh,bshk->bhk", kv_w, kc)
        return (C_new, n_new, m_new), h_c

    (C_f, n_f, m_f), hs = jax.lax.scan(
        chunk_step, (state["C"], state["n"], state["m"]),
        (qf, kf, vf, li, lf))
    h_out = hs.swapaxes(0, 1).reshape(b, s, h, hd).astype(q.dtype)
    return h_out, {"C": C_f, "n": n_f, "m": m_f}


# ===========================================================================
# sLSTM
# ===========================================================================

def slstm_init_state(b: int, h: int, hd: int) -> dict:
    return {
        "c": jnp.zeros((b, h, hd), jnp.float32),
        "n": jnp.ones((b, h, hd), jnp.float32),
        "h": jnp.zeros((b, h, hd), jnp.float32),
        "m": jnp.zeros((b, h, hd), jnp.float32),
    }


def slstm_step(state: dict, zx, ix, fx, ox, r_z, r_i, r_f, r_o
               ) -> tuple[dict, jax.Array]:
    """One sLSTM step with per-head recurrent weights.

    zx/ix/fx/ox: (B,H,hd) input-projected pre-activations;
    r_*: (H, hd, hd) block-diagonal recurrent weights acting on h_{t-1},
    or (B, H, hd, hd) per-row (banked mixed-variant serving).
    """
    hp = state["h"]

    def rec(r):
        if r.ndim == 4:
            return jnp.einsum("bhd,bhde->bhe", hp, r)
        return jnp.einsum("bhd,hde->bhe", hp, r)
    z = jnp.tanh(zx.astype(jnp.float32) + rec(r_z))
    li = ix.astype(jnp.float32) + rec(r_i)
    lf = jax.nn.log_sigmoid(fx.astype(jnp.float32) + rec(r_f))
    o = jax.nn.sigmoid(ox.astype(jnp.float32) + rec(r_o))
    m_new = jnp.maximum(lf + state["m"], li)
    f_act = jnp.exp(lf + state["m"] - m_new)
    i_act = jnp.exp(li - m_new)
    c = f_act * state["c"] + i_act * z
    n = f_act * state["n"] + i_act
    h_new = o * (c / jnp.maximum(n, 1e-6))
    return {"c": c, "n": n, "h": h_new, "m": m_new}, h_new


def slstm_scan(zx, ix, fx, ox, r_z, r_i, r_f, r_o, state: dict | None = None
               ) -> tuple[jax.Array, dict]:
    """Sequential sLSTM over (B,S,H,hd) pre-activations."""
    b, s, h, hd = zx.shape
    if state is None:
        state = slstm_init_state(b, h, hd)

    def step(st, xs):
        return slstm_step(st, *xs, r_z, r_i, r_f, r_o)

    xs = tuple(x.swapaxes(0, 1) for x in (zx, ix, fx, ox))
    final, hs = jax.lax.scan(step, state, xs)
    return hs.swapaxes(0, 1).astype(zx.dtype), final


# ===========================================================================
# Mamba2 (SSD)
# ===========================================================================

def mamba_init_state(b: int, h: int, p: int, n: int) -> jax.Array:
    return jnp.zeros((b, h, p, n), jnp.float32)


def mamba_step(state: jax.Array, x, bm, cm, dt, a_log, d_skip
               ) -> tuple[jax.Array, jax.Array]:
    """One SSD step.  x: (B,H,P); bm/cm: (B,N); dt: (B,H);
    a_log (H,) or (B,H), d_skip (H,) or (B,H) (banked per-row)."""
    xf = x.astype(jnp.float32)
    a = -jnp.exp(a_log.astype(jnp.float32))               # (H,)|(B,H) neg
    da = jnp.exp(dt.astype(jnp.float32) * a)              # (B,H)
    upd = dt.astype(jnp.float32)[..., None, None] * (
        xf[..., :, None] * bm.astype(jnp.float32)[:, None, None, :])
    s_new = da[..., None, None] * state + upd
    y = jnp.einsum("bhpn,bn->bhp", s_new, cm.astype(jnp.float32))
    ds = d_skip.astype(jnp.float32)
    y = y + (ds[None, :, None] if ds.ndim == 1 else ds[:, :, None]) * xf
    return s_new, y.astype(x.dtype)


def mamba_chunkwise(x, bm, cm, dt, a_log, d_skip,
                    state: jax.Array | None = None, chunk: int = 128
                    ) -> tuple[jax.Array, jax.Array]:
    """Chunkwise-parallel SSD.

    x: (B,S,H,P); bm/cm: (B,S,N) (single B/C group shared over heads);
    dt: (B,S,H) post-softplus; a_log/d_skip: (H,) or (B,H) per-row (banked
    mixed-variant serving).
    Returns (y (B,S,H,P), final state (B,H,P,N)).
    """
    b, s, h, p = x.shape
    n = bm.shape[-1]
    c = _pick_chunk(s, chunk)
    nc = s // c
    if state is None:
        state = mamba_init_state(b, h, p, n)

    a = -jnp.exp(a_log.astype(jnp.float32))               # (H,)|(B,H)
    a_c = a[None, :] if a.ndim == 1 else a[:, None, :]    # vs dtk (B,c,H)
    ds = d_skip.astype(jnp.float32)
    ds_c = ds[None, None, :, None] if ds.ndim == 1 else ds[:, None, :, None]

    def to_chunks(t):
        return t.reshape(b, nc, c, *t.shape[2:]).swapaxes(0, 1)

    xc = to_chunks(x.astype(jnp.float32))                 # (nc,B,c,H,P)
    bc = to_chunks(bm.astype(jnp.float32))                # (nc,B,c,N)
    cc = to_chunks(cm.astype(jnp.float32))
    dtc = to_chunks(dt.astype(jnp.float32))               # (nc,B,c,H)

    causal = jnp.tril(jnp.ones((c, c), bool))

    lp_dtype = x.dtype  # bf16 in production: the (B,c,c,H) intra-chunk
    # matrices dominate SSD HBM traffic — keep them in the input dtype and
    # let the einsums accumulate fp32 (preferred_element_type)

    def chunk_step(s_p, inp):
        xk, bk, ck, dtk = inp
        ldak = dtk * a_c                                  # (B,c,H) log dA ≤ 0
        lcum = jnp.cumsum(ldak, axis=1)                   # inclusive
        # intra: M_{is} = (C_i·B_s)·exp(L_i − L_s)·dt_s for s ≤ i
        cb = jnp.einsum("bin,bsn->bis", ck.astype(lp_dtype),
                        bk.astype(lp_dtype),
                        preferred_element_type=jnp.float32)  # (B,i,s)
        decay = jnp.exp(lcum[:, :, None, :] - lcum[:, None, :, :])  # (B,i,s,H)
        decay = jnp.where(causal[None, :, :, None], decay, 0.0)
        m = (cb[..., None] * decay * dtk[:, None, :, :]).astype(lp_dtype)
        y = jnp.einsum("bish,bshp->bihp", m, xk.astype(lp_dtype),
                       preferred_element_type=jnp.float32)
        # inter: exp(L_i)·C_i·S_prev
        y = y + jnp.exp(lcum)[..., None] * jnp.einsum(
            "bhpn,bin->bihp", s_p, ck)
        y = y + ds_c * xk
        # carry: S_next = exp(L_c)·S_prev + Σ_s exp(L_c − L_s)·dt_s·x_s ⊗ B_s
        l_tot = lcum[:, -1, :]                            # (B,H)
        w = jnp.exp(l_tot[:, None, :] - lcum) * dtk       # (B,s,H)
        s_new = jnp.exp(l_tot)[..., None, None] * s_p + \
            jnp.einsum("bsh,bshp,bsn->bhpn", w, xk, bk)
        return s_new, y

    s_f, ys = jax.lax.scan(chunk_step, state, (xc, bc, cc, dtc))
    y = ys.swapaxes(0, 1).reshape(b, s, h, p).astype(x.dtype)
    return y, s_f
