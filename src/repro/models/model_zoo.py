"""Unified model API: one ``Model`` facade over the four family modules.

Methods (all functional, params = plain-array pytree after param.split):
  init(rng)                     -> Param tree (arrays + logical axes)
  forward(params, batch)        -> (logits, aux)          [train / eval]
  prefill(params, batch, T)     -> (last_logits, cache)   [serving]
  decode_step(params, tok, c)   -> (logits, cache)
  init_cache(batch, T)          -> cache pytree
  cache_pspecs(long_context)    -> logical-axes tree for the cache
  input_specs(shape_spec)       -> ShapeDtypeStruct batch stand-ins
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer, whisper, xlstm, zamba


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    _mod: Any

    # -- construction ------------------------------------------------------
    def init(self, rng) -> dict:
        return self._mod.init(rng, self.cfg)

    # -- compute -----------------------------------------------------------
    # ``overlay`` (models/delta_overlay.py) is an optional pytree of packed
    # per-module deltas riding alongside ``params``: matmuls with an entry
    # dispatch to the fused on-the-fly delta GEMM (serving a variant with
    # zero dense reconstruction); None means plain base/materialised params.
    # ``variant_idx`` (B,) int32 marks the overlay as BANKED (leading bank
    # axis on every leaf, slot 0 = base): each batch row fuses its own
    # variant's delta — one jitted call serves a mixed-variant batch
    # (DESIGN.md §9).
    def forward(self, params, batch, overlay=None, variant_idx=None):
        return self._mod.forward(params, batch, self.cfg, overlay=overlay,
                                 variant_idx=variant_idx)

    def prefill(self, params, batch, max_len: int, cache_dtype=jnp.bfloat16,
                overlay=None, variant_idx=None):
        return self._mod.prefill(params, batch, self.cfg, max_len,
                                 cache_dtype=cache_dtype, overlay=overlay,
                                 variant_idx=variant_idx)

    def decode_step(self, params, token, cache, overlay=None,
                    variant_idx=None):
        return self._mod.decode_step(params, token, cache, self.cfg,
                                     overlay=overlay,
                                     variant_idx=variant_idx)

    # -- speculative verify (DESIGN.md §15) --------------------------------
    # verify_step teacher-forces T tokens per row over the LIVE decode
    # cache (per-row positions) and returns (logits (B,T,V), rewind_state);
    # verify_rewind(rewind_state, keep) drops the rejected suffix — the
    # cache each lane would hold after consuming only its first keep[b]
    # tokens.  Both are bit-exact with T sequential decode_step calls:
    # attention families run a parallel teacher-forced pass (decode-exact
    # arithmetic per query — attention.verify_attention); recurrent-state
    # families (ssm/hybrid) scan decode_step itself, because their
    # sequence paths (e.g. xlstm's chunkwise mlstm) are NOT numerically
    # interchangeable with the stepwise recurrence, and snapshot the state
    # after every step so rewind is a per-row gather.
    def verify_step(self, params, tokens, cache, overlay=None,
                    variant_idx=None):
        if hasattr(self._mod, "verify_step"):
            logits, new_cache = self._mod.verify_step(
                params, tokens, cache, self.cfg, overlay=overlay,
                variant_idx=variant_idx)
            return logits, ("pos", new_cache, tokens.shape[1])

        def body(state, tok):
            lg, new_state = self._mod.decode_step(
                params, tok, state, self.cfg, overlay=overlay,
                variant_idx=variant_idx)
            return new_state, (lg, new_state)

        _, (logits, snaps) = jax.lax.scan(body, cache,
                                          jnp.swapaxes(tokens, 0, 1))
        return jnp.swapaxes(logits, 0, 1), ("snap", snaps, None)

    def verify_rewind(self, rewind_state, keep):
        """keep (B,) int32 in [1, T]: tokens each lane actually consumed."""
        mode, payload, span = rewind_state
        if mode == "pos":
            return self._mod.rewind_cache(payload, keep, span)
        # snapshot select: leaf (T, ...) -> per-row slice at keep[b] - 1,
        # the batch axis located via the state pspecs ("act_batch")
        specs = jax.tree.leaves(self.cache_pspecs(),
                                is_leaf=lambda x: isinstance(x, tuple))
        leaves, treedef = jax.tree_util.tree_flatten(payload)
        assert len(specs) == len(leaves), \
            "cache_pspecs out of sync with the snapshot structure"
        out = []
        for leaf, sp in zip(leaves, specs):
            ba = sp.index("act_batch") + 1          # +1: leading step axis
            shape = [1] * leaf.ndim
            shape[ba] = leaf.shape[ba]
            idx = jnp.broadcast_to(
                (keep - 1).astype(jnp.int32).reshape(shape),
                (1,) + leaf.shape[1:])
            out.append(jnp.take_along_axis(leaf, idx, axis=0)[0])
        return jax.tree_util.tree_unflatten(treedef, out)

    # -- caches ------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        if cfg.family == "ssm":
            return xlstm.init_state(cfg, batch)
        if cfg.family == "hybrid":
            return zamba.init_state(cfg, batch, max_len, dtype)
        if cfg.family == "audio":
            return whisper.init_cache(cfg, batch, max_len, dtype)
        return transformer.init_cache(cfg, batch, max_len, dtype)

    def cache_pspecs(self, long_context: bool = False,
                     kv_seq_shard: bool = False):
        cfg = self.cfg
        if cfg.family == "ssm":
            return xlstm.state_pspecs(cfg, long_context)
        if cfg.family == "hybrid":
            return zamba.state_pspecs(cfg, long_context)
        if cfg.family == "audio":
            return whisper.cache_pspecs(cfg, long_context, kv_seq_shard)
        return transformer.cache_pspecs(cfg, long_context, kv_seq_shard)

    # -- abstract inputs (dry-run) ------------------------------------------
    def input_specs(self, seq_len: int, global_batch: int,
                    kind: str = "train") -> dict:
        """ShapeDtypeStruct stand-ins for every model input (no allocation).

        For train/prefill: the full token batch (+ frontend stубs).
        For decode: a single-token batch (the cache is built separately).
        """
        cfg = self.cfg
        i32 = jnp.int32
        if kind == "decode":
            return {"tokens": jax.ShapeDtypeStruct((global_batch,), i32)}
        specs = {}
        if cfg.family == "vlm":
            n_img = cfg.num_image_tokens
            specs["image_embeds"] = jax.ShapeDtypeStruct(
                (global_batch, n_img, cfg.d_model), jnp.bfloat16)
            text_len = seq_len - n_img
            specs["tokens"] = jax.ShapeDtypeStruct((global_batch, text_len), i32)
        elif cfg.family == "audio":
            specs["frames"] = jax.ShapeDtypeStruct(
                (global_batch, cfg.encoder_frames, cfg.d_model), jnp.bfloat16)
            specs["tokens"] = jax.ShapeDtypeStruct((global_batch, seq_len), i32)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((global_batch, seq_len), i32)
        if kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((global_batch, seq_len), i32)
        return specs

    def batch_pspecs(self, kind: str = "train") -> dict:
        """Logical axes for input batches (mirrors input_specs keys)."""
        cfg = self.cfg
        if kind == "decode":
            return {"tokens": ("act_batch",)}
        specs = {}
        if cfg.family == "vlm":
            specs["image_embeds"] = ("act_batch", "act_seq", "act_embed")
        if cfg.family == "audio":
            specs["frames"] = ("act_batch", "act_seq", "act_embed")
        specs["tokens"] = ("act_batch", "act_seq")
        if kind == "train":
            specs["labels"] = ("act_batch", "act_seq")
        return specs


_FAMILY_MODULES = {
    "dense": transformer, "moe": transformer, "vlm": transformer,
    "ssm": xlstm, "hybrid": zamba, "audio": whisper,
}


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg=cfg, _mod=_FAMILY_MODULES[cfg.family])
