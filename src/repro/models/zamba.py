"""Zamba2-style hybrid: Mamba2 backbone + ONE shared attention block.

81 Mamba2 blocks; after every 6th block the single shared transformer
block (attention at width 2·d over concat[h, original_embedding], output
projected back to d, plus a gated MLP) is re-applied with the SAME weights
(13 applications + 3 trailing Mamba blocks).  Per-invocation LoRA deltas
from the Zamba2 paper are omitted (DESIGN.md §8) — weight sharing is the
property that matters for delta compression (one delta, reused 13×).

Decode state: per-Mamba-layer (SSD state + conv window) — O(1) in sequence
— plus one KV cache per shared-block application point.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical_constraint as lc
from repro.models import attention as A
from repro.models import ssm
from repro.models.delta_overlay import oget
from repro.models.layers import (embed_init, embed_lookup, linear,
                                 mlp_apply, mlp_init, psel, rmsnorm,
                                 rmsnorm_init, unembed_logits)
from repro.models.param import dense_init, ones_init, stack_layers, zeros_init
from repro.models.xlstm import causal_conv, conv_step


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def _dims(cfg):
    di = 2 * cfg.d_model
    h = cfg.ssm_heads
    p = di // h
    n = cfg.ssm_state
    return di, h, p, n


def mamba_block_init(key, cfg) -> dict:
    """Projections are SEPARATE per role (z / x / B,C / dt) rather than one
    fused w_in: a fused projection's output splits are misaligned with the
    model-axis shards, and GSPMD pays ~50 halo collective-permutes per
    layer re-slicing them (measured 156 GB/step).  B,C and dt are tiny and
    replicated over model ("ffn_small")."""
    d = cfg.d_model
    di, h, p, n = _dims(cfg)
    ks = jax.random.split(key, 7)
    return {
        "ln": rmsnorm_init(d),
        "w_z": dense_init(ks[6], (di, d), ("ssm", "embed")),
        "w_xc": dense_init(ks[1], (di, d), ("ssm", "embed")),
        "w_bc": dense_init(ks[2], (2 * n, d), ("ffn_small", "embed")),
        "w_dt": dense_init(ks[3], (h, d), ("ffn_small", "embed")),
        "conv_xc": dense_init(ks[4], (cfg.ssm_conv, di), (None, "ssm"),
                              scale=0.3),
        "conv_bc": dense_init(ks[5], (cfg.ssm_conv, 2 * n), (None, None),
                              scale=0.3),
        "a_log": zeros_init((h,), (None,)),
        "dt_bias": zeros_init((h,), (None,)),
        "d_skip": ones_init((h,), (None,)),
        "gate_norm": ones_init((di,), (None,)),
        "w_out": dense_init(ks[0], (d, di), ("embed", "ssm")),
    }


def mamba_block_state(cfg, batch: int) -> dict:
    di, h, p, n = _dims(cfg)
    return {"ssm": ssm.mamba_init_state(batch, h, p, n),
            "conv_xc": jnp.zeros((batch, cfg.ssm_conv - 1, di), jnp.float32),
            "conv_bc": jnp.zeros((batch, cfg.ssm_conv - 1, 2 * n),
                                 jnp.float32)}


def _hsel(p, key, ov, vidx):
    """Banked per-row select for a non-broadcast param (SSD (H,) vectors,
    (K,C) conv kernels): p[key], or bank[vidx] prepending a batch dim."""
    return psel(p[key], oget(ov, key), vidx, lead=0)


def _mamba_proj(p, x, cfg, ov=None, vidx=None):
    di, h, _, n = _dims(cfg)
    xi = rmsnorm(x, psel(p["ln"], oget(ov, "ln"), vidx), cfg.norm_eps)
    z = linear(xi, p["w_z"], oget(ov, "w_z"), vidx,
               waxes=("ssm", "embed"))
    xc = linear(xi, p["w_xc"], oget(ov, "w_xc"), vidx,
                waxes=("ssm", "embed"))
    bc = linear(xi, p["w_bc"], oget(ov, "w_bc"), vidx,
                waxes=("ffn_small", "embed"))
    dt_raw = linear(xi, p["w_dt"], oget(ov, "w_dt"), vidx,
                    waxes=("ffn_small", "embed"))
    return z, xc, bc, dt_raw


def _mamba_post(p, y, z, x, cfg, ov=None, vidx=None):
    b, s, _ = x.shape
    di, h, pp, n = _dims(cfg)
    y = y.reshape(b, s, di) * jax.nn.silu(z)
    y = rmsnorm(y, psel(p["gate_norm"], oget(ov, "gate_norm"), vidx),
                cfg.norm_eps)
    return x + linear(y, p["w_out"], oget(ov, "w_out"), vidx,
                      waxes=("embed", "ssm"))


def mamba_block_apply(p, x, cfg, state: dict, ov=None, vidx=None):
    b, s, d = x.shape
    di, h, pp, n = _dims(cfg)
    z, xc_pre, bc_pre, dt_raw = _mamba_proj(p, x, cfg, ov=ov, vidx=vidx)
    xc = jax.nn.silu(causal_conv(xc_pre, _hsel(p, "conv_xc", ov, vidx)))
    bc = jax.nn.silu(causal_conv(bc_pre, _hsel(p, "conv_bc", ov, vidx)))
    bm, cm = bc[..., :n], bc[..., n:]
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32)
        + psel(p["dt_bias"], oget(ov, "dt_bias"), vidx).astype(jnp.float32))
    x_heads = lc(xc.reshape(b, s, h, pp), "act_batch", "act_seq", "act_ssm", None)
    y, ssm_state = ssm.mamba_chunkwise(
        x_heads, bm, cm, dt, _hsel(p, "a_log", ov, vidx),
        _hsel(p, "d_skip", ov, vidx), state=state["ssm"])
    tail_xc = jnp.concatenate(
        [state["conv_xc"].astype(xc_pre.dtype), xc_pre],
        axis=1)[:, -(cfg.ssm_conv - 1):]
    tail_bc = jnp.concatenate(
        [state["conv_bc"].astype(bc_pre.dtype), bc_pre],
        axis=1)[:, -(cfg.ssm_conv - 1):]
    return (_mamba_post(p, y, z, x, cfg, ov=ov, vidx=vidx),
            {"ssm": ssm_state, "conv_xc": tail_xc.astype(jnp.float32),
             "conv_bc": tail_bc.astype(jnp.float32)})


def mamba_block_step(p, x, cfg, state: dict, ov=None, vidx=None):
    b, _, d = x.shape
    di, h, pp, n = _dims(cfg)
    z, xc_pre, bc_pre, dt_raw = _mamba_proj(p, x, cfg, ov=ov, vidx=vidx)
    win_xc, xc1 = conv_step(state["conv_xc"].astype(xc_pre.dtype),
                            xc_pre[:, 0], _hsel(p, "conv_xc", ov, vidx))
    win_bc, bc1 = conv_step(state["conv_bc"].astype(bc_pre.dtype),
                            bc_pre[:, 0], _hsel(p, "conv_bc", ov, vidx))
    xc = jax.nn.silu(xc1)
    bc = jax.nn.silu(bc1)
    bm, cm = bc[..., :n], bc[..., n:]
    dtb = psel(p["dt_bias"], oget(ov, "dt_bias"), vidx, lead=0)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                         + dtb.astype(jnp.float32))
    ssm_state, y = ssm.mamba_step(state["ssm"], xc.reshape(b, h, pp), bm, cm,
                                  dt, _hsel(p, "a_log", ov, vidx),
                                  _hsel(p, "d_skip", ov, vidx))
    return (_mamba_post(p, y[:, None], z, x, cfg, ov=ov, vidx=vidx),
            {"ssm": ssm_state, "conv_xc": win_xc.astype(jnp.float32),
             "conv_bc": win_bc.astype(jnp.float32)})


# ---------------------------------------------------------------------------
# shared attention block (width 2d in, d out)
# ---------------------------------------------------------------------------

def shared_block_init(key, cfg) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    return {
        "ln1": rmsnorm_init(2 * d),
        "wq": dense_init(ks[0], (cfg.q_dim, 2 * d), ("q_heads", "embed")),
        "wk": dense_init(ks[1], (cfg.kv_dim, 2 * d), ("kv_heads", "embed")),
        "wv": dense_init(ks[2], (cfg.kv_dim, 2 * d), ("kv_heads", "embed")),
        "wo": dense_init(ks[3], (d, cfg.q_dim), ("embed", "q_heads")),
        "ln2": rmsnorm_init(d),
        "mlp": mlp_init(ks[4], d, cfg.d_ff),
    }


def _shared_qkv(p, h2, cfg, positions, ov=None, vidx=None):
    b, s, _ = h2.shape
    hi = rmsnorm(h2, psel(p["ln1"], oget(ov, "ln1"), vidx), cfg.norm_eps)
    q = linear(hi, p["wq"], oget(ov, "wq"), vidx, waxes=("q_heads", "embed")
               ).reshape(b, s, cfg.num_heads, cfg.head_dim)
    k = linear(hi, p["wk"], oget(ov, "wk"), vidx, waxes=("kv_heads", "embed")
               ).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    v = linear(hi, p["wv"], oget(ov, "wv"), vidx, waxes=("kv_heads", "embed")
               ).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    from repro.models.layers import apply_rope
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def shared_block_apply(p, x, x0, cfg, positions, ov=None, vidx=None):
    h2 = jnp.concatenate([x, x0], axis=-1)
    q, k, v = _shared_qkv(p, h2, cfg, positions, ov=ov, vidx=vidx)
    o = A.flash_attention(q, k, v, causal=True)
    x = x + linear(o.reshape(*x.shape[:-1], cfg.q_dim), p["wo"],
                   oget(ov, "wo"), vidx, waxes=("embed", "q_heads"))
    x = x + mlp_apply(p["mlp"],
                      rmsnorm(x, psel(p["ln2"], oget(ov, "ln2"), vidx),
                              cfg.norm_eps),
                      ov=oget(ov, "mlp"), vidx=vidx)
    return x


def shared_block_step(p, x, x0, cfg, cache: dict, pos, ov=None, vidx=None):
    """``pos`` is (B,) — per-lane decode positions."""
    h2 = jnp.concatenate([x, x0], axis=-1)
    q, k, v = _shared_qkv(p, h2, cfg, jnp.asarray(pos, jnp.int32)[:, None],
                          ov=ov, vidx=vidx)
    new_cache = A.cache_insert(cache, k, v, pos)
    o = A.decode_attention(q, new_cache["k"], new_cache["v"],
                           new_cache["slot_pos"], pos)
    x = x + linear(o.reshape(*x.shape[:-1], cfg.q_dim), p["wo"],
                   oget(ov, "wo"), vidx, waxes=("embed", "q_heads"))
    x = x + mlp_apply(p["mlp"],
                      rmsnorm(x, psel(p["ln2"], oget(ov, "ln2"), vidx),
                              cfg.norm_eps),
                      ov=oget(ov, "mlp"), vidx=vidx)
    return x, new_cache


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

def _layout(cfg) -> tuple[int, int, int]:
    """(n_super, per, n_rem): num_layers = n_super*per + n_rem."""
    per = cfg.attn_every
    n_super = cfg.num_layers // per
    return n_super, per, cfg.num_layers - n_super * per


def init(rng, cfg) -> dict:
    ks = jax.random.split(rng, 5)
    return {
        "embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model),
        "final_norm": rmsnorm_init(cfg.d_model),
        "unembed": dense_init(ks[1], (cfg.padded_vocab, cfg.d_model),
                              ("vocab", "embed"), scale=cfg.d_model ** -0.5),
        "mamba": stack_layers(lambda k: mamba_block_init(k, cfg), ks[2],
                              cfg.num_layers),
        "shared": shared_block_init(ks[3], cfg),
    }


def _rep(tree, n):
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape).copy(),
                        tree)


def mamba_only_state(cfg, batch: int) -> dict:
    """Training-path state: SSD carries only, no KV caches allocated."""
    return {"pos": jnp.zeros((batch,), jnp.int32),
            "mamba": _rep(mamba_block_state(cfg, batch), cfg.num_layers),
            "attn_kv": None}


def init_state(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    n_super, per, n_rem = _layout(cfg)
    kv = A.make_kv_cache(batch, max_len, cfg.num_kv_heads, cfg.head_dim, dtype)
    st = mamba_only_state(cfg, batch)
    st["attn_kv"] = _rep(kv, n_super)
    return st


def state_pspecs(cfg, long_context: bool = False):
    seq_ax = "act_seq" if long_context else None
    return {
        "pos": ("act_batch",),
        "mamba": {"ssm": (None, "act_batch", "act_ssm", None, None),
                  "conv_xc": (None, "act_batch", None, "act_ssm"),
                  "conv_bc": (None, "act_batch", None, None)},
        "attn_kv": {"k": (None, "act_batch", seq_ax, "act_kv", "act_hd"),
                    "v": (None, "act_batch", seq_ax, "act_kv", "act_hd"),
                    "slot_pos": (None, "act_batch", seq_ax)},
    }


def _split_mamba(tree, cfg):
    n_super, per, n_rem = _layout(cfg)
    main = jax.tree.map(lambda a: a[:n_super * per].reshape(
        n_super, per, *a.shape[1:]), tree)
    rem = jax.tree.map(lambda a: a[n_super * per:], tree)
    return main, rem


def forward(params, batch, cfg, state: dict | None = None, overlay=None,
            variant_idx=None):
    vidx = variant_idx
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = embed_lookup(params["embed"], tokens, cfg.compute_dtype,
                     bank=oget(overlay, "embed"), vidx=vidx)
    x = lc(x, "act_batch", "act_seq", "act_embed")
    x0 = x
    positions = jnp.arange(s)
    if state is None:
        state = mamba_only_state(cfg, b)
    m_params, r_params = _split_mamba(params["mamba"], cfg)
    m_ov, r_ov = _split_mamba(oget(overlay, "mamba"), cfg)
    sh_ov = oget(overlay, "shared")
    m_state, r_state = _split_mamba(state["mamba"], cfg)
    n_super, per, n_rem = _layout(cfg)
    shared = params["shared"]

    def body(h, xs):
        mp, mo, ms = xs
        new_states = []
        for j in range(per):
            pj = jax.tree.map(lambda a: a[j], mp)
            oj = jax.tree.map(lambda a: a[j], mo)
            sj = jax.tree.map(lambda a: a[j], ms)
            h, sj_new = mamba_block_apply(pj, h, cfg, sj, ov=oj, vidx=vidx)
            new_states.append(sj_new)
        h = shared_block_apply(shared, h, x0, cfg, positions, ov=sh_ov,
                               vidx=vidx)
        return h, jax.tree.map(lambda *a: jnp.stack(a), *new_states)

    body_fn = body
    if cfg.remat:
        body_fn = jax.checkpoint(body,
                                 policy=jax.checkpoint_policies.nothing_saveable)
    x, m_new = jax.lax.scan(body_fn, x, (m_params, m_ov, m_state))

    r_new = []
    for j in range(n_rem):
        pj = jax.tree.map(lambda a: a[j], r_params)
        oj = jax.tree.map(lambda a: a[j], r_ov)
        sj = jax.tree.map(lambda a: a[j], r_state)
        x, sj_new = mamba_block_apply(pj, x, cfg, sj, ov=oj, vidx=vidx)
        r_new.append(sj_new)

    x = rmsnorm(x, psel(params["final_norm"], oget(overlay, "final_norm"),
                        vidx), cfg.norm_eps)
    logits = unembed_logits(x, params["unembed"],
                            bank=oget(overlay, "unembed"), vidx=vidx)
    logits = lc(logits, "act_batch", "act_seq", "act_vocab")
    flat_m = jax.tree.map(lambda a: a.reshape(-1, *a.shape[2:]), m_new)
    if r_new:
        r_stack = jax.tree.map(lambda *a: jnp.stack(a), *r_new)
        flat_m = jax.tree.map(lambda a, b2: jnp.concatenate([a, b2]),
                              flat_m, r_stack)
    new_state = {"pos": state["pos"] + s, "mamba": flat_m,
                 "attn_kv": state.get("attn_kv")}
    return logits, {"moe_aux": jnp.float32(0), "state": new_state}


def prefill(params, batch, cfg, max_len: int, cache_dtype=jnp.bfloat16,
            overlay=None, variant_idx=None):
    """Single pass over the prompt: SSD states carried, shared-block K/V
    captured at every application point to fill the KV caches."""
    vidx = variant_idx
    b, s = batch["tokens"].shape
    state0 = init_state(cfg, b, max_len, cache_dtype)
    x = embed_lookup(params["embed"], batch["tokens"], cfg.compute_dtype,
                     bank=oget(overlay, "embed"), vidx=vidx)
    x = lc(x, "act_batch", "act_seq", "act_embed")
    x0 = x
    positions = jnp.arange(s)
    m_params, r_params = _split_mamba(params["mamba"], cfg)
    m_ov, r_ov = _split_mamba(oget(overlay, "mamba"), cfg)
    sh_ov = oget(overlay, "shared")
    m_state, r_state = _split_mamba(state0["mamba"], cfg)
    n_super, per, n_rem = _layout(cfg)

    def body(h, xs):
        mp, mo, ms = xs
        new_states = []
        for j in range(per):
            pj = jax.tree.map(lambda a: a[j], mp)
            oj = jax.tree.map(lambda a: a[j], mo)
            sj = jax.tree.map(lambda a: a[j], ms)
            h, sj_new = mamba_block_apply(pj, h, cfg, sj, ov=oj, vidx=vidx)
            new_states.append(sj_new)
        h2 = jnp.concatenate([h, x0], axis=-1)
        _, k, v = _shared_qkv(params["shared"], h2, cfg, positions, ov=sh_ov,
                              vidx=vidx)
        h = shared_block_apply(params["shared"], h, x0, cfg, positions,
                               ov=sh_ov, vidx=vidx)
        return h, (jax.tree.map(lambda *a: jnp.stack(a), *new_states), k, v)

    x, (m_new, k_all, v_all) = jax.lax.scan(body, x,
                                            (m_params, m_ov, m_state))
    r_new = []
    for j in range(n_rem):
        pj = jax.tree.map(lambda a: a[j], r_params)
        oj = jax.tree.map(lambda a: a[j], r_ov)
        sj = jax.tree.map(lambda a: a[j], r_state)
        x, sj_new = mamba_block_apply(pj, x, cfg, sj, ov=oj, vidx=vidx)
        r_new.append(sj_new)

    x = rmsnorm(x, psel(params["final_norm"], oget(overlay, "final_norm"),
                        vidx), cfg.norm_eps)
    logits = unembed_logits(x, params["unembed"],
                            bank=oget(overlay, "unembed"), vidx=vidx)

    kv = jax.vmap(lambda c, kk, vv: A.cache_insert(c, kk, vv, 0))(
        state0["attn_kv"], k_all, v_all)
    flat_m = jax.tree.map(lambda a: a.reshape(-1, *a.shape[2:]), m_new)
    if r_new:
        r_stack = jax.tree.map(lambda *a: jnp.stack(a), *r_new)
        flat_m = jax.tree.map(lambda a, b2: jnp.concatenate([a, b2]),
                              flat_m, r_stack)
    return logits[:, -1, :], {"pos": jnp.full((b,), s, jnp.int32),
                              "mamba": flat_m, "attn_kv": kv}


def decode_step(params, token, state, cfg, overlay=None, variant_idx=None):
    vidx = variant_idx
    pos = state["pos"]                      # (B,) per-lane positions
    b = token.shape[0]
    x = embed_lookup(params["embed"], token[:, None], cfg.compute_dtype,
                     bank=oget(overlay, "embed"), vidx=vidx)
    x0 = x
    m_params, r_params = _split_mamba(params["mamba"], cfg)
    m_ov, r_ov = _split_mamba(oget(overlay, "mamba"), cfg)
    sh_ov = oget(overlay, "shared")
    m_state, r_state = _split_mamba(state["mamba"], cfg)
    n_super, per, n_rem = _layout(cfg)

    def body(h, xs):
        mp, mo, ms, kv = xs
        new_states = []
        for j in range(per):
            pj = jax.tree.map(lambda a: a[j], mp)
            oj = jax.tree.map(lambda a: a[j], mo)
            sj = jax.tree.map(lambda a: a[j], ms)
            h, sj_new = mamba_block_step(pj, h, cfg, sj, ov=oj, vidx=vidx)
            new_states.append(sj_new)
        h, kv_new = shared_block_step(params["shared"], h, x0, cfg, kv, pos,
                                      ov=sh_ov, vidx=vidx)
        return h, (jax.tree.map(lambda *a: jnp.stack(a), *new_states), kv_new)

    x, (m_new, kv_new) = jax.lax.scan(body, x,
                                      (m_params, m_ov, m_state,
                                       state["attn_kv"]))
    r_new = []
    for j in range(n_rem):
        pj = jax.tree.map(lambda a: a[j], r_params)
        oj = jax.tree.map(lambda a: a[j], r_ov)
        sj = jax.tree.map(lambda a: a[j], r_state)
        x, sj_new = mamba_block_step(pj, x, cfg, sj, ov=oj, vidx=vidx)
        r_new.append(sj_new)

    x = rmsnorm(x, psel(params["final_norm"], oget(overlay, "final_norm"),
                        vidx), cfg.norm_eps)
    logits = unembed_logits(x, params["unembed"],
                            bank=oget(overlay, "unembed"), vidx=vidx)
    flat_m = jax.tree.map(lambda a: a.reshape(-1, *a.shape[2:]), m_new)
    if r_new:
        r_stack = jax.tree.map(lambda *a: jnp.stack(a), *r_new)
        flat_m = jax.tree.map(lambda a, b2: jnp.concatenate([a, b2]),
                              flat_m, r_stack)
    new_state = {"pos": pos + 1, "mamba": flat_m, "attn_kv": kv_new}
    return logits[:, 0, :], new_state
