"""xLSTM language model (xlstm-350m): mLSTM + sLSTM blocks, pattern 7:1.

Block structure follows arXiv:2405.04517 (knobs noted in DESIGN.md §8):
* mLSTM block: pre-LN → up-proj ×2 (mixer + gate branch) → causal conv4 →
  q/k from conv path, v from pre-conv path → chunkwise matrix-memory cell →
  per-head RMS norm → SiLU-gated output → down-proj.  O(1) decode state.
* sLSTM block: pre-LN → causal conv4 feeding i/f gates → scalar-memory
  recurrence with block-diagonal per-head recurrent weights → per-head
  norm → gated 4/3 FFN.  Sequential over time (lax.scan).

Layers scan as super-blocks of (7 mLSTM, 1 sLSTM); decode state is an
explicit pytree so serving hot-swap works identically to transformers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical_constraint as lc
from repro.models import ssm
from repro.models.delta_overlay import oget
from repro.models.layers import (embed_init, embed_lookup, linear, psel,
                                 rmsnorm, rmsnorm_init, unembed_logits)
from repro.models.param import dense_init, ones_init, stack_layers, zeros_init


# ---------------------------------------------------------------------------
# causal depthwise conv
# ---------------------------------------------------------------------------

def causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """x (B,S,C), w (K,C) depthwise; left-padded causal.  w may also be
    (B,K,C) — per-row banked conv weights (mixed-variant batches)."""
    k = w.shape[-2]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    s = x.shape[1]
    if w.ndim == 2:
        y = sum(xp[:, j:j + s] * w[j][None, None, :].astype(x.dtype)
                for j in range(k))
    else:
        y = sum(xp[:, j:j + s] * w[:, j][:, None, :].astype(x.dtype)
                for j in range(k))
    return y


def conv_step(window: jax.Array, x_new: jax.Array, w: jax.Array
              ) -> tuple[jax.Array, jax.Array]:
    """window (B,K-1,C) past inputs; returns (new window, conv output (B,C)).
    w (K,C) shared or (B,K,C) per row (banked)."""
    k = w.shape[-2]
    full = jnp.concatenate([window, x_new[:, None, :]], axis=1)  # (B,K,C)
    wf = w.astype(x_new.dtype)
    if w.ndim == 2:
        y = jnp.einsum("bkc,kc->bc", full, wf)
    else:
        y = jnp.einsum("bkc,bkc->bc", full, wf)
    return full[:, 1:], y


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------

def mlstm_block_init(key, cfg) -> dict:
    d = cfg.d_model
    di = 2 * d
    h = cfg.num_heads
    ks = jax.random.split(key, 8)
    return {
        "ln": rmsnorm_init(d),
        "w_up": dense_init(ks[0], (di, d), ("ssm", "embed")),
        "w_gate": dense_init(ks[1], (di, d), ("ssm", "embed")),
        "conv": dense_init(ks[2], (cfg.ssm_conv, di), (None, "ssm"), scale=0.3),
        "wq": dense_init(ks[3], (di, di), ("ssm", None)),
        "wk": dense_init(ks[4], (di, di), ("ssm", None)),
        "wv": dense_init(ks[5], (di, di), ("ssm", None)),
        "w_if": dense_init(ks[6], (2 * h, di), (None, "ssm"), scale=0.02),
        "b_if": zeros_init((2 * h,), (None,)),
        "out_norm": ones_init((di,), (None,)),
        "w_down": dense_init(ks[7], (d, di), ("embed", "ssm")),
    }


def _mlstm_heads(cfg):
    di = 2 * cfg.d_model
    return cfg.num_heads, di // cfg.num_heads


def mlstm_block_state(cfg, batch: int) -> dict:
    h, hd = _mlstm_heads(cfg)
    di = 2 * cfg.d_model
    return {"cell": ssm.mlstm_init_state(batch, h, hd),
            "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di), jnp.float32)}


def _mlstm_pre(p, x, cfg, ov=None, vidx=None):
    """Shared projection work for both seq and step paths (pre-conv)."""
    hcount, hd = _mlstm_heads(cfg)
    xi = rmsnorm(x, psel(p["ln"], oget(ov, "ln"), vidx), cfg.norm_eps)
    xm = linear(xi, p["w_up"], oget(ov, "w_up"), vidx,
                waxes=("ssm", "embed"))
    z = linear(xi, p["w_gate"], oget(ov, "w_gate"), vidx,
               waxes=("ssm", "embed"))
    return xm, z


def _conv_w(p, key, ov, vidx):
    """Conv weight, per-row (B,K,C) when banked."""
    return psel(p[key], oget(ov, key), vidx, lead=0)


def _out_norm_scale(p, ov, vidx, b, hcount, hd):
    on = oget(ov, "out_norm")
    if on is None or vidx is None:
        return p["out_norm"].reshape(hcount, hd)
    return jnp.take(on, vidx, axis=0).reshape(b, 1, hcount, hd)


def mlstm_block_apply(p, x, cfg, state: dict, ov=None, vidx=None):
    """Sequence path: x (B,S,D) -> (y, new state)."""
    b, s, d = x.shape
    hcount, hd = _mlstm_heads(cfg)
    xm, z = _mlstm_pre(p, x, cfg, ov=ov, vidx=vidx)
    xc = jax.nn.silu(causal_conv(xm, _conv_w(p, "conv", ov, vidx)))
    xc = lc(xc, "act_batch", "act_seq", "act_ssm")
    q = linear(xc, p["wq"], oget(ov, "wq"), vidx,
               waxes=("ssm", None)).reshape(b, s, hcount, hd)
    k = linear(xc, p["wk"], oget(ov, "wk"), vidx, waxes=("ssm", None)
               ).reshape(b, s, hcount, hd) * hd ** -0.5
    v = linear(xm, p["wv"], oget(ov, "wv"), vidx,
               waxes=("ssm", None)).reshape(b, s, hcount, hd)
    gates = (linear(xc, p["w_if"], oget(ov, "w_if"), vidx,
                    waxes=(None, "ssm"))
             + psel(p["b_if"], oget(ov, "b_if"), vidx).astype(x.dtype))
    ig, fg = jnp.split(gates, 2, axis=-1)              # (B,S,H)
    h_seq, cell = ssm.mlstm_chunkwise(q, k, v, ig, fg, state=state["cell"])
    h_seq = rmsnorm(h_seq, _out_norm_scale(p, ov, vidx, b, hcount, hd),
                    cfg.norm_eps)
    y = linear(h_seq.reshape(b, s, 2 * d) * jax.nn.silu(z), p["w_down"],
               oget(ov, "w_down"), vidx, waxes=("embed", "ssm"))
    # conv window for decode continuation
    di = 2 * d
    tail = jnp.concatenate(
        [state["conv"].astype(xm.dtype), xm], axis=1)[:, -(cfg.ssm_conv - 1):]
    return x + y, {"cell": cell, "conv": tail.astype(jnp.float32)}


def mlstm_block_step(p, x, cfg, state: dict, ov=None, vidx=None):
    """Decode path: x (B,1,D)."""
    b, _, d = x.shape
    hcount, hd = _mlstm_heads(cfg)
    xm, z = _mlstm_pre(p, x, cfg, ov=ov, vidx=vidx)
    conv_win, xc1 = conv_step(state["conv"].astype(xm.dtype), xm[:, 0],
                              _conv_w(p, "conv", ov, vidx))
    xc = jax.nn.silu(xc1)[:, None, :]
    q = linear(xc, p["wq"], oget(ov, "wq"), vidx,
               waxes=("ssm", None)).reshape(b, hcount, hd)
    k = linear(xc, p["wk"], oget(ov, "wk"), vidx, waxes=("ssm", None)
               ).reshape(b, hcount, hd) * hd ** -0.5
    v = linear(xm, p["wv"], oget(ov, "wv"), vidx,
               waxes=("ssm", None)).reshape(b, hcount, hd)
    gates = (linear(xc, p["w_if"], oget(ov, "w_if"), vidx,
                    waxes=(None, "ssm"))
             + psel(p["b_if"], oget(ov, "b_if"), vidx).astype(x.dtype))[:, 0]
    ig, fg = jnp.split(gates, 2, axis=-1)
    cell, h_t = ssm.mlstm_step(state["cell"], q, k, v, ig, fg)
    h_t = rmsnorm(h_t[:, None].reshape(b, 1, hcount, hd),
                  _out_norm_scale(p, ov, vidx, b, hcount, hd), cfg.norm_eps)
    y = linear(h_t.reshape(b, 1, 2 * d) * jax.nn.silu(z), p["w_down"],
               oget(ov, "w_down"), vidx, waxes=("embed", "ssm"))
    return x + y, {"cell": cell, "conv": conv_win.astype(jnp.float32)}


# ---------------------------------------------------------------------------
# sLSTM block
# ---------------------------------------------------------------------------

def slstm_block_init(key, cfg) -> dict:
    d = cfg.d_model
    h = cfg.num_heads
    hd = d // h
    ffn = max(64, int(4 * d / 3) // 64 * 64)
    ks = jax.random.split(key, 9)
    return {
        "ln": rmsnorm_init(d),
        "conv": dense_init(ks[0], (cfg.ssm_conv, d), (None, "embed"), scale=0.3),
        "w_zi": dense_init(ks[1], (2 * d, d), (None, "embed")),   # z,o from x
        "w_if": dense_init(ks[2], (2 * d, d), (None, "embed")),   # i,f from conv
        "r_z": dense_init(ks[3], (h, hd, hd), (None, None, None), scale=0.1),
        "r_i": dense_init(ks[4], (h, hd, hd), (None, None, None), scale=0.1),
        "r_f": dense_init(ks[5], (h, hd, hd), (None, None, None), scale=0.1),
        "r_o": dense_init(ks[6], (h, hd, hd), (None, None, None), scale=0.1),
        "out_norm": ones_init((d,), (None,)),
        "w_ff1": dense_init(ks[7], (2 * ffn, d), ("ffn", "embed")),
        "w_ff2": dense_init(ks[8], (d, ffn), ("embed", "ffn")),
    }


def slstm_block_state(cfg, batch: int) -> dict:
    h = cfg.num_heads
    hd = cfg.d_model // h
    return {"cell": ssm.slstm_init_state(batch, h, hd),
            "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_model), jnp.float32)}


def _slstm_gate_pre(p, xi, xc, cfg, ov=None, vidx=None):
    b = xi.shape[0]
    s = xi.shape[1]
    h = cfg.num_heads
    hd = cfg.d_model // h
    zo = linear(xi, p["w_zi"], oget(ov, "w_zi"), vidx,
                waxes=(None, "embed"))
    if_ = linear(xc, p["w_if"], oget(ov, "w_if"), vidx,
                 waxes=(None, "embed"))
    zx, ox = jnp.split(zo, 2, axis=-1)
    ix, fx = jnp.split(if_, 2, axis=-1)
    rs = lambda t: t.reshape(b, s, h, hd)
    return rs(zx), rs(ix), rs(fx), rs(ox)


def _slstm_rec(p, ov, vidx):
    """Recurrent weights r_z/r_i/r_f/r_o — per-row (B,H,hd,hd) banked."""
    return tuple(psel(p[k], oget(ov, k), vidx, lead=0)
                 for k in ("r_z", "r_i", "r_f", "r_o"))


def _slstm_post(p, h_seq, x, cfg, ov=None, vidx=None):
    b, s = x.shape[:2]
    d = cfg.d_model
    hn = rmsnorm(h_seq.reshape(b, s, d),
                 psel(p["out_norm"], oget(ov, "out_norm"), vidx),
                 cfg.norm_eps)
    ff = linear(hn, p["w_ff1"], oget(ov, "w_ff1"), vidx,
                waxes=("ffn", "embed"))
    gate, up = jnp.split(ff, 2, axis=-1)
    y = linear(jax.nn.silu(gate) * up, p["w_ff2"], oget(ov, "w_ff2"), vidx,
               waxes=("embed", "ffn"))
    return x + y


def slstm_block_apply(p, x, cfg, state: dict, ov=None, vidx=None):
    xi = rmsnorm(x, psel(p["ln"], oget(ov, "ln"), vidx), cfg.norm_eps)
    xc = jax.nn.silu(causal_conv(xi, _conv_w(p, "conv", ov, vidx)))
    pre = _slstm_gate_pre(p, xi, xc, cfg, ov=ov, vidx=vidx)
    h_seq, cell = ssm.slstm_scan(*pre, *_slstm_rec(p, ov, vidx),
                                 state=state["cell"])
    tail = jnp.concatenate(
        [state["conv"].astype(xi.dtype), xi], axis=1)[:, -(cfg.ssm_conv - 1):]
    return (_slstm_post(p, h_seq, x, cfg, ov=ov, vidx=vidx),
            {"cell": cell, "conv": tail.astype(jnp.float32)})


def slstm_block_step(p, x, cfg, state: dict, ov=None, vidx=None):
    xi = rmsnorm(x, psel(p["ln"], oget(ov, "ln"), vidx), cfg.norm_eps)
    conv_win, xc1 = conv_step(state["conv"].astype(xi.dtype), xi[:, 0],
                              _conv_w(p, "conv", ov, vidx))
    xc = jax.nn.silu(xc1)[:, None, :]
    pre = _slstm_gate_pre(p, xi, xc, cfg, ov=ov, vidx=vidx)
    cell, h_t = ssm.slstm_step(state["cell"], *(t[:, 0] for t in pre),
                               *_slstm_rec(p, ov, vidx))
    h_t = h_t.astype(x.dtype)   # slstm_step computes fp32; keep carry dtype
    return (_slstm_post(p, h_t[:, None].reshape(x.shape), x, cfg, ov=ov,
                        vidx=vidx),
            {"cell": cell, "conv": conv_win.astype(jnp.float32)})


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

def _super_shape(cfg) -> tuple[int, int]:
    """(n_super, mlstm_per_super); layers = n_super * (ratio + 1)."""
    per = cfg.mlstm_ratio + 1
    assert cfg.num_layers % per == 0, (cfg.num_layers, per)
    return cfg.num_layers // per, cfg.mlstm_ratio


def init(rng, cfg) -> dict:
    n_super, n_m = _super_shape(cfg)
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    return {
        "embed": embed_init(k1, cfg.padded_vocab, cfg.d_model),
        "final_norm": rmsnorm_init(cfg.d_model),
        "unembed": dense_init(k4, (cfg.padded_vocab, cfg.d_model),
                              ("vocab", "embed"), scale=cfg.d_model ** -0.5),
        "mlstm": stack_layers(lambda k: mlstm_block_init(k, cfg), k2,
                              n_super * n_m),
        "slstm": stack_layers(lambda k: slstm_block_init(k, cfg), k3, n_super),
    }


def init_state(cfg, batch: int) -> dict:
    n_super, n_m = _super_shape(cfg)
    def rep(tree, n):
        return jax.tree.map(lambda a: jnp.broadcast_to(
            a, (n,) + a.shape).copy(), tree)
    return {"pos": jnp.zeros((batch,), jnp.int32),
            "mlstm": rep(mlstm_block_state(cfg, batch), n_super * n_m),
            "slstm": rep(slstm_block_state(cfg, batch), n_super)}


def state_pspecs(cfg, long_context: bool = False):
    """Logical axes for the decode state (constant-size: never seq-sharded)."""
    m_axes = {"cell": {"C": (None, "act_batch", "act_ssm", None, None),
                       "n": (None, "act_batch", "act_ssm", None),
                       "m": (None, "act_batch", "act_ssm")},
              "conv": (None, "act_batch", None, "act_ssm")}
    s_axes = {"cell": {k: (None, "act_batch", None, None) for k in
                       ("c", "n", "h", "m")},
              "conv": (None, "act_batch", None, "act_ssm")}
    return {"pos": ("act_batch",), "mlstm": m_axes, "slstm": s_axes}


def _run(params, x, cfg, state, step: bool, overlay=None, vidx=None):
    """Shared super-block scan for sequence and decode paths."""
    n_super, n_m = _super_shape(cfg)
    m_params = jax.tree.map(
        lambda a: a.reshape(n_super, n_m, *a.shape[1:]), params["mlstm"])
    m_overlay = jax.tree.map(
        lambda a: a.reshape(n_super, n_m, *a.shape[1:]), oget(overlay, "mlstm"))
    s_overlay = oget(overlay, "slstm")
    m_state = jax.tree.map(
        lambda a: a.reshape(n_super, n_m, *a.shape[1:]), state["mlstm"])
    m_apply = mlstm_block_step if step else mlstm_block_apply
    s_apply = slstm_block_step if step else slstm_block_apply

    def body(h, xs):
        mp, mo, ms, sp, so, ss = xs
        new_ms = []
        for j in range(n_m):
            pj = jax.tree.map(lambda a: a[j], mp)
            oj = jax.tree.map(lambda a: a[j], mo)
            sj = jax.tree.map(lambda a: a[j], ms)
            h, sj_new = m_apply(pj, h, cfg, sj, ov=oj, vidx=vidx)
            new_ms.append(sj_new)
        h, ss_new = s_apply(sp, h, cfg, ss, ov=so, vidx=vidx)
        return h, (jax.tree.map(lambda *a: jnp.stack(a), *new_ms), ss_new)

    body_fn = body
    if cfg.remat and not step:
        body_fn = jax.checkpoint(body,
                                 policy=jax.checkpoint_policies.nothing_saveable)
    x, (m_new, s_new) = jax.lax.scan(
        body_fn, x, (m_params, m_overlay, m_state, params["slstm"],
                     s_overlay, state["slstm"]))
    new_state = {"pos": state["pos"] + x.shape[1],
                 "mlstm": jax.tree.map(
                     lambda a: a.reshape(n_super * n_m, *a.shape[2:]), m_new),
                 "slstm": s_new}
    return x, new_state


def forward(params, batch, cfg, state: dict | None = None, overlay=None,
            variant_idx=None):
    vidx = variant_idx
    tokens = batch["tokens"]
    x = embed_lookup(params["embed"], tokens, cfg.compute_dtype,
                     bank=oget(overlay, "embed"), vidx=vidx)
    x = lc(x, "act_batch", "act_seq", "act_embed")
    if state is None:
        state = init_state(cfg, tokens.shape[0])
    x, new_state = _run(params, x, cfg, state, step=False, overlay=overlay,
                        vidx=vidx)
    x = rmsnorm(x, psel(params["final_norm"], oget(overlay, "final_norm"),
                        vidx), cfg.norm_eps)
    logits = unembed_logits(x, params["unembed"],
                            bank=oget(overlay, "unembed"), vidx=vidx)
    logits = lc(logits, "act_batch", "act_seq", "act_vocab")
    return logits, {"moe_aux": jnp.float32(0), "state": new_state}


def prefill(params, batch, cfg, max_len: int = 0, cache_dtype=None,
            overlay=None, variant_idx=None):
    logits, aux = forward(params, batch, cfg, overlay=overlay,
                          variant_idx=variant_idx)
    return logits[:, -1, :], aux["state"]


def decode_step(params, token, state, cfg, overlay=None, variant_idx=None):
    vidx = variant_idx
    x = embed_lookup(params["embed"], token[:, None], cfg.compute_dtype,
                     bank=oget(overlay, "embed"), vidx=vidx)
    x, new_state = _run(params, x, cfg, state, step=True, overlay=overlay,
                        vidx=vidx)
    x = rmsnorm(x, psel(params["final_norm"], oget(overlay, "final_norm"),
                        vidx), cfg.norm_eps)
    logits = unembed_logits(x, params["unembed"],
                            bank=oget(overlay, "unembed"), vidx=vidx)
    return logits[:, 0, :], new_state
