"""Mixture-of-Experts: fine-grained routed experts + shared experts.

Routing: group-limited capacity dispatch (GShard-style), formulated so that
GSPMD inserts the expert-parallel all-to-alls from sharding constraints:

1. tokens reshaped to (G, N, D) groups; G follows the batch sharding
   ("act_groups" → data axis), so routing decisions are shard-local;
2. per (group, expert) top-C token selection — C = N·top_k/E·capacity —
   gives static shapes (no sort over the global token stream);
3. the gathered dispatch tensor (G, E, C, D) is constraint-resharded with
   experts on the "model" axis (→ all-to-all), grouped-GEMM'd against the
   expert stacks, and scatter-added back.

Tokens overflowing an expert's capacity within their group are dropped
(standard capacity-factor semantics); the aux load-balancing loss keeps
overflow rare.  Shared experts (DeepSeek-MoE / Moonlight) run densely.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical_constraint
from repro.models.delta_overlay import oget
from repro.models.layers import linear
from repro.models.param import Param, dense_init


def moe_init(key, cfg) -> dict:
    d, e_ff, e = cfg.d_model, cfg.expert_d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (e, d), ("experts", "embed"), scale=0.02),
        "w_gate": dense_init(ks[1], (e, e_ff, d), ("experts", "ffn", "embed")),
        "w_up": dense_init(ks[2], (e, e_ff, d), ("experts", "ffn", "embed")),
        "w_down": dense_init(ks[3], (e, d, e_ff), ("experts", "embed", "ffn")),
    }
    if cfg.num_shared_experts:
        # shared experts are tiny (num_shared·e_ff hidden): REPLICATE them
        # over the model axis ("ffn_small" rule) — their full-residual TP
        # psums (one fwd + one bwd per layer) cost far more wire than the
        # replicated compute (≈0.04 s/step vs ≈2 s of collectives)
        sh_ff = cfg.expert_d_ff * cfg.num_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(k1, (sh_ff, d), ("ffn_small", "embed")),
            "w_up": dense_init(k2, (sh_ff, d), ("ffn_small", "embed")),
            "w_down": dense_init(k3, (d, sh_ff), ("embed", "ffn_small")),
        }
    return p


def _group_tokens(x: jax.Array, target_group: int = 4096
                  ) -> tuple[jax.Array, tuple]:
    """(B, S, D) -> (G, N, D); groups follow batch sharding when possible."""
    b, s, d = x.shape
    t = b * s
    n = min(target_group, t)
    while t % n:
        n -= 1
    g = t // n
    return x.reshape(g, n, d), (b, s, d)


def _expert_mm(xe: jax.Array, w: jax.Array, ent,
               waxes=("experts", "ffn", "embed")) -> jax.Array:
    """Per-expert matmul: xe (E, M, D) · w (E, F, D) -> (E, M, F).

    With a delta-overlay entry (stacked over the expert dim) each expert's
    GEMM runs the fused on-the-fly delta kernel against its base weight.
    Inside an active mesh the whole stack lowers as ONE shard_map over the
    expert-sharded axis — shard_map(vmap(kernel)), each device running the
    fused kernels for its own experts — because the plain formulation here
    (vmap over a shard_map'd kernel) is not a supported composition; the
    dispatcher declines (None) when the stack can't partition and the
    global vmap path below runs under GSPMD exactly as before."""
    if ent is None:
        if getattr(w, "__quant_leaf__", False):
            # int8 base: per-output-channel scales factor out of the
            # contraction exactly (the scaled dim survives to the output)
            return (jnp.einsum("emd,efd->emf", xe, w.q.astype(xe.dtype))
                    * w.scale.astype(xe.dtype)[:, None, :])
        return jnp.einsum("emd,efd->emf", xe, w.astype(xe.dtype))
    from repro.kernels import dispatch as D
    st = D.state()
    if st is not None:
        y = D.bitlinear_axes_stacked(st, xe, ent, w, waxes)
        if y is not None:
            return y
    with D.no_dispatch():
        return jax.vmap(lambda x_, e_, w_: linear(x_, w_, e_))(xe, ent, w)


def moe_apply(p: dict, x: jax.Array, cfg, ov=None, vidx=None
              ) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y, aux_loss).

    ``vidx`` (B,) enables mixed-variant batches over a BANKED overlay: the
    router (an uncompressed extra) is applied per token by masked select
    over the bank, and the grouped expert GEMMs fall back to masked
    per-variant application (DESIGN.md §9) — V fused passes with
    non-matching rows zeroed, jittable and exact per row.  Note capacity
    dispatch couples rows: a token's survival can depend on which other
    variants share its group, exactly as it depends on batch composition
    in single-variant serving.
    """
    b, s, _ = x.shape
    e, k = cfg.num_experts, cfg.top_k
    xg, orig = _group_tokens(x)
    g, n, d = xg.shape
    cap = max(1, int(n * k / e * cfg.capacity_factor))
    cap = min(cap, n)
    # per-token variant indices in group layout (tokens are row-major)
    vidx_gn = (None if vidx is None
               else jnp.broadcast_to(vidx[:, None], (b, s)).reshape(g, n))

    xg = logical_constraint(xg, "act_groups", None, None)
    rb = oget(ov, "router")
    if rb is None or vidx_gn is None:
        logits = (xg @ p["router"].T.astype(x.dtype)).astype(jnp.float32)
    else:
        # banked router: identical matmul per bank slot, rows select their
        # own variant's routing scores (slot 0 = base)
        logits = xg @ rb[0].T.astype(x.dtype)
        for vi in range(1, rb.shape[0]):
            lv = xg @ rb[vi].T.astype(x.dtype)
            logits = jnp.where((vidx_gn == vi)[..., None], lv, logits)
        logits = logits.astype(jnp.float32)                         # (G,N,E)
    probs = jax.nn.softmax(logits, axis=-1)

    # shard-local top_k: XLA's sort partitioning otherwise all-gathers the
    # full score tensors (measured ~50 GB/step on the moonshot train cell)
    from repro.distributed.sharding import local_top_k
    top_val, top_idx = local_top_k(probs, k, ("act_groups", None, None))
    top_val = top_val / jnp.maximum(top_val.sum(-1, keepdims=True), 1e-9)

    # score[g, e, n] = normalized gate prob if e in token n's top-k else 0
    sel = jax.nn.one_hot(top_idx, e, dtype=jnp.float32) * top_val[..., None]
    score = jnp.swapaxes(sel.sum(axis=2), 1, 2)                 # (G,E,N)

    c_val, c_idx = local_top_k(score, cap, ("act_groups", None, None))

    # dispatch gather: (G,E,C,D), experts resharded onto the model axis
    xd = jnp.take_along_axis(
        xg[:, None, :, :], c_idx[..., None], axis=2)            # (G,E,C,D)
    xd = logical_constraint(xd, "act_groups", "act_experts", None, None)

    # grouped expert GEMMs (gated SwiGLU); with an overlay the per-expert
    # matmuls run expert-major (E, G·C, ·) so the fused delta kernel sees
    # one (M, K) GEMM per expert stack entry
    has_delta = ov is not None and any(oget(ov, k_) is not None
                                       for k_ in ("w_gate", "w_up", "w_down"))
    if has_delta and vidx_gn is not None:
        # mixed-variant banked overlay: masked per-variant application —
        # banking the per-row gather inside the grouped (E, M, ·) GEMMs is
        # awkward (rows are dispatch slots, not batch lanes), so run the
        # existing per-variant fused pass once per bank slot with
        # non-matching rows zeroed and select (slot 0 = base weights)
        from repro.models.delta_overlay import entry_slot
        xe = xd.transpose(1, 0, 2, 3).reshape(e, g * cap, d)
        vd = jnp.take_along_axis(vidx_gn[:, None, :], c_idx, axis=2)  # (G,E,C)
        vidx_e = vd.transpose(1, 0, 2).reshape(e, g * cap)
        ents = {k_: oget(ov, k_) for k_ in ("w_gate", "w_up", "w_down")}
        nbank = next(v.packed.shape[0] for v in ents.values()
                     if v is not None)
        ye = jnp.zeros((e, g * cap, d), x.dtype)
        for vi in range(nbank):
            mask = (vidx_e == vi)[..., None]
            xv = jnp.where(mask, xe, 0)
            sl = {k_: entry_slot(v, vi) for k_, v in ents.items()}
            hv = (jax.nn.silu(_expert_mm(xv, p["w_gate"], sl["w_gate"]))
                  * _expert_mm(xv, p["w_up"], sl["w_up"]))
            yv = _expert_mm(hv, p["w_down"], sl["w_down"],
                            waxes=("experts", "embed", "ffn"))
            ye = jnp.where(mask, yv, ye)
        yd = ye.reshape(e, g, cap, d).transpose(1, 0, 2, 3)
    elif has_delta:
        xe = xd.transpose(1, 0, 2, 3).reshape(e, g * cap, d)
        he = (jax.nn.silu(_expert_mm(xe, p["w_gate"], oget(ov, "w_gate")))
              * _expert_mm(xe, p["w_up"], oget(ov, "w_up")))
        ye = _expert_mm(he, p["w_down"], oget(ov, "w_down"),
                        waxes=("experts", "embed", "ffn"))
        yd = ye.reshape(e, g, cap, d).transpose(1, 0, 2, 3)
    else:
        def emm(eq, xop, w):
            # possibly-quantized expert stack: scale (E, F_out) broadcasts
            # onto the (G, E, C, F_out) output — exact factoring, no dense
            # dequant (DESIGN.md §16)
            if getattr(w, "__quant_leaf__", False):
                return (jnp.einsum(eq, xop, w.q.astype(x.dtype))
                        * w.scale.astype(x.dtype)[None, :, None, :])
            return jnp.einsum(eq, xop, w.astype(x.dtype))
        h = jax.nn.silu(emm("gecd,efd->gecf", xd, p["w_gate"])) * \
            emm("gecd,efd->gecf", xd, p["w_up"])
        yd = emm("gecf,edf->gecd", h, p["w_down"])
    yd = yd * c_val[..., None].astype(x.dtype)                  # combine weight
    # mask out capacity slots that hold zero-score (unrouted) tokens
    yd = jnp.where((c_val > 0)[..., None], yd, 0)
    yd = logical_constraint(yd, "act_groups", "act_experts", None, None)

    # combine scatter-add back to token order
    y = jnp.zeros((g, n, d), x.dtype)
    flat_idx = c_idx.reshape(g, e * cap)
    y = jax.vmap(lambda yt, it, vt: yt.at[it].add(vt))(
        y, flat_idx, yd.reshape(g, e * cap, d))
    y = logical_constraint(y, "act_groups", None, None)

    # shared experts: weights replicated over `model`, computed dense on
    # each rank's batch shard.  Measured alternatives (moonshot train):
    # TP-sharded = +2 full-residual psums/layer (bound 8.8 s); sequence-TP
    # = cheaper compute but gather/scatter wire dominates (bound 7.9 s);
    # replication wins on the dominant term (bound 6.1 s) despite 16×
    # redundant shared-expert FLOPs.
    if "shared" in p:
        from repro.models.layers import mlp_apply
        y = y + mlp_apply(p["shared"], xg, ov=oget(ov, "shared"),
                          vidx=vidx_gn, ffn_ax="ffn_small")

    # load-balancing aux loss (Switch-style): f_i · P_i summed over experts
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_idx, e, dtype=jnp.float32).sum(2), axis=(0, 1)) / k
    frac_probs = jnp.mean(probs, axis=(0, 1))               # (E,)
    aux = e * jnp.sum(frac_tokens * frac_probs)

    return y.reshape(orig), aux.astype(jnp.float32)
