"""Decoder-only transformer LM: dense, MoE, VLM and local:global variants.

One scanned block definition covers deepseek-7b, qwen3-8b, starcoder2-3b,
gemma3-12b (5:1 local:global via per-layer scanned window/theta arrays),
moonshot / deepseek-moe (MoE blocks + unrolled first-dense layers) and
internvl2 (stub patch embeddings prepended to the token stream).

Forward (train / prefill): flat ``lax.scan`` over layers with optional
per-layer remat.  Decode: super-block scan — layers reshaped to
(n_super, pattern_len, ...) so heterogeneous KV caches (1024-slot ring for
local layers vs full-length for global layers) stay uniform under scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical_constraint as lc
from repro.models import attention as A
from repro.models import moe as MOE
from repro.models.delta_overlay import oget
from repro.models.layers import (cast_to, embed_init, embed_lookup, linear,
                                 mlp_apply, mlp_init, psel, rmsnorm,
                                 rmsnorm_init, unembed_logits)
from repro.models.param import dense_init, stack_layers


# ---------------------------------------------------------------------------
# layer pattern
# ---------------------------------------------------------------------------

def layer_pattern(cfg) -> list[dict]:
    """Static per-super-block layer descriptors.  Uniform archs have a
    single-entry pattern; gemma3 has [local×5, global]."""
    if cfg.local_global_pattern > 0:
        local = {"window": cfg.sliding_window, "theta": cfg.rope_theta_local}
        glob = {"window": 0, "theta": cfg.rope_theta}
        return [dict(local) for _ in range(cfg.local_global_pattern)] + [glob]
    return [{"window": cfg.sliding_window, "theta": cfg.rope_theta}]


def scan_layer_meta(cfg, n_layers: int) -> tuple[jax.Array, jax.Array]:
    """(theta (L,), window (L,)) arrays for the flat training scan."""
    pat = layer_pattern(cfg)
    thetas = jnp.array([pat[i % len(pat)]["theta"] for i in range(n_layers)],
                       jnp.float32)
    windows = jnp.array([pat[i % len(pat)]["window"] for i in range(n_layers)],
                        jnp.int32)
    return thetas, windows


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _block_init(key, cfg, moe_layer: bool) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "ln1": rmsnorm_init(cfg.d_model),
        "attn": A.attn_init(k1, cfg),
        "ln2": rmsnorm_init(cfg.d_model),
    }
    if moe_layer:
        p["moe"] = MOE.moe_init(k2, cfg)
    else:
        p["mlp"] = mlp_init(k3, cfg.d_model, cfg.d_ff)
    return p


def init(rng, cfg) -> dict:
    keys = jax.random.split(rng, 4)
    is_moe = cfg.family == "moe"
    n_pre = cfg.moe_first_dense if is_moe else 0
    n_scan = cfg.num_layers - n_pre
    params = {
        "embed": embed_init(keys[0], cfg.padded_vocab, cfg.d_model),
        "final_norm": rmsnorm_init(cfg.d_model),
        "layers": stack_layers(
            lambda k: _block_init(k, cfg, moe_layer=is_moe), keys[1], n_scan),
    }
    if n_pre:
        params["pre_layers"] = stack_layers(
            lambda k: _block_init(k, cfg, moe_layer=False), keys[2], n_pre)
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(keys[3], (cfg.padded_vocab, cfg.d_model),
                                       ("vocab", "embed"), scale=cfg.d_model ** -0.5)
    return params


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------

def _attn_part(p, x, cfg, positions, theta, window, kv_override=None,
               decode_pos=None, io=None, ov=None, vidx=None):
    """Attention sub-block.  Returns (out, (k, v)) — k/v exported for cache
    building during prefill.  ``io`` (dict or None) collects per-linear
    (input, output) pairs — the functional stand-in for the paper's
    PyTorch forward hooks (calibration cache, Alg. 3).  ``ov`` is the
    block's delta-overlay subtree (on-the-fly variant execution); with
    ``vidx`` the subtree is BANKED and every batch row fuses its own
    variant (DESIGN.md §9)."""
    ov_a = oget(ov, "attn")
    h = rmsnorm(x, psel(p["ln1"], oget(ov, "ln1"), vidx), cfg.norm_eps)
    q, k, v = A.qkv_project(p["attn"], h, cfg, positions, theta, ov=ov_a,
                            vidx=vidx)
    if kv_override is None:
        o = A.flash_attention(q, k, v, causal=True, window=window)
    else:
        k_cache, v_cache, slot_pos = kv_override
        o = A.decode_attention(q, k_cache, v_cache, slot_pos, decode_pos,
                               window=0)
    o = o.reshape(*x.shape[:-1], cfg.q_dim)
    # constraint forces the row-parallel psum HERE, in bf16 — without it
    # GSPMD defers the reduction into the next op's fp32 domain (rmsnorm
    # upcast), doubling the wire bytes of every TP all-reduce
    wo_out = lc(linear(o, p["attn"]["wo"], oget(ov_a, "wo"), vidx,
                       waxes=("embed", "q_heads")),
                "act_batch", "act_seq", None)
    if io is not None:
        b, s, _ = x.shape
        io["attn.wq"] = (h, q.reshape(b, s, -1))
        io["attn.wk"] = (h, k.reshape(b, s, -1))
        io["attn.wv"] = (h, v.reshape(b, s, -1))
        io["attn.wo"] = (o, wo_out)
    return x + wo_out, (k, v)


def _ffn_part(p, x, cfg, io=None, ov=None, vidx=None):
    h = rmsnorm(x, psel(p["ln2"], oget(ov, "ln2"), vidx), cfg.norm_eps)
    if "moe" in p:
        y, aux = MOE.moe_apply(p["moe"], h, cfg, ov=oget(ov, "moe"),
                               vidx=vidx)
    else:
        y, aux = lc(mlp_apply(p["mlp"], h, ov=oget(ov, "mlp"), vidx=vidx),
                    "act_batch", "act_seq", None), jnp.float32(0)
        if io is not None:
            gate = h @ p["mlp"]["w_gate"].T.astype(h.dtype)
            up = h @ p["mlp"]["w_up"].T.astype(h.dtype)
            down_in = jax.nn.silu(gate) * up
            io["mlp.w_gate"] = (h, gate)
            io["mlp.w_up"] = (h, up)
            io["mlp.w_down"] = (down_in, y)
    return x + y, aux


def block_apply(p, x, cfg, positions, theta, window, io=None, ov=None,
                vidx=None):
    # bf16 residual-stream boundary: the block-input cotangent (where the
    # column-parallel backward psum lands) stays bf16
    x = lc(x, "act_batch", "act_seq", None)
    x, kv = _attn_part(p, x, cfg, positions, theta, window, io=io, ov=ov,
                       vidx=vidx)
    x, aux = _ffn_part(p, x, cfg, io=io, ov=ov, vidx=vidx)
    return x, kv, aux


# ---------------------------------------------------------------------------
# embedding front (handles vlm prefix)
# ---------------------------------------------------------------------------

def embed_inputs(params, batch, cfg, ov=None, vidx=None) -> jax.Array:
    """params is the plain-array tree (post param.split)."""
    x = embed_lookup(params["embed"], batch["tokens"], cfg.compute_dtype,
                     bank=oget(ov, "embed"), vidx=vidx)
    if cfg.family == "vlm" and "image_embeds" in batch:
        img = cast_to(batch["image_embeds"], cfg.compute_dtype)
        x = jnp.concatenate([img, x], axis=1)
    return lc(x, "act_batch", "act_seq", "act_embed")


def _unembed(params, x, cfg, ov=None, vidx=None):
    key = "embed" if cfg.tie_embeddings else "unembed"
    logits = unembed_logits(x, params[key], bank=oget(ov, key), vidx=vidx)
    return lc(logits, "act_batch", "act_seq", "act_vocab")


# ---------------------------------------------------------------------------
# forward (train / prefill teacher-forced)
# ---------------------------------------------------------------------------

def forward(params, batch, cfg, collect_kv: bool = False,
            collect_io: bool = False, overlay=None, variant_idx=None):
    """-> (logits (B,S,V), aux dict).

    aux["kv"] (L,B,S,Hkv,hd)×2 when collect_kv (prefill cache building).
    aux["io"] {proj_name: (X (L,B,S,·), Y (L,B,S,·))} when collect_io — the
    calibration cache stand-in for the paper's forward hooks; stacked over
    scan layers, so one forward yields every layer's linear IO.
    overlay: optional delta-overlay tree mirroring params — matmuls with an
    entry run the fused on-the-fly delta GEMM against the base weight.
    variant_idx: optional (B,) int32 — overlay leaves are then BANKED
    (leading bank axis; extras included) and every batch row serves its own
    variant, slot 0 meaning base (DESIGN.md §9).
    """
    vidx = variant_idx
    x = embed_inputs(params, batch, cfg, ov=overlay, vidx=vidx)
    b, s, _ = x.shape
    positions = jnp.arange(s)
    aux_total = jnp.float32(0)
    kv_all = []
    pre_io = []

    n_pre = 0
    if "pre_layers" in params:
        pre = params["pre_layers"]
        ov_pre = oget(overlay, "pre_layers")
        n_pre = jax.tree.leaves(pre)[0].shape[0]
        for i in range(n_pre):
            pi = jax.tree.map(lambda a: a[i], pre)
            ov_i = jax.tree.map(lambda a: a[i], ov_pre)
            io_i = {} if collect_io else None
            x, kv, aux = block_apply(pi, x, cfg, positions,
                                     cfg.rope_theta, cfg.sliding_window,
                                     io=io_i, ov=ov_i, vidx=vidx)
            aux_total += aux
            if collect_kv:
                kv_all.append(kv)
            if collect_io:
                pre_io.append(io_i)

    thetas, windows = scan_layer_meta(cfg, cfg.num_layers - n_pre)
    ov_layers = oget(overlay, "layers")

    def body(carry, xs):
        h, aux_acc = carry
        lp, ovl, theta, window = xs
        io_i = {} if collect_io else None
        h, kv, aux = block_apply(lp, h, cfg, positions, theta, window,
                                 io=io_i, ov=ovl, vidx=vidx)
        ys = (kv if collect_kv else None, io_i if collect_io else None)
        return (h, aux_acc + aux), ys

    body_fn = body
    if cfg.remat and not collect_io:
        body_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)

    (x, aux_total), (kv_scan, io_scan) = jax.lax.scan(
        body_fn, (x, aux_total), (params["layers"], ov_layers,
                                  thetas, windows))

    x = rmsnorm(x, psel(params["final_norm"], oget(overlay, "final_norm"),
                        vidx), cfg.norm_eps)
    logits = _unembed(params, x, cfg, ov=overlay, vidx=vidx)
    aux = {"moe_aux": aux_total}
    if collect_kv:
        if kv_all:
            pre_k = jnp.stack([kv[0] for kv in kv_all])
            pre_v = jnp.stack([kv[1] for kv in kv_all])
            aux["pre_kv"] = (pre_k, pre_v)
        aux["kv"] = kv_scan
    if collect_io:
        aux["io"] = io_scan
        if pre_io:
            aux["pre_io"] = jax.tree.map(lambda *a: jnp.stack(a), *pre_io)
    return logits, aux


# ---------------------------------------------------------------------------
# decode: caches + single-token step
# ---------------------------------------------------------------------------

def _cache_sizes(cfg, max_len: int) -> list[int]:
    """Per-pattern-position cache length."""
    return [min(e["window"], max_len) if e["window"] > 0 else max_len
            for e in layer_pattern(cfg)]


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    pat = layer_pattern(cfg)
    n_pre = cfg.moe_first_dense if cfg.family == "moe" else 0
    n_scan = cfg.num_layers - n_pre
    assert n_scan % len(pat) == 0, \
        f"num_layers {cfg.num_layers} incompatible with pattern {len(pat)}"
    n_super = n_scan // len(pat)
    sizes = _cache_sizes(cfg, max_len)

    def stack_caches(n_stack, size):
        one = A.make_kv_cache(batch, size, cfg.num_kv_heads, cfg.head_dim, dtype)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_stack,) + a.shape).copy(), one)

    # pos is PER BATCH ROW: continuous batching admits/retires lanes
    # independently, so each lane carries its own decode position
    cache = {"pos": jnp.zeros((batch,), jnp.int32),
             "slots": [stack_caches(n_super, sz) for sz in sizes]}
    if n_pre:
        cache["pre"] = stack_caches(n_pre, max_len)
    return cache


def cache_pspecs(cfg, long_context: bool,
                 kv_seq_shard: bool = False) -> object:
    """Logical-axes tree matching init_cache output (for sharding).

    kv_seq_shard: shard the cache SEQUENCE over the model axis — the
    distributed flash-decode layout used when kv-head counts don't divide
    the tensor-parallel axis (qwen3 kv=8 vs 16): attention reductions over
    the sharded T dim lower to tiny (B,H)/(B,H,hd) psums instead of
    full-logit all-reduces from a head-dim-sharded contraction."""
    if long_context:
        seq_ax = "act_seq"
    elif kv_seq_shard:
        seq_ax = "act_seq_tp"
    else:
        seq_ax = None
    kv_heads_ax = None if kv_seq_shard else "act_kv"
    hd_ax = None if kv_seq_shard else "act_hd"
    kv_axes = {"k": (None, "act_batch", seq_ax, kv_heads_ax, hd_ax),
               "v": (None, "act_batch", seq_ax, kv_heads_ax, hd_ax),
               "slot_pos": (None, "act_batch", seq_ax)}
    # ring (windowed) caches are small: never sequence-sharded
    ring_axes = {"k": (None, "act_batch", None, "act_kv", "act_hd"),
                 "v": (None, "act_batch", None, "act_kv", "act_hd"),
                 "slot_pos": (None, "act_batch", None)}
    pat = layer_pattern(cfg)
    spec = {"pos": ("act_batch",),
            "slots": [ring_axes if e["window"] > 0 else kv_axes
                      for e in pat]}
    n_pre = cfg.moe_first_dense if cfg.family == "moe" else 0
    if n_pre:
        spec["pre"] = kv_axes
    return spec


def _decode_pos_q(pos) -> jax.Array:
    """Per-row decode positions (B,) -> RoPE positions (B, 1)."""
    return jnp.asarray(pos, jnp.int32)[:, None]


def _decode_block(p, x, cfg, layer_cache, pat_entry, pos, ov=None,
                  vidx=None):
    """One layer in decode mode; returns (x, updated layer cache).
    ``pos`` is per batch row (B,) — lanes may sit at different depths."""
    window = pat_entry["window"]
    ov_a = oget(ov, "attn")
    h = rmsnorm(x, psel(p["ln1"], oget(ov, "ln1"), vidx), cfg.norm_eps)
    q, k, v = A.qkv_project(p["attn"], h, cfg, _decode_pos_q(pos),
                            pat_entry["theta"], ov=ov_a, vidx=vidx)
    new_cache = A.cache_insert(layer_cache, k, v, pos, ring=window > 0)
    o = A.decode_attention(q, new_cache["k"], new_cache["v"],
                           new_cache["slot_pos"], pos, window=window)
    o = o.reshape(*x.shape[:-1], cfg.q_dim)
    x = x + linear(o, p["attn"]["wo"], oget(ov_a, "wo"), vidx,
                   waxes=("embed", "q_heads"))
    x, _ = _ffn_part(p, x, cfg, ov=ov, vidx=vidx)
    return x, new_cache


def _decode_block_stacked(p, x, cfg, caches, idx, pat_entry, pos, ov=None,
                          vidx=None):
    """One layer in decode mode against a STACKED cache carried by the
    scan: inserts one token in place, reads the layer slice for attention.
    Returns (x, updated stacked caches)."""
    window = pat_entry["window"]
    ov_a = oget(ov, "attn")
    h = rmsnorm(x, psel(p["ln1"], oget(ov, "ln1"), vidx), cfg.norm_eps)
    q, k, v = A.qkv_project(p["attn"], h, cfg, _decode_pos_q(pos),
                            pat_entry["theta"], ov=ov_a, vidx=vidx)
    caches = A.cache_insert_stacked(caches, idx, k, v, pos,
                                    ring=window > 0)
    view = A.cache_layer_view(caches, idx)
    o = A.decode_attention(q, view["k"], view["v"], view["slot_pos"], pos,
                           window=window)
    o = o.reshape(*x.shape[:-1], cfg.q_dim)
    x = x + linear(o, p["attn"]["wo"], oget(ov_a, "wo"), vidx,
                   waxes=("embed", "q_heads"))
    x, _ = _ffn_part(p, x, cfg, ov=ov, vidx=vidx)
    return x, caches


def decode_step(params, token, cache, cfg, overlay=None, variant_idx=None):
    """token (B,) int32 -> (logits (B,V), updated cache).

    cache["pos"] is (B,) — per-lane positions (continuous batching)."""
    vidx = variant_idx
    pos = cache["pos"]
    x = embed_lookup(params["embed"], token[:, None], cfg.compute_dtype,
                     bank=oget(overlay, "embed"), vidx=vidx)
    x = lc(x, "act_batch", None, "act_embed")
    pat = layer_pattern(cfg)

    new_cache = {"pos": pos + 1, "slots": None}
    if "pre_layers" in params:
        pre = params["pre_layers"]
        ov_pre = oget(overlay, "pre_layers")
        n_pre = jax.tree.leaves(pre)[0].shape[0]
        pre_out = []
        for i in range(n_pre):
            pi = jax.tree.map(lambda a: a[i], pre)
            ov_i = jax.tree.map(lambda a: a[i], ov_pre)
            ci = jax.tree.map(lambda a: a[i], cache["pre"])
            x, ci_new = _decode_block(
                pi, x, cfg, ci, {"window": 0, "theta": cfg.rope_theta}, pos,
                ov=ov_i, vidx=vidx)
            pre_out.append(ci_new)
        new_cache["pre"] = jax.tree.map(lambda *a: jnp.stack(a), *pre_out)

    n_pre = cfg.moe_first_dense if cfg.family == "moe" else 0
    n_scan = cfg.num_layers - n_pre
    n_super = n_scan // len(pat)
    # reshape flat (L, ...) params to (n_super, pattern_len, ...); the
    # overlay shadows the params stack, so it reshapes identically
    sup_params = jax.tree.map(
        lambda a: a.reshape(n_super, len(pat), *a.shape[1:]), params["layers"])
    sup_overlay = jax.tree.map(
        lambda a: a.reshape(n_super, len(pat), *a.shape[1:]),
        oget(overlay, "layers"))

    # caches ride in the scan CARRY (in-place one-token DUS per layer);
    # passing them as xs/ys would rewrite the full cache every step
    def body(carry, xs):
        h, slots = carry
        lp, ovl, idx = xs
        new_slots = []
        for j, entry in enumerate(pat):
            pj = jax.tree.map(lambda a: a[j], lp)
            ovj = jax.tree.map(lambda a: a[j], ovl)
            h, cj = _decode_block_stacked(pj, h, cfg, slots[j], idx,
                                          entry, pos, ov=ovj, vidx=vidx)
            new_slots.append(cj)
        return (h, new_slots), None

    (x, new_slots), _ = jax.lax.scan(
        body, (x, list(cache["slots"])),
        (sup_params, sup_overlay, jnp.arange(n_super)))
    new_cache["slots"] = new_slots

    x = rmsnorm(x, psel(params["final_norm"], oget(overlay, "final_norm"),
                        vidx), cfg.norm_eps)
    logits = _unembed(params, x, cfg, ov=overlay, vidx=vidx)
    return logits[:, 0, :], new_cache


# ---------------------------------------------------------------------------
# speculative verify: T teacher-forced tokens over the live decode cache
# ---------------------------------------------------------------------------

def _verify_block(p, x, cfg, layer_cache, pat_entry, pos, ov=None,
                  vidx=None):
    """``_decode_block`` generalised to T tokens per row: the T new K/V
    land at per-row positions pos..pos+T-1 and every query attends the
    cache through ``verify_attention`` (bit-exact per query slice with
    the decode path)."""
    ov_a = oget(ov, "attn")
    t = x.shape[1]
    h = rmsnorm(x, psel(p["ln1"], oget(ov, "ln1"), vidx), cfg.norm_eps)
    positions = _decode_pos_q(pos) + jnp.arange(t, dtype=jnp.int32)
    q, k, v = A.qkv_project(p["attn"], h, cfg, positions,
                            pat_entry["theta"], ov=ov_a, vidx=vidx)
    new_cache = A.cache_insert_multi(layer_cache, k, v, pos)
    o = A.verify_attention(q, new_cache["k"], new_cache["v"],
                           new_cache["slot_pos"], pos, window=0)
    o = o.reshape(*x.shape[:-1], cfg.q_dim)
    x = x + linear(o, p["attn"]["wo"], oget(ov_a, "wo"), vidx,
                   waxes=("embed", "q_heads"))
    x, _ = _ffn_part(p, x, cfg, ov=ov, vidx=vidx)
    return x, new_cache


def _verify_block_stacked(p, x, cfg, caches, idx, pat_entry, pos, ov=None,
                          vidx=None):
    """``_decode_block_stacked`` generalised to T tokens per row."""
    ov_a = oget(ov, "attn")
    t = x.shape[1]
    h = rmsnorm(x, psel(p["ln1"], oget(ov, "ln1"), vidx), cfg.norm_eps)
    positions = _decode_pos_q(pos) + jnp.arange(t, dtype=jnp.int32)
    q, k, v = A.qkv_project(p["attn"], h, cfg, positions,
                            pat_entry["theta"], ov=ov_a, vidx=vidx)
    caches = A.cache_insert_stacked_multi(caches, idx, k, v, pos)
    view = A.cache_layer_view(caches, idx)
    o = A.verify_attention(q, view["k"], view["v"], view["slot_pos"], pos,
                           window=0)
    o = o.reshape(*x.shape[:-1], cfg.q_dim)
    x = x + linear(o, p["attn"]["wo"], oget(ov_a, "wo"), vidx,
                   waxes=("embed", "q_heads"))
    x, _ = _ffn_part(p, x, cfg, ov=ov, vidx=vidx)
    return x, caches


def verify_step(params, tokens, cache, cfg, overlay=None, variant_idx=None):
    """tokens (B, T) teacher-forced -> (logits (B, T, V), cache advanced
    by T).  The k-token verify of speculative decoding (DESIGN.md §15):
    structurally the decode scan with T-token activations, so logits[:,t]
    is bit-exact with the T sequential ``decode_step`` calls that consume
    tokens[:, :t+1] — rejected suffixes rewind via ``rewind_cache``.

    Windowed (ring) layers are rejected: a ring write wraps modulo the
    window, so rejected-token inserts would clobber in-window history
    that a ``pos`` retreat cannot restore."""
    if any(e["window"] > 0 for e in layer_pattern(cfg)):
        raise ValueError(
            "verify_step requires windowless KV caches (ring buffers "
            "cannot rewind rejected speculative writes)")
    vidx = variant_idx
    pos = cache["pos"]
    b, t = tokens.shape
    x = embed_lookup(params["embed"], tokens, cfg.compute_dtype,
                     bank=oget(overlay, "embed"), vidx=vidx)
    x = lc(x, "act_batch", None, "act_embed")
    pat = layer_pattern(cfg)

    new_cache = {"pos": pos + t, "slots": None}
    if "pre_layers" in params:
        pre = params["pre_layers"]
        ov_pre = oget(overlay, "pre_layers")
        n_pre = jax.tree.leaves(pre)[0].shape[0]
        pre_out = []
        for i in range(n_pre):
            pi = jax.tree.map(lambda a: a[i], pre)
            ov_i = jax.tree.map(lambda a: a[i], ov_pre)
            ci = jax.tree.map(lambda a: a[i], cache["pre"])
            x, ci_new = _verify_block(
                pi, x, cfg, ci, {"window": 0, "theta": cfg.rope_theta}, pos,
                ov=ov_i, vidx=vidx)
            pre_out.append(ci_new)
        new_cache["pre"] = jax.tree.map(lambda *a: jnp.stack(a), *pre_out)

    n_pre = cfg.moe_first_dense if cfg.family == "moe" else 0
    n_scan = cfg.num_layers - n_pre
    n_super = n_scan // len(pat)
    sup_params = jax.tree.map(
        lambda a: a.reshape(n_super, len(pat), *a.shape[1:]), params["layers"])
    sup_overlay = jax.tree.map(
        lambda a: a.reshape(n_super, len(pat), *a.shape[1:]),
        oget(overlay, "layers"))

    def body(carry, xs):
        h, slots = carry
        lp, ovl, idx = xs
        new_slots = []
        for j, entry in enumerate(pat):
            pj = jax.tree.map(lambda a: a[j], lp)
            ovj = jax.tree.map(lambda a: a[j], ovl)
            h, cj = _verify_block_stacked(pj, h, cfg, slots[j], idx,
                                          entry, pos, ov=ovj, vidx=vidx)
            new_slots.append(cj)
        return (h, new_slots), None

    (x, new_slots), _ = jax.lax.scan(
        body, (x, list(cache["slots"])),
        (sup_params, sup_overlay, jnp.arange(n_super)))
    new_cache["slots"] = new_slots

    x = rmsnorm(x, psel(params["final_norm"], oget(overlay, "final_norm"),
                        vidx), cfg.norm_eps)
    logits = _unembed(params, x, cfg, ov=overlay, vidx=vidx)
    return logits, new_cache


def rewind_cache(cache, keep, span: int):
    """Drop the last span - keep[b] verify positions per row: ``pos``
    retreats and nothing else moves.  Non-ring caches index slots by
    absolute position, so the rejected entries (slot_pos > new pos) are
    masked out of every later attention read and are overwritten by the
    next write at their position before they could ever validate."""
    return dict(cache, pos=cache["pos"] - (span - keep))


# ---------------------------------------------------------------------------
# prefill: full forward + cache build
# ---------------------------------------------------------------------------

def prefill(params, batch, cfg, max_len: int, cache_dtype=jnp.bfloat16,
            overlay=None, variant_idx=None):
    """Teacher-forced pass over the prompt; returns (last_logits, cache)."""
    logits, aux = forward(params, batch, cfg, collect_kv=True,
                          overlay=overlay, variant_idx=variant_idx)
    b = batch["tokens"].shape[0]
    s = logits.shape[1]
    cache = init_cache(cfg, b, max_len, cache_dtype)
    pat = layer_pattern(cfg)
    k_scan, v_scan = aux["kv"]          # (L_scan, B, S, Hkv, hd)
    n_scan = k_scan.shape[0]
    n_super = n_scan // len(pat)
    k_sup = k_scan.reshape(n_super, len(pat), *k_scan.shape[1:])
    v_sup = v_scan.reshape(n_super, len(pat), *v_scan.shape[1:])

    new_slots = []
    for j, entry in enumerate(pat):
        slot = cache["slots"][j]
        if entry["window"] > 0:
            upd = jax.vmap(lambda c, kk, vv: A.prefill_ring(
                c, kk, vv, entry["window"]))(slot, k_sup[:, j], v_sup[:, j])
        else:
            upd = jax.vmap(lambda c, kk, vv: A.cache_insert(c, kk, vv, 0))(
                slot, k_sup[:, j], v_sup[:, j])
        new_slots.append(upd)
    cache["slots"] = new_slots
    if "pre_kv" in aux:
        pk, pv = aux["pre_kv"]
        cache["pre"] = jax.vmap(lambda c, kk, vv: A.cache_insert(c, kk, vv, 0))(
            cache["pre"], pk, pv)
    cache["pos"] = jnp.full((b,), s, jnp.int32)
    return logits[:, -1, :], cache
