"""Whisper-style encoder-decoder (whisper-base); conv frontend stubbed.

Per the assignment, the audio frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings (B, encoder_frames, d_model) standing in for
the two conv1d layers.  Encoder: bidirectional self-attention blocks.
Decoder: causal self-attention + cross-attention to the encoder output.
Positions: sinusoidal (DESIGN.md §8 notes the learned-positions deviation).
GELU (non-gated) MLPs as in the original architecture.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical_constraint as lc
from repro.models import attention as A
from repro.models.delta_overlay import oget
from repro.models.layers import (embed_init, embed_lookup, linear,
                                 mlp2_apply, mlp2_init, psel, rmsnorm,
                                 rmsnorm_init, sinusoidal_positions,
                                 unembed_logits)
from repro.models.param import dense_init, stack_layers


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _proj_init(key, cfg, d_kv_src=None):
    d = cfg.d_model
    src = d_kv_src or d
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, (cfg.q_dim, d), ("q_heads", "embed")),
        "wk": dense_init(k2, (cfg.kv_dim, src), ("kv_heads", "embed")),
        "wv": dense_init(k3, (cfg.kv_dim, src), ("kv_heads", "embed")),
        "wo": dense_init(k4, (d, cfg.q_dim), ("embed", "q_heads")),
    }


def enc_block_init(key, cfg) -> dict:
    k1, k2 = jax.random.split(key)
    return {"ln1": rmsnorm_init(cfg.d_model), "attn": _proj_init(k1, cfg),
            "ln2": rmsnorm_init(cfg.d_model),
            "mlp": mlp2_init(k2, cfg.d_model, cfg.d_ff)}


def dec_block_init(key, cfg) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": rmsnorm_init(cfg.d_model), "self_attn": _proj_init(k1, cfg),
            "ln_x": rmsnorm_init(cfg.d_model), "cross_attn": _proj_init(k2, cfg),
            "ln2": rmsnorm_init(cfg.d_model),
            "mlp": mlp2_init(k3, cfg.d_model, cfg.d_ff)}


def _qkv(p, xq, xkv, cfg, ov=None, vidx=None):
    """Whisper has 8 heads vs a 16-way model axis → sequence-TP attention
    (see attention.qkv_project): shard the q sequence over `model`; the
    encoder side (1500 frames, not divisible) falls back to replicated."""
    from repro.distributed.sharding import ctx_axis_size
    b, s, _ = xq.shape
    t = xkv.shape[1]
    ms = ctx_axis_size("model") or 1
    head_tp = cfg.num_heads % ms == 0
    axes = (("act_batch", "act_seq", "act_heads") if head_tp
            else ("act_batch", "act_seq_tp", None))
    q = lc(linear(xq, p["wq"], oget(ov, "wq"), vidx,
                  waxes=("q_heads", "embed")).astype(xq.dtype), *axes)
    k = lc(linear(xkv, p["wk"], oget(ov, "wk"), vidx,
                  waxes=("kv_heads", "embed")).astype(xq.dtype), *axes)
    v = lc(linear(xkv, p["wv"], oget(ov, "wv"), vidx,
                  waxes=("kv_heads", "embed")).astype(xq.dtype), *axes)
    q = q.reshape(b, s, cfg.num_heads, cfg.head_dim)
    k = k.reshape(b, t, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(b, t, cfg.num_kv_heads, cfg.head_dim)
    return q, k, v


def _attn(p, xq, xkv, cfg, causal, ov=None, vidx=None):
    q, k, v = _qkv(p, xq, xkv, cfg, ov=ov, vidx=vidx)
    o = A.flash_attention(q, k, v, causal=causal)
    return linear(o.reshape(*xq.shape[:-1], cfg.q_dim), p["wo"],
                  oget(ov, "wo"), vidx, waxes=("embed", "q_heads"))


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

def init(rng, cfg) -> dict:
    ks = jax.random.split(rng, 5)
    return {
        "embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model),
        "enc_layers": stack_layers(lambda k: enc_block_init(k, cfg), ks[1],
                                   cfg.encoder_layers),
        "enc_norm": rmsnorm_init(cfg.d_model),
        "dec_layers": stack_layers(lambda k: dec_block_init(k, cfg), ks[2],
                                   cfg.num_layers),
        "dec_norm": rmsnorm_init(cfg.d_model),
    }


def _tap_linear(io, name, x_in, w, out):
    if io is not None:
        io[name] = (x_in, out)


def encode(params, frames: jax.Array, cfg, collect_io: bool = False,
           overlay=None, vidx=None):
    """frames: (B, F, d) stub embeddings -> encoder output (B, F, d)."""
    x = frames.astype(jnp.dtype(cfg.compute_dtype))
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)
    x = lc(x, "act_batch", "act_seq", "act_embed")

    def body(h, xs):
        lp, ovl = xs
        ov_a = oget(ovl, "attn")
        io = {} if collect_io else None
        hn = rmsnorm(h, psel(lp["ln1"], oget(ovl, "ln1"), vidx),
                     cfg.norm_eps)
        q, k, v = _qkv(lp["attn"], hn, hn, cfg, ov=ov_a, vidx=vidx)
        b, f, _ = hn.shape
        if io is not None:
            io["attn.wq"] = (hn, q.reshape(b, f, -1))
            io["attn.wk"] = (hn, k.reshape(b, f, -1))
            io["attn.wv"] = (hn, v.reshape(b, f, -1))
        o = A.flash_attention(q, k, v, causal=False
                              ).reshape(b, f, cfg.q_dim)
        wo_out = linear(o, lp["attn"]["wo"], oget(ov_a, "wo"), vidx,
                        waxes=("embed", "q_heads"))
        _tap_linear(io, "attn.wo", o, None, wo_out)
        h = h + wo_out
        ov_m = oget(ovl, "mlp")
        hm = rmsnorm(h, psel(lp["ln2"], oget(ovl, "ln2"), vidx),
                     cfg.norm_eps)
        mid = jax.nn.gelu(linear(hm, lp["mlp"]["w_in"], oget(ov_m, "w_in"),
                                 vidx, waxes=("ffn", "embed")))
        out = linear(mid, lp["mlp"]["w_out"], oget(ov_m, "w_out"), vidx,
                     waxes=("embed", "ffn"))
        if io is not None:
            io["mlp.w_in"] = (hm, linear(hm, lp["mlp"]["w_in"],
                                         oget(ov_m, "w_in"), vidx,
                                         waxes=("ffn", "embed")))
            io["mlp.w_out"] = (mid, out)
        h = h + out
        return h, io

    body_fn = body
    if cfg.remat and not collect_io:
        body_fn = jax.checkpoint(body,
                                 policy=jax.checkpoint_policies.nothing_saveable)
    x, enc_io = jax.lax.scan(body_fn, x, (params["enc_layers"],
                                          oget(overlay, "enc_layers")))
    out = rmsnorm(x, psel(params["enc_norm"], oget(overlay, "enc_norm"),
                          vidx), cfg.norm_eps)
    return (out, enc_io) if collect_io else (out, None)


def forward(params, batch, cfg, collect_kv: bool = False,
            collect_io: bool = False, overlay=None, variant_idx=None):
    """Teacher-forced: batch = {"tokens" (B,S), "frames" (B,F,d)}.

    collect_io: per-linear (X, Y) calibration caches as stacked scan
    outputs (aux["enc_io"] / aux["dec_io"]) — Alg. 3's hooks for the
    encoder-decoder family."""
    vidx = variant_idx
    enc_out, enc_io = encode(params, batch["frames"], cfg,
                             collect_io=collect_io, overlay=overlay,
                             vidx=vidx)
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = embed_lookup(params["embed"], tokens, cfg.compute_dtype,
                     bank=oget(overlay, "embed"), vidx=vidx)
    x = x + sinusoidal_positions(s, cfg.d_model).astype(x.dtype)
    x = lc(x, "act_batch", "act_seq", "act_embed")

    def body(h, xs):
        lp, ovl = xs
        io = {} if collect_io else None
        ov_s = oget(ovl, "self_attn")
        hs = rmsnorm(h, psel(lp["ln1"], oget(ovl, "ln1"), vidx),
                     cfg.norm_eps)
        q, k, v = _qkv(lp["self_attn"], hs, hs, cfg, ov=ov_s, vidx=vidx)
        if io is not None:
            io["self_attn.wq"] = (hs, q.reshape(b, s, -1))
            io["self_attn.wk"] = (hs, k.reshape(b, s, -1))
            io["self_attn.wv"] = (hs, v.reshape(b, s, -1))
        o = A.flash_attention(q, k, v, causal=True)
        o = o.reshape(b, s, cfg.q_dim)
        wo_out = linear(o, lp["self_attn"]["wo"], oget(ov_s, "wo"), vidx,
                        waxes=("embed", "q_heads"))
        _tap_linear(io, "self_attn.wo", o, None, wo_out)
        h = h + wo_out
        ov_x = oget(ovl, "cross_attn")
        hx = rmsnorm(h, psel(lp["ln_x"], oget(ovl, "ln_x"), vidx),
                     cfg.norm_eps)
        qx, kx, vx = _qkv(lp["cross_attn"], hx, enc_out, cfg, ov=ov_x,
                          vidx=vidx)
        if io is not None:
            f = enc_out.shape[1]
            io["cross_attn.wq"] = (hx, qx.reshape(b, s, -1))
            io["cross_attn.wk"] = (enc_out, kx.reshape(b, f, -1))
            io["cross_attn.wv"] = (enc_out, vx.reshape(b, f, -1))
        ox = A.flash_attention(qx, kx, vx, causal=False
                               ).reshape(b, s, cfg.q_dim)
        xo_out = linear(ox, lp["cross_attn"]["wo"], oget(ov_x, "wo"), vidx,
                        waxes=("embed", "q_heads"))
        _tap_linear(io, "cross_attn.wo", ox, None, xo_out)
        h = h + xo_out
        ov_m = oget(ovl, "mlp")
        hm = rmsnorm(h, psel(lp["ln2"], oget(ovl, "ln2"), vidx),
                     cfg.norm_eps)
        mid = jax.nn.gelu(linear(hm, lp["mlp"]["w_in"], oget(ov_m, "w_in"),
                                 vidx, waxes=("ffn", "embed")))
        out = linear(mid, lp["mlp"]["w_out"], oget(ov_m, "w_out"), vidx,
                     waxes=("embed", "ffn"))
        if io is not None:
            io["mlp.w_in"] = (hm, linear(hm, lp["mlp"]["w_in"],
                                         oget(ov_m, "w_in"), vidx,
                                         waxes=("ffn", "embed")))
            io["mlp.w_out"] = (mid, out)
        h = h + out
        ys = (k, v) if collect_kv else None
        return h, (ys, io)

    body_fn = body
    if cfg.remat and not collect_io:
        body_fn = jax.checkpoint(body,
                                 policy=jax.checkpoint_policies.nothing_saveable)
    x, (kv, dec_io) = jax.lax.scan(body_fn, x, (params["dec_layers"],
                                                oget(overlay, "dec_layers")))
    x = rmsnorm(x, psel(params["dec_norm"], oget(overlay, "dec_norm"),
                        vidx), cfg.norm_eps)
    logits = unembed_logits(x, params["embed"],            # tied embeddings
                            bank=oget(overlay, "embed"), vidx=vidx)
    logits = lc(logits, "act_batch", "act_seq", "act_vocab")
    aux = {"moe_aux": jnp.float32(0), "enc_out": enc_out}
    if collect_kv:
        aux["kv"] = kv
    if collect_io:
        aux["enc_io"] = enc_io
        aux["dec_io"] = dec_io
    return logits, aux


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    rep = lambda tree: jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape).copy(), tree)
    return {
        "pos": jnp.zeros((batch,), jnp.int32),
        "self": rep(A.make_kv_cache(batch, max_len, cfg.num_kv_heads,
                                    cfg.head_dim, dtype)),
        "cross_k": jnp.zeros((cfg.num_layers, batch, cfg.encoder_frames,
                              cfg.num_kv_heads, cfg.head_dim), dtype),
        "cross_v": jnp.zeros((cfg.num_layers, batch, cfg.encoder_frames,
                              cfg.num_kv_heads, cfg.head_dim), dtype),
    }


def cache_pspecs(cfg, long_context: bool = False,
                 kv_seq_shard: bool = False):
    seq_ax = "act_seq_tp" if kv_seq_shard else None
    h_ax = None if kv_seq_shard else "act_kv"
    d_ax = None if kv_seq_shard else "act_hd"
    kv = {"k": (None, "act_batch", seq_ax, h_ax, d_ax),
          "v": (None, "act_batch", seq_ax, h_ax, d_ax),
          "slot_pos": (None, "act_batch", seq_ax)}
    cross = (None, "act_batch", None, h_ax, d_ax)
    return {"pos": ("act_batch",), "self": kv,
            "cross_k": cross, "cross_v": cross}


def prefill(params, batch, cfg, max_len: int, cache_dtype=jnp.bfloat16,
            overlay=None, variant_idx=None):
    vidx = variant_idx
    logits, aux = forward(params, batch, cfg, collect_kv=True,
                          overlay=overlay, variant_idx=vidx)
    b, s = batch["tokens"].shape
    cache = init_cache(cfg, b, max_len, cache_dtype)
    k_all, v_all = aux["kv"]
    cache["self"] = jax.vmap(lambda c, kk, vv: A.cache_insert(c, kk, vv, 0))(
        cache["self"], k_all, v_all)
    enc_out = aux["enc_out"]

    def cross_kv(lp, ovl):
        t = enc_out.shape[1]
        ov_x = oget(ovl, "cross_attn")
        k = linear(enc_out, lp["cross_attn"]["wk"], oget(ov_x, "wk"), vidx,
                   waxes=("kv_heads", "embed")
                   ).reshape(b, t, cfg.num_kv_heads, cfg.head_dim)
        v = linear(enc_out, lp["cross_attn"]["wv"], oget(ov_x, "wv"), vidx,
                   waxes=("kv_heads", "embed")
                   ).reshape(b, t, cfg.num_kv_heads, cfg.head_dim)
        return k.astype(cache_dtype), v.astype(cache_dtype)

    ck, cv = jax.vmap(cross_kv)(params["dec_layers"],
                                oget(overlay, "dec_layers"))
    cache["cross_k"], cache["cross_v"] = ck, cv
    cache["pos"] = jnp.full((b,), s, jnp.int32)
    return logits[:, -1, :], cache


def verify_step(params, tokens, cache, cfg, overlay=None, variant_idx=None):
    """tokens (B, T) teacher-forced over the live decode cache ->
    (logits (B, T, V), cache advanced by T) — the speculative verify
    (DESIGN.md §15).  Structurally ``decode_step`` with T-token
    activations: self-attention reads through ``verify_attention``
    (bit-exact per query with the decode path), cross-attention sees all
    encoder frames for every query exactly as decode does, and rejected
    suffixes rewind via ``rewind_cache`` (a pure ``pos`` retreat —
    whisper's self cache is never windowed)."""
    vidx = variant_idx
    pos = cache["pos"]                      # (B,) per-lane positions
    b, s = tokens.shape
    x = embed_lookup(params["embed"], tokens, cfg.compute_dtype,
                     bank=oget(overlay, "embed"), vidx=vidx)
    pos_table = sinusoidal_positions(cfg.max_seq_len, cfg.d_model)
    posn = pos[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
    x = x + jnp.take(pos_table, posn, axis=0).astype(x.dtype)
    frame_pos = jnp.arange(cfg.encoder_frames, dtype=jnp.int32)

    def body(h, xs):
        lp, ovl, sc, ck, cv = xs
        ov_s = oget(ovl, "self_attn")
        ov_x = oget(ovl, "cross_attn")
        hs = rmsnorm(h, psel(lp["ln1"], oget(ovl, "ln1"), vidx),
                     cfg.norm_eps)
        q, k, v = _qkv(lp["self_attn"], hs, hs, cfg, ov=ov_s, vidx=vidx)
        sc_new = A.cache_insert_multi(sc, k, v, pos)
        o = A.verify_attention(q, sc_new["k"], sc_new["v"],
                               sc_new["slot_pos"], pos)
        h = h + linear(o.reshape(b, s, cfg.q_dim), lp["self_attn"]["wo"],
                       oget(ov_s, "wo"), vidx, waxes=("embed", "q_heads"))
        hx = rmsnorm(h, psel(lp["ln_x"], oget(ovl, "ln_x"), vidx),
                     cfg.norm_eps)
        qx = linear(hx, lp["cross_attn"]["wq"], oget(ov_x, "wq"), vidx,
                    waxes=("q_heads", "embed")
                    ).reshape(b, s, cfg.num_heads, cfg.head_dim)
        ox = A.verify_attention(qx, ck, cv, frame_pos,
                                pos + cfg.encoder_frames)
        h = h + linear(ox.reshape(b, s, cfg.q_dim), lp["cross_attn"]["wo"],
                       oget(ov_x, "wo"), vidx, waxes=("embed", "q_heads"))
        h = h + mlp2_apply(lp["mlp"],
                           rmsnorm(h, psel(lp["ln2"], oget(ovl, "ln2"),
                                           vidx), cfg.norm_eps),
                           ov=oget(ovl, "mlp"), vidx=vidx)
        return h, sc_new

    x, self_new = jax.lax.scan(
        body, x, (params["dec_layers"], oget(overlay, "dec_layers"),
                  cache["self"], cache["cross_k"], cache["cross_v"]))
    x = rmsnorm(x, psel(params["dec_norm"], oget(overlay, "dec_norm"),
                        vidx), cfg.norm_eps)
    logits = unembed_logits(x, params["embed"],
                            bank=oget(overlay, "embed"), vidx=vidx)
    new_cache = dict(cache, pos=pos + s, **{"self": self_new})
    return logits, new_cache


def rewind_cache(cache, keep, span: int):
    """Drop the last span - keep[b] verify positions per row (see
    transformer.rewind_cache — same non-ring slot_pos masking argument)."""
    return dict(cache, pos=cache["pos"] - (span - keep))


def decode_step(params, token, cache, cfg, overlay=None, variant_idx=None):
    vidx = variant_idx
    pos = cache["pos"]                      # (B,) per-lane positions
    b = token.shape[0]
    x = embed_lookup(params["embed"], token[:, None], cfg.compute_dtype,
                     bank=oget(overlay, "embed"), vidx=vidx)
    pos_table = sinusoidal_positions(cfg.max_seq_len, cfg.d_model)
    x = x + jnp.take(pos_table, pos, axis=0)[:, None, :].astype(x.dtype)
    frame_pos = jnp.arange(cfg.encoder_frames, dtype=jnp.int32)

    def body(h, xs):
        lp, ovl, sc, ck, cv = xs
        ov_s = oget(ovl, "self_attn")
        ov_x = oget(ovl, "cross_attn")
        hs = rmsnorm(h, psel(lp["ln1"], oget(ovl, "ln1"), vidx),
                     cfg.norm_eps)
        q, k, v = _qkv(lp["self_attn"], hs, hs, cfg, ov=ov_s, vidx=vidx)
        sc_new = A.cache_insert(sc, k, v, pos)
        o = A.decode_attention(q, sc_new["k"], sc_new["v"],
                               sc_new["slot_pos"], pos)
        h = h + linear(o.reshape(b, 1, cfg.q_dim), lp["self_attn"]["wo"],
                       oget(ov_s, "wo"), vidx, waxes=("embed", "q_heads"))
        hx = rmsnorm(h, psel(lp["ln_x"], oget(ovl, "ln_x"), vidx),
                     cfg.norm_eps)
        qx = linear(hx, lp["cross_attn"]["wq"], oget(ov_x, "wq"), vidx,
                    waxes=("q_heads", "embed")
                    ).reshape(b, 1, cfg.num_heads, cfg.head_dim)
        ox = A.decode_attention(qx, ck, cv, frame_pos, pos + cfg.encoder_frames)
        h = h + linear(ox.reshape(b, 1, cfg.q_dim), lp["cross_attn"]["wo"],
                       oget(ov_x, "wo"), vidx, waxes=("embed", "q_heads"))
        h = h + mlp2_apply(lp["mlp"],
                           rmsnorm(h, psel(lp["ln2"], oget(ovl, "ln2"),
                                           vidx), cfg.norm_eps),
                           ov=oget(ovl, "mlp"), vidx=vidx)
        return h, sc_new

    x, self_new = jax.lax.scan(
        body, x, (params["dec_layers"], oget(overlay, "dec_layers"),
                  cache["self"], cache["cross_k"], cache["cross_v"]))
    x = rmsnorm(x, psel(params["dec_norm"], oget(overlay, "dec_norm"),
                        vidx), cfg.norm_eps)
    logits = unembed_logits(x, params["embed"],
                            bank=oget(overlay, "embed"), vidx=vidx)
    new_cache = dict(cache, pos=pos + 1, **{"self": self_new})
    return logits[:, 0, :], new_cache
