"""Pallas TPU kernels for the perf-critical hot spots.

- ``unpack_apply``: loader-path dense reconstruction Ŵ = v⊙unpack(B) + W_b.
- ``bitlinear``:   on-the-fly fused delta GEMM y = x @ Ŵᵀ (static axis mode).
- ``bitlinear_axes``: dual-axis fused delta GEMM — the serving-overlay hot
  path (v_eff = v_row ⊕ v_col; axis selection is data, not a static arg).
- ``flash_attention_fwd``: serving-prefill flash attention with
  VMEM-resident logits (the memory-bound prefill cells' fix).

``ref.py`` / models.attention hold the pure-jnp oracles; every kernel is
validated against them in interpret mode (tests/test_kernels.py,
tests/test_flash_kernel.py).
"""
from repro.kernels.ops import (bitlinear, bitlinear_axes,  # noqa: F401
                               flash_attention_fwd, unpack_apply)
