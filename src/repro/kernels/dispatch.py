"""Partitioned kernel dispatch: per-shard Pallas delta GEMMs (DESIGN.md §12).

The sharded serving path (DESIGN.md §11) jits whole decode steps with
explicit in/out shardings and lets GSPMD partition everything inside —
including the fused delta kernels.  That works for the interpret-mode (CPU)
lowering, but on a real TPU mesh a ``pl.pallas_call`` is a single opaque
custom call: GSPMD cannot slice into it, so the global kernel would force
full all-gathers of the very weight tiles the mesh exists to split.  This
module is the explicit alternative: wrap each fused delta GEMM in
``shard_map`` so every device runs the Pallas kernel on its OWN weight /
overlay tile, with block sizes picked from shard-local dims and the one
required collective (a psum over the contracted model axis, for
column-sharded weights) stated in the open.

Axis derivation (one source of truth, shared with the storage layer):

* the caller passes the shadowed weight's logical axes ``waxes`` (the same
  ``(*lead, out_ax, in_ax)`` tuples ``models/param.py`` declares and
  ``delta_overlay.entry_axes`` consumes);
* ``resolve_spec`` maps them onto the active mesh under the active rule
  set — exactly the resolution that placed the weight, overlay and bank
  leaves on device, so shard_map's in_specs describe layouts the operands
  already have (no resharding on the hot path);
* the packed sign plane is STORED with its byte dim replicated
  (``entry_axes`` — it is 8x smaller than the weight), but when the
  weight's in-axis is model-sharded the in_specs here slice that byte dim
  to the shard: each device reads only its K-tile's bytes.

Activation: the dispatch keys off the ambient ``shard_ctx`` (mesh + rules
— serving/engine.py already traces every sharded step inside it), so ops
wrappers route here automatically on a mesh and fall back to the global
jit path single-device.  ``no_dispatch()`` restores the PR-4 GSPMD
behaviour for A/B parity and latency comparisons
(benchmarks/shard_map_kernels.py; engine ``kernel_dispatch="gspmd"``).

Fallback contract: every entry point returns ``None`` when a per-shard
lowering is not possible — unknown weight axes, a shard-local K tile that
is not a multiple of the packing width (``_pick_block`` now refuses those
instead of silently picking a global-only block), or nothing to shard —
and the ops wrapper then serves the global kernel unchanged.  Dispatch is
an optimisation layer: it must never change results, only layouts.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import math
import threading
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

PACK = 8

_local = threading.local()


# ---------------------------------------------------------------------------
# activation
# ---------------------------------------------------------------------------

def state() -> Optional[tuple]:
    """(mesh, rules) when per-shard dispatch should engage, else None.

    Reads the ambient shard_ctx at TRACE time — the sharded engine and the
    dry-run both lower their step jits inside ``with mesh, shard_ctx(...)``,
    so kernels traced there see the pair; tier-1 single-device paths see
    None and keep the global jit wrappers byte-for-byte unchanged."""
    if getattr(_local, "off", 0):
        return None
    from repro.distributed.sharding import active_mesh, active_rules
    mesh = active_mesh()
    rules = active_rules()
    if mesh is None or rules is None:
        return None
    return mesh, rules


@contextlib.contextmanager
def no_dispatch():
    """Force the global (GSPMD-partitioned) kernel path inside an active
    mesh context — the PR-4 baseline the per-shard path is compared
    against, and the escape hatch for callers that vmap over kernels
    (vmap-of-shard_map is not a supported composition here)."""
    prev = getattr(_local, "off", 0)
    _local.off = prev + 1
    try:
        yield
    finally:
        _local.off = prev


# ---------------------------------------------------------------------------
# planning
# ---------------------------------------------------------------------------

def _names(part) -> tuple:
    """Mesh-axis names of one PartitionSpec entry (None -> ())."""
    if part is None:
        return ()
    return part if isinstance(part, tuple) else (part,)


def _size(mesh, part) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return math.prod(sizes[n] for n in _names(part))


@dataclasses.dataclass(frozen=True)
class Plan:
    """Resolved partitioning of one fused delta GEMM.

    ``m_part``: mesh axes of the flattened batch rows (data-parallel lanes);
    ``o_part`` / ``i_part``: mesh axes of the weight's out / in dim — at
    most one is non-None (``resolve_spec`` never assigns a mesh axis
    twice); ``psum_axes``: contracted axes to psum over (non-empty exactly
    when the in dim is sharded, i.e. each shard holds partial sums)."""
    m_part: object
    o_part: object
    i_part: object

    @property
    def psum_axes(self) -> tuple:
        return _names(self.i_part)


def plan_matmul(mesh, rules: dict, waxes, m: Optional[int], n: int,
                k: int) -> Optional[Plan]:
    """Partitioning plan for y[m, n] = x[m, k] @ Ŵ[n, k]ᵀ, or None when
    the per-shard path cannot run (caller falls back to the global
    kernel).  ``waxes`` are the weight's logical axes (last two used);
    ``m=None`` plans weight-only ops (unpack_apply) with no batch dim."""
    if waxes is None or len(waxes) < 2:
        return None
    from repro.distributed.sharding import resolve_spec
    o_part, i_part = resolve_spec((n, k), tuple(waxes[-2:]), rules, mesh)
    m_part = None
    if m is not None:
        m_part = resolve_spec((m,), ("act_batch",), rules, mesh)[0]
        if set(_names(m_part)) & (set(_names(o_part)) | set(_names(i_part))):
            m_part = None       # pathological rule set: batch wins nothing
    if i_part is not None and (k // _size(mesh, i_part)) % PACK:
        # the shard-local K tile (and its packed byte dim) would not align
        # to the packing width — _pick_block rightly refuses such dims, so
        # this matmul stays on the global path
        return None
    if m_part is None and o_part is None and i_part is None:
        return None             # fully replicated: global path IS local
    return Plan(m_part=m_part, o_part=o_part, i_part=i_part)


# compiled shard_map callables, memoized per (op kind, mesh, plan, operand
# shapes/dtypes, statics): every entry point below builds a FRESH closure,
# so without this cache eager callers (e.g. the registry's mesh dense
# reconstruction) would re-trace and re-lower on every call — jit'ing the
# shard_map and keying on everything the trace depends on restores the
# compile-once behaviour of the global @jax.jit wrappers.  Mesh and Plan
# are hashable; shapes/dtypes/statics are plain tuples.
#
# The memo is LRU-BOUNDED: a long-lived multi-variant server sees a new
# key per (shape, mesh, plan) combination and an unbounded dict would
# grow with every novel workload shape for the life of the process.
# Eviction only drops the python wrapper — executables already inlined
# into an engine step jit, or held by a caller, stay alive.
_MEMO_CAP = 256
_compiled: "collections.OrderedDict" = collections.OrderedDict()
memo_stats = {"hits": 0, "misses": 0, "evictions": 0,
              "persist_hits": 0, "persist_compiles": 0,
              "compile_seconds": 0.0}


def memo_info() -> dict:
    """Dispatch-memo observability: counters + current occupancy
    (engine.status() and benchmarks/run.py surface this)."""
    return {**memo_stats, "entries": len(_compiled), "cap": _MEMO_CAP}


def set_memo_cap(cap: int) -> None:
    """Resize the memo bound (tests); evicts LRU down to ``cap``."""
    global _MEMO_CAP
    if cap < 1:
        raise ValueError("memo cap must be >= 1")
    _MEMO_CAP = cap
    while len(_compiled) > _MEMO_CAP:
        _compiled.popitem(last=False)
        memo_stats["evictions"] += 1


def _persist_parts(key) -> tuple:
    """Map one memo key to process-stable persistent-cache parts: the
    Mesh hashes per-process, so it is replaced by its (axes, shape,
    device-kind) fingerprint; Plans and aval tuples repr stably."""
    from repro.core import compile_cache as CC
    return tuple(CC.mesh_fp(p) if isinstance(p, jax.sharding.Mesh)
                 else repr(p) for p in key)


class _CachedFn:
    """One memo entry: the wrapped jit plus, for EAGER callers, a
    compiled stage resolved through the ambient persistent cache
    (core/compile_cache.py).  Dispatch entry points are usually traced
    inside an outer step jit — there the wrapped call inlines and the
    outer executable owns the compile — but eager callers (the
    registry's mesh dense reconstruction) pay a real per-process
    compile that a warm cache turns into a deserialize."""

    __slots__ = ("key", "jitted", "compiled")

    def __init__(self, key, fn):
        self.key = key
        self.jitted = jax.jit(fn)
        self.compiled = None

    def __call__(self, *args):
        if self.compiled is not None:
            return self.compiled(*args)
        if any(isinstance(a, jax.core.Tracer) for a in args):
            return self.jitted(*args)
        from repro.core import compile_cache as CC
        cc = CC.get_default()
        if cc is None:
            return self.jitted(*args)
        parts = ("dispatch",) + _persist_parts(self.key)
        compiled = cc.get(parts)
        if compiled is None:
            import time
            t0 = time.perf_counter()
            compiled = self.jitted.lower(*args).compile()
            memo_stats["compile_seconds"] += time.perf_counter() - t0
            memo_stats["persist_compiles"] += 1
            cc.put(cc.key(*parts), compiled)
        else:
            memo_stats["persist_hits"] += 1
        self.compiled = compiled
        return compiled(*args)


def _cached_jit(key, build):
    fn = _compiled.get(key)
    if fn is not None:
        _compiled.move_to_end(key)
        memo_stats["hits"] += 1
        return fn
    memo_stats["misses"] += 1
    fn = _CachedFn(key, build())
    _compiled[key] = fn
    while len(_compiled) > _MEMO_CAP:
        _compiled.popitem(last=False)
        memo_stats["evictions"] += 1
    return fn


def _avals(*arrays) -> tuple:
    return tuple((tuple(a.shape), jnp.dtype(a.dtype).name) for a in arrays)


def _unwrap_quant(w):
    """(payload, scale-or-None) of a base-weight operand — the dispatch
    twin of ``ops._unwrap_quant`` (duck-typed; ops imports this module,
    not the reverse).  An int8 base adds one per-output-channel scale
    operand to the shard_map, sharded like the out dim it scales; the
    int8 payload aval makes the memo key dtype-distinct on its own."""
    if getattr(w, "__quant_leaf__", False):
        return w.q, w.scale
    return w, None


# ---------------------------------------------------------------------------
# shard_map'd entry points (ops.py routes here; every one may return None)
# ---------------------------------------------------------------------------

def bitlinear_axes(st, x: jax.Array, packed: jax.Array, v_row: jax.Array,
                   v_col: jax.Array, w_base,
                   waxes) -> Optional[jax.Array]:
    """shard_map'd fused y = x @ ((v_row ⊕ v_col) ⊙ unpack(B) + W_b)ᵀ.

    ``w_base`` may be a QuantWeight: the per-output-channel scale rides
    as one extra operand sharded with the out dim and each shard's
    Pallas call dequantizes its own int8 tile in VMEM."""
    mesh, rules = st
    wq, ws = _unwrap_quant(w_base)
    *lead, k = x.shape
    n = wq.shape[0]
    x2 = x.reshape(-1, k)
    plan = plan_matmul(mesh, rules, waxes, x2.shape[0], n, k)
    if plan is None:
        return None
    mp, op, ip = plan.m_part, plan.o_part, plan.i_part

    def shard_fn(x2, pk, vr, vc, wb, *ws_op):
        # import from the SUBMODULES directly: the kernels package
        # re-exports same-named jitted functions over the module attrs
        from repro.kernels.bitlinear import bitlinear_axes_p
        import repro.kernels.ops as O
        lm, lk = x2.shape
        ln = wb.shape[0]
        y = bitlinear_axes_p(
            x2, pk, vr.reshape(ln, 1), vc.reshape(1, lk), wb,
            block_m=O._pick_block(lm, O._TILE_M),
            block_n=O._pick_block(ln, O._TILE_N),
            block_k=O._pick_block(lk, O._TILE_K, multiple=PACK),
            interpret=O._interpret(),
            w_scale=ws_op[0].reshape(ln, 1) if ws_op else None)
        if plan.psum_axes:
            y = jax.lax.psum(y, plan.psum_axes)
        return y

    vr = v_row.reshape(n)
    vc = v_col.reshape(k)
    in_specs = (P(mp, ip), P(op, ip), P(op), P(ip), P(op, ip))
    operands = (x2, packed, vr, vc, wq)
    if ws is not None:
        in_specs += (P(op),)
        operands += (ws.reshape(n),)
    fn = _cached_jit(
        ("axes", mesh, plan, _avals(*operands)),
        lambda: shard_map(
            shard_fn, mesh=mesh,
            in_specs=in_specs,
            out_specs=P(mp, op),    # op is None whenever ip carried model
            check_rep=False))
    y = fn(*operands)
    return y.astype(x.dtype).reshape(*lead, n)


def _bank_part(mesh, rules: dict, nb: int, plan: Plan):
    """Mesh partition of the bank slot axis under the active rules (None
    = replicated, the pre-§17 layout).  Pod-local banks resolve to "pod";
    a bank axis that does not divide, or whose mesh axes already carry
    the weight's out/in dim (they share the overlay operands with the
    bank dim — a mesh axis may appear only once per spec), falls back to
    replicated.  ``plan.m_part`` using "pod" is fine: the batch rows live
    in a DIFFERENT operand."""
    from repro.distributed.sharding import resolve_spec
    bp = resolve_spec((nb,), ("bank",), rules, mesh)[0]
    if bp is None:
        return None
    used = set(_names(plan.o_part)) | set(_names(plan.i_part))
    if set(_names(bp)) & used:
        return None
    return bp


def _axes_linear_index(names: tuple):
    """Row-major linear index of this shard over the given mesh axes
    (inside shard_map) — the pod offset term of the banked vidx
    translation."""
    idx = None
    for nm in names:
        ai = jax.lax.axis_index(nm)
        idx = ai if idx is None else idx * jax.lax.psum(1, nm) + ai
    return idx


def bitlinear_axes_banked(st, x: jax.Array, variant_idx: jax.Array,
                          packed: jax.Array, v_row: jax.Array,
                          v_col: jax.Array, w_base: jax.Array,
                          waxes) -> Optional[jax.Array]:
    """shard_map'd mixed-variant fused GEMM: overlay leaves carry a leading
    bank axis; each device gathers its rows' slots from its OWN weight
    tile's bank — admission stays collective-free and so does the per-row
    gather.

    The bank axis is replicated by default; under pod-local rules
    (DESIGN.md §17) it shards over "pod" and ``variant_idx`` — which the
    engine writes as GLOBAL slot ids (pod p owns slots [p*S, (p+1)*S)) —
    is translated to the shard-local slot by subtracting this pod's
    offset.  The affinity router only ever routes a row to its own pod's
    slots, so the clamp is a memory-safety bound, not a semantic path."""
    mesh, rules = st
    wq, ws = _unwrap_quant(w_base)
    *lead, k = x.shape
    n = wq.shape[0]
    nb = packed.shape[0]
    x2 = x.reshape(-1, k)
    m = x2.shape[0]
    plan = plan_matmul(mesh, rules, waxes, m, n, k)
    if plan is None:
        return None
    mp, op, ip = plan.m_part, plan.o_part, plan.i_part
    bp = _bank_part(mesh, rules, nb, plan)
    lnb = nb // _size(mesh, bp)         # shard-local bank slots
    import repro.kernels.ops as _O
    vidx2 = _O.flatten_vidx(variant_idx, tuple(lead)).reshape(m, 1)

    def shard_fn(x2, vi, pk, vr, vc, wb, *ws_op):
        from repro.kernels.bitlinear import bitlinear_axes_banked_p
        import repro.kernels.ops as O
        lm, lk = x2.shape
        ln = wb.shape[0]
        if bp is not None:
            off = _axes_linear_index(_names(bp)) * lnb
            vi = jnp.clip(vi - off, 0, lnb - 1)
        y = bitlinear_axes_banked_p(
            x2, vi, pk, vr.reshape(lnb, ln, 1), vc.reshape(lnb, 1, lk), wb,
            block_m=O._pick_block(lm, O._TILE_BANKED_M),
            block_n=O._pick_block(ln, O._TILE_BANKED_N),
            block_k=O._pick_block(lk, O._TILE_BANKED_K, multiple=PACK),
            interpret=O._interpret(),
            w_scale=ws_op[0].reshape(ln, 1) if ws_op else None)
        if plan.psum_axes:
            y = jax.lax.psum(y, plan.psum_axes)
        return y

    pk = packed.reshape(nb, n, k // PACK)
    vr = v_row.reshape(nb, n)
    vc = v_col.reshape(nb, k)
    in_specs = (P(mp, ip), P(mp, None), P(bp, op, ip), P(bp, op),
                P(bp, ip), P(op, ip))
    operands = (x2, vidx2, pk, vr, vc, wq)
    if ws is not None:
        in_specs += (P(op),)
        operands += (ws.reshape(n),)
    fn = _cached_jit(
        ("banked", mesh, plan, bp, _avals(*operands)),
        lambda: shard_map(
            shard_fn, mesh=mesh,
            in_specs=in_specs,
            out_specs=P(mp, op),
            check_rep=False))
    y = fn(*operands)
    return y.astype(x.dtype).reshape(*lead, n)


def bitlinear_axes_stacked(st, xe: jax.Array, entry, w,
                           waxes) -> Optional[jax.Array]:
    """shard_map'd per-expert fused GEMMs: xe (E, M, D) · entry leaves
    (E, F, D/8)/(E, F)/(E, D) · w (E, F, D) -> (E, M, F).

    The MoE expert stacks shard their EXPERT dim over the model axis, so
    the per-shard body vmaps the 2-D kernel over the local experts —
    shard_map(vmap(kernel)), the composition that works, instead of
    vmap(shard_map(kernel)), which does not.  Falls back through the same
    plan contract when experts don't divide (then ffn/embed may carry the
    axis and the contraction psums).  ``w`` may be a QuantWeight with an
    (E, F) scale riding the expert/ffn axes."""
    mesh, rules = st
    wq, ws = _unwrap_quant(w)
    if waxes is None or len(waxes) != 3:
        return None
    from repro.distributed.sharding import resolve_spec
    e, m, d = xe.shape
    f = wq.shape[1]
    ep, fp, dp = resolve_spec((e, f, d), tuple(waxes), rules, mesh)
    if dp is not None and (d // _size(mesh, dp)) % PACK:
        return None
    if ep is None and fp is None and dp is None:
        return None
    psum_axes = _names(dp)

    def shard_fn(xl, pk, vr, vc, wb, *ws_op):
        from repro.kernels.bitlinear import bitlinear_axes_p
        import repro.kernels.ops as O
        _, lm, ld = xl.shape
        lf = wb.shape[1]
        bm = O._pick_block(lm, O._TILE_M)
        bn = O._pick_block(lf, O._TILE_N)
        bk = O._pick_block(ld, O._TILE_K, multiple=PACK)

        def one(x2, p2, r2, c2, w2, *s2):
            return bitlinear_axes_p(
                x2, p2, r2.reshape(lf, 1), c2.reshape(1, ld), w2,
                block_m=bm, block_n=bn, block_k=bk,
                interpret=O._interpret(),
                w_scale=s2[0].reshape(lf, 1) if s2 else None)

        y = jax.vmap(one)(xl, pk, vr, vc, wb, *ws_op)
        if psum_axes:
            y = jax.lax.psum(y, psum_axes)
        return y

    in_specs = (P(ep, None, dp), P(ep, fp, dp), P(ep, fp), P(ep, dp),
                P(ep, fp, dp))
    operands = (xe, entry.packed, entry.v_row, entry.v_col, wq)
    if ws is not None:
        in_specs += (P(ep, fp),)
        operands += (ws,)
    fn = _cached_jit(
        ("stacked", mesh, (ep, fp, dp), _avals(*operands)),
        lambda: shard_map(
            shard_fn, mesh=mesh,
            in_specs=in_specs,
            out_specs=P(ep, None, fp),
            check_rep=False))
    y = fn(*operands)
    return y.astype(xe.dtype)


def unpack_apply(st, packed: jax.Array, v: jax.Array, w_base,
                 mode: str, out_dtype, waxes) -> Optional[jax.Array]:
    """shard_map'd Ŵ = v ⊙ unpack(B) + W_b: pure per-tile reconstruction,
    no contraction — every shard rebuilds exactly its own weight tile.
    ``w_base`` may be a QuantWeight (int8 base, per-tile dequant)."""
    mesh, rules = st
    wq, ws = _unwrap_quant(w_base)
    n, k = wq.shape
    plan = plan_matmul(mesh, rules, waxes, None, n, k)
    if plan is None:
        return None
    op, ip = plan.o_part, plan.i_part
    v_spec = {"row": P(op, None), "col": P(None, ip),
              "scalar": P(None, None)}[mode]

    def shard_fn(pk, v2, wb, *ws_op):
        import repro.kernels.ops as O
        from repro.kernels.unpack_apply import unpack_apply_p
        ln, lk = wb.shape
        return unpack_apply_p(
            pk, v2, wb,
            block_m=O._pick_block(ln, O._TILE_M),
            block_n=O._pick_block(lk, O._TILE_N, multiple=PACK),
            out_dtype=out_dtype, interpret=O._interpret(),
            w_scale=ws_op[0].reshape(ln, 1) if ws_op else None)

    from repro.kernels.ops import _v2d
    v2 = _v2d(v, mode, n, k)
    in_specs = (P(op, ip), v_spec, P(op, ip))
    operands = (packed, v2, wq)
    if ws is not None:
        in_specs += (P(op),)
        operands += (ws.reshape(n),)
    fn = _cached_jit(
        ("unpack", mesh, plan, mode, jnp.dtype(out_dtype).name,
         _avals(*operands)),
        lambda: shard_map(
            shard_fn, mesh=mesh,
            in_specs=in_specs,
            out_specs=P(op, ip),
            check_rep=False))
    return fn(*operands)
