"""Pallas TPU kernel: forward flash attention (serving path).

The §Roofline tables show prefill cells are memory-bound, dominated by
attention logit traffic: the jnp-level flash scan materialises one
(S × chunk) fp32 logit block per step through HBM (dot output + softmax
reduce reads + second dot input ≈ 4 passes).  This kernel keeps the logit
block, the online-softmax statistics and the output accumulator resident
in VMEM — HBM traffic drops to reading Q/K/V once and writing O once, the
flash-attention ideal.  Napkin (qwen3 prefill_32k, per device): logits
traffic ≈ 2.4 s of the 3.6 s memory term → kernel-resident logits bring
the memory term toward ≈1.2 s (weights+activations), ≈3× on that term.

Forward-only by design: training keeps the custom-VJP jnp path
(models/attention.py); serving (prefill) has no backward.

Layout: q (BH, S, hd) · k/v (BH_kv, T, hd) — heads flattened into the
leading dim by ops.py; GQA handled by index-mapping each q head to its
kv head (bh // group).  Grid (BH, S/bq, T/bk), causal masking by absolute
positions, fp32 accumulation in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, bq: int, bk: int, n_k: int,
            q_offset: int, kv_offset: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale              # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                      # (bk, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)
    q_pos = q_offset + qi * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bk), 0)
    k_pos = kv_offset + ki * bk + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bk), 1)
    if causal:
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
    m_scr[...] = m_new
    v = v_ref[0].astype(jnp.float32)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _finalize():
        o_ref[0, ...] = (acc_scr[...]
                         / jnp.maximum(l_scr[...], 1e-30)[:, None]
                         ).astype(o_ref.dtype)


def flash_attention_fwd_p(q: jax.Array, k: jax.Array, v: jax.Array, *,
                          group: int, causal: bool = True,
                          q_offset: int = 0, kv_offset: int = 0,
                          block_q: int = 128, block_k: int = 128,
                          interpret: bool = True) -> jax.Array:
    """q (BH, S, hd); k/v (BH//group, T, hd) -> o (BH, S, hd)."""
    bh, s, hd = q.shape
    _, t, _ = k.shape
    bq = min(block_q, s)
    while s % bq:
        bq //= 2
    bk = min(block_k, t)
    while t % bk:
        bk //= 2
    n_k = t // bk
    grid = (bh, s // bq, n_k)

    from jax.experimental.pallas import tpu as pltpu
    scratch = [pltpu.VMEM((bq,), jnp.float32),
               pltpu.VMEM((bq,), jnp.float32),
               pltpu.VMEM((bq, hd), jnp.float32)]

    kernel = functools.partial(
        _kernel, scale=hd ** -0.5, causal=causal, bq=bq, bk=bk, n_k=n_k,
        q_offset=q_offset, kv_offset=kv_offset)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b // group, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(q, k, v)
