"""Pallas TPU kernel: fused on-the-fly delta GEMM  y = x @ (v⊙unpack(B)+W_b)ᵀ.

The paper (§4, last paragraph) notes an "on-the-fly variant [that] could
apply [deltas] dynamically in each forward pass and avoid switch costs, but
would introduce runtime overhead unless supported by fused GEMM kernels".
This is that kernel, adapted TPU-natively:

* GPU approach would be XNOR/popcount bit-tricks; on TPU the MXU wants a
  dense bf16 tile anyway, so we unpack the (bn × bk/8) uint8 tile to ±1 in
  VMEM (VPU shifts), fuse the per-axis FMA to form Ŵ-tile, and issue a
  *single* MXU dot per tile — identical FLOPs to the dense GEMM.
* The win is bandwidth: decode-time GEMV is HBM-bound; streaming the delta
  costs 1/16 of the base-weight bytes, so serving a *different* variant per
  step costs ~6% extra traffic instead of 2× (two dense weight reads) or a
  full dense re-materialisation per swap.

Shapes:  x (M, K) · packed (N, K/8) · w_base (N, K) · y (M, N).
Per-axis scale v2d pre-reshaped by ops.py: row (N, 1) · col (1, K) ·
scalar (1, 1).  (row scales output features = rows of W.)

Grid (M/bm, N/bn, K/bk), K innermost; fp32 accumulation directly in the
output block (out dtype fp32; caller casts).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.unpack_apply import _unpack_tile

PACK = 8


def _kernel(x_ref, packed_ref, v_ref, wb_ref, out_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    signs = _unpack_tile(packed_ref[...], jnp.float32)      # (bn, bk)
    v = v_ref[...].astype(jnp.float32)                      # (bn,1)|(1,bk)|(1,1)
    w_hat = (v * signs + wb_ref[...].astype(jnp.float32))   # (bn, bk)
    x = x_ref[...].astype(jnp.float32)                      # (bm, bk)
    out_ref[...] += jax.lax.dot_general(
        x, w_hat, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


def _kernel_axes(x_ref, packed_ref, vr_ref, vc_ref, wb_ref, out_ref):
    """Dual-axis variant: effective scale v[n,k] = v_row[n] + v_col[k].

    The serving overlay (models/delta_overlay.py) zeroes the UNSELECTED
    axis vector per matrix, so the sum reduces to exactly the selected
    per-axis scale — one kernel covers row, col and scalar entries, and
    the axis choice stays a plain array (scan/vmap-able over stacked
    layers) instead of a static mode argument.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    signs = _unpack_tile(packed_ref[...], jnp.float32)      # (bn, bk)
    v = (vr_ref[...].astype(jnp.float32)                    # (bn, 1)
         + vc_ref[...].astype(jnp.float32))                 # (1, bk)
    w_hat = (v * signs + wb_ref[...].astype(jnp.float32))   # (bn, bk)
    x = x_ref[...].astype(jnp.float32)                      # (bm, bk)
    out_ref[...] += jax.lax.dot_general(
        x, w_hat, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


def _kernel_q8(x_ref, packed_ref, v_ref, wq_ref, ws_ref, out_ref):
    """Int8-base variant of ``_kernel``: the (bn, bk) base tile arrives
    int8 and is dequantized in VMEM against the per-output-channel fp16
    scale (a (bn, 1) broadcast) before the delta FMA — one dequant +
    delta-apply per tile, same single MXU dot."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    signs = _unpack_tile(packed_ref[...], jnp.float32)      # (bn, bk)
    v = v_ref[...].astype(jnp.float32)
    wb = (wq_ref[...].astype(jnp.float32)
          * ws_ref[...].astype(jnp.float32))                # (bn, bk)
    w_hat = v * signs + wb
    x = x_ref[...].astype(jnp.float32)
    out_ref[...] += jax.lax.dot_general(
        x, w_hat, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


def _kernel_axes_q8(x_ref, packed_ref, vr_ref, vc_ref, wq_ref, ws_ref,
                    out_ref):
    """Int8-base variant of ``_kernel_axes`` (same in-tile dequant)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    signs = _unpack_tile(packed_ref[...], jnp.float32)      # (bn, bk)
    v = (vr_ref[...].astype(jnp.float32)
         + vc_ref[...].astype(jnp.float32))
    wb = (wq_ref[...].astype(jnp.float32)
          * ws_ref[...].astype(jnp.float32))                # (bn, bk)
    w_hat = v * signs + wb
    x = x_ref[...].astype(jnp.float32)
    out_ref[...] += jax.lax.dot_general(
        x, w_hat, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


def _kernel_axes_banked(x_ref, vidx_ref, packed_ref, vr_ref, vc_ref, wb_ref,
                        out_ref):
    """Banked variant: overlay operands carry a leading bank axis V and each
    batch ROW selects its own bank slot via ``variant_idx`` (slot 0 = base,
    whose packed/vector slots are zero, so v_eff = 0 and Ŵ-row = W_b).

    Mixed-variant decode is a GEMV per row (HBM-bound, M = batch slots), so
    instead of one MXU dot per variant (V× FLOPs when every row differs) the
    kernel gathers each row's PACKED tile + axis vectors from the bank in
    VMEM, unpacks per row, and contracts on the VPU — work is O(M·bn·bk),
    independent of bank size.  The whole bank block rides in VMEM: packed is
    1/16 the bytes of the base tile per slot, so even V=16 costs ~2× the
    base-weight tile footprint.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    vidx = vidx_ref[...][:, 0]                              # (bm,)
    packed = jnp.take(packed_ref[...], vidx, axis=0)        # (bm, bn, bk/8)
    bm, bn, bkp = packed.shape
    signs = _unpack_tile(packed.reshape(bm * bn, bkp),
                         jnp.float32).reshape(bm, bn, bkp * PACK)
    v = (jnp.take(vr_ref[...], vidx, axis=0).astype(jnp.float32)   # (bm,bn,1)
         + jnp.take(vc_ref[...], vidx, axis=0).astype(jnp.float32))
    w_hat = v * signs + wb_ref[...].astype(jnp.float32)[None]      # (bm,bn,bk)
    x = x_ref[...].astype(jnp.float32)                             # (bm, bk)
    out_ref[...] += jnp.einsum("mnk,mk->mn", w_hat, x,
                               preferred_element_type=jnp.float32)


def _kernel_axes_banked_q8(x_ref, vidx_ref, packed_ref, vr_ref, vc_ref,
                           wq_ref, ws_ref, out_ref):
    """Int8-base variant of ``_kernel_axes_banked``: the shared base tile
    dequantizes ONCE per tile (not per bank slot) before the per-row
    broadcast — banked extras and the bank gather are unchanged."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    vidx = vidx_ref[...][:, 0]                              # (bm,)
    packed = jnp.take(packed_ref[...], vidx, axis=0)        # (bm, bn, bk/8)
    bm, bn, bkp = packed.shape
    signs = _unpack_tile(packed.reshape(bm * bn, bkp),
                         jnp.float32).reshape(bm, bn, bkp * PACK)
    v = (jnp.take(vr_ref[...], vidx, axis=0).astype(jnp.float32)
         + jnp.take(vc_ref[...], vidx, axis=0).astype(jnp.float32))
    wb = (wq_ref[...].astype(jnp.float32)
          * ws_ref[...].astype(jnp.float32))                # (bn, bk)
    w_hat = v * signs + wb[None]                            # (bm, bn, bk)
    x = x_ref[...].astype(jnp.float32)                      # (bm, bk)
    out_ref[...] += jnp.einsum("mnk,mk->mn", w_hat, x,
                               preferred_element_type=jnp.float32)


def bitlinear_axes_banked_p(x: jax.Array, vidx: jax.Array, packed: jax.Array,
                            vr2d: jax.Array, vc2d: jax.Array,
                            w_base: jax.Array, *, block_m: int, block_n: int,
                            block_k: int, interpret: bool,
                            w_scale: jax.Array = None) -> jax.Array:
    """x (M, K) · vidx (M, 1) int32 · packed (V, N, K/8) · vr2d (V, N, 1) ·
    vc2d (V, 1, K) · w_base (N, K) -> y (M, N) fp32.  ``w_scale`` (N, 1)
    fp16 selects the int8-base kernel (w_base is then int8)."""
    m, k_dim = x.shape
    n, _ = w_base.shape
    nbank = packed.shape[0]
    assert k_dim % PACK == 0 and block_k % PACK == 0
    assert m % block_m == 0 and n % block_n == 0 and k_dim % block_k == 0
    assert vidx.shape == (m, 1) and vidx.dtype == jnp.int32
    assert vr2d.shape == (nbank, n, 1) and vc2d.shape == (nbank, 1, k_dim)
    grid = (m // block_m, n // block_n, k_dim // block_k)

    in_specs = [
        pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((block_m, 1), lambda i, j, kk: (i, 0)),
        pl.BlockSpec((nbank, block_n, block_k // PACK),
                     lambda i, j, kk: (0, j, kk)),
        pl.BlockSpec((nbank, block_n, 1), lambda i, j, kk: (0, j, 0)),
        pl.BlockSpec((nbank, 1, block_k), lambda i, j, kk: (0, 0, kk)),
        pl.BlockSpec((block_n, block_k), lambda i, j, kk: (j, kk)),
    ]
    operands = [x, vidx, packed, vr2d, vc2d, w_base]
    kernel = _kernel_axes_banked
    if w_scale is not None:
        assert w_scale.shape == (n, 1)
        in_specs.append(pl.BlockSpec((block_n, 1), lambda i, j, kk: (j, 0)))
        operands.append(w_scale)
        kernel = _kernel_axes_banked_q8

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(*operands)


def bitlinear_axes_p(x: jax.Array, packed: jax.Array, vr2d: jax.Array,
                     vc2d: jax.Array, w_base: jax.Array, *, block_m: int,
                     block_n: int, block_k: int, interpret: bool,
                     w_scale: jax.Array = None) -> jax.Array:
    """``w_scale`` (N, 1) fp16 selects the int8-base kernel: w_base is
    then the int8 payload, dequantized per tile in VMEM."""
    m, k_dim = x.shape
    n, _ = w_base.shape
    assert k_dim % PACK == 0 and block_k % PACK == 0
    assert m % block_m == 0 and n % block_n == 0 and k_dim % block_k == 0
    assert vr2d.shape == (n, 1) and vc2d.shape == (1, k_dim)
    grid = (m // block_m, n // block_n, k_dim // block_k)

    in_specs = [
        pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((block_n, block_k // PACK), lambda i, j, kk: (j, kk)),
        pl.BlockSpec((block_n, 1), lambda i, j, kk: (j, 0)),
        pl.BlockSpec((1, block_k), lambda i, j, kk: (0, kk)),
        pl.BlockSpec((block_n, block_k), lambda i, j, kk: (j, kk)),
    ]
    operands = [x, packed, vr2d, vc2d, w_base]
    kernel = _kernel_axes
    if w_scale is not None:
        assert w_scale.shape == (n, 1)
        in_specs.append(pl.BlockSpec((block_n, 1), lambda i, j, kk: (j, 0)))
        operands.append(w_scale)
        kernel = _kernel_axes_q8

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(*operands)


def bitlinear_p(x: jax.Array, packed: jax.Array, v2d: jax.Array,
                w_base: jax.Array, *, block_m: int, block_n: int,
                block_k: int, interpret: bool,
                w_scale: jax.Array = None) -> jax.Array:
    m, k_dim = x.shape
    n, _ = w_base.shape
    assert k_dim % PACK == 0 and block_k % PACK == 0
    assert m % block_m == 0 and n % block_n == 0 and k_dim % block_k == 0
    grid = (m // block_m, n // block_n, k_dim // block_k)

    vn, vk = v2d.shape  # (N,1) | (1,K) | (1,1)
    v_block = (block_n if vn > 1 else 1, block_k if vk > 1 else 1)

    def v_index(i, j, kk):
        return (j if vn > 1 else 0, kk if vk > 1 else 0)

    in_specs = [
        pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((block_n, block_k // PACK), lambda i, j, kk: (j, kk)),
        pl.BlockSpec(v_block, v_index),
        pl.BlockSpec((block_n, block_k), lambda i, j, kk: (j, kk)),
    ]
    operands = [x, packed, v2d, w_base]
    kernel = _kernel
    if w_scale is not None:
        assert w_scale.shape == (n, 1)
        in_specs.append(pl.BlockSpec((block_n, 1), lambda i, j, kk: (j, 0)))
        operands.append(w_scale)
        kernel = _kernel_q8

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(*operands)
