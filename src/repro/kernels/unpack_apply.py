"""Pallas TPU kernel: fused dense reconstruction Ŵ = v ⊙ unpack(B) + W_b.

This is the loader hot path (paper §3.2 "Storage and load-time"): after the
packed mask + scale vector arrive in HBM (one transfer per module), this
kernel streams W_b once and the packed mask at 1/16 the bytes of a bf16
weight, unpacking to ±1 *inside VMEM* and applying the per-axis FMA on the
VPU.  HBM traffic ≈ (1 + 1/16)·|W| reads + |W| writes — the unpack never
round-trips a dense ±1 matrix through HBM.

Layout contract (matches repro.core.delta):
  packed : (d_out, d_in // 8) uint8, little-endian bit j ↔ column i*8+j
  w_base : (d_out, d_in)
  v2d    : row  (d_out, 1) · col (1, d_in) · scalar (1, 1)  — pre-reshaped
           by ops.py so the kernel is mode-agnostic (pure broadcast FMA).

Blocking: grid (d_out/bm, d_in/bn); bn must be a multiple of 8 (packing) and
should be a multiple of 128 (lane width) in production.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PACK = 8


def _unpack_tile(packed_tile: jax.Array, out_dtype) -> jax.Array:
    """(bm, bn//8) uint8 -> (bm, bn) ±1 in out_dtype, little-endian."""
    bm, bnp = packed_tile.shape
    shifts = jnp.arange(PACK, dtype=jnp.uint8)
    bits = (packed_tile[:, :, None] >> shifts) & jnp.uint8(1)
    bits = bits.reshape(bm, bnp * PACK)
    return (bits.astype(out_dtype) * 2 - 1).astype(out_dtype)


def _kernel(packed_ref, v_ref, wb_ref, out_ref):
    signs = _unpack_tile(packed_ref[...], jnp.float32)
    v = v_ref[...].astype(jnp.float32)          # (bm,1) | (1,bn) | (1,1)
    wb = wb_ref[...].astype(jnp.float32)
    out_ref[...] = (v * signs + wb).astype(out_ref.dtype)


def _kernel_q8(packed_ref, v_ref, wq_ref, ws_ref, out_ref):
    """Int8-base variant: dequantize the base tile in VMEM (per-output-
    channel fp16 scale, a (bm, 1) broadcast) before the same FMA — the
    dense fp base is never read from nor written to HBM."""
    signs = _unpack_tile(packed_ref[...], jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    wb = wq_ref[...].astype(jnp.float32) * ws_ref[...].astype(jnp.float32)
    out_ref[...] = (v * signs + wb).astype(out_ref.dtype)


def unpack_apply_p(packed: jax.Array, v2d: jax.Array, w_base: jax.Array,
                   *, block_m: int, block_n: int, out_dtype,
                   interpret: bool, w_scale: jax.Array = None) -> jax.Array:
    """``w_scale`` (d_out, 1) fp16 selects the int8-base kernel: w_base is
    then the int8 payload and the tile loop dequantizes in VMEM."""
    d_out, d_in = w_base.shape
    assert d_in % PACK == 0 and block_n % PACK == 0
    assert d_out % block_m == 0 and d_in % block_n == 0
    grid = (d_out // block_m, d_in // block_n)

    vm, vn = v2d.shape  # (d_out,1) | (1,d_in) | (1,1)
    v_block = (block_m if vm > 1 else 1, block_n if vn > 1 else 1)

    def v_index(i, j):
        return (i if vm > 1 else 0, j if vn > 1 else 0)

    in_specs = [
        pl.BlockSpec((block_m, block_n // PACK), lambda i, j: (i, j)),
        pl.BlockSpec(v_block, v_index),
        pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
    ]
    operands = [packed, v2d, w_base]
    kernel = _kernel
    if w_scale is not None:
        assert w_scale.shape == (d_out, 1)
        in_specs.append(pl.BlockSpec((block_m, 1), lambda i, j: (i, 0)))
        operands.append(w_scale)
        kernel = _kernel_q8

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d_out, d_in), out_dtype),
        interpret=interpret,
    )(*operands)
