"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package must match its oracle here to within dtype
tolerance (tests/test_kernels.py sweeps shapes/dtypes/modes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import delta as D


def _deq(w_base: jax.Array, w_scale) -> jax.Array:
    """Dense-dequant the int8 base when a per-output-channel scale rides
    along (``w_scale`` (d_out,), broadcast over the contracted dim) —
    the oracle twin of the kernels' in-tile dequant."""
    wb = w_base.astype(jnp.float32)
    if w_scale is not None:
        wb = wb * w_scale.astype(jnp.float32)[..., None]
    return wb


def unpack_apply_ref(packed: jax.Array, v: jax.Array, w_base: jax.Array,
                     mode: str, dtype=jnp.float32,
                     w_scale: jax.Array = None) -> jax.Array:
    """Ŵ = v ⊙ unpack(B) + W_b  — dense reconstruction oracle."""
    return D.reconstruct(packed, v, _deq(w_base, w_scale), mode, dtype=dtype)


def bitlinear_ref(x: jax.Array, packed: jax.Array, v: jax.Array,
                  w_base: jax.Array, mode: str,
                  w_scale: jax.Array = None) -> jax.Array:
    """y = x @ (v ⊙ unpack(B) + W_b)ᵀ — fused delta-GEMM oracle.

    Computed the *dense* way (reconstruct then matmul) in fp32 so the oracle
    is unambiguous; the kernel accumulates in fp32 too.
    """
    w_hat = D.reconstruct(packed, v, _deq(w_base, w_scale), mode,
                          dtype=jnp.float32)
    return (x.astype(jnp.float32) @ w_hat.T).astype(x.dtype)


def bitlinear_axes_ref(x: jax.Array, packed: jax.Array, v_row: jax.Array,
                       v_col: jax.Array, w_base: jax.Array,
                       w_scale: jax.Array = None) -> jax.Array:
    """Dual-axis oracle: v[n,k] = v_row[n] + v_col[k] (overlay convention:
    the unselected vector is zero, so the sum IS the selected scale)."""
    d_out, d_in = w_base.shape
    signs = D.unpack_signs(packed, d_in, jnp.float32)
    v = (v_row.astype(jnp.float32)[:, None]
         + v_col.astype(jnp.float32)[None, :])
    w_hat = v * signs + _deq(w_base, w_scale)
    return (x.astype(jnp.float32) @ w_hat.T).astype(x.dtype)


def bitlinear_axes_banked_ref(x: jax.Array, variant_idx: jax.Array,
                              packed: jax.Array, v_row: jax.Array,
                              v_col: jax.Array, w_base: jax.Array,
                              w_scale: jax.Array = None) -> jax.Array:
    """Banked oracle: overlay operands carry a leading bank axis V; each row
    of x computes against the bank slot named by variant_idx (slot 0 = base:
    its vectors are zero, so Ŵ[0] = W_b exactly).

    x (M, K) · variant_idx (M,) int32 · packed (V, N, K/8) · v_row (V, N) ·
    v_col (V, K) · w_base (N, K) -> (M, N).
    """
    d_out, d_in = w_base.shape
    signs = D.unpack_signs(packed, d_in, jnp.float32)        # (V, N, K)
    v = (v_row.astype(jnp.float32)[:, :, None]
         + v_col.astype(jnp.float32)[:, None, :])
    w_hat = v * signs + _deq(w_base, w_scale)[None]          # (V, N, K)
    w_sel = jnp.take(w_hat, variant_idx, axis=0)             # (M, N, K)
    y = jnp.einsum("mnk,mk->mn", w_sel, x.astype(jnp.float32))
    return y.astype(x.dtype)
