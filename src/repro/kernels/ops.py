"""Jit'd public wrappers for the Pallas kernels.

Handles: per-axis-mode v reshaping, block-size selection (hardware-aligned
where the shape allows, divisor fallback otherwise), interpret-mode fallback
on CPU hosts (this container), and output dtype casting.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import bitlinear as _bl
from repro.kernels import unpack_apply as _ua

PACK = 8

# VMEM budget heuristics (v5e has ~128 MiB VMEM per core; stay well under).
_TILE_M = 256
_TILE_N = 512
_TILE_K = 512

# Banked (mixed-variant) kernel: the per-row Ŵ gather materialises a
# (bm, bn, bk) fp32 block in VMEM, so M stays decode-sized (batch slots)
# and N/K tiles shrink: 16·256·256·4 B ≈ 4 MiB.
_TILE_BANKED_M = 16
_TILE_BANKED_N = 256
_TILE_BANKED_K = 256


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pick_block(dim: int, target: int, multiple: int = 1) -> int:
    """Largest divisor of ``dim`` that is <= target and a multiple of
    ``multiple``; falls back to ``dim`` itself (always valid)."""
    best = dim
    for cand in range(min(dim, target), 0, -1):
        if dim % cand == 0 and cand % multiple == 0:
            best = cand
            break
    return best


def _v2d(v: jax.Array, mode: str, d_out: int, d_in: int) -> jax.Array:
    if mode == "row":
        assert v.shape == (d_out,), (v.shape, d_out)
        return v.reshape(d_out, 1)
    if mode == "col":
        assert v.shape == (d_in,), (v.shape, d_in)
        return v.reshape(1, d_in)
    if mode == "scalar":
        return v.reshape(1, 1)
    raise ValueError(mode)


@functools.partial(jax.jit, static_argnames=("mode", "out_dtype"))
def unpack_apply(packed: jax.Array, v: jax.Array, w_base: jax.Array,
                 mode: str = "row", out_dtype=None) -> jax.Array:
    """Production Ŵ = v ⊙ unpack(B) + W_b (loader hot path)."""
    d_out, d_in = w_base.shape
    out_dtype = out_dtype or w_base.dtype
    bm = _pick_block(d_out, _TILE_M)
    bn = _pick_block(d_in, _TILE_N, multiple=PACK)
    return _ua.unpack_apply_p(
        packed, _v2d(v, mode, d_out, d_in), w_base,
        block_m=bm, block_n=bn, out_dtype=out_dtype,
        interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("causal", "q_offset",
                                              "kv_offset"))
def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, q_offset: int = 0,
                        kv_offset: int = 0) -> jax.Array:
    """Pallas forward flash attention (serving/prefill hot path).

    q (B, S, Hq, hd); k/v (B, T, Hkv, hd) — GQA via head index mapping.
    Logits never leave VMEM (see kernels/flash_attn.py for the roofline
    argument).  Forward-only: training uses models/attention.py.
    """
    from repro.kernels.flash_attn import flash_attention_fwd_p
    b, s, hq, hd = q.shape
    _, t, hkv, _ = k.shape
    group = hq // hkv
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, s, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, t, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, t, hd)
    o = flash_attention_fwd_p(qf, kf, vf, group=group, causal=causal,
                              q_offset=q_offset, kv_offset=kv_offset,
                              interpret=_interpret())
    return o.reshape(b, hq, s, hd).transpose(0, 2, 1, 3)


@jax.jit
def bitlinear_axes(x: jax.Array, packed: jax.Array, v_row: jax.Array,
                   v_col: jax.Array, w_base: jax.Array) -> jax.Array:
    """Fused y = x @ ((v_row ⊕ v_col) ⊙ unpack(B) + W_b)ᵀ.

    Effective scale v[n,k] = v_row[n] + v_col[k]; the on-the-fly serving
    overlay zeroes the unselected axis vector per matrix, so this one
    entry point covers row-, col- and scalar-scaled deltas with no static
    mode argument (the axis choice stays data, scan-able over layers).
    x may carry leading batch dims; fp32 accumulate, cast back to x.dtype.
    """
    *lead, k_dim = x.shape
    n, _ = w_base.shape
    x2 = x.reshape(-1, k_dim)
    m = x2.shape[0]
    bm = _pick_block(m, _TILE_M)
    bn = _pick_block(n, _TILE_N)
    bk = _pick_block(k_dim, _TILE_K, multiple=PACK)
    y = _bl.bitlinear_axes_p(
        x2, packed, v_row.reshape(n, 1), v_col.reshape(1, k_dim), w_base,
        block_m=bm, block_n=bn, block_k=bk, interpret=_interpret())
    return y.astype(x.dtype).reshape(*lead, n)


@jax.jit
def bitlinear_axes_banked(x: jax.Array, variant_idx: jax.Array,
                          packed: jax.Array, v_row: jax.Array,
                          v_col: jax.Array, w_base: jax.Array) -> jax.Array:
    """Mixed-variant fused y: row m of x computes against bank slot
    ``variant_idx[m]`` of a stacked overlay (slot 0 = base, zero delta).

    packed (V, N, K/8) · v_row (V, N) · v_col (V, K) stack the per-variant
    overlay leaves along a leading bank axis; ``variant_idx`` is int32 with
    shape x.shape[:-1] or (x.shape[0],) (broadcast over the remaining lead
    dims — one variant per batch row).  The decode-time GEMV stays
    HBM-bound: the kernel gathers each row's packed tile + vectors in VMEM,
    so per-step traffic is base weights + bank bytes, independent of how
    many distinct variants share the batch (DESIGN.md §9).
    """
    *lead, k_dim = x.shape
    n, _ = w_base.shape
    nbank = packed.shape[0]
    x2 = x.reshape(-1, k_dim)
    m = x2.shape[0]
    if variant_idx.shape == tuple(lead):
        vidx = variant_idx.reshape(m)
    else:
        vidx = jnp.broadcast_to(
            variant_idx.reshape(variant_idx.shape[0],
                                *([1] * (len(lead) - 1))),
            tuple(lead)).reshape(m)
    bm = _pick_block(m, _TILE_BANKED_M)
    bn = _pick_block(n, _TILE_BANKED_N)
    bk = _pick_block(k_dim, _TILE_BANKED_K, multiple=PACK)
    y = _bl.bitlinear_axes_banked_p(
        x2, vidx.astype(jnp.int32).reshape(m, 1), packed,
        v_row.reshape(nbank, n, 1), v_col.reshape(nbank, 1, k_dim), w_base,
        block_m=bm, block_n=bn, block_k=bk, interpret=_interpret())
    return y.astype(x.dtype).reshape(*lead, n)


@functools.partial(jax.jit, static_argnames=("mode",))
def bitlinear(x: jax.Array, packed: jax.Array, v: jax.Array,
              w_base: jax.Array, mode: str = "row") -> jax.Array:
    """Fused y = x @ (v ⊙ unpack(B) + W_b)ᵀ, fp32 accumulate, cast to x.dtype.

    x may have leading batch dims; they are flattened into M.
    """
    *lead, k_dim = x.shape
    n, _ = w_base.shape
    x2 = x.reshape(-1, k_dim)
    m = x2.shape[0]
    bm = _pick_block(m, _TILE_M)
    bn = _pick_block(n, _TILE_N)
    bk = _pick_block(k_dim, _TILE_K, multiple=PACK)
    y = _bl.bitlinear_p(
        x2, packed, _v2d(v, mode, n, k_dim), w_base,
        block_m=bm, block_n=bn, block_k=bk, interpret=_interpret())
    return y.astype(x.dtype).reshape(*lead, n)
