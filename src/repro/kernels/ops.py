"""Jit'd public wrappers for the Pallas kernels.

Handles: per-axis-mode v reshaping, block-size selection (hardware-aligned
where the shape allows, divisor fallback otherwise), interpret-mode fallback
on CPU hosts (this container), and output dtype casting.

Partitioned execution (DESIGN.md §12): inside an active mesh context
(``distributed.sharding.shard_ctx`` — the sharded engine and the dry-run
trace there) the delta-GEMM wrappers route through
``kernels/dispatch.py``, which lowers them as shard_map'd per-shard
kernels with block sizes picked from SHARD-LOCAL dims; the caller passes
the shadowed weight's logical axes via ``waxes`` to drive the spec
derivation.  Without a mesh — or when the dispatcher declines (unknown
axes, packing-width misalignment) — the original global jit path runs
unchanged, so single-device tier-1 behaviour is identical.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import bitlinear as _bl
from repro.kernels import dispatch as _dp
from repro.kernels import unpack_apply as _ua

PACK = 8

# VMEM budget heuristics (v5e has ~128 MiB VMEM per core; stay well under).
_TILE_M = 256
_TILE_N = 512
_TILE_K = 512

# Banked (mixed-variant) kernel: the per-row Ŵ gather materialises a
# (bm, bn, bk) fp32 block in VMEM, so M stays decode-sized (batch slots)
# and N/K tiles shrink: 16·256·256·4 B ≈ 4 MiB.
_TILE_BANKED_M = 16
_TILE_BANKED_N = 256
_TILE_BANKED_K = 256


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _unwrap_quant(w):
    """Split a base-weight operand into (payload, scale-or-None).

    An int8-quantized base arrives as a ``core/quantize.QuantWeight``
    pytree (duck-typed on the ``__quant_leaf__`` marker — works on
    tracers); a full-precision base passes through with no scale.  Every
    kernel wrapper routes its weight operand here, so both base dtypes
    share one code path end to end."""
    if getattr(w, "__quant_leaf__", False):
        return w.q, w.scale
    return w, None


def _pick_block(dim: int, target: int, multiple: int = 1) -> int:
    """Largest divisor of ``dim`` that is <= target and a multiple of
    ``multiple``.

    When ``dim % multiple == 0`` a valid block always exists (``multiple``
    itself divides).  Otherwise NO block satisfies the kernels' divisibility
    asserts — the old fallback returned ``dim`` itself, which is only valid
    for global shapes and silently mis-sized blocks for shard-local dims
    that are not packing-width multiples (e.g. the packed byte dim after an
    8-way model split) — so refuse loudly; the dispatch planner checks
    alignment up front and keeps such matmuls on the global path."""
    if dim % multiple:
        raise ValueError(
            f"no valid block for dim={dim}: not a multiple of {multiple} "
            "(shard-local kernel dims must stay aligned to the packing "
            "width; kernels/dispatch.py falls back to the global path "
            "for such splits)")
    for cand in range(min(dim, target), 0, -1):
        if dim % cand == 0 and cand % multiple == 0:
            return cand
    # only reachable when multiple > target: no divisor <= target can be a
    # multiple, so take the smallest VALID block (divides dim, aligned)
    # rather than an oversized dim-sized one
    return multiple


def flatten_vidx(variant_idx: jax.Array, lead: tuple) -> jax.Array:
    """Per-row variant indices -> flattened batch rows (m,) int32.

    ``variant_idx`` has shape ``lead`` (one slot per row) or ``(lead[0],)``
    (broadcast over the remaining lead dims).  The ONE definition of the
    banked vidx convention — both the global jit path and the shard_map
    dispatch (kernels/dispatch.py) flatten through here, so the two
    lowerings can never drift apart."""
    import math
    m = math.prod(lead)
    if variant_idx.shape == tuple(lead):
        return variant_idx.astype(jnp.int32).reshape(m)
    return jnp.broadcast_to(
        variant_idx.reshape(variant_idx.shape[0], *([1] * (len(lead) - 1))),
        tuple(lead)).astype(jnp.int32).reshape(m)


def _v2d(v: jax.Array, mode: str, d_out: int, d_in: int) -> jax.Array:
    if mode == "row":
        assert v.shape == (d_out,), (v.shape, d_out)
        return v.reshape(d_out, 1)
    if mode == "col":
        assert v.shape == (d_in,), (v.shape, d_in)
        return v.reshape(1, d_in)
    if mode == "scalar":
        return v.reshape(1, 1)
    raise ValueError(mode)


@functools.partial(jax.jit, static_argnames=("mode", "out_dtype"))
def _unpack_apply_global(packed: jax.Array, v: jax.Array, w_base,
                         mode: str, out_dtype) -> jax.Array:
    wq, ws = _unwrap_quant(w_base)
    d_out, d_in = wq.shape
    bm = _pick_block(d_out, _TILE_M)
    bn = _pick_block(d_in, _TILE_N, multiple=PACK)
    return _ua.unpack_apply_p(
        packed, _v2d(v, mode, d_out, d_in), wq,
        block_m=bm, block_n=bn, out_dtype=out_dtype,
        interpret=_interpret(),
        w_scale=None if ws is None else ws.reshape(d_out, 1))


def unpack_apply(packed: jax.Array, v: jax.Array, w_base,
                 mode: str = "row", out_dtype=None,
                 waxes=None) -> jax.Array:
    """Production Ŵ = v ⊙ unpack(B) + W_b (loader hot path).

    ``w_base`` may be a QuantWeight (int8 base): the kernel then
    dequantizes per tile and the default out dtype follows the scale.
    ``waxes`` (the weight's logical axes) + an active mesh context lower
    this as a shard_map'd per-tile reconstruction — each device rebuilds
    only its own Ŵ shard; otherwise the global jit path runs."""
    _, ws = _unwrap_quant(w_base)
    out_dtype = out_dtype or (ws.dtype if ws is not None else w_base.dtype)
    st = _dp.state()
    if st is not None and waxes is not None:
        y = _dp.unpack_apply(st, packed, v, w_base, mode, out_dtype, waxes)
        if y is not None:
            return y
    return _unpack_apply_global(packed, v, w_base, mode=mode,
                                out_dtype=out_dtype)


@functools.partial(jax.jit, static_argnames=("causal", "q_offset",
                                              "kv_offset"))
def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, q_offset: int = 0,
                        kv_offset: int = 0) -> jax.Array:
    """Pallas forward flash attention (serving/prefill hot path).

    q (B, S, Hq, hd); k/v (B, T, Hkv, hd) — GQA via head index mapping.
    Logits never leave VMEM (see kernels/flash_attn.py for the roofline
    argument).  Forward-only: training uses models/attention.py.
    """
    from repro.kernels.flash_attn import flash_attention_fwd_p
    b, s, hq, hd = q.shape
    _, t, hkv, _ = k.shape
    group = hq // hkv
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, s, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, t, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, t, hd)
    o = flash_attention_fwd_p(qf, kf, vf, group=group, causal=causal,
                              q_offset=q_offset, kv_offset=kv_offset,
                              interpret=_interpret())
    return o.reshape(b, hq, s, hd).transpose(0, 2, 1, 3)


@jax.jit
def _bitlinear_axes_global(x: jax.Array, packed: jax.Array, v_row: jax.Array,
                           v_col: jax.Array, w_base) -> jax.Array:
    wq, ws = _unwrap_quant(w_base)
    *lead, k_dim = x.shape
    n, _ = wq.shape
    x2 = x.reshape(-1, k_dim)
    m = x2.shape[0]
    bm = _pick_block(m, _TILE_M)
    bn = _pick_block(n, _TILE_N)
    bk = _pick_block(k_dim, _TILE_K, multiple=PACK)
    y = _bl.bitlinear_axes_p(
        x2, packed, v_row.reshape(n, 1), v_col.reshape(1, k_dim), wq,
        block_m=bm, block_n=bn, block_k=bk, interpret=_interpret(),
        w_scale=None if ws is None else ws.reshape(n, 1))
    return y.astype(x.dtype).reshape(*lead, n)


def bitlinear_axes(x: jax.Array, packed: jax.Array, v_row: jax.Array,
                   v_col: jax.Array, w_base: jax.Array,
                   waxes=None) -> jax.Array:
    """Fused y = x @ ((v_row ⊕ v_col) ⊙ unpack(B) + W_b)ᵀ.

    Effective scale v[n,k] = v_row[n] + v_col[k]; the on-the-fly serving
    overlay zeroes the unselected axis vector per matrix, so this one
    entry point covers row-, col- and scalar-scaled deltas with no static
    mode argument (the axis choice stays data, scan-able over layers).
    x may carry leading batch dims; fp32 accumulate, cast back to x.dtype.

    ``waxes`` (the shadowed weight's logical axes, threaded by
    models/layers.linear) + an active mesh context lower this per-shard
    under shard_map (kernels/dispatch.py); otherwise the global jit.
    """
    st = _dp.state()
    if st is not None and waxes is not None:
        y = _dp.bitlinear_axes(st, x, packed, v_row, v_col, w_base, waxes)
        if y is not None:
            return y
    return _bitlinear_axes_global(x, packed, v_row, v_col, w_base)


@jax.jit
def _bitlinear_axes_banked_global(x: jax.Array, variant_idx: jax.Array,
                                  packed: jax.Array, v_row: jax.Array,
                                  v_col: jax.Array, w_base) -> jax.Array:
    wq, ws = _unwrap_quant(w_base)
    *lead, k_dim = x.shape
    n, _ = wq.shape
    nbank = packed.shape[0]
    x2 = x.reshape(-1, k_dim)
    m = x2.shape[0]
    vidx = flatten_vidx(variant_idx, tuple(lead))
    bm = _pick_block(m, _TILE_BANKED_M)
    bn = _pick_block(n, _TILE_BANKED_N)
    bk = _pick_block(k_dim, _TILE_BANKED_K, multiple=PACK)
    y = _bl.bitlinear_axes_banked_p(
        x2, vidx.astype(jnp.int32).reshape(m, 1), packed,
        v_row.reshape(nbank, n, 1), v_col.reshape(nbank, 1, k_dim), wq,
        block_m=bm, block_n=bn, block_k=bk, interpret=_interpret(),
        w_scale=None if ws is None else ws.reshape(n, 1))
    return y.astype(x.dtype).reshape(*lead, n)


def bitlinear_axes_banked(x: jax.Array, variant_idx: jax.Array,
                          packed: jax.Array, v_row: jax.Array,
                          v_col: jax.Array, w_base: jax.Array,
                          waxes=None) -> jax.Array:
    """Mixed-variant fused y: row m of x computes against bank slot
    ``variant_idx[m]`` of a stacked overlay (slot 0 = base, zero delta).

    packed (V, N, K/8) · v_row (V, N) · v_col (V, K) stack the per-variant
    overlay leaves along a leading bank axis; ``variant_idx`` is int32 with
    shape x.shape[:-1] or (x.shape[0],) (broadcast over the remaining lead
    dims — one variant per batch row).  The decode-time GEMV stays
    HBM-bound: the kernel gathers each row's packed tile + vectors in VMEM,
    so per-step traffic is base weights + bank bytes, independent of how
    many distinct variants share the batch (DESIGN.md §9).

    ``waxes`` + an active mesh context lower this per-shard (each device
    gathers slots from its own weight tile's bank — kernels/dispatch.py);
    otherwise the global jit path runs.
    """
    st = _dp.state()
    if st is not None and waxes is not None:
        y = _dp.bitlinear_axes_banked(st, x, variant_idx, packed, v_row,
                                      v_col, w_base, waxes)
        if y is not None:
            return y
    return _bitlinear_axes_banked_global(x, variant_idx, packed, v_row,
                                         v_col, w_base)


@functools.partial(jax.jit, static_argnames=("mode",))
def bitlinear(x: jax.Array, packed: jax.Array, v: jax.Array,
              w_base, mode: str = "row") -> jax.Array:
    """Fused y = x @ (v ⊙ unpack(B) + W_b)ᵀ, fp32 accumulate, cast to x.dtype.

    x may have leading batch dims; they are flattened into M.  ``w_base``
    may be a QuantWeight (int8 base, dequantized per tile).
    """
    wq, ws = _unwrap_quant(w_base)
    *lead, k_dim = x.shape
    n, _ = wq.shape
    x2 = x.reshape(-1, k_dim)
    m = x2.shape[0]
    bm = _pick_block(m, _TILE_M)
    bn = _pick_block(n, _TILE_N)
    bk = _pick_block(k_dim, _TILE_K, multiple=PACK)
    y = _bl.bitlinear_p(
        x2, packed, _v2d(v, mode, n, k_dim), wq,
        block_m=bm, block_n=bn, block_k=bk, interpret=_interpret(),
        w_scale=None if ws is None else ws.reshape(n, 1))
    return y.astype(x.dtype).reshape(*lead, n)
