"""1-bit per-axis gradient compression with error feedback (beyond-paper).

The paper's representation — sign mask + per-axis scale — applied to
*gradients* for cross-pod data parallelism: within a pod, gradients reduce
in full precision over fast ICI; across pods (slow DCN), each pod
exchanges sign(g)+per-row scale: 16× less DCN traffic per step.  Error
feedback (residual carried to the next step) keeps SGD convergence —
standard 1-bit Adam / EF-signSGD theory.

Two entry points:
* ``make_ef_transform`` — a ``grad_transform`` hook for train.step that
  quantises+dequantises gradients with persistent error feedback
  (simulates the cross-pod wire format end-to-end; used by tests to show
  convergence is preserved).
* ``compressed_psum`` — the actual wire exchange as a shard_map collective
  over a mesh axis: pack → all_gather(packed + scales) → decompress →
  mean.  Wire bytes ≈ bits/16 of the fp32 exchange.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import delta as D


def _compressible(g: jax.Array) -> bool:
    return g.ndim >= 2 and g.shape[-1] % 8 == 0


def quantize(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """g -> (packed sign bits, per-row fp16 scale).  Per-axis scale over
    the last dim (row mode on (..., rows, cols))."""
    gf = g.astype(jnp.float32)
    packed = D.pack_signs(D.sign_mask(gf))
    scale = jnp.mean(jnp.abs(gf), axis=-1).astype(jnp.float16)
    return packed, scale


def dequantize(packed: jax.Array, scale: jax.Array, d_last: int
               ) -> jax.Array:
    signs = D.unpack_signs(packed, d_last, jnp.float32)
    return scale.astype(jnp.float32)[..., None] * signs


def wire_bytes(g: jax.Array) -> tuple[int, int]:
    """(compressed, fp32) bytes for one tensor's cross-pod exchange."""
    if not _compressible(g):
        return 4 * g.size, 4 * g.size
    comp = g.size // 8 + 2 * int(g.size // g.shape[-1])
    return comp, 4 * g.size


def make_ef_transform():
    """Returns (transform(grads, ef_state) -> (grads, ef_state), init_fn).

    transform quantises each compressible leaf of (g + e), dequantises,
    and carries the residual e' = (g + e) − deq — exactly what each pod
    would send/receive across DCN.
    """
    def init(grads_template):
        return jax.tree.map(
            lambda g: jnp.zeros_like(g, dtype=jnp.float32)
            if _compressible(g) else None, grads_template)

    def transform(grads, ef):
        def one(g, e):
            if not _compressible(g):
                return g, None
            tot = g.astype(jnp.float32) + (e if e is not None else 0.0)
            packed, scale = quantize(tot)
            deq = dequantize(packed, scale, g.shape[-1])
            return deq.astype(g.dtype), tot - deq
        flat_g, treedef = jax.tree.flatten(grads)
        flat_e = treedef.flatten_up_to(ef)
        out = [one(g, e) for g, e in zip(flat_g, flat_e)]
        new_g = treedef.unflatten([o[0] for o in out])
        new_e = treedef.unflatten([o[1] for o in out])
        return new_g, new_e

    return transform, init


def compressed_psum(g: jax.Array, axis_name: str) -> jax.Array:
    """Mean of ``g`` across ``axis_name`` exchanging only (packed signs,
    fp16 scales).  Call inside shard_map; g is this shard's local value.
    """
    if not _compressible(g):
        return jax.lax.pmean(g, axis_name)
    packed, scale = quantize(g)
    all_packed = jax.lax.all_gather(packed, axis_name)    # (P, ..., cols/8)
    all_scale = jax.lax.all_gather(scale, axis_name)
    deq = dequantize(all_packed, all_scale, g.shape[-1])  # (P, ..., cols)
    return jnp.mean(deq, axis=0).astype(g.dtype)


def cross_pod_grad_mean(grads, mesh, axis_name: str = "pod"):
    """Apply compressed_psum leaf-wise over the pod axis (grads replicated
    within pod, differing across pods)."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    def fn(*leaves):
        return tuple(compressed_psum(l, axis_name) for l in leaves)

    flat, treedef = jax.tree.flatten(grads)
    specs = tuple(P() for _ in flat)  # replicated per pod-shard
    out = shard_map(fn, mesh=mesh, in_specs=specs, out_specs=specs,
                    check_rep=False)(*flat)
    return jax.tree.unflatten(treedef, list(out))
