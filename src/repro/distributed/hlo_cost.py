"""Static cost analyzer over optimized HLO text — with while-loop trip counts.

``compiled.cost_analysis()`` counts a while-loop body ONCE, so any scan-based
model (all of ours: layers, flash-attention chunks, SSD chunks) is
undercounted by the trip count.  This walker parses the HLO module text,
recurses through fusions / calls / while bodies / conditionals, and
multiplies by ``backend_config["known_trip_count"]`` (fallback: the loop
bound constant in the condition computation).

Returned totals (per device, since the module is the SPMD-partitioned
per-device program):
  flops            dot FLOPs (2·M·N·K), the MXU work
  bytes            fusion-idealized HBM traffic: the CPU backend wraps each
                   elementwise op in its own trivial fusion, so op-level IO
                   counting would overcount ~10× vs a real TPU compile.  We
                   model TPU fusion instead: traffic is charged only at
                   materialization boundaries (dot / reduce / concatenate /
                   sort / scatter / collectives), elementwise+broadcast
                   chains and CPU-inserted copy/transpose are free, gathers
                   charge result+indices (not the table), dynamic-(update-)
                   slice charges the slice (in-place donation).  Stated in
                   EXPERIMENTS.md §Roofline.
  collectives      per-op counts / result bytes / ring wire bytes,
                   trip-multiplied
Also exposes per-op-name flop aggregation for §Perf bottleneck hunting.
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

_ZERO_BYTE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                  "bitcast", "after-all", "iota"}

# materialization boundaries under the TPU-fusion model (operands + result
# charged); everything else is assumed fused → free.  copy/transpose are
# excluded: the CPU backend inserts them for layout/loop-carry reasons that
# TPU layout assignment avoids (verified via byte attribution on the
# whisper train cell: >600 GB of CPU-only copy/transpose traffic).
# static slice/pad also fuse into consumers on TPU (the causal-conv shift
# chain showed 7 TB of fused-on-TPU slice traffic on the zamba train cell);
# dynamic-(update-)slice are special-cased in cost().
_MATERIALIZE = {"dot", "convolution", "reduce",
                "sort", "scatter",
                "concatenate", "reduce-window", "select-and-scatter",
                "reverse", "cholesky", "triangular-solve",
                "rng-bit-generator"}


def _shape_info(seg: str):
    """All (dtype, dims) in a type segment; returns (bytes, first_dims)."""
    total = 0
    first_dims = None
    for dtype, dims in _SHAPE_RE.findall(seg):
        if dtype not in _DTYPE_BYTES:
            continue
        d = [int(x) for x in dims.split(",")] if dims else []
        n = 1
        for x in d:
            n *= x
        total += n * _DTYPE_BYTES[dtype]
        if first_dims is None:
            first_dims = d
    return total, (first_dims if first_dims is not None else [])


def _balanced_operands(line: str, op_start: int) -> tuple[str, str]:
    """Split '(operands)' at op_start into (operands_str, attrs_str)."""
    depth = 0
    for i in range(op_start, len(line)):
        ch = line[i]
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return line[op_start + 1:i], line[i + 1:]
    return line[op_start + 1:], ""


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    result_bytes: int
    result_dims: list
    operands: list
    attrs: str
    line: str


def parse_computations(text: str) -> dict:
    comps: dict[str, list[Instr]] = {}
    current: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if current is None:
            m = _COMP_HDR_RE.match(stripped)
            if m and stripped.endswith("{"):
                current = m.group(1)
                comps[current] = []
            continue
        if stripped == "}":
            current = None
            continue
        m = _INSTR_RE.match(stripped)
        if not m:
            continue
        name, type_seg, opcode = m.groups()
        rb, rdims = _shape_info(type_seg)
        op_paren = stripped.find(opcode + "(") + len(opcode)
        operands_str, attrs = _balanced_operands(stripped, op_paren)
        operands = re.findall(r"%([\w.\-]+)", operands_str)
        comps[current].append(Instr(name, opcode, rb, rdims, operands,
                                    attrs, stripped))
    return comps


def _group_size(attrs: str) -> int:
    m = _GROUPS_IOTA_RE.search(attrs)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(attrs)
    if m:
        return len(m.group(1).split(","))
    return 2


def _wire_factor(op: str, n: int) -> float:
    if op == "all-reduce":
        return 2 * (n - 1) / n
    if op == "all-gather":
        return (n - 1) / n
    if op == "reduce-scatter":
        return float(n - 1)
    if op == "all-to-all":
        return (n - 1) / n
    return 1.0


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    coll_counts: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    coll_bytes: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    coll_wire: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    flops_by_op: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    def add(self, other: "Totals", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.transcendentals += other.transcendentals * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] += v * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] += v * mult
        for k, v in other.coll_wire.items():
            self.coll_wire[k] += v * mult
        for k, v in other.flops_by_op.items():
            self.flops_by_op[k] += v * mult


class HloCostModel:
    def __init__(self, text: str):
        self.comps = parse_computations(text)
        self.shapes = {c: {i.name: (i.result_bytes, i.result_dims)
                           for i in instrs}
                       for c, instrs in self.comps.items()}
        self._memo: dict[str, Totals] = {}
        # entry = computation whose header line had ENTRY; approximate:
        # the one not referenced by any calls/body/condition
        called = set()
        for instrs in self.comps.values():
            for i in instrs:
                for rx in (_CALLS_RE, _BODY_RE, _COND_RE):
                    for mm in rx.findall(i.attrs):
                        called.add(mm)
                m = _BRANCHES_RE.search(i.attrs)
                if m:
                    called.update(re.findall(r"%([\w.\-]+)", m.group(1)))
        entries = [c for c in self.comps if c not in called]
        self.entry = entries[-1] if entries else next(iter(self.comps))

    # ---- per-instruction -------------------------------------------------
    def _promoted_bf16(self, comp: str, i: Instr) -> bool:
        """True when an f32 all-reduce's operands are convert-from-bf16
        (CPU AllReducePromotion artifact; bf16 on TPU)."""
        # result type segment sits between " = " and the opcode call; the
        # instruction NAME also contains the opcode string, so split on
        # " = " first
        seg = i.line.split(" = ", 1)[-1].lstrip()
        if not (seg.startswith("f32[") or seg.startswith("(f32[")):
            return False
        instr_map = {x.name: x for x in self.comps.get(comp, [])}
        for o in i.operands:
            src = instr_map.get(o)
            if src is None:
                return False
            if src.opcode == "convert" or (src.opcode == "fusion"
                                           and "convert" in src.name):
                continue
            return False
        return bool(i.operands)

    def _dot_flops(self, comp: str, i: Instr) -> float:
        out_elems = 1
        for d in i.result_dims:
            out_elems *= d
        contract = 1
        m = _LHS_CDIMS_RE.search(i.attrs)
        if m and i.operands:
            lhs = self.shapes[comp].get(i.operands[0])
            if lhs:
                dims = lhs[1]
                for idx in (int(x) for x in m.group(1).split(",") if x):
                    if idx < len(dims):
                        contract *= dims[idx]
        return 2.0 * out_elems * contract

    def _operand_bytes(self, comp: str, i: Instr) -> int:
        total = 0
        for o in i.operands:
            s = self.shapes[comp].get(o)
            if s:
                total += s[0]
        return total

    def _trip_count(self, i: Instr) -> int:
        m = _TRIP_RE.search(i.attrs)
        if m:
            return int(m.group(1))
        cond = _COND_RE.search(i.attrs)
        if cond and cond.group(1) in self.comps:
            consts = [int(x) for instr in self.comps[cond.group(1)]
                      for x in re.findall(r"constant\((\d+)\)", instr.line)]
            if consts:
                return max(consts)
        return 1

    # ---- computation cost --------------------------------------------------
    def cost(self, comp: Optional[str] = None) -> Totals:
        comp = comp or self.entry
        if comp in self._memo:
            return self._memo[comp]
        t = Totals()
        self._memo[comp] = t  # guard cycles
        for i in self.comps.get(comp, []):
            opc = i.opcode
            if opc == "while":
                trips = self._trip_count(i)
                body = _BODY_RE.search(i.attrs)
                cond = _COND_RE.search(i.attrs)
                if body and body.group(1) in self.comps:
                    t.add(self.cost(body.group(1)), trips)
                if cond and cond.group(1) in self.comps:
                    t.add(self.cost(cond.group(1)), trips)
                continue
            if opc == "conditional":
                m = _BRANCHES_RE.search(i.attrs)
                if m:
                    branches = re.findall(r"%([\w.\-]+)", m.group(1))
                    costs = [self.cost(b) for b in branches
                             if b in self.comps]
                    if costs:
                        t.add(max(costs, key=lambda c: c.flops))
                continue
            if opc in ("fusion", "call", "async-start"):
                # recurse: inner materializing ops are charged there; the
                # fusion node itself is free (TPU-fusion model)
                m = _CALLS_RE.search(i.attrs)
                if m and m.group(1) in self.comps:
                    t.add(self.cost(m.group(1)))
                continue
            if opc in _COLLECTIVES or opc.rstrip("-start") in _COLLECTIVES:
                base = opc[:-6] if opc.endswith("-start") else opc
                if base in _COLLECTIVES:
                    n = _group_size(i.attrs)
                    nbytes = i.result_bytes
                    # CPU-XLA promotes bf16 all-reduces to f32
                    # (AllReducePromotion pass — TPU reduces bf16
                    # natively): when the operand is a convert-from-bf16
                    # fusion, charge the bf16 wire bytes
                    if base == "all-reduce" and self._promoted_bf16(comp, i):
                        nbytes //= 2
                    t.coll_counts[base] += 1
                    t.coll_bytes[base] += nbytes
                    t.coll_wire[base] += nbytes * _wire_factor(base, n)
                    t.bytes += nbytes + self._operand_bytes(comp, i) // (
                        2 if nbytes < i.result_bytes else 1)
                continue
            if opc.endswith("-done"):
                continue
            if opc == "dot":
                f = self._dot_flops(comp, i)
                t.flops += f
                key = "dot"
                mm = re.search(r'op_name="([^"]*)"', i.attrs)
                if mm:
                    key = mm.group(1).split("/")[-1][:64]
                t.flops_by_op[key] += f
                t.bytes += i.result_bytes + self._operand_bytes(comp, i)
                continue
            if opc in ("exponential", "tanh", "log", "rsqrt", "power"):
                n = 1
                for d in i.result_dims:
                    n *= d
                t.transcendentals += n
            if opc == "gather":
                # TPU gather reads selected rows, not the whole table
                idx_bytes = 0
                if len(i.operands) > 1:
                    s = self.shapes[comp].get(i.operands[1])
                    idx_bytes = s[0] if s else 0
                t.bytes += 2 * i.result_bytes + idx_bytes
                continue
            if opc == "dynamic-update-slice":
                # in-place donation: traffic ≈ the update slice
                upd_bytes = 0
                if len(i.operands) > 1:
                    s = self.shapes[comp].get(i.operands[1])
                    upd_bytes = s[0] if s else 0
                t.bytes += 2 * upd_bytes
                continue
            if opc == "dynamic-slice":
                # fuses into its consumer on TPU; the consumer (dot etc.)
                # charges the operand read — charging here double-counts
                continue
            if opc in _MATERIALIZE:
                t.bytes += i.result_bytes + self._operand_bytes(comp, i)
        self._memo[comp] = t
        return t


def analyze(text: str) -> dict:
    model = HloCostModel(text)
    t = model.cost()
    top = sorted(t.flops_by_op.items(), key=lambda kv: -kv[1])[:12]
    return {
        "flops": t.flops,
        "bytes": t.bytes,
        "transcendentals": t.transcendentals,
        "collectives": {
            "counts": dict(t.coll_counts),
            "result_bytes": dict(t.coll_bytes),
            "wire_bytes": dict(t.coll_wire),
            "total_wire_bytes": sum(t.coll_wire.values()),
        },
        "top_flop_ops": top,
    }
