"""Post-compile HLO analysis: collective traffic + roofline terms.

``cost_analysis`` gives HLO FLOPs and bytes, but not collective traffic —
we parse the optimized (SPMD-partitioned, per-device) HLO text and sum the
operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, applying ring-algorithm wire factors:

  all-reduce      2·(n−1)/n · bytes
  all-gather        (n−1)/n · result bytes
  reduce-scatter    (n−1)   · result bytes   (input = n·result)
  all-to-all        (n−1)/n · bytes
  collective-permute        bytes

Hardware model (TPU v5e, from the assignment): 197 TFLOP/s bf16 per chip,
819 GB/s HBM, ~50 GB/s/link ICI.  The collective term conservatively
charges all traffic to ONE link (a 2D-torus chip has more); the roofline
table notes this.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(segment: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(segment):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2  # unknown grouping: assume minimal


def _wire_factor(op: str, n: int) -> float:
    if op == "all-reduce":
        return 2 * (n - 1) / n
    if op == "all-gather":
        return (n - 1) / n
    if op == "reduce-scatter":
        return float(n - 1)
    if op == "all-to-all":
        return (n - 1) / n
    return 1.0  # collective-permute


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    result_bytes: dict
    wire_bytes: dict

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())

    def to_dict(self) -> dict:
        return {"counts": self.counts, "result_bytes": self.result_bytes,
                "wire_bytes": self.wire_bytes,
                "total_wire_bytes": self.total_wire_bytes}


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts = {c: 0 for c in _COLLECTIVES}
    res_bytes = {c: 0 for c in _COLLECTIVES}
    wire = {c: 0.0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        lhs, _, rhs = ls.partition("=")
        rhs = rhs.strip()
        op = None
        for c in _COLLECTIVES:
            # match "bf16[...] all-reduce(" or "(f32[..]) all-reduce-start("
            if re.search(rf"\b{c}(-start)?\(", rhs):
                op = c
                break
        if op is None:
            continue
        if f"{op}-done" in rhs:
            continue  # avoid double counting async pairs
        # result shape(s) = text before the op name
        seg = rhs.split(op)[0]
        nbytes = _shape_bytes(seg)
        n = _group_size(rhs)
        counts[op] += 1
        res_bytes[op] += nbytes
        wire[op] += nbytes * _wire_factor(op, n)
    return CollectiveStats(counts=counts, result_bytes=res_bytes,
                           wire_bytes=wire)


# ---------------------------------------------------------------------------
# roofline
# ---------------------------------------------------------------------------

def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   wire_bytes_per_device: float,
                   model_flops_per_device: float = 0.0) -> dict:
    """Three roofline terms in seconds (per assignment formulae) plus:

    * ``useful_ratio``  = MODEL_FLOPS / HLO_FLOPs  (remat / redundancy waste)
    * ``mfu_bound``     = model-flops-time / max(term): the best MFU this
      compiled program could reach if the dominant term ran at peak — the
      static-analysis stand-in for measured MFU (CPU-only container).
    """
    compute_s = flops_per_device / PEAK_FLOPS
    memory_s = bytes_per_device / HBM_BW
    collective_s = wire_bytes_per_device / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    model_s = model_flops_per_device / PEAK_FLOPS
    return {**terms, "dominant": dominant, "roofline_s": bound,
            "model_flops_s": model_s,
            "useful_ratio": (model_flops_per_device / flops_per_device
                             if flops_per_device else 0.0),
            "mfu_bound": (model_s / bound) if bound else 0.0}


def cost_summary(compiled, hlo_text: Optional[str] = None) -> dict:
    """Extract flops/bytes from compiled.cost_analysis() + collectives."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    ca = dict(ca or {})
    flops = float(ca.get("flops", 0.0))
    bytes_accessed = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = parse_collectives(text)
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                mem[k] = int(v)
    except Exception as e:  # CPU backend may not implement it
        mem["error"] = str(e)
    return {"flops": flops, "bytes_accessed": bytes_accessed,
            "collectives": coll.to_dict(), "memory": mem,
            "transcendentals": float(ca.get("transcendentals", 0.0))}
