"""Logical-axis sharding: rules, divisibility fallback, constraint helper.

Models annotate parameters and activations with *logical* axis names
(models/param.py docstring lists the vocabulary).  This module maps them to
mesh axes:

* every logical axis has an ordered candidate list of mesh axes (or axis
  tuples); the first candidate whose size divides the dimension and whose
  mesh axes are still unused in this spec wins — this is the divisibility
  fallback that lets e.g. starcoder2's 2 KV heads fall through to a
  head_dim shard instead of failing to lower;
* rule sets differ per workload (train / prefill / decode / long-context
  decode) — long_500k swaps batch-sharding for sequence-sharding of the KV
  cache (DESIGN.md §5);
* ``activation_rules`` are applied inside model code through
  :func:`logical_constraint`, which is a no-op outside an active mesh
  context, so smoke tests on CPU run the same code paths unsharded.
"""
from __future__ import annotations

import contextlib
import math
import threading
from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Candidate = Union[str, tuple]

# ---------------------------------------------------------------------------
# rule sets
# ---------------------------------------------------------------------------

# Parameters: TP on the natural axis + FSDP over data on the other axis.
PARAM_RULES = {
    "vocab": ["model"],
    "embed": ["data"],
    "ffn": ["model"],
    "ffn_small": [],          # replicated over model (tiny shared experts)
    "q_heads": ["model"],
    "kv_heads": ["model"],
    "experts": ["model"],
    "ssm": ["model"],
    "conv": [],
    "layers": [],
    # overlay-bank slot axis (models/delta_overlay.py): replicated — every
    # device holds all bank slots of its own weight shard, so per-row slot
    # gathers in the banked delta GEMM stay device-local and bank admission
    # needs no collectives (DESIGN.md §11).  Pod-local banks
    # (rules_for(..., pod_banks=True)) shard this axis over "pod" instead:
    # each pod holds only its own slot range and admission scatters touch
    # one pod's devices (DESIGN.md §17)
    "bank": [],
}

# Pod-local overlay banks (DESIGN.md §17): the bank axis shards over the
# pod axis — slot p*S..(p+1)*S-1 lives only on pod p's devices, so an
# admission scatter writes one pod's shard and crosses no pod boundary.
# resolve_spec's divisibility fallback makes this degrade to replicated
# on meshes without a "pod" axis (single-pod serving, tier-1 CPU runs).
BANK_RULE_POD = ["pod"]

# Pure tensor-parallel params (serving: no FSDP; weights replicated over
# data so decode GEMVs need no weight all-gathers).
PARAM_RULES_SERVE = {**PARAM_RULES, "embed": []}


def _act_rules(seq_sharded: bool) -> dict:
    return {
        "act_batch": [] if seq_sharded else [("pod", "data"), "data"],
        "act_seq": [("pod", "data"), "data"] if seq_sharded else [],
        "act_seq_tp": ["model"],    # context-parallel attention (heads < TP)
        "act_embed": [],
        "act_heads": ["model"],
        "act_kv": ["model"],
        "act_hd": ["model"],        # fallback target when head counts don't divide
        "act_ffn": ["model"],
        "act_vocab": ["model"],
        "act_experts": ["model"],
        "act_groups": [("pod", "data"), "data"],
        "act_ssm": ["model"],
    }

ACT_RULES_TRAIN = _act_rules(seq_sharded=False)
ACT_RULES_DECODE = _act_rules(seq_sharded=False)
ACT_RULES_LONG = _act_rules(seq_sharded=True)


def rules_for(kind: str, long_context: bool = False,
              pod_banks: bool = False) -> dict:
    """(param_rules, act_rules) merged dict for a workload kind.

    "_forward_only" marks gradient-free workloads: sequence-TP attention
    is safe there (its backward pathology — per-chunk KV re-gathers — can't
    occur), and it beats flat-q sharding for indivisible head counts.

    ``pod_banks`` (serving kinds only) swaps the overlay-bank slot rule
    from replicated to pod-sharded (DESIGN.md §17): every consumer of the
    rule set — bank allocation, engine in_shardings, the shard_map kernel
    dispatch — then agrees that slot s lives on pod s // slots_per_pod."""
    if kind == "train":
        return {**PARAM_RULES, **ACT_RULES_TRAIN}
    if kind in ("prefill", "decode"):
        act = ACT_RULES_LONG if long_context else ACT_RULES_DECODE
        rules = {**PARAM_RULES_SERVE, **act, "_forward_only": True}
        if pod_banks:
            rules["bank"] = BANK_RULE_POD
        return rules
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# spec resolution with divisibility fallback
# ---------------------------------------------------------------------------

def _axis_size(mesh: Mesh, name: str) -> Optional[int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name)


def resolve_spec(shape: Sequence[int], axes: Sequence[Optional[str]],
                 rules: dict, mesh: Mesh) -> P:
    """Map logical axes of one array to a PartitionSpec."""
    parts, used = [], set()
    for dim, ax in zip(shape, axes):
        if ax is None:
            parts.append(None)
            continue
        cands: Sequence[Candidate] = rules.get(ax, [])
        chosen = None
        for cand in cands:
            names = cand if isinstance(cand, tuple) else (cand,)
            sizes = [_axis_size(mesh, n) for n in names]
            if any(s is None for s in sizes):        # axis absent (single-pod)
                continue
            if any(n in used for n in names):
                continue
            if dim % math.prod(sizes) == 0:
                chosen = names
                used.update(names)
                break
        if chosen is None:
            parts.append(None)
        else:
            parts.append(chosen if len(chosen) > 1 else chosen[0])
    return P(*parts)


def _axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None)))
                                        for e in x)


def tree_shardings(tree_shapes, tree_axes, rules: dict, mesh: Mesh):
    """Shape-tree (arrays or ShapeDtypeStructs) + logical-axes tree ->
    NamedSharding tree.

    Mapped over the *axes* tree (axis tuples are pytree nodes, so they must
    drive the flattening) with the shape tree as the second operand.
    """
    def one(axes, x):
        return NamedSharding(mesh, resolve_spec(x.shape, axes, rules, mesh))
    return jax.tree.map(one, tree_axes, tree_shapes, is_leaf=_axes_leaf)


def tree_pspecs(tree_shapes, tree_axes, rules: dict, mesh: Mesh):
    """Same as tree_shardings but returns raw PartitionSpecs."""
    def one(axes, x):
        return resolve_spec(x.shape, axes, rules, mesh)
    return jax.tree.map(one, tree_axes, tree_shapes, is_leaf=_axes_leaf)


# ---------------------------------------------------------------------------
# activation constraints (mesh context)
# ---------------------------------------------------------------------------

_ctx = threading.local()


@contextlib.contextmanager
def shard_ctx(mesh: Mesh, rules: dict):
    """Activate a mesh + rule set so model-internal ``logical_constraint``
    calls become with_sharding_constraint; no-op otherwise."""
    prev = getattr(_ctx, "state", None)
    _ctx.state = (mesh, rules)
    try:
        yield
    finally:
        _ctx.state = prev


def active_mesh() -> Optional[Mesh]:
    st = getattr(_ctx, "state", None)
    return st[0] if st else None


def active_rules() -> Optional[dict]:
    """Rule set of the active shard_ctx (None when inactive).  The kernel
    dispatch layer (kernels/dispatch.py) reads the pair (active_mesh,
    active_rules) at trace time to decide whether a delta GEMM lowers as a
    per-shard shard_map'd kernel or stays on the global GSPMD path."""
    st = getattr(_ctx, "state", None)
    return st[1] if st else None


def ctx_axis_size(name: str) -> Optional[int]:
    """Size of a mesh axis in the active context (None when inactive or the
    axis is absent).  Lets model code pick sharding strategy by
    divisibility (e.g. head-TP vs sequence-TP attention)."""
    mesh = active_mesh()
    if mesh is None:
        return None
    return _axis_size(mesh, name)


def ctx_forward_only() -> bool:
    """True inside a serving (gradient-free) rules context."""
    st = getattr(_ctx, "state", None)
    return bool(st and st[1].get("_forward_only"))


def logical_constraint(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    st = getattr(_ctx, "state", None)
    if st is None:
        return x
    mesh, rules = st
    spec = resolve_spec(x.shape, axes, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def local_top_k(score: jax.Array, k: int, axes: Sequence[Optional[str]]
                ) -> tuple:
    """top_k over the last dim, forced shard-local via shard_map.

    XLA's sort partitioner all-gathers the operand even when the sort dim
    is unsharded (measured ~50 GB/step on the MoE train cell); wrapping in
    shard_map keeps each shard's top_k local.  ``axes`` are the logical
    axes of ``score`` (last must be None).  No-op outside a mesh context.
    """
    st = getattr(_ctx, "state", None)
    if st is None:
        return jax.lax.top_k(score, k)
    mesh, rules = st
    spec = resolve_spec(score.shape, axes, rules, mesh)
    out_spec = P(*(list(spec)[:-1] + [None]))
    from jax.experimental.shard_map import shard_map
    return shard_map(lambda s: tuple(jax.lax.top_k(s, k)), mesh=mesh,
                     in_specs=(spec,), out_specs=(out_spec, out_spec),
                     check_rep=False)(score)
