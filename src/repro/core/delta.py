"""Core 1-bit delta math: sign extraction, bit packing, per-axis scales.

Implements the paper's representation

    What = v (.) B + W_b,   B = sign(W_f - W_b) in {-1,+1}^(dout x din)

with B packed 1 bit per entry along the *input* axis (paper §Implementation
remarks: "Masks B stay packed end-to-end (1 bit along input axis)").

Conventions
-----------
* Weight matrices are (d_out, d_in) — output rows, input columns — matching
  the paper's notation.  A linear layer computes ``y = x @ W.T``.
* ``row`` mode: v has shape (d_out,) and scales whole output rows
  (broadcast over columns).  ``col`` mode: v has shape (d_in,) and scales
  whole input columns (broadcast over rows).  ``scalar`` mode (the BitDelta
  baseline): v is a () scalar.
* Packing: sign bits are mapped {-1 -> 0, +1 -> 1} and packed little-endian
  into uint8 planes of shape (d_out, d_in // 8).  d_in must be a multiple
  of 8 (true for every architecture in the zoo); ``pad_to_packable`` exists
  for odd shapes in tests.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

AxisMode = Literal["row", "col", "scalar"]

PACK = 8  # bits per uint8 plane


# ---------------------------------------------------------------------------
# sign / pack / unpack
# ---------------------------------------------------------------------------

def sign_mask(delta: jax.Array) -> jax.Array:
    """sign(delta) in {-1, +1}; zeros map to +1 (paper fixes B at 1 bit,
    forbidding explicit zeros — §4 Limitations)."""
    return jnp.where(delta >= 0, jnp.int8(1), jnp.int8(-1))


def pack_signs(signs: jax.Array) -> jax.Array:
    """Pack a {-1,+1} (..., d_in) array into (..., d_in//8) uint8 planes.

    Little-endian within each byte: bit j of byte i covers column i*8+j.
    """
    if signs.shape[-1] % PACK != 0:
        raise ValueError(f"last dim {signs.shape[-1]} not a multiple of {PACK}")
    bits = (signs > 0).astype(jnp.uint8)  # {-1,+1} -> {0,1}
    bits = bits.reshape(*signs.shape[:-1], signs.shape[-1] // PACK, PACK)
    shifts = jnp.arange(PACK, dtype=jnp.uint8)
    return jnp.sum(bits << shifts, axis=-1).astype(jnp.uint8)


def unpack_signs(packed: jax.Array, d_in: int, dtype=jnp.float32) -> jax.Array:
    """Inverse of :func:`pack_signs`: (..., d_in//8) uint8 -> (..., d_in) ±1."""
    if packed.shape[-1] * PACK != d_in:
        raise ValueError(
            f"packed last dim {packed.shape[-1]} * {PACK} != d_in {d_in}")
    shifts = jnp.arange(PACK, dtype=jnp.uint8)
    bits = (packed[..., None] >> shifts) & jnp.uint8(1)  # (..., d_in//8, 8)
    bits = bits.reshape(*packed.shape[:-1], d_in)
    return (bits.astype(dtype) * 2 - 1).astype(dtype)


def pad_to_packable(w: jax.Array) -> tuple[jax.Array, int]:
    """Pad last dim up to a multiple of 8; returns (padded, original_d_in)."""
    d_in = w.shape[-1]
    rem = (-d_in) % PACK
    if rem == 0:
        return w, d_in
    pad = [(0, 0)] * (w.ndim - 1) + [(0, rem)]
    return jnp.pad(w, pad), d_in


# ---------------------------------------------------------------------------
# per-axis scale initialisation (Alg. 6 lines 3/5)
# ---------------------------------------------------------------------------

def init_scale(delta: jax.Array, mode: AxisMode) -> jax.Array:
    """v0 = mean(|ΔW|, axis) — the paper's initialisation before training.

    delta: (..., d_out, d_in); leading dims (stacked layers / experts) are
    preserved — each stacked matrix gets its own per-axis vector.
    row  -> mean over columns  -> (..., d_out)
    col  -> mean over rows     -> (..., d_in)
    scalar -> per-matrix mean  -> (...)
    """
    a = jnp.abs(delta)
    if mode == "row":
        return jnp.mean(a, axis=-1)
    if mode == "col":
        return jnp.mean(a, axis=-2)
    if mode == "scalar":
        return jnp.mean(a, axis=(-2, -1))
    raise ValueError(mode)


def broadcast_scale(v: jax.Array, mode: AxisMode) -> jax.Array:
    """Reshape v so it broadcasts against a (d_out, d_in) sign matrix.

    Supports stacked leading dims: v may be (..., d) — the trailing axis is
    the per-axis dimension.
    """
    if mode == "row":
        return v[..., :, None]
    if mode == "col":
        return v[..., None, :]
    if mode == "scalar":
        return v[..., None, None] if v.ndim else v
    raise ValueError(mode)


# ---------------------------------------------------------------------------
# compress / reconstruct
# ---------------------------------------------------------------------------

def compress(w_base: jax.Array, w_ft: jax.Array, mode: AxisMode
             ) -> tuple[jax.Array, jax.Array]:
    """Compress a fine-tuned weight to (packed_mask, v0).

    Returns packed uint8 (d_out, d_in//8) and the init scale for ``mode``.
    """
    delta = (w_ft - w_base).astype(jnp.float32)
    packed = pack_signs(sign_mask(delta))
    v0 = init_scale(delta, mode).astype(jnp.float16)
    return packed, v0


def reconstruct(packed: jax.Array, v: jax.Array, w_base: jax.Array,
                mode: AxisMode, dtype=None) -> jax.Array:
    """Ŵ = v ⊙ unpack(B) + W_b.  Pure-jnp reference path (the Pallas kernel
    in ``repro.kernels.unpack_apply`` is the production path)."""
    dtype = dtype or w_base.dtype
    d_in = w_base.shape[-1]
    signs = unpack_signs(packed, d_in, dtype=jnp.float32)
    vb = broadcast_scale(v.astype(jnp.float32), mode)
    return (vb * signs + w_base.astype(jnp.float32)).astype(dtype)


def delta_matmul(x: jax.Array, packed: jax.Array, v: jax.Array,
                 w_base: jax.Array, mode: AxisMode) -> jax.Array:
    """On-the-fly y = x @ Ŵᵀ without densifying the delta *into HBM*.

    Mathematically:
      row:  y = x @ W_bᵀ + (x @ Sᵀ) * v        (v broadcasts over out dim)
      col:  y = x @ W_bᵀ + ((x * v) @ Sᵀ)
      scalar: y = x @ W_bᵀ + v * (x @ Sᵀ)
    Reference path; the fused Pallas kernel lives in repro.kernels.bitlinear.
    """
    d_in = w_base.shape[-1]
    signs = unpack_signs(packed, d_in, dtype=x.dtype)
    base = x @ w_base.T.astype(x.dtype)
    if mode == "row":
        return base + (x @ signs.T) * v.astype(x.dtype)
    if mode == "col":
        return base + (x * v.astype(x.dtype)) @ signs.T
    if mode == "scalar":
        return base + v.astype(x.dtype) * (x @ signs.T)
    raise ValueError(mode)


# ---------------------------------------------------------------------------
# incremental update patches (version-to-version wire format)
#
# BitDelta (arXiv 2402.10193) motivates the incremental case: two successive
# fine-tunes of one base differ by a far smaller residual than fine-tune vs
# base, so a new VERSION of a variant ships as a patch against its parent.
# One uniform wire transform covers every buffer kind:
#
#   1. XOR the parent's and the new version's WIRE bytes (packed uint8 sign
#      planes, fp16 vectors/extras, bool selectors) — unchanged bytes
#      become 0, and for sign planes specifically the XOR is the set of
#      flipped sign bits;
#   2. run-length-suppress the zero runs: maximal nonzero stretches become
#      (start, length, literal-bytes) segments, with short zero gaps
#      merged into a segment so overhead stays ~12 bytes per region.
#
# The transform is EXACT at the bit level: applying a patch reproduces
# buffers bit-identical to a fresh full publish of the new version, so a
# patched variant serves with exact greedy-token parity.
# ---------------------------------------------------------------------------

def xor_bytes(old: np.ndarray, new: np.ndarray) -> np.ndarray:
    """Flat uint8 XOR of two wire buffers (same shape + dtype)."""
    old = np.ascontiguousarray(old)
    new = np.ascontiguousarray(new)
    if old.shape != new.shape or old.dtype != new.dtype:
        raise ValueError(
            f"wire buffers must match, got {old.dtype}{old.shape} vs "
            f"{new.dtype}{new.shape}; incremental patches require an "
            "unchanged module structure (publish full)")
    return old.view(np.uint8).ravel() ^ new.view(np.uint8).ravel()


def zrle_encode(flat: np.ndarray, *, merge_gap: int = 16
                ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Zero-run suppression of a flat uint8 XOR stream ->
    (starts int64, lengths int32, literals uint8).

    Segments are maximal nonzero stretches; stretches separated by at most
    ``merge_gap`` zero bytes merge into one segment (12 bytes of overhead
    beats a dozen 1-byte segments).  A localised update — a few rows of a
    matrix — costs ~its own bytes; an untouched buffer costs nothing."""
    flat = np.ascontiguousarray(flat, dtype=np.uint8).ravel()
    nz = np.flatnonzero(flat)
    if nz.size == 0:
        return (np.zeros(0, np.int64), np.zeros(0, np.int32),
                np.zeros(0, np.uint8))
    brk = np.flatnonzero(np.diff(nz) > merge_gap)
    starts = nz[np.concatenate([[0], brk + 1])]
    ends = nz[np.concatenate([brk, [nz.size - 1]])] + 1
    lits = np.concatenate([flat[s:e] for s, e in zip(starts, ends)])
    return (starts.astype(np.int64), (ends - starts).astype(np.int32), lits)


def zrle_decode(starts: np.ndarray, lens: np.ndarray, lits: np.ndarray,
                size: int) -> np.ndarray:
    """Inverse of :func:`zrle_encode` -> dense flat uint8 of ``size``."""
    out = np.zeros(size, np.uint8)
    off = 0
    for s, n in zip(np.asarray(starts, np.int64), np.asarray(lens)):
        if s + n > size:
            raise ValueError(
                f"XOR segment [{s}, {s + n}) exceeds buffer size {size}")
        out[s:s + n] = lits[off:off + n]
        off += int(n)
    if off != len(lits):
        raise ValueError("XOR literal stream length mismatch")
    return out


# ---------------------------------------------------------------------------
# storage accounting (paper Table 2)
# ---------------------------------------------------------------------------

def artifact_bytes(d_out: int, d_in: int, mode: AxisMode) -> int:
    """Bytes to store one compressed matrix: packed mask + FP16 vector."""
    mask = d_out * d_in // PACK
    if mode == "row":
        vec = 2 * d_out
    elif mode == "col":
        vec = 2 * d_in
    else:
        vec = 2
    return mask + vec


def fp16_bytes(d_out: int, d_in: int) -> int:
    return 2 * d_out * d_in


def compression_ratio(d_out: int, d_in: int, mode: AxisMode) -> float:
    return fp16_bytes(d_out, d_in) / artifact_bytes(d_out, d_in, mode)
