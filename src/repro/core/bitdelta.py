"""Compressed linear module: the paper's drop-in replacement layer.

A :class:`DeltaLinear` represents one patched linear projection

    y = x @ (v ⊙ unpack(B) + W_b)ᵀ

in one of three apply modes:

* ``"dense"``   — reconstruct Ŵ once (loader path; paper's deployed mode:
                  "We add all residual terms at once ... yielding inference
                  identical to FP16 weights").
* ``"onfly"``   — fused delta GEMM per forward (no switch cost; the paper's
                  §4 "alternative on-the-fly variant", backed by the Pallas
                  ``bitlinear`` kernel).
* ``"ref"``     — pure-jnp reference (used by calibration: it is
                  differentiable w.r.t. v).

All state is a plain pytree so the module composes with pjit/scan/remat.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import delta as D


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DeltaLinear:
    """Pytree state of one compressed projection."""
    packed: jax.Array          # (d_out, d_in//8) uint8
    v: jax.Array               # (d_out,) | (d_in,) | () fp16/fp32
    w_base: jax.Array          # (d_out, d_in)
    mode: str = dataclasses.field(metadata=dict(static=True), default="row")

    @property
    def shape(self) -> tuple[int, int]:
        return self.w_base.shape

    # -- construction ------------------------------------------------------
    @classmethod
    def from_pair(cls, w_base: jax.Array, w_ft: jax.Array, mode: str
                  ) -> "DeltaLinear":
        packed, v0 = D.compress(w_base, w_ft, mode)
        return cls(packed=packed, v=v0, w_base=w_base, mode=mode)

    # -- forward -----------------------------------------------------------
    def reconstruct(self, dtype=None) -> jax.Array:
        return D.reconstruct(self.packed, self.v, self.w_base, self.mode,
                             dtype=dtype)

    def __call__(self, x: jax.Array, apply_mode: str = "ref") -> jax.Array:
        if apply_mode == "ref":
            *lead, k = x.shape
            y = D.delta_matmul(x.reshape(-1, k), self.packed, self.v,
                               self.w_base, self.mode)
            return y.reshape(*lead, -1)
        if apply_mode == "onfly":
            from repro.kernels import ops as K
            return K.bitlinear(x, self.packed, self.v, self.w_base,
                               mode=self.mode)
        if apply_mode == "dense":
            w_hat = self.reconstruct(dtype=x.dtype)
            return x @ w_hat.T
        raise ValueError(apply_mode)

    # -- accounting --------------------------------------------------------
    def artifact_bytes(self) -> int:
        d_out, d_in = self.w_base.shape
        return D.artifact_bytes(d_out, d_in, self.mode)


def reconstruction_error(lin: DeltaLinear, w_ft: jax.Array) -> jax.Array:
    """||Ŵ - W_f||_F / ||W_f - W_b||_F — weight-space residual error.

    (The paper optimizes *output* error, not this; we report both.)"""
    w_hat = lin.reconstruct(dtype=jnp.float32)
    num = jnp.linalg.norm(w_hat - w_ft.astype(jnp.float32))
    den = jnp.linalg.norm(w_ft.astype(jnp.float32)
                          - lin.w_base.astype(jnp.float32)) + 1e-12
    return num / den


def best_static_axis(w_base: jax.Array, w_ft: jax.Array) -> str:
    """Weight-space heuristic axis choice (no calibration): lower Frobenius
    residual with the init scale.  Calibration (core.calibration) replaces
    this with the paper's output-MSE selection."""
    errs: dict[str, Any] = {}
    for mode in ("row", "col"):
        lin = DeltaLinear.from_pair(w_base, w_ft, mode)
        errs[mode] = float(reconstruction_error(lin, w_ft))
    return min(errs, key=errs.get)
