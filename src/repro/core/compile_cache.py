"""Persistent compilation cache: the wrapped→lowered→compiled split.

Restart-to-first-token is a production SLO (ROADMAP "compile-once
serving"): the engine's step pairs and the dispatch layer's shard_map
kernels each cost seconds of XLA compile (moe ``decode_fused`` alone is
11.6s at (2, 16, 16)), re-paid on every server restart even though
nothing about the program changed.  This module makes the three jit
stages explicit — ``jax.jit(fn)`` (wrapped), ``.lower(*args)``
(lowered), ``.compile()`` (compiled) — and persists the COMPILED stage
across processes via ``jax.experimental.serialize_executable`` (the
JaCe ``translation_cache.py`` exemplar, SNIPPETS.md §3).

Safety model — a stale cache can only MISS, never serve a wrong
executable:

* every key is a sha256 over (a) the caller's semantic parts — op kind,
  mesh fingerprint, plan, avals, donation/sharding fingerprints — and
  (b) an ENVIRONMENT fingerprint: jax + jaxlib versions, backend,
  device kind/count, and a content hash of every ``repro`` source file.
  Changing any of them changes the key, so upgrades and code edits
  degrade to a compile + re-populate, not a wrong answer;
* each entry file re-states its environment fingerprint in cleartext
  metadata and ``get`` re-checks it before deserializing (belt and
  braces against key collisions and hand-copied cache dirs);
* corrupt / truncated / undeserializable entries count in ``stats``
  and read as a miss — never an exception on the serving path.

Where executable serialization is unavailable (some backends refuse
``serialize``), the cache degrades to JAX's own persistent compilation
cache: ``enable_xla_fallback`` points ``jax_compilation_cache_dir`` at
a subdirectory, so ``.compile()`` still skips XLA's backend work on a
warm restart even when we cannot persist the loaded executable
ourselves.

Observability: ``stats`` counts hits / misses / compiles /
compile-seconds / corrupt entries / env mismatches — surfaced through
``ServingEngine.status()`` and printed by ``benchmarks/run.py``.
"""
from __future__ import annotations

import hashlib
import os
import pathlib
import pickle
import time
from typing import Optional

import jax
import jax.numpy as jnp

_FORMAT = 1

# -- fingerprints ------------------------------------------------------------

_code_fp_cache: Optional[str] = None


def code_fingerprint() -> str:
    """sha256 over the contents of every ``repro`` source file.  A code
    edit anywhere in the package invalidates the whole cache — coarse,
    but it is the property that lets the warm path skip tracing
    entirely: if the sources are byte-identical, the jaxpr a key's
    parts describe is too."""
    global _code_fp_cache
    if _code_fp_cache is not None:
        return _code_fp_cache
    root = pathlib.Path(__file__).resolve().parents[1]   # src/repro
    h = hashlib.sha256()
    for p in sorted(root.rglob("*.py")):
        h.update(str(p.relative_to(root)).encode())
        h.update(p.read_bytes())
    _code_fp_cache = h.hexdigest()[:16]
    return _code_fp_cache


def env_fingerprint() -> tuple:
    """Everything outside the program that decides whether a serialized
    executable is loadable AND correct here: library versions, backend,
    and the device topology the executable was compiled for."""
    import jaxlib
    devs = jax.devices()
    return (jax.__version__, jaxlib.__version__,
            jax.default_backend(),
            devs[0].device_kind if devs else "none", len(devs),
            code_fingerprint())


def aval_fp(tree) -> tuple:
    """Stable fingerprint of a pytree of arrays / ShapeDtypeStructs:
    (structure string, ((shape, dtype, weak_type), ...)).  Two trees
    with equal fingerprints trace to the same jaxpr arguments."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return (str(treedef),
            tuple((tuple(l.shape), jnp.dtype(l.dtype).name,
                   bool(getattr(l, "weak_type", False))) for l in leaves))


def mesh_fp(mesh) -> tuple:
    """Process-stable mesh identity: axis names, shape, device kind.
    (The Mesh object itself hashes per-process — fine for the in-memory
    memo, useless in a persistent key.)"""
    if mesh is None:
        return ("no-mesh",)
    devs = mesh.devices.reshape(-1)
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape),
            devs[0].device_kind if devs.size else "none")


def sharding_fp(tree) -> str:
    """Fingerprint of a pytree of shardings (NamedSharding reprs are
    stable across processes for the same topology); None passes
    through."""
    if tree is None:
        return "none"
    return str(jax.tree.map(
        lambda s: str(s), tree,
        is_leaf=lambda x: x is None or hasattr(x, "devices_indices_map")))


# -- the cache ---------------------------------------------------------------

class CompileCache:
    """Directory-backed store of serialized XLA executables.

    ``get`` returns a loaded ``Compiled`` or None (miss — also on any
    corruption or environment mismatch); ``put`` serializes one; both
    never raise on the serving path.  ``load_or_compile`` is the
    one-stop wrapped→lowered→compiled helper callers use."""

    def __init__(self, path, *, xla_fallback: bool = True):
        self.path = pathlib.Path(path)
        self.path.mkdir(parents=True, exist_ok=True)
        self.stats = {"hits": 0, "misses": 0, "puts": 0,
                      "compiles": 0, "compile_seconds": 0.0,
                      "deserialize_seconds": 0.0,
                      "corrupt": 0, "env_mismatch": 0,
                      "serialize_failures": 0}
        if xla_fallback:
            self._enable_xla_fallback()

    def _enable_xla_fallback(self) -> None:
        """Point JAX's own persistent compilation cache at a subdir so
        even executables we cannot serialize ourselves (and plain jits
        that never route through here) compile warm on restart."""
        try:
            xla_dir = self.path / "xla"
            xla_dir.mkdir(exist_ok=True)
            jax.config.update("jax_compilation_cache_dir", str(xla_dir))
            jax.config.update("jax_persistent_cache_min_compile_time_secs",
                              0.0)
        except Exception:
            pass   # older jaxlib without the knobs: executables only

    # -- keys ---------------------------------------------------------------
    def key(self, *parts) -> str:
        """sha256 over the canonical repr of ``parts`` + the environment
        fingerprint.  Parts must be primitives / strings / tuples —
        callers fingerprint meshes and shardings with the helpers
        above, never pass live objects."""
        payload = repr((parts, env_fingerprint())).encode()
        return hashlib.sha256(payload).hexdigest()

    def _entry(self, key: str) -> pathlib.Path:
        return self.path / f"{key}.exe"

    # -- read / write -------------------------------------------------------
    def get(self, parts_or_key):
        """Loaded ``Compiled`` for these key parts, or None.  Corrupt
        files and environment mismatches are counted and read as a
        clean miss."""
        key = (parts_or_key if isinstance(parts_or_key, str)
               else self.key(*parts_or_key))
        p = self._entry(key)
        if not p.exists():
            self.stats["misses"] += 1
            return None
        try:
            with open(p, "rb") as f:
                entry = pickle.load(f)
            if entry.get("format") != _FORMAT:
                self.stats["corrupt"] += 1
                return None
        except Exception:
            self.stats["corrupt"] += 1
            return None
        if entry.get("env") != env_fingerprint():
            # key collisions can't produce this (env is IN the key) but
            # hand-moved cache dirs and truncated hashes could — re-check
            # in cleartext before trusting opaque executable bytes
            self.stats["env_mismatch"] += 1
            return None
        try:
            from jax.experimental import serialize_executable as se
            t0 = time.perf_counter()
            compiled = se.deserialize_and_load(
                entry["payload"], entry["in_tree"], entry["out_tree"])
            self.stats["deserialize_seconds"] += time.perf_counter() - t0
        except Exception:
            self.stats["corrupt"] += 1
            return None
        self.stats["hits"] += 1
        return compiled

    def put(self, key_or_parts, compiled) -> bool:
        """Serialize ``compiled`` under the key; atomic (tmp +
        os.replace) so a crashed writer leaves a clean miss, not a torn
        entry.  Returns False when this executable refuses
        serialization (the XLA fallback dir still covers it)."""
        key = (key_or_parts if isinstance(key_or_parts, str)
               else self.key(*key_or_parts))
        try:
            from jax.experimental import serialize_executable as se
            payload, in_tree, out_tree = se.serialize(compiled)
        except Exception:
            self.stats["serialize_failures"] += 1
            return False
        entry = {"format": _FORMAT, "env": env_fingerprint(),
                 "payload": payload, "in_tree": in_tree,
                 "out_tree": out_tree}
        tmp = self._entry(key).with_suffix(f".tmp{os.getpid()}")
        try:
            with open(tmp, "wb") as f:
                pickle.dump(entry, f)
            os.replace(tmp, self._entry(key))
        except Exception:
            self.stats["serialize_failures"] += 1
            tmp.unlink(missing_ok=True)
            return False
        self.stats["puts"] += 1
        return True

    def load_or_compile(self, parts, jitted, args, *, ctx=None):
        """The staged path in one call: persistent hit → loaded
        executable; miss → ``jitted.lower(*args).compile()`` (inside
        ``ctx`` — mesh / shard_ctx / dispatch contexts apply at TRACE
        time) and persist.  Returns (compiled, "hit" | "compiled")."""
        import contextlib
        key = self.key(*parts)
        compiled = self.get(key)
        if compiled is not None:
            return compiled, "hit"
        t0 = time.perf_counter()
        with (ctx if ctx is not None else contextlib.nullcontext()):
            compiled = jitted.lower(*args).compile()
        self.stats["compiles"] += 1
        self.stats["compile_seconds"] += time.perf_counter() - t0
        self.put(key, compiled)
        return compiled, "compiled"


# -- process default ---------------------------------------------------------
# One ambient cache per process, configured by REPRO_COMPILE_CACHE_DIR:
# the dispatch memo and the bank-write jit pick it up without plumbing a
# handle through every layer; Deployment(compile_cache_dir=...) overrides
# explicitly for its engine.  Tests install their own via set_default.

_default: object = None
_default_resolved = False


def get_default() -> Optional[CompileCache]:
    global _default, _default_resolved
    if not _default_resolved:
        _default_resolved = True
        d = os.environ.get("REPRO_COMPILE_CACHE_DIR")
        if d:
            _default = CompileCache(d)
    return _default


def set_default(cache: Optional[CompileCache]) -> Optional[CompileCache]:
    """Install (or clear, with None) the process-ambient cache; returns
    the previous one so tests can restore it."""
    global _default, _default_resolved
    prev = _default
    _default = cache
    _default_resolved = True
    return prev


class CachedCallable:
    """A jit with an explicit compiled stage behind the persistent cache.

    Call semantics match the wrapped jit exactly:

    * called with TRACERS (inlined into an outer jit trace): delegates
      to the plain jitted call — staging is meaningless mid-trace;
    * called eagerly with no ambient cache: plain jitted call;
    * called eagerly with a cache: resolve wrapped→lowered→compiled
      through it (keyed on ``parts`` + args avals + the environment)
      and call the executable directly.  One executable per distinct
      aval signature is held per instance.

    Static kwargs are supported (forwarded to ``lower`` and folded into
    the key); donation declared on the wrapped jit survives
    serialization, so donated-buffer callers keep their in-place
    semantics on the warm path.
    """

    def __init__(self, jitted, parts, *, cache="ambient"):
        self.jitted = jitted
        self.parts = tuple(parts)
        self._cache = cache
        self._exe: dict = {}

    def cache(self) -> Optional[CompileCache]:
        return get_default() if self._cache == "ambient" else self._cache

    def __call__(self, *args, **kwargs):
        if any(isinstance(a, jax.core.Tracer)
               for a in jax.tree.leaves((args, kwargs))):
            return self.jitted(*args, **kwargs)
        cc = self.cache()
        if cc is None:
            return self.jitted(*args, **kwargs)
        akey = (tuple(aval_fp(a) for a in args),
                tuple(sorted((k, repr(v)) for k, v in kwargs.items())))
        exe = self._exe.get(akey)
        if exe is None:
            exe, _ = cc.load_or_compile(self.parts + (akey,), self.jitted,
                                        args, ctx=None) \
                if not kwargs else self._load_kw(cc, akey, args, kwargs)
            self._exe[akey] = exe
        try:
            return exe(*args)
        except Exception:
            # aval-compatible but call-incompatible executable (layout
            # drift, committed-device mismatch): correctness beats cache
            self._exe.pop(akey, None)
            return self.jitted(*args, **kwargs)

    def aot(self, *args) -> str:
        """Force the compiled stage for these (possibly abstract) args
        now — the warmup hook.  Returns "hit" (persistent cache),
        "compiled", "warm" (already staged in-process), or "none" (no
        cache attached: nothing to stage against)."""
        cc = self.cache()
        if cc is None:
            return "none"
        akey = (tuple(aval_fp(a) for a in args), ())
        if akey in self._exe:
            return "warm"
        exe, how = cc.load_or_compile(self.parts + (akey,), self.jitted,
                                      args)
        self._exe[akey] = exe
        return how

    def _load_kw(self, cc, akey, args, kwargs):
        key = cc.key(*(self.parts + (akey,)))
        compiled = cc.get(key)
        if compiled is not None:
            return compiled, "hit"
        t0 = time.perf_counter()
        compiled = self.jitted.lower(*args, **kwargs).compile()
        cc.stats["compiles"] += 1
        cc.stats["compile_seconds"] += time.perf_counter() - t0
        cc.put(key, compiled)
        return compiled, "compiled"
