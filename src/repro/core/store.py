"""Delta artifact store: serialization + manifest + integrity.

Artifact layout (one directory per fine-tuned variant):
  manifest.json   paths, shapes, axis selections, dtypes, sha256 per tensor,
                  base-checkpoint fingerprint (guards against applying a
                  delta to the wrong base)
  deltas.npz      packed masks (uint8) + selected scale vectors (fp16)
                  + selector bits
  extras.npz      uncompressed fine-tuned leaves (embeddings/norms), fp16

Masks stay packed end-to-end (paper §Implementation remarks) — the loader
transfers the packed buffer and unpacks on device via the Pallas kernel.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import pathlib
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.calibration import DeltaEntry, DeltaModel


def _sha(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()[:16]


def base_fingerprint(base_params) -> str:
    """Cheap fingerprint of the base checkpoint (shapes + sampled bytes)."""
    h = hashlib.sha256()
    for path, leaf in sorted(
            ((".".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path), l)
             for path, l in jax.tree_util.tree_flatten_with_path(
                 base_params)[0])):
        h.update(path.encode())
        h.update(str(leaf.shape).encode())
        arr = np.asarray(jax.device_get(leaf)).ravel()
        h.update(arr[:64].tobytes())
    return h.hexdigest()[:16]


STORE_VERSION = 2   # v2: artifact_bytes + per-file sizes persisted on disk


def save_artifact(dm: DeltaModel, out_dir: str, *,
                  base_fp: Optional[str] = None,
                  meta: Optional[dict] = None) -> dict:
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    manifest = {"version": STORE_VERSION, "base_fingerprint": base_fp,
                "meta": meta or {}, "deltas": {}, "extras": {}}
    dz, ez = {}, {}
    for path, e in dm.deltas.items():
        key = path.replace(".", "__")
        packed = np.asarray(jax.device_get(e.packed))
        use_row = np.asarray(jax.device_get(e.use_row))
        v_row = np.asarray(jax.device_get(e.v_row)).astype(np.float16)
        v_col = np.asarray(jax.device_get(e.v_col)).astype(np.float16)
        dz[f"{key}__packed"] = packed
        dz[f"{key}__v_row"] = v_row
        dz[f"{key}__v_col"] = v_col
        dz[f"{key}__use_row"] = use_row
        manifest["deltas"][path] = {
            "packed_shape": list(packed.shape),
            "scalar": bool(e.scalar),
            "sha": _sha(packed),
            "axis_counts": {
                "row": int(use_row.sum()),
                "col": int(use_row.size - use_row.sum())},
        }
    for path, v in dm.extras.items():
        key = path.replace(".", "__")
        arr = np.asarray(jax.device_get(v)).astype(np.float16)
        ez[key] = arr
        manifest["extras"][path] = {"shape": list(arr.shape),
                                    "sha": _sha(arr)}
    np.savez(out / "deltas.npz", **dz)
    np.savez(out / "extras.npz", **ez)
    # payload sizes are known once the npz files exist, so artifact_bytes
    # (and per-file sizes, for truncation detection at load) can be
    # PERSISTED in manifest.json rather than only returned to the caller
    manifest["files"] = {f: (out / f).stat().st_size
                         for f in ("deltas.npz", "extras.npz")}
    manifest["artifact_bytes"] = sum(manifest["files"].values())
    tmp = out / "manifest.json.tmp"
    tmp.write_text(json.dumps(manifest, indent=2))
    tmp.rename(out / "manifest.json")          # atomic finalize
    return manifest


def load_artifact(in_dir: str, *, expect_base_fp: Optional[str] = None,
                  verify: bool = True) -> DeltaModel:
    path = pathlib.Path(in_dir)
    manifest = json.loads((path / "manifest.json").read_text())
    if expect_base_fp and manifest.get("base_fingerprint") and \
            manifest["base_fingerprint"] != expect_base_fp:
        raise ValueError(
            f"artifact built for base {manifest['base_fingerprint']}, "
            f"got {expect_base_fp}")
    # truncation sanity check (store v2+): the manifest records each
    # payload file's byte size — a partial copy/rsync shows up here before
    # np.load chokes on (or silently accepts) a short file
    if verify:
        for fname, nbytes in manifest.get("files", {}).items():
            actual = (path / fname).stat().st_size \
                if (path / fname).exists() else -1
            if actual != nbytes:
                raise IOError(
                    f"truncated artifact: {fname} is {actual} bytes, "
                    f"manifest records {nbytes}")
    dz = np.load(path / "deltas.npz")
    ez = np.load(path / "extras.npz")
    deltas, extras = {}, {}
    for p, info in manifest["deltas"].items():
        key = p.replace(".", "__")
        packed = dz[f"{key}__packed"]
        if verify and _sha(packed) != info["sha"]:
            raise IOError(f"corrupt mask for {p}")
        deltas[p] = DeltaEntry(
            packed=jnp.asarray(packed),
            v_row=jnp.asarray(dz[f"{key}__v_row"]).astype(jnp.float32),
            v_col=jnp.asarray(dz[f"{key}__v_col"]).astype(jnp.float32),
            use_row=jnp.asarray(dz[f"{key}__use_row"]),
            scalar=info["scalar"])
    for p, info in manifest["extras"].items():
        arr = ez[p.replace(".", "__")]
        if verify and _sha(arr) != info["sha"]:
            raise IOError(f"corrupt extra for {p}")
        extras[p] = jnp.asarray(arr)
    return DeltaModel(deltas=deltas, extras=extras)


def save_checkpoint_fp16(params, out_path: str) -> int:
    """Full fp16 checkpoint (the baseline the paper compares load against)."""
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        key = "__".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = np.asarray(jax.device_get(leaf)).astype(np.float16)
    p = pathlib.Path(out_path)
    p.parent.mkdir(parents=True, exist_ok=True)
    np.savez(p, **flat)
    return p.stat().st_size
