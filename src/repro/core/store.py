"""Delta artifact store: serialization + manifest + version lineage.

Artifact layout (one directory per PUBLISHED VERSION of a variant):
  manifest.json   paths, shapes, axis selections, dtypes, sha256 per tensor,
                  base-checkpoint fingerprint (guards against applying a
                  delta to the wrong base), and — store v3 — version
                  lineage: variant name, monotonic version id, parent
                  version, artifact kind ("full" | "patch")
  deltas.npz      full publish: packed masks (uint8) + scale vectors (fp16)
  extras.npz      full publish: uncompressed fine-tuned leaves, fp16
  patch.npz       incremental publish: RLE-encoded XOR of the parent's
                  packed sign planes + sparse fp16 vector/extras updates
                  (core/delta.py wire helpers; exact in the wire domain)

:class:`VariantStore` arranges versions under ``root/<name>/v%04d`` with a
``versions.json`` lineage index per variant whose ``latest`` field is THE
serving pointer — publish advances it, rollback moves it back (constant
time, no artifact IO).  Manifests are finalized with tmp-file +
``os.replace`` so a crash mid-publish can never leave a readable-but-torn
manifest, and an unfinished version directory is invisible until the index
commits.

Masks stay packed end-to-end (paper §Implementation remarks) — the loader
transfers the packed buffer and unpacks on device via the Pallas kernel.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import json
import os
import pathlib
import threading
import zipfile
from typing import Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
import numpy.lib.format as _npformat

from repro.core import delta as D
from repro.core.calibration import DeltaEntry, DeltaModel


def _sha(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()[:16]


def base_fingerprint(base_params) -> str:
    """Cheap fingerprint of the base checkpoint (shapes + sampled bytes)."""
    h = hashlib.sha256()
    for path, leaf in sorted(
            ((".".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path), l)
             for path, l in jax.tree_util.tree_flatten_with_path(
                 base_params)[0])):
        h.update(path.encode())
        h.update(str(leaf.shape).encode())
        arr = np.asarray(jax.device_get(leaf)).ravel()
        h.update(arr[:64].tobytes())
    return h.hexdigest()[:16]


STORE_VERSION = 3   # v3: version lineage (variant/version/parent/kind)
                    # v2: artifact_bytes + per-file sizes persisted on disk


def _write_manifest(out: pathlib.Path, manifest: dict) -> None:
    """Atomic finalize: the manifest appears complete or not at all.
    ``os.replace`` (not rename-semantics-by-luck) so a crash between write
    and publish leaves only the tmp file, which readers never look at."""
    tmp = out / "manifest.json.tmp"
    tmp.write_text(json.dumps(manifest, indent=2))
    os.replace(tmp, out / "manifest.json")


def read_manifest(in_dir: str) -> dict:
    """Read + structurally validate a manifest; a torn/truncated file (a
    crash that bypassed the atomic finalize, a partial copy) raises IOError
    instead of surfacing as a confusing JSON/KeyError downstream."""
    path = pathlib.Path(in_dir) / "manifest.json"
    if not path.exists():
        raise IOError(f"no manifest at {path}")
    try:
        manifest = json.loads(path.read_text())
    except json.JSONDecodeError as e:
        raise IOError(f"torn or corrupt manifest {path}: {e}") from e
    if not isinstance(manifest, dict) or \
            not {"deltas", "extras"} <= set(manifest):
        raise IOError(f"torn or corrupt manifest {path}: "
                      "missing required sections")
    return manifest


# ---------------------------------------------------------------------------
# streamed per-module ingest (the async admission pipeline's read side)
# ---------------------------------------------------------------------------

DEFAULT_CHUNK_BYTES = 4 << 20   # bounded read granularity per payload chunk


def _device_put_copies() -> bool:
    """Whether ``jax.device_put`` of a numpy array COPIES host memory on
    this backend.  CPU zero-copies (the numpy buffer becomes the device
    buffer), so a staging buffer handed to the device must never be
    recycled there; accelerators copy across PCIe and the buffer is
    reusable once the transfer future resolves.  Probed once."""
    global _DEVICE_PUT_COPIES
    if _DEVICE_PUT_COPIES is None:
        probe = np.arange(32, dtype=np.uint8)
        dev = jax.device_put(probe)
        jax.block_until_ready(dev)
        probe[0] ^= 0xFF
        _DEVICE_PUT_COPIES = int(np.asarray(dev)[0]) != int(probe[0])
    return _DEVICE_PUT_COPIES


_DEVICE_PUT_COPIES: Optional[bool] = None


class StagingPool:
    """Reusable host staging buffers for streamed ingest.

    ``take`` returns a buffer of the requested (shape, dtype), reusing a
    released buffer of the same byte size when one exists; ``give``
    releases a buffer back.  The pool keeps at most ``max_buffers`` per
    size class, so an ingest pipeline's peak host RAM is O(largest module
    × in-flight window), not O(artifact).

    On zero-copy backends (CPU: ``jax.device_put`` aliases the numpy
    buffer) ``give`` of a device-transferred buffer is refused by the
    caller passing ``transferred=True`` — recycling it would rewrite live
    bank weights."""

    def __init__(self, max_buffers: int = 2):
        self.max_buffers = max_buffers
        self._free: dict[int, list] = {}
        self.stats = {"takes": 0, "reuses": 0, "drops": 0}

    def take(self, shape, dtype) -> np.ndarray:
        nbytes = int(np.prod(shape)) * np.dtype(dtype).itemsize
        self.stats["takes"] += 1
        bucket = self._free.get(nbytes)
        if bucket:
            self.stats["reuses"] += 1
            raw = bucket.pop()
        else:
            raw = np.empty(nbytes, np.uint8)
        return raw.view(np.dtype(dtype))[: int(np.prod(shape))].reshape(shape)

    def give(self, arr: np.ndarray, *, transferred: bool = False) -> None:
        if transferred and not _device_put_copies():
            # the "host" buffer IS the device buffer now — dropping our
            # reference hands ownership to jax; recycling would corrupt
            self.stats["drops"] += 1
            return
        raw = arr.view(np.uint8).reshape(-1)
        base = raw.base if raw.base is not None else raw
        bucket = self._free.setdefault(int(raw.nbytes), [])
        if len(bucket) < self.max_buffers:
            bucket.append(np.asarray(base).view(np.uint8).reshape(-1))
        else:
            self.stats["drops"] += 1


def _stream_npz_member(zf: zipfile.ZipFile, member: str, *,
                       chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                       pool: Optional[StagingPool] = None) -> np.ndarray:
    """Read ONE .npy member of an (uncompressed) npz in bounded chunks
    into a host array, checking truncation per chunk — a short stream
    raises IOError at the first missing byte instead of np.load silently
    mis-parsing (or buffering the whole payload first)."""
    with zf.open(member) as f:
        version = _npformat.read_magic(f)
        if version == (1, 0):
            shape, fortran, dtype = _npformat.read_array_header_1_0(f)
        elif version == (2, 0):
            shape, fortran, dtype = _npformat.read_array_header_2_0(f)
        else:                       # exotic npy version: no streaming path
            return _npformat.read_array(f)
        count = int(np.prod(shape))
        out = (pool.take(shape, dtype) if pool is not None
               else np.empty(count, dtype).reshape(shape))
        buf = out.reshape(-1).view(np.uint8)
        nbytes = count * dtype.itemsize
        got = 0
        while got < nbytes:
            want = min(int(chunk_bytes), nbytes - got)
            n = f.readinto(memoryview(buf)[got:got + want])
            if not n:
                raise IOError(
                    f"truncated artifact member {member}: got {got} of "
                    f"{nbytes} bytes")
            got += n
        if fortran:                 # np.savez writes C-order; be tolerant
            out = out.reshape(-1).reshape(shape[::-1]).T
    return out


def iter_artifact_modules(in_dir: str, *, verify: bool = True,
                          chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                          pool: Optional[StagingPool] = None,
                          pacer: Optional[Callable[[], None]] = None
                          ) -> Iterator[tuple]:
    """Stream a FULL artifact module by module: yields
    ``("delta", path, info, {packed, v_row, v_col, use_row})`` then
    ``("extra", path, info, array)``, all host numpy arrays read in
    bounded chunks (peak host RAM is O(largest module), not O(artifact)).
    Per-module sha verification happens here, host-side, so a consumer on
    an ingest thread never hands a corrupt module to the device.

    The manifest-level file-size check still runs first (catches container
    truncation cheaply); the per-chunk check above catches member-level
    truncation the container sizes cannot see.

    ``pacer`` (if given) is called between module streams.  A background
    ingest thread passes a short sleep here so it yields the host between
    modules instead of monopolising cores for the whole read — on hosts
    where ingest and decode dispatch share CPUs, this bounds how much of
    the ingest any single decode step can absorb (serving-SLO pacing)."""
    path = pathlib.Path(in_dir)
    manifest = read_manifest(path)
    if manifest.get("kind", "full") != "full":
        raise ValueError(
            f"{path} holds an incremental update patch (parent version "
            f"{manifest.get('lineage', {}).get('parent_version')}); "
            "materialise it via VariantStore.load")
    if verify:
        for fname, nbytes in manifest.get("files", {}).items():
            actual = (path / fname).stat().st_size \
                if (path / fname).exists() else -1
            if actual != nbytes:
                raise IOError(
                    f"truncated artifact: {fname} is {actual} bytes, "
                    f"manifest records {nbytes}")
    with zipfile.ZipFile(path / "deltas.npz") as zf:
        for p, info in manifest["deltas"].items():
            key = p.replace(".", "__")
            fields = {f: _stream_npz_member(zf, f"{key}__{f}.npy",
                                            chunk_bytes=chunk_bytes,
                                            pool=pool)
                      for f in ("packed", "v_row", "v_col", "use_row")}
            if verify and _sha(fields["packed"]) != info["sha"]:
                raise IOError(f"corrupt mask for {p}")
            yield "delta", p, info, fields
            if pacer is not None:
                pacer()
    with zipfile.ZipFile(path / "extras.npz") as zf:
        for p, info in manifest["extras"].items():
            arr = _stream_npz_member(zf, p.replace(".", "__") + ".npy",
                                     chunk_bytes=chunk_bytes, pool=pool)
            if verify and _sha(arr) != info["sha"]:
                raise IOError(f"corrupt extra for {p}")
            yield "extra", p, info, arr
            if pacer is not None:
                pacer()


def save_artifact(dm: DeltaModel, out_dir: str, *,
                  base_fp: Optional[str] = None,
                  meta: Optional[dict] = None,
                  lineage: Optional[dict] = None) -> dict:
    """Full publish.  ``lineage`` (store v3) records
    {variant, version, parent_version} for VariantStore-managed artifacts;
    standalone artifacts (the v1/v2 call shape) simply omit it."""
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    manifest = {"version": STORE_VERSION, "kind": "full",
                "base_fingerprint": base_fp, "lineage": lineage or {},
                "meta": meta or {}, "deltas": {}, "extras": {}}
    dz, ez = {}, {}
    for path, e in dm.deltas.items():
        key = path.replace(".", "__")
        packed = np.asarray(jax.device_get(e.packed))
        use_row = np.asarray(jax.device_get(e.use_row))
        v_row = np.asarray(jax.device_get(e.v_row)).astype(np.float16)
        v_col = np.asarray(jax.device_get(e.v_col)).astype(np.float16)
        dz[f"{key}__packed"] = packed
        dz[f"{key}__v_row"] = v_row
        dz[f"{key}__v_col"] = v_col
        dz[f"{key}__use_row"] = use_row
        manifest["deltas"][path] = {
            "packed_shape": list(packed.shape),
            "scalar": bool(e.scalar),
            "sha": _sha(packed),
            "axis_counts": {
                "row": int(use_row.sum()),
                "col": int(use_row.size - use_row.sum())},
        }
    for path, v in dm.extras.items():
        key = path.replace(".", "__")
        arr = np.asarray(jax.device_get(v)).astype(np.float16)
        ez[key] = arr
        manifest["extras"][path] = {"shape": list(arr.shape),
                                    "sha": _sha(arr)}
    np.savez(out / "deltas.npz", **dz)
    np.savez(out / "extras.npz", **ez)
    # payload sizes are known once the npz files exist, so artifact_bytes
    # (and per-file sizes, for truncation detection at load) can be
    # PERSISTED in manifest.json rather than only returned to the caller
    manifest["files"] = {f: (out / f).stat().st_size
                         for f in ("deltas.npz", "extras.npz")}
    manifest["artifact_bytes"] = sum(manifest["files"].values())
    _write_manifest(out, manifest)
    return manifest


def load_artifact(in_dir: str, *, expect_base_fp: Optional[str] = None,
                  verify: bool = True,
                  chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                  pacer: Optional[Callable[[], None]] = None) -> DeltaModel:
    """Load a FULL artifact.  Accepts v1 (no size accounting), v2, and v3
    (lineage) manifests; patch artifacts need their parent and load through
    ``VariantStore.load``.

    The payload is STREAMED per module in ``chunk_bytes`` reads with
    per-chunk truncation checks (``iter_artifact_modules``) — the whole
    artifact is never buffered in host RAM before device transfer, so
    peak host footprint is O(largest module)."""
    path = pathlib.Path(in_dir)
    manifest = read_manifest(path)
    if manifest.get("kind", "full") == "full" and expect_base_fp and \
            manifest.get("base_fingerprint") and \
            manifest["base_fingerprint"] != expect_base_fp:
        raise ValueError(
            f"artifact built for base {manifest['base_fingerprint']}, "
            f"got {expect_base_fp}")
    deltas, extras = {}, {}
    for kind, p, info, payload in iter_artifact_modules(
            path, verify=verify, chunk_bytes=chunk_bytes, pacer=pacer):
        if kind == "delta":
            deltas[p] = DeltaEntry(
                packed=jnp.asarray(payload["packed"]),
                v_row=jnp.asarray(payload["v_row"]).astype(jnp.float32),
                v_col=jnp.asarray(payload["v_col"]).astype(jnp.float32),
                use_row=jnp.asarray(payload["use_row"]),
                scalar=info["scalar"])
        else:
            extras[p] = jnp.asarray(payload)
    return DeltaModel(deltas=deltas, extras=extras)


# ---------------------------------------------------------------------------
# incremental update patches (store v3, kind="patch")
# ---------------------------------------------------------------------------

def _wire_entry(e: DeltaEntry) -> dict:
    """One delta entry in the WIRE domain (what a full publish stores):
    uint8 packed planes, fp16 vectors, bool selector."""
    return {"packed": np.asarray(jax.device_get(e.packed), np.uint8),
            "v_row": np.asarray(jax.device_get(e.v_row)).astype(np.float16),
            "v_col": np.asarray(jax.device_get(e.v_col)).astype(np.float16),
            "use_row": np.asarray(jax.device_get(e.use_row), bool)}


def save_update_patch(parent_dm: DeltaModel, new_dm: DeltaModel,
                      out_dir: str, *, base_fp: Optional[str] = None,
                      meta: Optional[dict] = None,
                      lineage: Optional[dict] = None) -> dict:
    """Incremental publish: write ``new_dm`` as a patch against
    ``parent_dm`` (the materialised parent VERSION, i.e. wire-domain
    values).  Per changed module: RLE-encoded XOR of the packed sign
    planes + sparse fp16 vector/selector/extras updates.  Unchanged
    modules cost nothing.  The manifest records the sha of each patched
    module's RESULT so materialisation verifies against the same integrity
    bar as a full publish (and applying to the wrong parent is caught).

    Raises ValueError when the module structure changed (added/removed
    modules, shape or scalar-mode changes) — publish a full version then.
    """
    if set(parent_dm.deltas) != set(new_dm.deltas) or \
            set(parent_dm.extras) != set(new_dm.extras):
        raise ValueError(
            "module structure changed between versions; publish full")
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    manifest = {"version": STORE_VERSION, "kind": "patch",
                "base_fingerprint": base_fp, "lineage": lineage or {},
                "meta": meta or {}, "deltas": {}, "extras": {}}
    pz = {}

    def encode(key: str, field: str, old: np.ndarray, new: np.ndarray
               ) -> bool:
        starts, lens, lits = D.zrle_encode(D.xor_bytes(old, new))
        if starts.size == 0:
            return False
        pz[f"{key}__{field}_starts"] = starts
        pz[f"{key}__{field}_lens"] = lens
        pz[f"{key}__{field}_lits"] = lits
        return True

    for path, ne in new_dm.deltas.items():
        pe = parent_dm.deltas[path]
        if pe.scalar != ne.scalar:
            raise ValueError(
                f"{path}: scalar mode changed between versions; publish full")
        old, new = _wire_entry(pe), _wire_entry(ne)
        key = path.replace(".", "__")
        changed = [f for f in ("packed", "v_row", "v_col", "use_row")
                   if encode(key, f, old[f], new[f])]
        if not changed:
            continue                    # module untouched by this version
        manifest["deltas"][path] = {
            "packed_shape": list(new["packed"].shape),
            "scalar": bool(ne.scalar),
            "sha": _sha(new["packed"]),
            "changed": changed,
            "sizes": {f: int(new[f].nbytes)
                      for f in ("packed", "v_row", "v_col", "use_row")}}
    for path, nv in new_dm.extras.items():
        old = np.asarray(jax.device_get(parent_dm.extras[path])
                         ).astype(np.float16)
        new = np.asarray(jax.device_get(nv)).astype(np.float16)
        key = path.replace(".", "__")
        if not encode(key, "x", old, new):
            continue
        manifest["extras"][path] = {"shape": list(new.shape),
                                    "sha": _sha(new)}
    np.savez(out / "patch.npz", **pz)
    manifest["files"] = {"patch.npz": (out / "patch.npz").stat().st_size}
    manifest["artifact_bytes"] = manifest["files"]["patch.npz"]
    _write_manifest(out, manifest)
    return manifest


def load_update_patch(in_dir: str, *, verify: bool = True
                      ) -> tuple[dict, dict, dict]:
    """Read a patch artifact -> (manifest, delta_patches, extras_patches)
    in the decoded form ``loader.apply_update`` consumes (dense XOR
    buffers, sparse index/value arrays)."""
    path = pathlib.Path(in_dir)
    manifest = read_manifest(path)
    if manifest.get("kind") != "patch":
        raise ValueError(f"{path} is not an update patch")
    if verify:
        for fname, nbytes in manifest.get("files", {}).items():
            actual = (path / fname).stat().st_size \
                if (path / fname).exists() else -1
            if actual != nbytes:
                raise IOError(
                    f"truncated patch: {fname} is {actual} bytes, "
                    f"manifest records {nbytes}")
    pz = np.load(path / "patch.npz")

    def decode(key: str, field: str, nbytes: int) -> np.ndarray:
        if f"{key}__{field}_starts" not in pz:
            return np.zeros(nbytes, np.uint8)      # field untouched
        return D.zrle_decode(pz[f"{key}__{field}_starts"],
                             pz[f"{key}__{field}_lens"],
                             pz[f"{key}__{field}_lits"], nbytes)

    delta_patches, extras_patches = {}, {}
    for p, info in manifest["deltas"].items():
        key = p.replace(".", "__")
        sz = info["sizes"]
        delta_patches[p] = {
            "packed": decode(key, "packed", sz["packed"]),
            "v_row": decode(key, "v_row", sz["v_row"]).view(np.uint16),
            "v_col": decode(key, "v_col", sz["v_col"]).view(np.uint16),
            "use_row": decode(key, "use_row", sz["use_row"]
                              ).view(np.bool_)}
    for p, info in manifest["extras"].items():
        key = p.replace(".", "__")
        nbytes = 2 * int(np.prod(info["shape"]))
        extras_patches[p] = decode(key, "x", nbytes).view(np.uint16)
    return manifest, delta_patches, extras_patches


# ---------------------------------------------------------------------------
# VariantStore: versioned variant library (the publish side of the
# lifecycle control plane; serving/api.Deployment is the serving side)
# ---------------------------------------------------------------------------

class VariantStore:
    """A library of variants, each a lineage of immutable versions.

    Layout::

        root/<name>/versions.json      lineage index + ``latest`` pointer
        root/<name>/v0001/             full publish (manifest v3 + npz)
        root/<name>/v0002/             full OR patch (parent_version=1)

    Version ids are monotonic per variant (rollback moves the pointer, a
    later publish still gets max+1).  Version directories are immutable
    once the index commits, so in-memory materialisation caching is always
    valid and rollback is a constant-time pointer move.  The cache is
    LRU-BOUNDED (``cache_versions``): under the frequent-update workload
    every version would otherwise stay alive forever — the serving side
    already frees stale residents, so the store must not re-leak them."""

    INDEX = "versions.json"

    def __init__(self, root, *, base_fp: Optional[str] = None,
                 cache_versions: int = 4, param_shardings=None):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.base_fp = base_fp
        self.cache_versions = max(1, cache_versions)
        # optional base-weight shardings tree (sharded deployments set it
        # — serving/api.Deployment): chain-walk patches then apply on the
        # derived per-leaf placements (loader.apply_update), so a freshly
        # materialised version starts life sharded instead of being
        # re-laid-out at its first serve
        self.param_shardings = param_shardings
        self._cache: "collections.OrderedDict[tuple, DeltaModel]" = \
            collections.OrderedDict()
        # publish (control thread) and load (admission-pipeline ingest
        # thread) share the materialisation cache + index files: serialise
        # them (reentrant: publish_update loads its parent)
        self._lock = threading.RLock()

    # -- index -------------------------------------------------------------
    def _vdir(self, name: str, version: int) -> pathlib.Path:
        return self.root / name / f"v{version:04d}"

    def _read_index(self, name: str) -> dict:
        p = self.root / name / self.INDEX
        if not p.exists():
            raise KeyError(f"unknown variant {name!r}")
        try:
            return json.loads(p.read_text())
        except json.JSONDecodeError as e:
            raise IOError(f"torn or corrupt index {p}: {e}") from e

    def _write_index(self, name: str, idx: dict) -> None:
        d = self.root / name
        d.mkdir(parents=True, exist_ok=True)
        tmp = d / (self.INDEX + ".tmp")
        tmp.write_text(json.dumps(idx, indent=2))
        os.replace(tmp, d / self.INDEX)     # pointer moves are atomic

    def names(self) -> list:
        return sorted(p.parent.name
                      for p in self.root.glob(f"*/{self.INDEX}"))

    def versions(self, name: str) -> list:
        return sorted(int(v) for v in self._read_index(name)["versions"])

    def latest(self, name: str) -> int:
        return int(self._read_index(name)["latest"])

    def version_info(self, name: str, version: int) -> dict:
        idx = self._read_index(name)
        try:
            return idx["versions"][str(version)]
        except KeyError:
            raise KeyError(f"variant {name!r} has no version {version}")

    def lineage(self, name: str, version: Optional[int] = None) -> list:
        """Version chain [full, ..., version] in patch-apply order."""
        v = self.latest(name) if version is None else version
        chain = []
        while True:
            info = self.version_info(name, v)
            chain.append(v)
            if info["kind"] == "full":
                return list(reversed(chain))
            v = int(info["parent"])

    # -- publish / update / rollback ---------------------------------------
    def _next_version(self, name: str) -> tuple[dict, int]:
        try:
            idx = self._read_index(name)
        except KeyError:
            idx = {"schema": 1, "latest": 0, "versions": {}}
        vers = [int(v) for v in idx["versions"]]
        return idx, max(vers, default=0) + 1

    @staticmethod
    def _check_name(name: str) -> None:
        """Variant names become directory names: restrict to a safe
        charset and forbid path traversal ('.', '..') — '@' is reserved
        for the registry's ``name@vN`` version addressing."""
        ok = bool(name) and name not in (".", "..") and \
            all(c.isalnum() or c in "._-" for c in name)
        if not ok:
            raise ValueError(f"invalid variant name {name!r}")

    def publish(self, name: str, dm: DeltaModel, *,
                meta: Optional[dict] = None) -> int:
        """Full publish: next monotonic version, latest pointer advances.
        Crash-safe ordering: payload npz -> atomic manifest -> atomic
        index; an unfinished version never becomes visible."""
        self._check_name(name)
        with self._lock:
            return self._publish_locked(name, dm, meta=meta)

    def _publish_locked(self, name: str, dm: DeltaModel, *,
                        meta: Optional[dict] = None) -> int:
        idx, v = self._next_version(name)
        manifest = save_artifact(
            dm, self._vdir(name, v), base_fp=self.base_fp, meta=meta,
            lineage={"variant": name, "version": v, "parent_version": None})
        idx["versions"][str(v)] = {
            "kind": "full", "parent": None,
            "dir": self._vdir(name, v).name,
            "artifact_bytes": manifest["artifact_bytes"]}
        idx["latest"] = v
        self._write_index(name, idx)
        return v

    def publish_update(self, name: str, dm: DeltaModel, *,
                       meta: Optional[dict] = None) -> int:
        """Incremental publish: ``dm`` becomes the next version as a patch
        against the CURRENT latest (which must exist — publish full
        first).  Typically moves far fewer bytes than a full publish: the
        version-to-version residual is small (BitDelta's observation), so
        the XOR planes RLE down and the fp16 diffs stay sparse."""
        self._check_name(name)
        with self._lock:
            return self._publish_update_locked(name, dm, meta=meta)

    def _publish_update_locked(self, name: str, dm: DeltaModel, *,
                               meta: Optional[dict] = None) -> int:
        parent_v = self.latest(name)
        parent = self.load(name, parent_v)
        idx, v = self._next_version(name)
        manifest = save_update_patch(
            parent, dm, self._vdir(name, v), base_fp=self.base_fp,
            meta=meta, lineage={"variant": name, "version": v,
                                "parent_version": parent_v})
        idx["versions"][str(v)] = {
            "kind": "patch", "parent": parent_v,
            "dir": self._vdir(name, v).name,
            "artifact_bytes": manifest["artifact_bytes"]}
        idx["latest"] = v
        self._write_index(name, idx)
        return v

    def rollback(self, name: str, to_version: Optional[int] = None) -> int:
        """Move the ``latest`` pointer back — constant time, no artifact
        IO.  Default target: the highest version id below the current
        pointer."""
        with self._lock:
            return self._rollback_locked(name, to_version)

    def _rollback_locked(self, name: str, to_version: Optional[int]) -> int:
        idx = self._read_index(name)
        cur = int(idx["latest"])
        if to_version is None:
            older = [int(v) for v in idx["versions"] if int(v) < cur]
            if not older:
                raise ValueError(
                    f"variant {name!r} has no version below {cur}")
            to_version = max(older)
        if str(to_version) not in idx["versions"]:
            raise KeyError(f"variant {name!r} has no version {to_version}")
        idx["latest"] = int(to_version)
        self._write_index(name, idx)
        return int(to_version)

    # -- materialisation ---------------------------------------------------
    def load(self, name: str, version: Optional[int] = None, *,
             verify: bool = True,
             pacer: Optional[Callable[[], None]] = None) -> DeltaModel:
        """Materialise a version: load the nearest full ancestor, apply
        patches forward (one jitted op per module,
        ``loader.apply_update``).  Results are cached per (name, version)
        — version dirs are immutable, so the cache never goes stale.

        ``pacer`` propagates to the streamed artifact read and runs between
        chain steps (see :func:`iter_artifact_modules`); note the store
        lock is held across the pacing sleeps, so a pacing ingest delays
        concurrent publishes, never corrupts them."""
        with self._lock:
            return self._load_locked(name, version, verify=verify,
                                     pacer=pacer)

    def _load_locked(self, name: str, version: Optional[int], *,
                     verify: bool,
                     pacer: Optional[Callable[[], None]] = None
                     ) -> DeltaModel:
        from repro.core import loader as L
        v = self.latest(name) if version is None else int(version)
        if (name, v) in self._cache:
            self._cache.move_to_end((name, v))
            return self._cache[(name, v)]
        chain = self.lineage(name, v)
        # start at the DEEPEST cached ancestor: with the steady-state
        # cache holding the previous version, an incremental update never
        # re-reads (or re-verifies) the full root artifact from disk
        start = 0
        for i in range(len(chain) - 1, -1, -1):
            if (name, chain[i]) in self._cache:
                start = i
                break
        for step in chain[start:]:
            if (name, step) in self._cache:
                self._cache.move_to_end((name, step))
                continue
            vdir = self._vdir(name, step)
            info = self.version_info(name, step)
            if info["kind"] == "full":
                dm = load_artifact(vdir, expect_base_fp=self.base_fp,
                                   verify=verify, pacer=pacer)
            else:
                manifest, dpatch, epatch = load_update_patch(vdir,
                                                             verify=verify)
                if self.base_fp and manifest.get("base_fingerprint") and \
                        manifest["base_fingerprint"] != self.base_fp:
                    raise ValueError(
                        f"patch built for base "
                        f"{manifest['base_fingerprint']}, got {self.base_fp}")
                dm = L.apply_update(self._cache[(name, int(info["parent"]))],
                                    dpatch, epatch,
                                    param_shardings=self.param_shardings)
                if verify:
                    self._verify_patched(manifest, dm, vdir)
            self._cache[(name, step)] = dm
            if pacer is not None:
                pacer()
        dm = self._cache[(name, v)]
        self._cache.move_to_end((name, v))
        # trim OUTSIDE the chain walk (a parent must never vanish before
        # its patch applies); the bound frees old versions' device arrays
        while len(self._cache) > self.cache_versions:
            self._cache.popitem(last=False)
        return dm

    @staticmethod
    def _verify_patched(manifest: dict, dm: DeltaModel,
                        vdir: pathlib.Path) -> None:
        """Patched modules must hash to the sha of the NEW version the
        publisher recorded — catches corruption AND wrong-parent apply."""
        for p, info in manifest["deltas"].items():
            got = _sha(np.asarray(jax.device_get(dm.deltas[p].packed),
                                  np.uint8))
            if got != info["sha"]:
                raise IOError(f"patched mask mismatch for {p} in {vdir}")
        for p, info in manifest["extras"].items():
            got = _sha(np.asarray(jax.device_get(dm.extras[p])
                                  ).astype(np.float16))
            if got != info["sha"]:
                raise IOError(f"patched extra mismatch for {p} in {vdir}")

    def artifact_bytes(self, name: str, version: int) -> int:
        return int(self.version_info(name, version)["artifact_bytes"])


def save_checkpoint_fp16(params, out_path: str) -> int:
    """Full fp16 checkpoint (the baseline the paper compares load against)."""
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        key = "__".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = np.asarray(jax.device_get(leaf)).astype(np.float16)
    p = pathlib.Path(out_path)
    p.parent.mkdir(parents=True, exist_ok=True)
    np.savez(p, **flat)
    return p.stat().st_size
