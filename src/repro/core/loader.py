"""Hot-swap loader: apply a packed delta onto a resident base model.

The paper's load-time result (§3.2: 0.80 s delta-apply vs 2.08 s full
checkpoint) comes from (i) moving 16× fewer bytes and (ii) ONE transfer
per module.  TPU-native mapping (DESIGN.md §3):

* one ``jax.device_put`` per module, placing the packed mask + fp16
  vectors with the SAME NamedSharding as the base weight's natural layout
  (mask shards along d_out exactly like the weight, so the unpack kernel
  runs fully sharded, no re-layout after the transfer);
* on-device fused reconstruction Ŵ = v⊙unpack(B) + W_b via the Pallas
  ``unpack_apply`` kernel (vmapped over stacked layer/expert dims);
* the base stays resident — swapping variants never reloads it.

Two serving-path entry points, one per residency mode (DESIGN.md §6):

* ``apply_artifact`` — swap-then-dense: materialise a full Ŵ copy per
  variant (fast steady-state, max_resident bounded by HBM);
* ``device_put_overlay`` — on-the-fly: transfer the packed delta as a
  ``models/delta_overlay`` tree and let forward fuse it into each GEMM
  (≈1/16 the resident bytes, no dense reconstruction ever).

Both return transfer/compute byte accounting for benchmarks.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.calibration import (DeltaModel, flatten_params,
                                    unflatten_like)


def _reconstruct_entry(entry, w_base: jax.Array, use_kernel: bool,
                       waxes=None):
    """Dense Ŵ from one (possibly stacked) entry.

    Unstacked (2-D) entries pass ``waxes`` through to the kernel wrapper,
    so inside a mesh context the reconstruction lowers as a shard_map'd
    per-tile unpack (each device rebuilds its own Ŵ shard —
    kernels/dispatch.py).  STACKED entries vmap over the lead dims, and
    vmap-of-shard_map is not a supported composition, so they pin the
    global kernel (GSPMD partitions it exactly as before).

    ``w_base`` may be a QuantWeight (int8 base): the kernel path
    dequantizes per tile and the dense Ŵ lands in the SCALE dtype (this
    is the dense-residency mode — already off the fused hot path)."""
    if use_kernel and not entry.scalar:
        from repro.kernels import dispatch as D
        from repro.kernels import ops as K

        def one(packed, vr, vc, ur, wb, waxes=None):
            odt = (wb.scale.dtype if getattr(wb, "__quant_leaf__", False)
                   else wb.dtype)
            w_r = K.unpack_apply(packed, vr, wb, mode="row",
                                 out_dtype=jnp.float32, waxes=waxes)
            w_c = K.unpack_apply(packed, vc, wb, mode="col",
                                 out_dtype=jnp.float32, waxes=waxes)
            return jnp.where(ur, w_r, w_c).astype(odt)

        if w_base.ndim == 2:
            return one(entry.packed, entry.v_row.astype(jnp.float32),
                       entry.v_col.astype(jnp.float32), entry.use_row,
                       w_base, waxes=waxes)
        fn = one
        for _ in range(w_base.ndim - 2):
            fn = jax.vmap(fn)
        with D.no_dispatch():
            return fn(entry.packed, entry.v_row.astype(jnp.float32),
                      entry.v_col.astype(jnp.float32), entry.use_row, w_base)
    if getattr(w_base, "__quant_leaf__", False):
        from repro.core.quantize import dequantize
        w_base = dequantize(w_base, w_base.scale.dtype)
    return entry.reconstruct(w_base)


def apply_artifact(base_params, dm: DeltaModel, *,
                   param_shardings=None, param_axes=None,
                   use_kernel: bool = True):
    """Materialise fine-tuned params on device.

    param_shardings: optional tree matching base_params — packed buffers
    are device_put with the matching sharding BEFORE the fused unpack, so
    the kernel runs sharded (one transfer per module, paper-faithful).
    param_axes: optional logical-axes tree (models.param.split) — threads
    each weight's axes into the unpack kernel so that, inside a mesh
    context, unstacked reconstructions lower per-shard under shard_map
    (kernels/dispatch.py).  Returns (params, stats).
    """
    base_flat = flatten_params(base_params)
    shard_flat = (flatten_params(param_shardings)
                  if param_shardings is not None else None)
    axes_flat = None
    if param_axes is not None:
        from repro.models.delta_overlay import flatten_axes
        axes_flat = flatten_axes(param_axes)
    t0 = time.perf_counter()
    transferred = 0
    out = {}
    for path, wb in base_flat.items():
        if path in dm.deltas:
            e = dm.deltas[path]
            if shard_flat is not None:
                # single transfer per module: packed mask placed directly
                # onto the weight's sharding (mask shards like the weight's
                # leading dims; vectors are tiny -> replicated)
                mask_sh = _mask_sharding(shard_flat[path], e.packed.ndim)
                e = type(e)(packed=jax.device_put(e.packed, mask_sh),
                            v_row=e.v_row, v_col=e.v_col,
                            use_row=e.use_row, scalar=e.scalar)
            transferred += e.packed.size + 2 * (e.v_row.size + e.v_col.size)
            out[path] = _reconstruct_entry(
                e, wb, use_kernel,
                waxes=axes_flat.get(path) if axes_flat else None)
        elif path in dm.extras:
            v = dm.extras[path].astype(wb.dtype)
            if shard_flat is not None:
                v = jax.device_put(v, shard_flat[path])
            transferred += 2 * v.size
            out[path] = v
        else:
            out[path] = wb
    params = unflatten_like(base_params, out)
    jax.block_until_ready(jax.tree.leaves(params)[0])
    stats = {"seconds": time.perf_counter() - t0,
             "transferred_bytes": int(transferred)}
    return params, stats


def stage_overlay_transfer(dm: DeltaModel, *, param_shardings=None
                           ) -> tuple[DeltaModel, list]:
    """Begin ASYNC per-module device transfers of a (host- or device-
    resident) DeltaModel: every leaf is ``jax.device_put`` without a
    fence, so the H2D copy of module k+1 overlaps whatever the serving
    thread is executing (a decode step, the scatter of module k).

    Returns ``(dm_on_device, futures)`` where ``futures`` is a list of
    ``(module_path, leaves)`` in transfer order — await one module with
    ``jax.block_until_ready(leaves)`` (or all of them via
    ``wait_transfers``).  With ``param_shardings`` (the shadowed BASE
    weights' shardings) each delta entry lands on its derived placement
    (``delta_overlay.entry_shardings_from_weight`` — same derivation the
    synchronous paths use), so a mesh admission scatter consumes
    shard-local operands.

    This is the staging half of the async admission pipeline
    (serving/admission.py): an ingest thread calls it after the chunked
    store read + patch chain + sha verification, and hands the returned
    DeltaModel to the serving thread, whose only remaining work is the
    donated bank scatter."""
    from repro.models.delta_overlay import entry_shardings_from_weight
    shard_flat = (flatten_params(param_shardings)
                  if param_shardings is not None else None)
    deltas, extras, futures = {}, {}, []
    for path, e in dm.deltas.items():
        ent_sh = None
        if shard_flat is not None and path in shard_flat and not e.scalar:
            ent_sh = entry_shardings_from_weight(shard_flat[path],
                                                 e.packed.ndim)
        if ent_sh is None:
            leaves = [jax.device_put(e.packed), jax.device_put(e.v_row),
                      jax.device_put(e.v_col), jax.device_put(e.use_row)]
        else:
            leaves = [jax.device_put(e.packed, ent_sh.packed),
                      jax.device_put(e.v_row, ent_sh.v_row),
                      jax.device_put(e.v_col, ent_sh.v_col),
                      jax.device_put(e.use_row)]
        deltas[path] = type(e)(packed=leaves[0], v_row=leaves[1],
                               v_col=leaves[2], use_row=leaves[3],
                               scalar=e.scalar)
        futures.append((path, leaves))
    for path, v in dm.extras.items():
        arr = (jax.device_put(v, shard_flat[path])
               if shard_flat is not None and path in shard_flat
               else jax.device_put(v))
        extras[path] = arr
        futures.append((path, [arr]))
    return DeltaModel(deltas=deltas, extras=extras), futures


def wait_transfers(futures: list) -> None:
    """Fence a ``stage_overlay_transfer`` future list (all modules)."""
    for _, leaves in futures:
        jax.block_until_ready(leaves)


def device_put_overlay(base_params, dm: DeltaModel, *,
                       param_shardings=None, vec_dtype=jnp.float16,
                       extras_dtype=jnp.float16, block: bool = True):
    """On-the-fly serving entry point: place a variant on device as a
    packed :mod:`repro.models.delta_overlay` tree — NO dense reconstruction.

    Transfers, per module, the packed mask (device_put with the base
    weight's mask sharding) plus the fp16 axis vectors; extras (norms,
    embeddings — uncompressed fine-tuned leaves) are swapped into a params
    VIEW that aliases every unchanged base weight, so resident HBM cost is
    overlay bytes + extras bytes (~1/16 of a dense fp16 copy when the
    linear stacks dominate).

    Returns (params_view, overlay, stats).  ``params_view`` pairs with
    ``overlay`` as the (base_params, overlay) arguments of model
    forward/prefill/decode_step.  ``block=False`` skips the final device
    fence: the transfers stay in flight as ordinary jax futures and the
    first consumer (or ``jax.block_until_ready``) awaits them — the
    staged admission path uses this so transfers overlap decode steps.
    """
    from repro.models.delta_overlay import from_delta_entry, insert_entry

    base_flat = flatten_params(base_params)
    shard_flat = (flatten_params(param_shardings)
                  if param_shardings is not None else None)
    t0 = time.perf_counter()
    transferred = 0
    overlay_tree: dict = {}
    out = {}
    for path, wb in base_flat.items():
        if path in dm.deltas:
            e = from_delta_entry(dm.deltas[path], vec_dtype=vec_dtype)
            packed, v_row, v_col = e.packed, e.v_row, e.v_col
            if shard_flat is not None:
                # EVERY overlay leaf lands on its derived sharding: the
                # mask like the weight (packed byte dim replicated), each
                # axis vector on the single weight axis it scales — so the
                # fused delta GEMM reads shard-local overlay tiles and
                # decode needs no overlay re-layout (DESIGN.md §11)
                mask_sh = _mask_sharding(shard_flat[path], packed.ndim)
                row_sh, col_sh = _vec_shardings(shard_flat[path],
                                                packed.ndim)
                packed = jax.device_put(packed, mask_sh)
                v_row = jax.device_put(v_row, row_sh) if row_sh is not None \
                    else jax.device_put(v_row)
                v_col = jax.device_put(v_col, col_sh) if col_sh is not None \
                    else jax.device_put(v_col)
            else:
                v_row = jax.device_put(v_row)
                v_col = jax.device_put(v_col)
            e = type(e)(packed=packed, v_row=v_row, v_col=v_col)
            transferred += e.nbytes()
            insert_entry(overlay_tree, path, e)
            out[path] = wb                      # base weight, shared
        elif path in dm.extras:
            v = dm.extras[path].astype(extras_dtype)
            if shard_flat is not None:
                v = jax.device_put(v, shard_flat[path])
            transferred += v.size * v.dtype.itemsize
            out[path] = v
        else:
            out[path] = wb
    params_view = unflatten_like(base_params, out)
    if block:
        leaves = jax.tree.leaves(overlay_tree) or jax.tree.leaves(
            params_view)
        jax.block_until_ready(leaves[0])
    stats = {"seconds": time.perf_counter() - t0,
             "transferred_bytes": int(transferred)}
    return params_view, overlay_tree, stats


def fused_resident_bytes(base_params, params_view, overlay) -> int:
    """HBM bytes a fused-resident variant actually adds on top of the
    always-resident base: overlay buffers + extras leaves that are not
    aliases of base arrays."""
    base_ids = {id(leaf) for leaf in jax.tree.leaves(base_params)}
    extra = sum(leaf.size * leaf.dtype.itemsize
                for leaf in jax.tree.leaves(params_view)
                if id(leaf) not in base_ids)
    from repro.models.delta_overlay import overlay_nbytes
    return overlay_nbytes(overlay) + extra


def _mask_sharding(weight_sharding, mask_ndim: int):
    """Packed mask shards like the weight on all dims except the packed
    last dim (d_in/8; replicated — 8x smaller).  Thin delegate over the
    ONE shared spec-surgery derivation in ``models/delta_overlay.
    entry_shardings_from_weight``."""
    from repro.models.delta_overlay import entry_shardings_from_weight
    ent = entry_shardings_from_weight(weight_sharding, mask_ndim)
    return weight_sharding if ent is None else ent.packed


def _vec_shardings(weight_sharding, w_ndim: int):
    """(v_row, v_col) shardings from the weight's — each axis vector keeps
    the spec entries of the weight dims it is a copy of.  Same shared
    derivation (``delta_overlay.entry_shardings_from_weight``) the update
    path uses, matching the logical derivation in ``entry_axes``
    (tests/test_sharded_serving.py asserts the equivalence); (None, None)
    when the sharding carries no inspectable spec."""
    from repro.models.delta_overlay import entry_shardings_from_weight
    ent = entry_shardings_from_weight(weight_sharding, w_ndim)
    return (None, None) if ent is None else (ent.v_row, ent.v_col)


# ---------------------------------------------------------------------------
# incremental version updates (store v3 patch artifacts)
# ---------------------------------------------------------------------------

def _xor16(v, xr):
    """XOR a (possibly fp32-held) fp16 wire buffer with uint16 XOR bits —
    exact at the bit level, so a patched vector is bit-identical to the
    new version's full publish."""
    bits = jax.lax.bitcast_convert_type(v.astype(jnp.float16), jnp.uint16)
    out = jax.lax.bitcast_convert_type(bits ^ xr.reshape(v.shape),
                                       jnp.float16)
    return out.astype(v.dtype)


@jax.jit
def _patch_entry(packed, v_row, v_col, use_row, pk_xor, vr_xor, vc_xor,
                 ur_xor):
    """Apply one module's update patch in a single compiled op: XOR the
    packed sign plane (flipped sign bits), the fp16 axis vectors, and the
    axis-selector flags with their decoded XOR buffers."""
    return (packed ^ pk_xor.reshape(packed.shape),
            _xor16(v_row, vr_xor),
            _xor16(v_col, vc_xor),
            use_row ^ ur_xor.reshape(use_row.shape))


@jax.jit
def _patch_extra(arr, xr):
    return _xor16(arr, xr).astype(jnp.float16)


def apply_update(dm: DeltaModel, delta_patches: dict, extras_patches: dict,
                 *, param_shardings=None) -> DeltaModel:
    """Materialise the NEXT version of a variant from its parent plus a
    decoded update patch — one jitted op per module, no disk round-trip
    through a full artifact.

    ``delta_patches``: path -> dict(packed, v_row, v_col, use_row) dense
    XOR buffers (store-side zero-run decoding already done): uint8 for the
    packed planes, uint16 for the fp16 vectors' bit patterns, bool for the
    selector.  ``extras_patches``: path -> uint16 XOR buffer.  Untouched
    modules are shared with the parent DeltaModel (no copy).

    Sharded parents stay sharded: each XOR buffer is placed onto its
    parent leaf's sharding before the jitted patch, so the update applies
    shard-local (no replicated wire operand, outputs inherit the parent
    placement — DESIGN.md §11).  With ``param_shardings`` (a tree or flat
    map of the shadowed BASE weights' shardings) host-resident parents are
    additionally lifted onto the placements derived by the shared
    spec-surgery helper ``delta_overlay.entry_shardings_from_weight`` —
    the same derivation ``device_put_overlay`` transfers with — so a
    patched variant starts life sharded instead of being re-laid-out at
    its first serve."""
    from repro.models.delta_overlay import entry_shardings_from_weight
    shard_flat = (flatten_params(param_shardings)
                  if param_shardings is not None else None)
    deltas = dict(dm.deltas)
    extras = dict(dm.extras)
    for path, p in delta_patches.items():
        e = deltas[path]
        if shard_flat is not None and path in shard_flat and not e.scalar:
            ent_sh = entry_shardings_from_weight(shard_flat[path],
                                                 e.packed.ndim)
            if ent_sh is not None:
                e = type(e)(packed=jax.device_put(e.packed, ent_sh.packed),
                            v_row=jax.device_put(e.v_row, ent_sh.v_row),
                            v_col=jax.device_put(e.v_col, ent_sh.v_col),
                            use_row=e.use_row, scalar=e.scalar)
        packed, v_row, v_col, use_row = _patch_entry(
            e.packed, e.v_row, e.v_col, e.use_row,
            _wire(p["packed"], e.packed), _wire(p["v_row"], e.v_row),
            _wire(p["v_col"], e.v_col), _wire(p["use_row"], e.use_row))
        deltas[path] = type(e)(packed=packed, v_row=v_row, v_col=v_col,
                               use_row=use_row, scalar=e.scalar)
    for path, xr in extras_patches.items():
        like = extras[path]
        if shard_flat is not None and path in shard_flat:
            like = jax.device_put(like, shard_flat[path])
        extras[path] = _patch_extra(like, _wire(xr, like))
    return DeltaModel(deltas=deltas, extras=extras)


def _wire(buf, like) -> jax.Array:
    """Decoded XOR buffer -> device, shaped and placed like the parent
    leaf (sharding only transfers when the parent carries a NamedSharding;
    shapes always match, dtypes intentionally don't)."""
    arr = jnp.asarray(buf).reshape(like.shape)
    sh = getattr(like, "sharding", None)
    if isinstance(sh, jax.sharding.NamedSharding):
        arr = jax.device_put(arr, sh)
    return arr


def load_full_checkpoint(npz_path: str, template_params):
    """Baseline loader: read a full fp16 checkpoint from disk into the
    template's structure (the paper's 2.08 s comparison path)."""
    import numpy as np
    t0 = time.perf_counter()
    data = np.load(npz_path)
    flat = {}
    for path, leaf in flatten_params(template_params).items():
        arr = data[path.replace(".", "__")]
        flat[path] = jnp.asarray(arr).astype(leaf.dtype)
    params = unflatten_like(template_params, flat)
    jax.block_until_ready(jax.tree.leaves(params)[0])
    return params, {"seconds": time.perf_counter() - t0,
                    "transferred_bytes": int(sum(
                        2 * l.size for l in jax.tree.leaves(params)))}
