"""Symmetric per-channel int8 quantization of shadowed base weights.

The second base-weight dtype (DESIGN.md §16): every target matrix the
1-bit delta machinery shadows can be held resident as int8 + one fp16
scale per output channel instead of full precision, halving (or better)
resident base HBM per device.  The fused Pallas kernels dequantize the
int8 base tile and apply the unpacked ±1 sign plane × v_row⊕v_col delta
in the SAME tile pass — the dense fp Ŵ (and the dense fp base) is never
written to HBM.

Scale layout: symmetric per-OUTPUT-channel.  For a weight stack
``W[..., d_out, d_in]``::

    scale[..., n] = max_k |W[..., n, k]| / 127          (fp16)
    q[..., n, k]  = clip(round(W[..., n, k] / scale), -127, 127)  (int8)

Per-output-channel (not per-input-channel) so that the no-overlay plain
path factors EXACTLY without materialising a dense dequant::

    x @ W.T  ==  (x @ q.T) * scale

and so the kernel's in-tile dequant broadcast is a cheap (bn, 1) column
read per (bn, bk) weight tile.

``QuantWeight`` is a registered pytree that duck-types ``.shape`` /
``.ndim`` / ``.dtype`` after its int8 payload, so shape-level consumers
(``calibration.is_target``, overlay struct builders) treat it like the
array it replaces.  Tree flattening treats it as a LEAF via the
``__quant_leaf__`` marker (``calibration.flatten_params`` checks the
attribute, not the class — no import cycle).

The same threading (one extra per-channel operand through kernels,
dispatch, loader, registry) is what unlocks an fp8 base later: only
``quantize_weight`` and the in-tile ``astype`` change.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

# floor keeps all-zero channels from dividing by zero; any q on such a
# channel is 0 anyway so the floor value never reaches the output
_SCALE_FLOOR = 1e-8


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class QuantWeight:
    """One quantized base weight (stack): int8 payload + fp16 per-output-
    channel scales.  A pytree of two leaves; flattened as ONE leaf by the
    params flatteners (``__quant_leaf__``)."""
    q: jax.Array                 # (..., d_out, d_in) int8
    scale: jax.Array             # (..., d_out) fp16

    __quant_leaf__ = True

    # duck-type the array the QuantWeight replaces: shape-level consumers
    # (is_target, overlay_struct, entry ndim checks) read these three
    @property
    def shape(self):
        return self.q.shape

    @property
    def ndim(self):
        return self.q.ndim

    @property
    def dtype(self):
        return self.q.dtype

    def nbytes(self) -> int:
        return (self.q.size * self.q.dtype.itemsize
                + self.scale.size * self.scale.dtype.itemsize)


def is_quant(x) -> bool:
    """True for QuantWeight instances (marker-based, matches the duck
    check used by ``calibration.flatten_params``)."""
    return isinstance(x, QuantWeight)


def quantize_weight(w: jax.Array) -> QuantWeight:
    """Symmetric per-output-channel int8 quantization of one weight
    (stack).  Scales calibrate from the weight itself (abs-max)."""
    w32 = jnp.asarray(w).astype(jnp.float32)
    s = jnp.maximum(jnp.max(jnp.abs(w32), axis=-1) / 127.0, _SCALE_FLOOR)
    q = jnp.clip(jnp.round(w32 / s[..., None]), -127, 127).astype(jnp.int8)
    return QuantWeight(q=q, scale=s.astype(jnp.float16))


def dequantize(qw: QuantWeight, dtype=jnp.float32) -> jax.Array:
    """Dense dequant — OFF the serving hot path (used by the dense
    residency mode, ref oracles and round-trip tests only)."""
    return (qw.q.astype(jnp.float32)
            * qw.scale.astype(jnp.float32)[..., None]).astype(dtype)


def quant_sharding(weight_sharding, w_ndim: int):
    """QuantWeight-of-NamedSharding for one quantized leaf by spec
    surgery on the fp weight's resolved sharding: the int8 payload keeps
    the weight's placement verbatim, the scale vector keeps the spec
    entries of the dims it copies ((lead..., d_out)) — the same surgery
    ``delta_overlay.entry_shardings_from_weight`` applies to v_row.
    Returns the input unchanged when it carries no inspectable spec
    (single-device placements)."""
    try:
        from jax.sharding import NamedSharding, PartitionSpec
        spec = list(weight_sharding.spec) + [None] * w_ndim
        spec = spec[:w_ndim]
        return QuantWeight(
            q=weight_sharding,
            scale=NamedSharding(weight_sharding.mesh,
                                PartitionSpec(*spec[:-1])))
    except Exception:
        return weight_sharding


def quantize_base(params, param_shardings=None):
    """Quantize every shadowed target weight of a base params tree.

    Returns ``(qparams, qshardings, stats)``: the params tree with
    target leaves replaced by :class:`QuantWeight` (non-targets
    untouched — embeddings, norms, convs stay full precision), the
    matching shardings tree with target leaves upgraded via
    :func:`quant_sharding` (None in, None out), and a byte accounting
    dict (``fp_bytes`` / ``int8_bytes`` / ``ratio`` over targets)."""
    from repro.core.calibration import (flatten_params, is_target,
                                        unflatten_like)
    flat = flatten_params(params)
    targets = {p for p, l in flat.items() if is_target(p, l)}
    fp_bytes = q_bytes = 0
    out = {}
    for path, leaf in flat.items():
        if path in targets:
            qw = quantize_weight(leaf)
            fp_bytes += leaf.size * leaf.dtype.itemsize
            q_bytes += qw.nbytes()
            out[path] = qw
        else:
            out[path] = leaf
    qparams = unflatten_like(params, out)
    qsh = None
    if param_shardings is not None:
        sflat = flatten_params(param_shardings)
        for path in targets:
            sflat[path] = quant_sharding(sflat[path], flat[path].ndim)
        qsh = unflatten_like(param_shardings, sflat)
    stats = {"targets": len(targets), "fp_bytes": int(fp_bytes),
             "int8_bytes": int(q_bytes),
             "ratio": q_bytes / max(fp_bytes, 1)}
    return qparams, qsh, stats


def quantize_struct(flat_shapes: dict, paths) -> dict:
    """Abstract twin of :func:`quantize_base` over a flat {path ->
    array | ShapeDtypeStruct} view: target leaves become QuantWeight-of-
    ShapeDtypeStruct (dry-run serving cells, AOT in_shardings)."""
    out = dict(flat_shapes)
    for p in paths:
        w = flat_shapes[p]
        out[p] = QuantWeight(
            q=jax.ShapeDtypeStruct(tuple(w.shape), jnp.int8),
            scale=jax.ShapeDtypeStruct(tuple(w.shape[:-1]), jnp.float16))
    return out
