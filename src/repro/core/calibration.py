"""Calibration pipeline: the paper's Alg. 1–7 in functional JAX.

Stages (paper §2 "Calibration cache, training, and stacking"):
  0. compress: B = sign(W_f − W_b) packed; v0 = mean(|ΔW|, axis); both row
     and col variants instantiated per target matrix.
  1. per-layer activation matching (Alg. 3/4): caches of (X, Y) pairs —
     X from the student stack (already-compressed layers below), Y from
     the teacher — fit v by MSE with AdamW, 5 epochs.
  2. axis selection (Alg. 6): row vs col by held-out MSE, per matrix.
  3. end-to-end logit matching (Alg. 2): jointly train all selected
     vectors so the stacked student reproduces teacher logits.

Targets: every linear projection in attention and MLP/expert blocks
(TARGET_KEYS), matching the paper's "all linear projections in attention
and MLP blocks".  Norms / biases / embeddings / convs are carried as
uncompressed fine-tuned extras (paper §4).

Stacked (scan) weights: masks/vectors carry the leading layer/expert dims;
each stacked matrix gets its own axis choice, mirroring the paper's
per-module selection.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import delta as D
from repro.optim.adamw import adamw_init, adamw_update

TARGET_KEYS = {"wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
               "w_in", "w_out", "w_ff1", "w_ff2", "w_zi", "w_if",
               "w_z", "w_xc", "w_bc", "w_dt",  # zamba split projections
               "router"}
# router excluded per paper (not an attention/MLP projection); kept here
# commented-out of the set on purpose:
TARGET_KEYS.discard("router")


# ---------------------------------------------------------------------------
# path utilities
# ---------------------------------------------------------------------------

def _quant_leaf(x) -> bool:
    """Default is_leaf: a quantized base weight (``core/quantize.py``
    QuantWeight) is ONE leaf of the params tree, not its (q, scale)
    sub-leaves — duck-typed on the marker attribute so this module never
    imports quantize (which imports back here)."""
    return bool(getattr(x, "__quant_leaf__", False))


def flatten_params(params, is_leaf=None) -> dict:
    """{dot-path -> leaf}; THE path scheme every flat view shares
    (delta/extras keys, overlay insertion, axes trees)."""
    flat = {}
    pairs = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=is_leaf or _quant_leaf)[0]
    for path, leaf in pairs:
        key = ".".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def unflatten_like(template, flat: dict):
    leaves_with_path = jax.tree_util.tree_flatten_with_path(
        template, is_leaf=_quant_leaf)
    paths = [".".join(str(getattr(p, "key", getattr(p, "idx", p)))
                      for p in path)
             for path, _ in leaves_with_path[0]]
    leaves = [flat[k] for k in paths]
    return jax.tree_util.tree_unflatten(leaves_with_path[1], leaves)


def is_target(path: str, arr) -> bool:
    last = path.split(".")[-1]
    return (last in TARGET_KEYS and arr.ndim >= 2
            and arr.shape[-1] % 8 == 0 and "conv" not in path)


# ---------------------------------------------------------------------------
# delta model
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DeltaEntry:
    """One target matrix stack: packed sign mask + both axis variants."""
    packed: jax.Array            # (..., dout, din//8) uint8
    v_row: jax.Array             # (..., dout) fp32 while training
    v_col: jax.Array             # (..., din)
    use_row: jax.Array           # (...,) bool — per stacked matrix
    scalar: bool = dataclasses.field(metadata=dict(static=True),
                                     default=False)

    def reconstruct(self, w_base: jax.Array, dtype=None) -> jax.Array:
        dtype = dtype or w_base.dtype
        signs = D.unpack_signs(self.packed, w_base.shape[-1], jnp.float32)
        if self.scalar:
            dv = self.v_row[..., None, None].astype(jnp.float32) * signs
        else:
            dr = self.v_row[..., :, None].astype(jnp.float32) * signs
            dc = self.v_col[..., None, :].astype(jnp.float32) * signs
            sel = self.use_row[..., None, None]
            dv = jnp.where(sel, dr, dc)
        return (w_base.astype(jnp.float32) + dv).astype(dtype)

    def artifact_bytes(self) -> int:
        """On-disk bytes: packed mask + the SELECTED fp16 vector per matrix
        + 1 selector bit per matrix (scalar mode: 2 bytes per matrix)."""
        mask = self.packed.size
        if self.scalar:
            return mask + 2 * int(self.v_row.size)
        n_mats = max(int(self.use_row.size), 1)
        d_out = self.v_row.shape[-1]
        d_in = self.v_col.shape[-1]
        n_row = int(jnp.sum(self.use_row))
        vec = 2 * (n_row * d_out + (n_mats - n_row) * d_in)
        return mask + vec + (n_mats + 7) // 8


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DeltaModel:
    deltas: dict                 # path -> DeltaEntry
    extras: dict                 # path -> fine-tuned value (uncompressed)

    def scale_params(self) -> dict:
        """The trainable pytree (v_row/v_col per target)."""
        return {k: {"v_row": e.v_row, "v_col": e.v_col}
                for k, e in self.deltas.items()}

    def with_scales(self, scales: dict) -> "DeltaModel":
        new = {k: dataclasses.replace(e, v_row=scales[k]["v_row"],
                                      v_col=scales[k]["v_col"])
               for k, e in self.deltas.items()}
        return DeltaModel(deltas=new, extras=self.extras)


def compress(base_params, ft_params, scalar: bool = False) -> DeltaModel:
    """Stage 0: masks + init scales for every target; ft extras for the
    rest (embeddings, norms, convs — paper §4 keeps them unpatched but the
    artifact must carry the fine-tuned values)."""
    base_flat = flatten_params(base_params)
    ft_flat = flatten_params(ft_params)
    deltas, extras = {}, {}
    for path, wb in base_flat.items():
        wf = ft_flat[path]
        if is_target(path, wb):
            dw = (wf - wb).astype(jnp.float32)
            packed = D.pack_signs(D.sign_mask(dw))
            if scalar:
                v0 = D.init_scale(dw, "scalar")
                deltas[path] = DeltaEntry(packed=packed, v_row=v0,
                                          v_col=v0, use_row=jnp.ones(
                                              dw.shape[:-2], bool),
                                          scalar=True)
            else:
                deltas[path] = DeltaEntry(
                    packed=packed,
                    v_row=D.init_scale(dw, "row"),
                    v_col=D.init_scale(dw, "col"),
                    use_row=jnp.ones(dw.shape[:-2], bool))
        else:
            extras[path] = wf
    return DeltaModel(deltas=deltas, extras=extras)


def apply_delta(base_params, dm: DeltaModel):
    """Materialise the student parameters (differentiable w.r.t. scales)."""
    base_flat = flatten_params(base_params)
    out = {}
    for path, wb in base_flat.items():
        if path in dm.deltas:
            out[path] = dm.deltas[path].reconstruct(wb)
        else:
            out[path] = dm.extras.get(path, wb)
    return unflatten_like(base_params, out)


def artifact_nbytes(dm: DeltaModel) -> int:
    total = sum(e.artifact_bytes() for e in dm.deltas.values())
    total += sum(2 * int(v.size) for v in dm.extras.values())  # fp16 extras
    return total


def fp16_checkpoint_nbytes(params) -> int:
    return sum(2 * int(x.size) for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Stage 1/2: per-layer activation matching + axis selection (Alg. 3/4/6)
# ---------------------------------------------------------------------------

def _fit_scale(packed, w_base, x, y, v0, mode, *, epochs: int = 5,
               lr: float = 1e-4, batch: int = 1024, val_frac: float = 0.2):
    """Fit one matrix's scale vector by output MSE; returns (v, val_mse).

    x: (N, din), y: (N, dout) — the calibration cache for this layer.
    """
    n = x.shape[0]
    n_val = max(1, int(n * val_frac))
    x_tr, y_tr = x[:-n_val], y[:-n_val]
    x_val, y_val = x[-n_val:], y[-n_val:]
    n_tr = x_tr.shape[0]
    bs = min(batch, n_tr)
    steps_per_epoch = max(1, n_tr // bs)
    total_steps = epochs * steps_per_epoch

    def mse(v, xb, yb):
        pred = D.delta_matmul(xb.astype(jnp.float32), packed,
                              v, w_base, mode)
        return jnp.mean((pred - yb.astype(jnp.float32)) ** 2)

    opt = adamw_init({"v": v0})

    def step(carry, i):
        v, opt_state = carry
        start = (i * bs) % max(n_tr - bs + 1, 1)
        xb = jax.lax.dynamic_slice_in_dim(x_tr, start, bs)
        yb = jax.lax.dynamic_slice_in_dim(y_tr, start, bs)
        loss, g = jax.value_and_grad(lambda vv: mse(vv["v"], xb, yb))(
            {"v": v})
        new, opt_state, _ = adamw_update({"v": v}, g, opt_state, lr=lr,
                                         weight_decay=0.0,
                                         grad_clip_norm=1e9)
        return (new["v"], opt_state), loss

    (v_fit, _), _ = jax.lax.scan(step, (v0.astype(jnp.float32), opt),
                                 jnp.arange(total_steps))
    return v_fit, mse(v_fit, x_val, y_val)


_fit_scale_jit = jax.jit(_fit_scale, static_argnames=("mode", "epochs",
                                                      "lr", "batch",
                                                      "val_frac"))


def fit_layer(entry: DeltaEntry, w_base_l, x, y, layer_idx=None, *,
              epochs: int = 5, lr: float = 1e-4):
    """Alg. 6 for one matrix: fit row and col variants, select by val MSE.

    entry fields may be stacked; ``layer_idx`` selects the matrix.
    Returns (v_row, v_col, use_row, val_mses).
    """
    packed = entry.packed if layer_idx is None else entry.packed[layer_idx]
    v_r0 = entry.v_row if layer_idx is None else entry.v_row[layer_idx]
    v_c0 = entry.v_col if layer_idx is None else entry.v_col[layer_idx]
    v_r, mse_r = _fit_scale_jit(packed, w_base_l, x, y, v_r0, "row",
                                epochs=epochs, lr=lr)
    v_c, mse_c = _fit_scale_jit(packed, w_base_l, x, y, v_c0, "col",
                                epochs=epochs, lr=lr)
    return v_r, v_c, mse_r <= mse_c, (float(mse_r), float(mse_c))


# ---------------------------------------------------------------------------
# Stage 3: end-to-end logit matching (Alg. 2)
# ---------------------------------------------------------------------------

def e2e_calibrate(forward_fn: Callable, base_params, dm: DeltaModel,
                  teacher_logits: list, batches: list, *,
                  epochs: int = 5, lr: float = 1e-4) -> DeltaModel:
    """Jointly train all scale vectors to match teacher logits.

    forward_fn(params, batch) -> logits.  teacher_logits[i] pre-computed
    (the paper caches them — Alg. 5).
    """
    scales = dm.scale_params()
    opt = adamw_init(scales)

    @jax.jit
    def update(scales, opt_state, batch, tl):
        def loss_fn(s):
            student = apply_delta(base_params, dm.with_scales(s))
            logits = forward_fn(student, batch)
            return jnp.mean((logits.astype(jnp.float32)
                             - tl.astype(jnp.float32)) ** 2)
        loss, g = jax.value_and_grad(loss_fn)(scales)
        new, opt_state, _ = adamw_update(scales, g, opt_state, lr=lr,
                                         weight_decay=0.0,
                                         grad_clip_norm=1e9)
        return new, opt_state, loss

    losses = []
    for _ in range(epochs):
        for batch, tl in zip(batches, teacher_logits):
            scales, opt, loss = update(scales, opt, batch, tl)
            losses.append(float(loss))
    return dm.with_scales(scales), losses


# ---------------------------------------------------------------------------
# full pipeline for the transformer family (uses IO capture)
# ---------------------------------------------------------------------------

def calibrate_transformer(model, base_params, ft_params, batches: list, *,
                          epochs: int = 5, lr: float = 1e-4,
                          e2e_epochs: int = 5, e2e_lr: float = 1e-4,
                          sequential: bool = True, scalar: bool = False,
                          progress: Optional[Callable] = None):
    """Faithful Alg. 1: caches → per-layer fits → axis select → e2e.

    ``sequential=True`` rebuilds the student cache after each block is
    installed (X from the already-compressed stack below, paper §2);
    ``False`` is the fast variant using base-stack inputs for all layers.
    Returns (DeltaModel, report dict).
    """
    from repro.models import transformer as T
    cfg = model.cfg
    dm = compress(base_params, ft_params, scalar=scalar)

    big = jnp.concatenate([b["tokens"] for b in batches], axis=0)
    cal_batch = {"tokens": big}

    teacher_fwd = jax.jit(lambda p, b: T.forward(p, b, cfg, collect_io=True))
    _, t_aux = teacher_fwd(ft_params, cal_batch)
    t_io = t_aux["io"]

    if scalar:
        # BitDelta baseline: single scalar per matrix, 1 epoch (paper §3.1)
        epochs = 1

    layer_keys = [k for k in dm.deltas if k.startswith("layers.")]
    n_layers = dm.deltas[layer_keys[0]].packed.shape[0] if layer_keys else 0
    base_flat = flatten_params(base_params)
    report = {"val_mse": {}, "axis": {}}

    student_fwd = jax.jit(lambda p, b: T.forward(p, b, cfg, collect_io=True))

    s_io = None
    for li in range(n_layers):
        if sequential or s_io is None:
            student = apply_delta(base_params, dm)
            _, s_aux = student_fwd(student, cal_batch)
            s_io = s_aux["io"]
        new_deltas = dict(dm.deltas)
        for key in layer_keys:
            proj = ".".join(key.split(".")[1:])    # e.g. "attn.wq"
            x_all, _ = s_io[proj]
            _, y_all = t_io[proj]
            x = x_all[li].reshape(-1, x_all.shape[-1])
            y = y_all[li].reshape(-1, y_all.shape[-1])
            entry = dm.deltas[key]
            wb = base_flat[key][li]
            if scalar:
                v, mse = _fit_scale_jit(entry.packed[li], wb, x, y,
                                        entry.v_row[li], "scalar",
                                        epochs=epochs, lr=lr)
                new_deltas[key] = dataclasses.replace(
                    entry, v_row=entry.v_row.at[li].set(v),
                    v_col=entry.v_col.at[li].set(v))
                report["val_mse"].setdefault(proj, []).append(float(mse))
            else:
                v_r, v_c, use_row, mses = fit_layer(entry, wb, x, y, li,
                                                    epochs=epochs, lr=lr)
                new_deltas[key] = dataclasses.replace(
                    entry,
                    v_row=entry.v_row.at[li].set(v_r),
                    v_col=entry.v_col.at[li].set(v_c),
                    use_row=entry.use_row.at[li].set(use_row))
                report["val_mse"].setdefault(proj, []).append(mses)
                report["axis"].setdefault(proj, []).append(
                    "row" if bool(use_row) else "col")
        dm = DeltaModel(deltas=new_deltas, extras=dm.extras)
        if progress:
            progress(li, n_layers)

    # non-stacked targets (pre_layers etc.): weight-space init only is kept;
    # the e2e stage below trains their vectors too.

    # Stage 3: end-to-end
    fwd = jax.jit(lambda p, b: T.forward(p, b, cfg)[0])
    teacher_logits = [fwd(ft_params, b) for b in batches]
    dm, e2e_losses = e2e_calibrate(lambda p, b: fwd(p, b), base_params, dm,
                                   teacher_logits, batches,
                                   epochs=e2e_epochs, lr=e2e_lr)
    report["e2e_losses"] = e2e_losses
    return dm, report


# ---------------------------------------------------------------------------
# encoder-decoder (whisper) family
# ---------------------------------------------------------------------------

def calibrate_encdec(model, base_params, ft_params, batches: list, *,
                     epochs: int = 5, lr: float = 1e-4,
                     e2e_epochs: int = 5, e2e_lr: float = 1e-4,
                     scalar: bool = False):
    """Alg. 1 for the whisper family: encoder stack first, then decoder,
    each block-sequential with teacher/student IO caches.  Proves the
    pipeline ports across architecture families (DESIGN.md §4)."""
    from repro.models import whisper as W
    cfg = model.cfg
    dm = compress(base_params, ft_params, scalar=scalar)
    if scalar:
        epochs = 1

    cal_batch = {
        "tokens": jnp.concatenate([b["tokens"] for b in batches], axis=0),
        "frames": jnp.concatenate([b["frames"] for b in batches], axis=0),
    }
    fwd_io = jax.jit(lambda p, b: W.forward(p, b, cfg, collect_io=True)[1])
    t_aux = fwd_io(ft_params, cal_batch)
    base_flat = flatten_params(base_params)
    report = {"val_mse": {}, "axis": {}}

    for group, io_key in (("enc_layers", "enc_io"), ("dec_layers", "dec_io")):
        keys = [k for k in dm.deltas if k.startswith(group + ".")]
        if not keys:
            continue
        n_layers = dm.deltas[keys[0]].packed.shape[0]
        for li in range(n_layers):
            student = apply_delta(base_params, dm)
            s_aux = fwd_io(student, cal_batch)
            new_deltas = dict(dm.deltas)
            for key in keys:
                proj = key[len(group) + 1:]
                x_all = s_aux[io_key][proj][0]
                y_all = t_aux[io_key][proj][1]
                x = x_all[li].reshape(-1, x_all.shape[-1])
                y = y_all[li].reshape(-1, y_all.shape[-1])
                entry = dm.deltas[key]
                wb = base_flat[key][li]
                if scalar:
                    v, mse = _fit_scale_jit(entry.packed[li], wb, x, y,
                                            entry.v_row[li], "scalar",
                                            epochs=epochs, lr=lr)
                    new_deltas[key] = dataclasses.replace(
                        entry, v_row=entry.v_row.at[li].set(v),
                        v_col=entry.v_col.at[li].set(v))
                else:
                    v_r, v_c, use_row, mses = fit_layer(
                        entry, wb, x, y, li, epochs=epochs, lr=lr)
                    new_deltas[key] = dataclasses.replace(
                        entry,
                        v_row=entry.v_row.at[li].set(v_r),
                        v_col=entry.v_col.at[li].set(v_c),
                        use_row=entry.use_row.at[li].set(use_row))
                    report["axis"].setdefault(f"{group}.{proj}", []).append(
                        "row" if bool(use_row) else "col")
            dm = DeltaModel(deltas=new_deltas, extras=dm.extras)

    fwd = jax.jit(lambda p, b: W.forward(p, b, cfg)[0])
    teacher_logits = [fwd(ft_params, b) for b in batches]
    dm, e2e_losses = e2e_calibrate(lambda p, b: fwd(p, b), base_params, dm,
                                   teacher_logits, batches,
                                   epochs=e2e_epochs, lr=e2e_lr)
    report["e2e_losses"] = e2e_losses
    return dm, report
