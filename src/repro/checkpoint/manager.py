"""Fault-tolerant checkpointing: atomic, hashed, retained, resumable.

Layout: <dir>/step_<N>/ {manifest.json, arrays.npz} — written to a tmp
directory and renamed (atomic on POSIX), so a crash mid-save can never
leave a half-written checkpoint that restore would pick up.  Restore scans
newest→oldest and skips candidates that fail integrity checks (torn files
from a dead writer, bit rot) — the training loop then resumes from the
newest *valid* step.  At scale, per-host shards of the sharded state would
write in parallel (process index in the filename); on this single-host
container the full state is gathered — interface is the same.
"""
from __future__ import annotations

import hashlib
import json
import pathlib
import shutil
import time
from typing import Any, Optional

import jax
import numpy as np


def _flat(tree) -> dict:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "__".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out[key] = leaf
    return out


def _sha(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()[:16]


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # -- save ----------------------------------------------------------------
    def save(self, step: int, state: Any, extra_meta: Optional[dict] = None
             ) -> pathlib.Path:
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f".tmp_step_{step:08d}_{time.time_ns()}"
        tmp.mkdir(parents=True)
        flat = {k: np.asarray(jax.device_get(v))
                for k, v in _flat(state).items()}
        manifest = {"step": int(step), "time": time.time(),
                    "meta": extra_meta or {},
                    "tensors": {k: {"shape": list(v.shape),
                                    "dtype": str(v.dtype),
                                    "sha": _sha(v)}
                                for k, v in flat.items()}}
        np.savez(tmp / "arrays.npz", **flat)
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)                      # atomic publish
        self._retain()
        return final

    def _retain(self) -> None:
        ckpts = self.list_steps()
        for step in ckpts[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{step:08d}", ignore_errors=True)
        for p in self.dir.glob(".tmp_step_*"):   # dead writers
            shutil.rmtree(p, ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def list_steps(self) -> list:
        steps = []
        for p in self.dir.glob("step_*"):
            try:
                steps.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(steps)

    def restore_latest(self, template: Any) -> tuple[Optional[int], Any]:
        """Newest VALID checkpoint restored into template's structure;
        (None, template) if none usable."""
        for step in reversed(self.list_steps()):
            try:
                return step, self.restore(step, template)
            except Exception:
                continue  # torn/corrupt: fall back to the previous one
        return None, template

    def restore(self, step: int, template: Any) -> Any:
        path = self.dir / f"step_{step:08d}"
        manifest = json.loads((path / "manifest.json").read_text())
        data = np.load(path / "arrays.npz")
        flat_t = _flat(template)
        out = {}
        for key, leaf in flat_t.items():
            arr = data[key]
            info = manifest["tensors"][key]
            if _sha(arr) != info["sha"]:
                raise IOError(f"integrity failure in {path.name}:{key}")
            out[key] = jax.numpy.asarray(arr).astype(leaf.dtype)
        leaves_with_path = jax.tree_util.tree_flatten_with_path(template)
        keys = ["__".join(str(getattr(p, "key", getattr(p, "idx", p)))
                          for p in path_)
                for path_, _ in leaves_with_path[0]]
        return jax.tree_util.tree_unflatten(
            leaves_with_path[1], [out[k] for k in keys])
